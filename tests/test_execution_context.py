"""The ExecutionContext resolution order and the deprecation shim.

Contract under test (repro.kernels.context): for each execution knob —
backend, block_b, segment, mesh_shape — an explicit ``context=`` argument
beats the ambient ``use_execution`` block, which beats the config/default
layer (``ButterflyConfig`` via ``from_butterfly_config``), which beats the
``REPRO_*`` env vars, which beat the autotuner/platform default. Plus: the
once-per-process env read behind ``resolve_backend`` (and its documented
``clear_backend_cache``), context composition, and — now that the
one-release deprecation shim is removed — that the old loose kwargs are
rejected outright.
"""

import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ButterflyConfig
from repro.core import butterfly as bf
from repro.core import layers as bl
from repro.kernels import context as exctx
from repro.kernels import ops as kops
from repro.kernels import tuning
from repro.kernels.context import ExecutionContext, use_execution


@pytest.fixture(autouse=True)
def _fresh_backend_cache():
    """Every test sees (and leaves behind) an unread env-backend cache."""
    exctx.clear_backend_cache()
    yield
    exctx.clear_backend_cache()


def _cfg(**kw) -> ButterflyConfig:
    return ButterflyConfig(**kw)


# ---------------------------------------------------------------------------
# Precedence: explicit > ambient > config > env (> autotune), per field
# ---------------------------------------------------------------------------

class TestPrecedence:
    # (field, explicit value, ambient value, config kwargs, env var+value,
    #  getter on the resolved context)
    CASES = [
        ("backend",
         ExecutionContext(backend="pallas_interpret"),
         ExecutionContext(backend="pallas"),
         dict(backend="jnp"),
         ("REPRO_KERNEL_BACKEND", "pallas"),
         lambda ctx: ctx.backend,
         ["pallas_interpret", "pallas", "jnp", "pallas"]),
        ("block_b",
         ExecutionContext(block_b=64),
         ExecutionContext(block_b=32),
         dict(block_b=16),
         ("REPRO_TUNE_BLOCK_B", "8"),
         lambda ctx: ctx.block_b,
         [64, 32, 16, None]),
        ("segment",
         ExecutionContext(segment=4),
         ExecutionContext(segment=3),
         dict(segment=2),
         ("REPRO_TUNE_SEGMENT", "1"),
         lambda ctx: ctx.segment,
         [4, 3, 2, None]),
        ("mesh_shape",
         ExecutionContext(mesh_shape=(8,)),
         ExecutionContext(mesh_shape=(2, 4)),
         dict(mesh_shape=(4, 2)),
         (None, None),
         lambda ctx: ctx.mesh_shape,
         [(8,), (2, 4), (4, 2), None]),
    ]

    @pytest.mark.parametrize("field,explicit,ambient,cfg_kw,env,get,want",
                             CASES, ids=[c[0] for c in CASES])
    def test_each_layer_beats_the_next(self, monkeypatch, field, explicit,
                                       ambient, cfg_kw, env, get, want):
        env_var, env_val = env
        if env_var is not None:
            monkeypatch.setenv(env_var, env_val)
            exctx.clear_backend_cache()
        default = ExecutionContext.from_butterfly_config(_cfg(**cfg_kw))

        # explicit beats ambient beats config
        with use_execution(ambient):
            got = exctx.resolve_execution(explicit, default=default)
            assert get(got) == want[0]
            got = exctx.resolve_execution(None, default=default)
            assert get(got) == want[1]
        # config beats env
        got = exctx.resolve_execution(None, default=default)
        assert get(got) == want[2]
        # nothing set: env (backend) or unset-means-downstream (tiles/mesh)
        got = exctx.resolve_execution(None, default=None)
        if field == "backend":
            assert get(got) == want[3]
        else:
            assert get(got) == want[3] or get(got) is None

    def test_block_b_env_reaches_tuning_when_unset(self, monkeypatch):
        """Resolution leaves block_b None; REPRO_TUNE_BLOCK_B then wins at
        kernel-call time, and a context value passed as override beats it."""
        monkeypatch.setenv("REPRO_TUNE_BLOCK_B", "8")
        assert tuning.resolve_block_b("butterfly", 256, jnp.float32,
                                      "fwd", override=None) == 8
        ctx = exctx.resolve_execution(ExecutionContext(block_b=64))
        assert tuning.resolve_block_b("butterfly", 256, jnp.float32,
                                      "fwd", override=ctx.block_b) == 64

    def test_vmem_budget_ambient_override(self):
        """The tuning-override fields are read ambiently by the autotuner."""
        base = tuning.vmem_budget()
        with use_execution(ExecutionContext(vmem_budget=123456)):
            assert tuning.vmem_budget() == 123456
        assert tuning.vmem_budget() == base

    def test_flash_block_q_ambient_override(self):
        with use_execution(ExecutionContext(flash_block_q=16)):
            assert tuning.flash_blocks(1024, 64, "float32") == (16, 16)
        assert tuning.flash_blocks(1024, 64, "float32") != (16, 16)


# ---------------------------------------------------------------------------
# Composition / finalization
# ---------------------------------------------------------------------------

def test_nested_ambient_blocks_merge_fieldwise():
    with use_execution(ExecutionContext(backend="jnp", block_b=16)):
        with use_execution(ExecutionContext(block_b=32)):
            ctx = exctx.current_execution()
            assert ctx.backend == "jnp"        # falls through to outer
            assert ctx.block_b == 32           # inner wins
        ctx = exctx.current_execution()
        assert ctx.block_b == 16
    assert exctx.current_execution() is None


def test_explicit_mesh_shape_beats_mismatched_sharding_ctx():
    """An active sharding context's mesh is only reused when it IS the
    requested shape; an explicitly different mesh_shape must win."""
    from repro.launch.mesh import simulated_mesh
    from repro.runtime import sharding as rsh

    with rsh.use_sharding(simulated_mesh(8)):
        # matching shape: the ambient mesh is reused
        same = exctx.resolve_execution(ExecutionContext(mesh_shape=(8,)))
        assert tuple(same.mesh.shape.values()) == (8,)
        # mismatching shape: the requested layout is built, not hijacked
        diff = exctx.resolve_execution(ExecutionContext(mesh_shape=(2, 4)))
        assert tuple(diff.mesh.shape.items()) == (("pod", 2), ("data", 4))


def test_resolution_is_idempotent_and_hashable():
    ctx = exctx.resolve_execution(ExecutionContext(backend="jnp",
                                                   mesh_shape=(2, 4)))
    assert ctx.mesh is not None
    assert ctx.mesh_layout() == "pod=2,data=4"
    again = exctx.resolve_execution(ctx)
    assert again == ctx and hash(again) == hash(ctx)
    # local() strips the mesh so shard regions can't re-route
    assert ctx.local().mesh is None and ctx.local().mesh_shape is None


def test_coerce_accepts_backend_strings():
    assert exctx.ExecutionContext.coerce("jnp") == ExecutionContext(
        backend="jnp")
    assert exctx.ExecutionContext.coerce(None) is None
    with pytest.raises(TypeError):
        exctx.ExecutionContext.coerce(123)
    with pytest.raises(ValueError):
        ExecutionContext(backend="nope")


def test_from_butterfly_config_lifts_execution_fields():
    bc = _cfg(backend="pallas_interpret", block_b=8, segment=2,
              mesh_shape=(8,))
    ctx = ExecutionContext.from_butterfly_config(bc)
    assert (ctx.backend, ctx.block_b, ctx.segment, ctx.mesh_shape) == \
        ("pallas_interpret", 8, 2, (8,))
    assert ExecutionContext.from_butterfly_config(None) == ExecutionContext()


# ---------------------------------------------------------------------------
# resolve_backend: cached env read + clear_backend_cache
# ---------------------------------------------------------------------------

def test_backend_env_read_is_cached_per_process(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas_interpret")
    exctx.clear_backend_cache()
    assert exctx.resolve_backend("auto") == "pallas_interpret"
    # flipping the env mid-process does NOT take effect: the read is cached
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "jnp")
    assert exctx.resolve_backend("auto") == "pallas_interpret"
    # ... until the documented test hook clears it
    exctx.clear_backend_cache()
    assert exctx.resolve_backend("auto") == "jnp"


def test_concrete_backend_skips_env(monkeypatch):
    monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas_interpret")
    exctx.clear_backend_cache()
    assert exctx.resolve_backend("jnp") == "jnp"
    with pytest.raises(ValueError):
        exctx.resolve_backend("not_a_backend")


# ---------------------------------------------------------------------------
# Post-shim surface: the loose kwargs are gone for good
# ---------------------------------------------------------------------------

def test_legacy_kwargs_are_rejected():
    """The one-release deprecation shim is removed: the old loose execution
    kwargs (and any other unknown kwarg) fail with a plain TypeError
    instead of warning-and-working."""
    n = 16
    w = bf.fjlt_weights(jax.random.PRNGKey(12), n)
    x = jax.random.normal(jax.random.PRNGKey(13), (2, n))
    with pytest.raises(TypeError, match="unexpected keyword"):
        kops.butterfly_apply(x, w, backend="jnp")
    with pytest.raises(TypeError, match="unexpected keyword"):
        kops.butterfly_apply(x, w, not_a_kwarg=1)
    spec = bl.make_spec(jax.random.PRNGKey(2), 24, 40)
    params = bl.init_butterfly_linear(jax.random.PRNGKey(3), spec)
    xs = jax.random.normal(jax.random.PRNGKey(4), (3, 24))
    with pytest.raises(TypeError, match="unexpected keyword"):
        bl.butterfly_linear_apply(spec, params, xs, block_b=4, segment=1)
    assert not hasattr(exctx, "apply_legacy")


def test_context_api_emits_no_deprecation_warnings():
    """First-party surface never warns: pure-context calls are the only
    surface (the CI examples step enforces the same with -W error)."""
    n = 16
    w = bf.fjlt_weights(jax.random.PRNGKey(14), n)
    x = jax.random.normal(jax.random.PRNGKey(15), (2, n))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        kops.butterfly_apply(x, w, context="jnp")
        with use_execution(ExecutionContext(backend="jnp")):
            kops.butterfly_apply(x, w)
