"""Engine parameter loading: checkpoint restore or fresh init.

Two entry points:

* :func:`restore_params` — newest-valid checkpoint from a
  :mod:`repro.checkpoint` directory (the Trainer's save layout: a
  ``{"params": ..., "opt": ...}`` tree; only the ``params`` subtree is
  read). Torn or corrupt checkpoints fall back to the next older valid one
  — the engine inherits the checkpoint subsystem's crash-safety contract
  for free.
* :func:`load_for_serving` — the CLI/engine convenience: restore when a
  directory is given and holds a valid checkpoint, else fresh-init (smoke
  runs, benchmarks).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointing import load_latest
from repro.configs.base import ModelConfig
from repro.models import lm
from repro.runtime import pytree as pt


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict:
    """Fresh engine params (the smoke-run path)."""
    return pt.init_params(jax.random.PRNGKey(seed), lm.model_specs(cfg))


def restore_params(cfg: ModelConfig, directory: str, *,
                   step: Optional[int] = None
                   ) -> Tuple[Optional[int], Optional[Dict]]:
    """Load model params from the newest valid checkpoint in ``directory``.

    Returns ``(step, params)`` — or ``(None, None)`` when the directory
    holds no restorable checkpoint (every candidate torn/corrupt/absent).
    The restore template is built from the arch's ParamSpecs, so shapes and
    tree structure are validated implicitly: a checkpoint from a different
    arch fails its candidate and falls through to older ones.
    """
    template = init_params(cfg, seed=0)
    s, tree, _extra = load_latest(directory, {"params": template}, step=step)
    if s is None:
        return None, None
    params = jax.tree_util.tree_map(
        lambda t, a: jnp.asarray(a, t.dtype) if a is not None else None,
        template, tree["params"], is_leaf=lambda x: x is None)
    return s, params


def load_for_serving(cfg: ModelConfig, checkpoint_dir: str = "", *,
                     seed: int = 0) -> Tuple[Optional[int], Dict]:
    """Params for a :class:`~repro.serve.engine.ServeEngine`: newest valid
    checkpoint when ``checkpoint_dir`` is set and restorable, else fresh
    init. Returns ``(restored_step_or_None, params)``."""
    if checkpoint_dir:
        step, params = restore_params(cfg, checkpoint_dir)
        if params is not None:
            return step, params
    return None, init_params(cfg, seed=seed)
