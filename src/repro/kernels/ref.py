"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth used by tests (``assert_allclose`` sweeps) and the
CPU fallback used by :mod:`repro.kernels.ops` when no TPU is present.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import butterfly as bf


def butterfly_ref(w: jnp.ndarray, x: jnp.ndarray,
                  transpose: bool = False) -> jnp.ndarray:
    """Oracle for the fused multi-stage butterfly kernel.

    ``x``: (..., n); ``w``: (p, 2, n).
    """
    if transpose:
        return bf.butterfly_transpose_apply(w, x)
    return bf.butterfly_apply(w, x)


def sandwich_ref(x: jnp.ndarray, b_in: jnp.ndarray, core: jnp.ndarray,
                 b_out: jnp.ndarray, sel_in: jnp.ndarray,
                 sel_out: jnp.ndarray, scale_in: float,
                 scale_out: float) -> jnp.ndarray:
    """Oracle for the fused sandwich kernel.

    ``sel_in``: (n1, k1) one-hot selection, ``sel_out``: (k2, n2) one-hot
    scatter; scales are the JL normalizations sqrt(n/k).
    """
    h = bf.butterfly_apply(b_in.astype(x.dtype), x)
    h = (h @ sel_in.astype(x.dtype)) * jnp.asarray(scale_in, x.dtype)
    h = jnp.einsum("...i,oi->...o", h, core.astype(x.dtype))
    z = (h @ sel_out.astype(x.dtype)) * jnp.asarray(scale_out, x.dtype)
    return bf.butterfly_transpose_apply(b_out.astype(x.dtype), z)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window: int = 0,
                        scale: float | None = None) -> jnp.ndarray:
    """Oracle for the flash-attention kernel.

    q: (B, H, S, D), k/v: (B, H, S, D) (kv heads already repeated).
    ``window`` > 0 limits attention to the last ``window`` positions.
    """
    B, H, S, D = q.shape
    if scale is None:
        scale = D ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)
