"""Benchmark runner: one section per paper table/figure.

Emits ``name,us_per_call,derived`` CSV lines (assignment format). Roofline
numbers come from the dry-run artifacts (``python -m repro.launch.dryrun``)
— summarized here if present.
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import (bench_autoencoder, bench_backward,  # noqa: E402
                        bench_kernels, bench_lm_butterfly, bench_nonlinear,
                        bench_param_counts, bench_serving, bench_sketch,
                        bench_speed, bench_theorem1, bench_two_phase,
                        common)


def summarize_dryrun(out_dir: str = "experiments/dryrun") -> None:
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("status") == "skipped":
            print(f"roofline/{r['arch']}__{r['shape']}__{r['mesh']},0.00,"
                  f"status=skipped;reason={r['reason']}")
            continue
        print(f"roofline/{r['arch']}__{r['shape']}__{r['mesh']},0.00,"
              f"t_compute={r['t_compute']:.4f};t_memory={r['t_memory']:.4f};"
              f"t_collective={r['t_collective']:.4f};"
              f"dominant={r['dominant']};util={r['flops_utilization']:.3f};"
              f"fit={r['hbm_fit']}")


def write_json(mode: str) -> str:
    """Dump every emitted row as BENCH_<mode>.json (the CI perf artifact)."""
    import jax

    path = f"BENCH_{mode}.json"
    payload = {
        "mode": mode,
        "jax_backend": jax.default_backend(),
        "rows": common.ROWS,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def main() -> None:
    import argparse

    parser = argparse.ArgumentParser(
        description="paper benchmark runner (CSV on stdout + BENCH_*.json)")
    # --quick (the CI gate) is an alias of the original --fast; argparse
    # rejects typos instead of silently running the full multi-hour sweep
    parser.add_argument("--quick", "--fast", dest="quick",
                        action="store_true",
                        help="reduced steps/sizes (the per-PR CI gate)")
    fast = parser.parse_args().quick
    print("name,us_per_call,derived")
    bench_param_counts.run()
    bench_theorem1.run()
    bench_kernels.run()
    bench_speed.run()
    bench_backward.run(ns=bench_backward.NS, batch=16 if fast else 64,
                       iters=5 if fast else None)
    bench_nonlinear.run(steps=120 if fast else 300)
    if fast:
        bench_autoencoder.run(train_steps=60)
        bench_two_phase.run(steps1=60, steps2=40)
        bench_sketch.run(steps=30)
        bench_lm_butterfly.run(steps=15)
        bench_serving.run(requests=24, max_new=8)
    else:
        bench_autoencoder.run()
        bench_two_phase.run()
        bench_sketch.run()
        bench_sketch.run_ell_sweep()
        bench_lm_butterfly.run()
        bench_serving.run()
    summarize_dryrun()
    path = write_json("quick" if fast else "full")
    print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
