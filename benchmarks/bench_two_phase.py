"""Paper Figure 6 (§5.3): two-phase learning — phase 1 (B frozen at FJLT
init, Theorem 1 guarantees local=global) then phase 2 (all trained)."""

from __future__ import annotations

import jax

from benchmarks.common import emit, gaussian_lowrank, synthetic_image_matrix
from repro.core import encdec


def run(steps1: int = 400, steps2: int = 300) -> None:
    X = synthetic_image_matrix(256, 256, seed=3)
    for k in (4, 8, 16):
        spec = encdec.make_spec(jax.random.PRNGKey(k), n=256, d=256, k=k)
        params = encdec.init_params(jax.random.PRNGKey(k + 1), spec)
        pred = float(encdec.theorem1_loss(spec, params["B"], X, X))
        pca = float(encdec.pca_loss(X, X, k))
        p1, _ = encdec.train(spec, params, X, X, steps=steps1, lr=3e-3,
                             train_B=False)
        phase1 = float(encdec.loss_fn(spec, p1, X, X))
        p2, _ = encdec.train(spec, p1, X, X, steps=steps2, lr=1e-3,
                             train_B=True)
        phase2 = float(encdec.loss_fn(spec, p2, X, X))
        emit(f"two_phase/k{k}", 0.0,
             f"thm1_prediction={pred:.4f};phase1={phase1:.4f};"
             f"phase2={phase2:.4f};pca={pca:.4f}")


if __name__ == "__main__":
    run()
