"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
(the kernel body executes in Python on CPU — exactly what the assignment
prescribes for validating TPU-target kernels without hardware)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import butterfly as bf
from repro.core import layers as bl
from repro.kernels import ops, ref
from repro.kernels.sandwich import one_hot_select


@pytest.mark.parametrize("n", [8, 64, 512])
@pytest.mark.parametrize("batch", [1, 3, 300])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_butterfly_kernel_forward(n, batch, dtype):
    w = bf.fjlt_weights(jax.random.PRNGKey(0), n)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, n)).astype(dtype)
    got = ops.butterfly_apply(x, w, context="pallas_interpret")
    want = ref.butterfly_ref(w.astype(dtype), x)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("n", [16, 128])
@pytest.mark.parametrize("transpose", [False, True])
def test_butterfly_kernel_transpose_and_grid(n, transpose):
    """Batch larger than one grid block exercises the BlockSpec tiling."""
    w = bf.random_weights(jax.random.PRNGKey(2), n)
    x = jax.random.normal(jax.random.PRNGKey(3), (700, n))
    got = ops.butterfly_apply(x, w, transpose=transpose,
                              context="pallas_interpret")
    want = ref.butterfly_ref(w, x, transpose=transpose)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_butterfly_kernel_nd_batch():
    n = 64
    w = bf.random_weights(jax.random.PRNGKey(4), n)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 3, 5, n))
    got = ops.butterfly_apply(x, w, context="pallas_interpret")
    want = ref.butterfly_ref(w, x)
    assert got.shape == x.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n1,n2,k1,k2", [(64, 64, 8, 8), (128, 256, 16, 12),
                                         (32, 128, 32, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sandwich_kernel_vs_layer(n1, n2, k1, k2, dtype):
    """Fused sandwich kernel == ButterflyLinear layer (the jnp production
    path) across shapes and dtypes."""
    spec = bl.make_spec(jax.random.PRNGKey(6), n1, n2, k_in=k1, k_out=k2,
                        use_bias=False)
    params = bl.init_butterfly_linear(jax.random.PRNGKey(7), spec)
    x = jax.random.normal(jax.random.PRNGKey(8), (9, n1)).astype(dtype)
    want = bl.butterfly_linear_apply(spec, params, x)
    sel_in = one_hot_select(spec.idx_in, n1)
    sel_out = one_hot_select(spec.idx_out, n2).T
    got = ops.sandwich_apply(
        x, params["b_in"], sel_in, params["core"], sel_out, params["b_out"],
        scale_in=math.sqrt(n1 / k1), scale_out=math.sqrt(n2 / k2),
        context="pallas_interpret")
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_kernel_jnp_backend_matches_interpret():
    n = 128
    w = bf.fjlt_weights(jax.random.PRNGKey(9), n)
    x = jax.random.normal(jax.random.PRNGKey(10), (17, n))
    a = ops.butterfly_apply(x, w, context="jnp")
    b = ops.butterfly_apply(x, w, context="pallas_interpret")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_ref_matches_naive():
    """The flash oracle itself against a trivially-correct softmax."""
    B, H, S, D = 2, 3, 32, 16
    q = jax.random.normal(jax.random.PRNGKey(11), (B, H, S, D))
    k = jax.random.normal(jax.random.PRNGKey(12), (B, H, S, D))
    v = jax.random.normal(jax.random.PRNGKey(13), (B, H, S, D))
    out = ref.flash_attention_ref(q, k, v, causal=True)
    # naive
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * D ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    logits = jnp.where(mask, logits, -1e30)
    want = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(logits, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Flash-attention Pallas kernel (beyond-paper)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24),
                                           (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_vs_oracle(causal, window, dtype):
    from repro.kernels.flash import flash_attention
    B, H, S, D = 2, 3, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(20), 3)
    q = jax.random.normal(ks[0], (B, H, S, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, H, S, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, H, S, D)).astype(dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=16, block_kv=16, interpret=True)
    want = ref.flash_attention_ref(q.astype(jnp.float32),
                                   k.astype(jnp.float32),
                                   v.astype(jnp.float32),
                                   causal=causal, window=window)
    tol = 1e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_kernel_block_shapes():
    from repro.kernels.flash import flash_attention
    B, H, S, D = 1, 2, 128, 8
    ks = jax.random.split(jax.random.PRNGKey(21), 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    for bq, bkv in [(32, 64), (64, 32), (128, 128)]:
        got = flash_attention(q, k, v, causal=True, block_q=bq,
                              block_kv=bkv, interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_nonlinear_butterfly_gates():
    """§7 future-work path: gated butterfly differs from linear, reduces to
    it when the activation is identity, and is differentiable."""
    from repro.core.butterfly import (butterfly_apply,
                                      butterfly_apply_nonlinear)
    n = 32
    w = bf.random_weights(jax.random.PRNGKey(22), n)
    x = jax.random.normal(jax.random.PRNGKey(23), (4, n))
    lin = butterfly_apply(w, x)
    gated = butterfly_apply_nonlinear(w, x)
    ident = butterfly_apply_nonlinear(w, x, act=lambda z: z)
    assert float(jnp.abs(gated - lin).max()) > 1e-3
    np.testing.assert_allclose(np.asarray(ident), np.asarray(lin),
                               rtol=1e-5, atol=1e-5)
    g = jax.grad(lambda w: jnp.sum(butterfly_apply_nonlinear(w, x) ** 2))(w)
    assert bool(jnp.isfinite(g).all())
