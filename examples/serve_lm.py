"""Continuous-batching serving demo on :class:`repro.serve.ServeEngine`.

Mixed-length prompts arrive over time through the async client; the engine
admits them into its decode-slot pool as slots free up — by default into a
paged KV cache pool with chunked prefill (one compile for every prompt
length), falling back to whole-bucket admission for archs the chunk path
can't serve — and advances every in-flight request one token per fused
pooled decode tick. Per-request TTFT/TPOT and the engine's
throughput/occupancy/pages snapshot are printed at the end.

A second act demos the lifecycle paths on a deliberately tiny page pool:
a request *preempted* mid-decode under ``admission="incremental"`` (pages
freed, request requeued, prefix recomputed — same greedy tokens out) and a
request *cancelled* via ``client.cancel(rid)`` (its future resolves with
``RequestCancelled``).

A third act runs the multi-replica tier: two engine replicas behind the
:class:`repro.serve.Router` (weighted least-outstanding dispatch, one
driver thread), with a live checkpoint hot-swap on a drained replica —
the newest checkpoint on disk is deliberately torn, so the loader falls
back to the newest *valid* one — while the other replica keeps serving.

Run: ``PYTHONPATH=src python examples/serve_lm.py --arch smollm-135m-smoke``
Try ``--arch recurrentgemma-2b-smoke`` (RG-LRU state: the engine switches
to exact-length prefill buckets, since padding would corrupt the recurrent
state) or ``--temperature 0.8 --top-p 0.9`` for nucleus sampling.
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m-smoke")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--gen-len", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-p", type=float, default=1.0)
    args = ap.parse_args()

    from repro.configs import registry
    from repro.serve import (Request, SamplingParams, ServeClient,
                             ServeEngine, loader)

    cfg = registry.get(args.arch)
    _, params = loader.load_for_serving(cfg, seed=0)
    engine = ServeEngine(
        cfg, params, slots=args.slots, max_len=args.max_len,
        sampling=SamplingParams(temperature=args.temperature,
                                top_p=args.top_p), seed=0)

    rng = np.random.default_rng(0)
    hi = min(48, args.max_len - args.gen_len)
    if hi < 4:
        raise SystemExit(
            f"--max-len {args.max_len} leaves no room for --gen-len "
            f"{args.gen_len}: need max_len - gen_len >= 4 (the per-slot "
            f"budget is prompt + generated tokens)")
    lengths = rng.integers(4, hi + 1, size=args.requests)
    print(f"arch={cfg.name}  slots={args.slots}  requests={args.requests}  "
          f"prompt lengths={lengths.tolist()}")

    def extras():
        # frontend-stub archs (VLM / enc-dec audio) ride per-request
        # precomputed embeddings, exactly like the training pipeline
        out = {}
        if cfg.frontend == "vision":
            out["frontend_embeds"] = rng.normal(
                size=(1, cfg.frontend_tokens, cfg.d_model)).astype("float32")
        if cfg.n_enc_layers:
            out["frames"] = rng.normal(
                size=(1, cfg.enc_seq, cfg.d_model)).astype("float32")
        return out or None

    futs = []
    with ServeClient(engine) as client:
        for plen in lengths:
            prompt = rng.integers(0, cfg.vocab_size, size=int(plen))
            futs.append(client.submit(Request(
                prompt=prompt, max_new_tokens=args.gen_len,
                extras=extras())))
            time.sleep(0.01)          # requests trickle in, engine runs
        for fut in futs:
            r = fut.result(timeout=600)
            m = r.metrics
            print(f"  req[{r.rid}] prompt={m.prompt_len:2d} "
                  f"ttft={m.ttft * 1e3:6.1f} ms  "
                  f"tpot={m.tpot * 1e3:5.1f} ms/token  "
                  f"tokens={r.tokens[:8]}{'...' if len(r.tokens) > 8 else ''}")

    snap = engine.metrics.snapshot()
    stats = engine.compile_stats
    buckets = sorted(k[2] for k in stats["traces"] if k[0] == "prefill")
    print(f"decode: {snap['decode_tok_per_s']:.1f} tok/s  "
          f"occupancy: {snap['slot_occupancy']:.2f}  "
          f"ticks: {snap['ticks']}  pool: {snap['pool']['kind']} "
          f"(pages hwm {snap['pool']['pages_hwm']}/"
          f"{snap['pool']['total_pages']})  compiles: {stats['compiles']}"
          + (f" (prefill buckets: {buckets})" if buckets else
             " (chunked prefill: one compile for all prompt lengths)"))

    lifecycle_demo(cfg, params, rng)
    router_demo(cfg, params)


def lifecycle_demo(cfg, params, rng):
    """Preemption + cancellation on a deliberately page-starved engine,
    recorded by a live :class:`repro.obs.Tracer` — every request's
    lifecycle (queue → admit → prefill chunks → decode → preempt →
    recompute → finish) lands as spans exportable with
    ``tracer.write_chrome_trace("trace.json")`` and viewable in
    Perfetto. The serving CLI wires the same thing via ``--trace-out``."""
    from repro.obs import Tracer
    from repro.serve import (Request, RequestCancelled, ServeClient,
                             ServeEngine)

    print("\n-- lifecycle demo: tiny pool, incremental admission --")
    tracer = Tracer()
    try:
        # 2 slots but only 4 usable 8-token pages: both requests' full
        # budgets cannot co-reside, so incremental admission must preempt
        engine = ServeEngine(cfg, params, slots=2, max_len=32,
                             page_size=8, num_pages=5, prefill_chunk=4,
                             admission="incremental", tracer=tracer,
                             seed=0)
    except ValueError as e:
        print(f"  skipped: {e}")
        return
    with ServeClient(engine) as client:
        mk = lambda: rng.integers(0, cfg.vocab_size, size=5)  # noqa: E731
        f1 = client.submit(Request(prompt=mk(), max_new_tokens=14))
        f2 = client.submit(Request(prompt=mk(), max_new_tokens=14))
        f3 = client.submit(Request(prompt=mk(), max_new_tokens=14,
                                   rid=99))
        client.cancel(99)
        for fut in (f1, f2):
            r = fut.result(timeout=600)
            tag = (f"preempted x{r.metrics.preemptions}, prefix recomputed"
                   if r.metrics.preemptions else "never preempted")
            print(f"  req[{r.rid}] finished with {len(r.tokens)} tokens "
                  f"({tag})")
        try:
            f3.result(timeout=600)
            print("  req[99] finished before the cancel landed")
        except RequestCancelled as e:
            print(f"  req[99] cancelled: {e}")
    snap = engine.metrics.snapshot()
    print(f"  engine counters: preempted={snap['preempted']} "
          f"recompute_tokens={snap['recompute_tokens']} "
          f"cancelled={snap['cancelled']}")
    counts = {}
    for ev in tracer.events():
        counts[ev["name"]] = counts.get(ev["name"], 0) + 1
    print(f"  tracer recorded {len(tracer)} events: "
          f"preempt={counts.get('preempt', 0)} "
          f"cancel={counts.get('cancel', 0)} "
          f"finish={counts.get('finish', 0)} "
          f"ticks={counts.get('tick', 0)} "
          f"(tracer.write_chrome_trace(path) -> Perfetto)")


def router_demo(cfg, params):
    """Two replicas behind the Router: balanced dispatch, then a live
    checkpoint hot-swap — drain replica 0, restore the newest *valid*
    checkpoint (the newest on disk is deliberately torn), swap params,
    undrain — while replica 1 keeps serving. No request is dropped."""
    import tempfile

    from repro.checkpoint.checkpointing import CheckpointManager
    from repro.serve import Request, Router, ServeEngine
    from repro.serve import trace as trace_lib
    from repro.serve.faults import tear_checkpoint

    print("\n-- router demo: 2 replicas, drain + checkpoint hot-swap --")
    try:
        engines = [ServeEngine(cfg, params, slots=2, max_len=32,
                               page_size=8, prefill_chunk=4, seed=0)
                   for _ in range(2)]
    except ValueError as e:
        print(f"  skipped: {e}")
        return
    items = trace_lib.generate(
        trace_lib.TraceSpec(requests=6, seed=7, min_prompt=4,
                            max_prompt=12, max_new_tokens=8),
        cfg.vocab_size)
    router = Router(engines)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        mgr = CheckpointManager(ckpt_dir)
        mgr.save(1, {"params": params})
        mgr.save(2, {"params": params})
        tear_checkpoint(ckpt_dir)      # newest step is now damaged
        with router:
            futs = [router.submit(it.request()) for it in items]
            step = router.swap_checkpoint(0, ckpt_dir)
            for fut in futs:
                fut.result(timeout=600)
    snap = router.snapshot()
    print(f"  swapped replica 0 to checkpoint step {step} (newest was "
          f"torn) while replica 1 served")
    print(f"  dispatched={[p['dispatched'] for p in snap['per_replica']]} "
          f"requeued={snap['requeued']} finished="
          f"{snap['requests_finished']} ttft p50="
          f"{snap['ttft_ms']['p50']:.1f} ms")


if __name__ == "__main__":
    main()
