"""Encoder–decoder butterfly network (paper §4) and Theorem 1 apparatus.

Network: ``Ȳ = D · E · B · X`` with
  * ``X ∈ R^{n×d}`` data, ``Y ∈ R^{m×d}`` targets (Y = X for auto-encoders),
  * ``B``: ℓ×n truncated butterfly (log n stages + fixed truncation),
  * ``E ∈ R^{k×ℓ}`` dense encoder core, ``D ∈ R^{m×k}`` dense decoder,
  * loss ``L(Ȳ) = ||Ȳ − Y||_F²``.

Theorem 1: at any critical point of (D, E) with B fixed (satisfying the
rank/eigenvalue assumptions), ``L = tr(YYᵀ) − Σ_{i∈I} λ_i(Σ(B))`` for some
``I ⊆ [ℓ]``, and local minima have ``I = [k]`` — i.e. with B frozen, local
minima are global. This module provides the forward/loss, closed-form optima,
the Theorem 1 predicted loss, baselines (PCA, FJLT+PCA), and one/two-phase
gradient training used by the paper's §5.2/§5.3 experiments.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import butterfly as bf
from repro.kernels import context as exctx
from repro.kernels import ops as kops
from repro.optim import optimizer as opt


@dataclass(frozen=True)
class EncDecSpec:
    n: int          # input dim (rows of X)
    m: int          # output dim (rows of Y)
    d: int          # number of data columns
    k: int          # bottleneck
    ell: int        # butterfly truncation (k <= ell <= m <= n)
    jl_scale: bool = True
    trunc_idx: Tuple[int, ...] = ()

    @property
    def pad_n(self) -> int:
        return bf.padded_dim(self.n)


def make_spec(key: jax.Array, n: int, d: int, k: int,
              ell: Optional[int] = None, m: Optional[int] = None,
              eps: float = 0.5) -> EncDecSpec:
    """ℓ defaults to the Proposition 4.1 prescription ``k log k + k/eps``."""
    m = n if m is None else m
    if ell is None:
        ell = min(n, max(k + 1, int(math.ceil(k * math.log2(max(k, 2))
                                              + k / eps))))
    idx = bf.truncation_indices(key, bf.padded_dim(n), ell)
    return EncDecSpec(n=n, m=m, d=d, k=k, ell=ell, trunc_idx=idx)


def init_params(key: jax.Array, spec: EncDecSpec) -> Dict[str, jnp.ndarray]:
    kb, ke, kd = jax.random.split(key, 3)
    scale_e = 1.0 / math.sqrt(spec.ell)
    scale_d = 1.0 / math.sqrt(spec.k)
    return {
        "B": bf.fjlt_weights(kb, spec.pad_n),
        "E": scale_e * jax.random.normal(ke, (spec.k, spec.ell)),
        "D": scale_d * jax.random.normal(kd, (spec.m, spec.k)),
    }


def apply_B(spec: EncDecSpec, w: jnp.ndarray, X: jnp.ndarray, *,
            context: exctx.ContextLike = None) -> jnp.ndarray:
    """``B X`` for column-data ``X (n×d)`` -> (ℓ×d).

    The butterfly product dispatches through :mod:`repro.kernels.ops`; the
    fused Pallas path is differentiable (custom_vjp), so training through
    ``apply_B`` keeps the single-HBM-round-trip kernel in both directions.
    Execution policy — backend, tile sizes, mesh — rides ``context``
    (:mod:`repro.kernels.context`); a context with a mesh shards the data
    columns (the batch dim of the transposed product) over its data axes via
    :mod:`repro.runtime.butterfly_sharding`.
    """
    Xp = X
    if spec.pad_n != spec.n:
        Xp = jnp.pad(X, ((0, spec.pad_n - spec.n), (0, 0)))
    H = kops.butterfly_apply(Xp.T, w, context=context)  # (d, pad_n)
    Ht = bf.truncate(H, spec.trunc_idx, spec.pad_n, spec.jl_scale)
    return Ht.T                                        # (ℓ, d)


def forward(spec: EncDecSpec, params: Dict, X: jnp.ndarray, *,
            context: exctx.ContextLike = None) -> jnp.ndarray:
    Xt = apply_B(spec, params["B"], X, context=context)
    return params["D"] @ (params["E"] @ Xt)


def loss_fn(spec: EncDecSpec, params: Dict, X: jnp.ndarray,
            Y: jnp.ndarray, *,
            context: exctx.ContextLike = None) -> jnp.ndarray:
    Yb = forward(spec, params, X, context=context)
    return jnp.sum(jnp.square(Yb - Y))


# ---------------------------------------------------------------------------
# Theory: Σ(B), Theorem 1 prediction, closed-form optimum for fixed B
# ---------------------------------------------------------------------------

def _pinv(G: jnp.ndarray) -> jnp.ndarray:
    """Moore-Penrose with a 1e-6 relative cutoff. jax >= 0.4.32 spells the
    cutoff ``rtol`` and deprecates ``rcond`` (a DeprecationWarning the CI
    examples step escalates to an error); older jax only knows ``rcond``."""
    try:
        return jnp.linalg.pinv(G, rtol=1e-6)
    except TypeError:
        return jnp.linalg.pinv(G, rcond=1e-6)


def sigma_B(spec: EncDecSpec, w: jnp.ndarray, X: jnp.ndarray,
            Y: jnp.ndarray) -> jnp.ndarray:
    """``Σ(B) = Y X̃ᵀ (X̃ X̃ᵀ)^{-1} X̃ Yᵀ`` with ``X̃ = B X`` (m×m, PSD)."""
    Xt = apply_B(spec, w, X)
    G = Xt @ Xt.T
    # pinv: when rank(X) < ℓ the Gram matrix is singular (Theorem 1's
    # assumption (a) fails); Moore-Penrose still yields the projection form
    # Σ(B) = Y Π_rowspace(X̃) Yᵀ, which is what the loss geometry uses.
    Ginv = _pinv(G)
    M = Y @ Xt.T
    return M @ Ginv @ M.T


def theorem1_loss(spec: EncDecSpec, w: jnp.ndarray, X: jnp.ndarray,
                  Y: jnp.ndarray, k: Optional[int] = None) -> jnp.ndarray:
    """Predicted loss at a local minimum with B fixed:
    ``tr(YYᵀ) − Σ_{i∈[k]} λ_i(Σ(B))``."""
    k = spec.k if k is None else k
    lam = jnp.linalg.eigvalsh(sigma_B(spec, w, X, Y))[::-1]
    return jnp.trace(Y @ Y.T) - jnp.sum(lam[:k])


def optimal_DE(spec: EncDecSpec, w: jnp.ndarray, X: jnp.ndarray,
               Y: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Closed-form global optimum of (D, E) for fixed B (Claim C.1 + I=[k]):
    ``D = U_k``, ``E = U_kᵀ Y X̃ᵀ (X̃X̃ᵀ)^{-1}``, U_k = top-k eigvecs of Σ(B)."""
    Xt = apply_B(spec, w, X)
    G = Xt @ Xt.T
    Ginv = _pinv(G)
    S = sigma_B(spec, w, X, Y)
    lam, U = jnp.linalg.eigh(S)
    Uk = U[:, ::-1][:, : spec.k]
    D = Uk
    E = Uk.T @ Y @ Xt.T @ Ginv
    return D, E


# ---------------------------------------------------------------------------
# Baselines (paper §5.2): PCA (= Δ_k) and FJLT+PCA (Proposition 4.1)
# ---------------------------------------------------------------------------

def pca_loss(X: jnp.ndarray, Y: jnp.ndarray, k: int) -> jnp.ndarray:
    """``Δ_k = ||Y_k − Y||_F²`` via exact SVD (auto-encoder: Y = X)."""
    s = jnp.linalg.svd(Y, compute_uv=False)
    return jnp.sum(jnp.square(s[k:]))


def sketch_rank_k(Xt: jnp.ndarray, X: jnp.ndarray, k: int) -> jnp.ndarray:
    """Best rank-k approximation of ``X`` from the rows of ``Xt`` (Sarlós):
    ``[X Π]_k`` with Π the projection onto rowspace(Xt)."""
    _, _, Vt = jnp.linalg.svd(Xt, full_matrices=False)   # (ℓ, d)
    XV = X @ Vt.T                                        # (n, ℓ)
    U2, S2, V2t = jnp.linalg.svd(XV, full_matrices=False)
    XVk = (U2[:, :k] * S2[:k]) @ V2t[:k]
    return XVk @ Vt


def fjlt_pca_loss(key: jax.Array, X: jnp.ndarray, k: int, ell: int
                  ) -> jnp.ndarray:
    """``||J_k(X) − X||_F²`` with J an ℓ×n FJLT (Proposition 4.1 baseline)."""
    n = X.shape[0]
    pad_n = bf.padded_dim(n)
    kw, ki = jax.random.split(key)
    w = bf.fjlt_weights(kw, pad_n)
    idx = bf.truncation_indices(ki, pad_n, ell)
    spec = EncDecSpec(n=n, m=n, d=X.shape[1], k=k, ell=ell, trunc_idx=idx)
    Xt = apply_B(spec, w, X)
    Xk = sketch_rank_k(Xt, X, k)
    return jnp.sum(jnp.square(X - Xk))


# ---------------------------------------------------------------------------
# Training (paper §5.2 one-phase, §5.3 two-phase)
# ---------------------------------------------------------------------------

def train(spec: EncDecSpec, params: Dict, X: jnp.ndarray, Y: jnp.ndarray,
          steps: int, lr: float = 1e-3, train_B: bool = True,
          log_every: int = 0,
          context: exctx.ContextLike = None) -> Tuple[Dict, list]:
    """Full-batch Adam on the reconstruction loss.

    ``train_B=False`` freezes the butterfly (phase 1 of two-phase learning).
    ``context`` carries the kernel execution policy — on TPU the fused
    Pallas kernel runs in the gradient too (custom_vjp); unset tile knobs
    are autotuned; a context with a mesh data-shards the butterfly product
    across devices. Returns (params, loss history).
    """
    tx = opt.adamw(lr)
    state = tx.init(params)

    def masked_loss(p):
        return loss_fn(spec, p, X, Y, context=context)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(masked_loss)(params)
        if not train_B:
            grads = dict(grads, B=jnp.zeros_like(grads["B"]))
        updates, state = tx.update(grads, state, params)
        params = opt.apply_updates(params, updates)
        return params, state, loss

    history = []
    for i in range(steps):
        params, state, loss = step(params, state)
        if log_every and (i % log_every == 0 or i == steps - 1):
            history.append(float(loss))
    return params, history


def train_two_phase(spec: EncDecSpec, params: Dict, X: jnp.ndarray,
                    Y: jnp.ndarray, steps1: int, steps2: int,
                    lr: float = 1e-3, log_every: int = 0,
                    context: exctx.ContextLike = None
                    ) -> Tuple[Dict, list, list]:
    """§5.3: phase 1 trains (D, E) with B frozen at its FJLT init (Theorem 1
    guarantees local = global here); phase 2 fine-tunes all three."""
    params, h1 = train(spec, params, X, Y, steps1, lr=lr, train_B=False,
                       log_every=log_every, context=context)
    params, h2 = train(spec, params, X, Y, steps2, lr=lr, train_B=True,
                       log_every=log_every, context=context)
    return params, h1, h2
