"""Gated MLP blocks (SwiGLU / GeGLU / plain GELU), butterfly-replaceable."""

from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.runtime.sharding import constrain


def mlp_specs(cfg: ModelConfig, d_ff: int = 0) -> Dict:
    E = cfg.d_model
    F = d_ff or cfg.d_ff
    out = {
        "up": cm.linear_specs(cfg, E, F, ("embed", "mlp"), site="mlp",
                              site_key="mlp_up"),
        "down": cm.linear_specs(cfg, F, E, ("mlp", "embed"), site="mlp",
                                site_key="mlp_down"),
    }
    if cfg.mlp_variant in ("swiglu", "geglu"):
        out["gate"] = cm.linear_specs(cfg, E, F, ("embed", "mlp"),
                                      site="mlp", site_key="mlp_gate")
    return out


def mlp_apply(cfg: ModelConfig, params: Dict, x: jnp.ndarray,
              d_ff: int = 0) -> jnp.ndarray:
    F = d_ff or cfg.d_ff
    act = cm.act_fn(cfg.mlp_variant)
    up = cm.linear_apply(cfg, params["up"], x, site="mlp",
                         site_key="mlp_up", n_out=F)
    if "gate" in params:
        gate = cm.linear_apply(cfg, params["gate"], x, site="mlp",
                               site_key="mlp_gate", n_out=F)
        h = act(gate) * up
    else:
        h = act(up)
    h = constrain(h, ("batch", None, "mlp"))
    return cm.linear_apply(cfg, params["down"], h, site="mlp",
                           site_key="mlp_down", n_out=cfg.d_model)
