"""GPipe pipeline parallelism: forward + gradient equivalence with the
unpipelined stack, on the suite's 8 simulated devices.

Runs in-process: ``tests/conftest.py`` owns the
``--xla_force_host_platform_device_count=8`` setup (and asserts it took),
so this module — like every other multi-device test — must NOT touch
XLA_FLAGS itself; the old import-time assignment silently no-op'd whenever
jax had already initialized.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.mesh import make_mesh
from repro.runtime.pipeline import pipeline_apply, reference_apply


def _stage_fn(params, x):
    return jnp.tanh(x @ params["w"] + params["b"])


@pytest.mark.slow
def test_gpipe_forward_and_grad_equivalence():
    S, D, B, T = 4, 16, 8, 4
    mesh = make_mesh((S, 2), ("stage", "data"))

    key = jax.random.PRNGKey(0)
    params = {
        "w": jax.random.normal(key, (S, D, D)) / jnp.sqrt(D),
        "b": jnp.zeros((S, D)),
    }
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D))

    want = reference_apply(_stage_fn, params, x)
    got = pipeline_apply(_stage_fn, params, x, mesh=mesh, microbatches=T)
    err = float(jnp.abs(got - want).max())
    assert err < 1e-5, f"forward mismatch {err}"

    def loss_pipe(p):
        return jnp.sum(pipeline_apply(_stage_fn, p, x, mesh=mesh,
                                      microbatches=T) ** 2)

    def loss_ref(p):
        return jnp.sum(reference_apply(_stage_fn, p, x) ** 2)

    g1 = jax.grad(loss_pipe)(params)
    g2 = jax.grad(loss_ref)(params)
    gerr = max(float(jnp.abs(a - b).max()) for a, b in
               zip(jax.tree_util.tree_leaves(g1),
                   jax.tree_util.tree_leaves(g2)))
    assert gerr < 1e-4, f"grad mismatch {gerr}"
