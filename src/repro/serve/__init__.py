"""``repro.serve`` — continuous-batching inference for the butterfly LMs.

    from repro.serve import Request, ServeEngine, ServeClient, loader

    cfg = registry.get("smollm-135m-smoke")
    step, params = loader.load_for_serving(cfg, checkpoint_dir)
    engine = ServeEngine(cfg, params, slots=4, max_len=128)  # paged pool
    with ServeClient(engine) as client:
        fut = client.submit(Request(prompt=[1, 2, 3], max_new_tokens=16))
        print(fut.result().tokens)

The engine serves over a :class:`CachePool` — paged by default
(``pool="paged"``: fixed-size pages, per-slot page tables, free-list
recycling, chunked prefill), with the dense PR-5 layout available as
``pool="dense"`` for bisection. Admission is ``"eager"`` (whole-budget
page reservation) or ``"incremental"`` (prompt-only reservation, per-tick
growth, preempt-youngest/recompute on exhaustion). Lifecycle failures are
typed — :class:`QueueFull`, :class:`DeadlineExceeded`,
:class:`RequestCancelled`, :class:`EngineWedged` — and every recovery
path is drivable on a seeded schedule via
:class:`~repro.serve.faults.FaultInjector`. Above the engine sits the
multi-replica tier: :class:`~repro.serve.router.Router` dispatches
requests across several in-process replicas (weighted least-outstanding,
``QueueFull`` failover, drain + checkpoint hot-swap), all driven by ONE
:class:`~repro.serve.client.TickDriver` thread; :mod:`repro.serve.trace`
owns seeded open-loop load generation. See :mod:`repro.serve.engine`
for the tick-loop / compile-cache design, :mod:`repro.serve.cache` for
the pool API, :mod:`repro.serve.faults` for fault injection, and
``python -m repro.launch.serve --help`` for the workload-replay CLI.
"""

from repro.serve import cache, faults, loader, metrics, sampling, trace
from repro.serve.cache import (CachePool, DenseCachePool, PagedCachePool,
                               PoolExhausted, make_pool)
from repro.serve.client import EngineWedged, ServeClient, TickDriver
from repro.serve.engine import (CompileCache, DeadlineExceeded,
                                GenerationResult, QueueFull, Request,
                                RequestCancelled, ServeEngine)
from repro.serve.faults import FaultInjector, InjectedFault
from repro.serve.metrics import EngineMetrics, RequestMetrics
from repro.serve.router import Router
from repro.serve.sampling import GREEDY, SamplingParams, sample_logits
from repro.serve.trace import TraceItem, TraceSpec

__all__ = [
    # engine + client + router
    "ServeEngine", "ServeClient", "TickDriver", "Router", "CompileCache",
    # request/result surface
    "Request", "GenerationResult",
    # typed lifecycle failures
    "QueueFull", "DeadlineExceeded", "RequestCancelled", "EngineWedged",
    # cache pools
    "CachePool", "DenseCachePool", "PagedCachePool", "PoolExhausted",
    "make_pool",
    # fault injection
    "FaultInjector", "InjectedFault",
    # metrics
    "EngineMetrics", "RequestMetrics",
    # sampling
    "SamplingParams", "GREEDY", "sample_logits",
    # load generation
    "TraceSpec", "TraceItem",
    # submodules
    "cache", "faults", "loader", "metrics", "sampling", "trace",
]
