"""Numerical validation of Theorem 1: measured loss at the (D,E) critical
point equals tr(YYᵀ) − Σ_{i∈[k]} λ_i(Σ(B)), and wrong eigen-subsets are
strictly worse (saddle classification)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import encdec


def run() -> None:
    for n, k in ((48, 4), (96, 8), (128, 16)):
        X = jnp.asarray(np.random.default_rng(n).normal(size=(n, n)),
                        jnp.float32)
        spec = encdec.make_spec(jax.random.PRNGKey(n), n=n, d=n, k=k)
        params = encdec.init_params(jax.random.PRNGKey(n + 1), spec)
        D, E = encdec.optimal_DE(spec, params["B"], X, X)
        measured = float(encdec.loss_fn(spec, dict(params, D=D, E=E), X, X))
        predicted = float(encdec.theorem1_loss(spec, params["B"], X, X))
        rel = abs(measured - predicted) / max(abs(predicted), 1e-9)
        emit(f"theorem1/n{n}_k{k}", 0.0,
             f"measured={measured:.4f};predicted={predicted:.4f};"
             f"rel_err={rel:.2e}")


if __name__ == "__main__":
    run()
