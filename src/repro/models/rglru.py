"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block: x → [gate branch: linear+GeLU] ⊙ [rec branch: linear → causal
depthwise conv(w=4) → RG-LRU] → output linear.

RG-LRU (real-gated linear recurrent unit)::

    r_t = σ(W_a x_t + b_a)              recurrence gate
    i_t = σ(W_x x_t + b_x)              input gate
    a_t = exp(-c · softplus(Λ) ⊙ r_t)   diagonal decay, c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

The recurrence is *diagonal*, so train/prefill run in O(S log S) via
``jax.lax.associative_scan`` (sub-quadratic — this is why the arch runs the
500k-context cell), and decode is an O(1) state update. The paper's butterfly
technique does not apply to the diagonal recurrence itself (nothing dense to
replace); it applies to the in/out projections (DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.runtime.pytree import ParamSpec
from repro.runtime.sharding import constrain

_C = 8.0


def rglru_specs(cfg: ModelConfig) -> Dict:
    E, R, W = cfg.d_model, cfg.lru_width_, cfg.conv_width
    dt = cfg.param_dtype
    return {
        "w_in": ParamSpec((E, R), dt, ("embed", "rnn_state"),
                          init="scaled_normal", fan_in_dim=0),
        "w_gate_branch": ParamSpec((E, R), dt, ("embed", "rnn_state"),
                                   init="scaled_normal", fan_in_dim=0),
        "conv": ParamSpec((W, R), dt, (None, "rnn_state"),
                          init="scaled_normal", scale=0.5, fan_in_dim=0),
        "w_a": ParamSpec((R, R), dt, ("rnn_state", None),
                         init="scaled_normal", fan_in_dim=0),
        "b_a": ParamSpec((R,), dt, (None,), init="zeros"),
        "w_x": ParamSpec((R, R), dt, ("rnn_state", None),
                         init="scaled_normal", fan_in_dim=0),
        "b_x": ParamSpec((R,), dt, (None,), init="zeros"),
        "lam": ParamSpec((R,), dt, (None,), init="normal", scale=0.5),
        "w_out": ParamSpec((R, E), dt, ("rnn_state", "embed"),
                           init="scaled_normal", fan_in_dim=0),
    }


def rglru_cache_spec(cfg: ModelConfig, batch: int) -> Dict:
    R, W = cfg.lru_width_, cfg.conv_width
    f32 = jnp.float32
    return {
        "h": jax.ShapeDtypeStruct((batch, R), f32),
        "conv": jax.ShapeDtypeStruct((batch, W - 1, R), cfg.cdtype()),
    }


def init_rglru_cache(cfg: ModelConfig, batch: int) -> Dict:
    R, W = cfg.lru_width_, cfg.conv_width
    return {"h": jnp.zeros((batch, R), jnp.float32),
            "conv": jnp.zeros((batch, W - 1, R), cfg.cdtype())}


def _causal_conv(x: jnp.ndarray, kernel: jnp.ndarray,
                 history: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Depthwise causal conv over time. x: (B,S,R); kernel: (W,R);
    history: (B,W-1,R) previous inputs (decode/chunked prefill)."""
    W = kernel.shape[0]
    if history is None:
        history = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([history.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    S = x.shape[1]
    for j in range(W):
        out = out + kernel[j].astype(x.dtype) * jax.lax.dynamic_slice_in_dim(
            xp, j, S, axis=1)
    return out


def _gates(params: Dict, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    cd = x.dtype
    r = jax.nn.sigmoid((x @ params["w_a"].astype(cd)
                        + params["b_a"].astype(cd)).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ params["w_x"].astype(cd)
                        + params["b_x"].astype(cd)).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) \
        * (i * x.astype(jnp.float32))
    return a, gated_x


def rglru_scan(params: Dict, x: jnp.ndarray,
               h0: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray,
                                                          jnp.ndarray]:
    """Parallel linear recurrence over (B,S,R). Returns (hs, h_last)."""
    a, b = _gates(params, x)                       # (B,S,R) f32 each

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    A, Bc = jax.lax.associative_scan(combine, (a, b), axis=1)
    if h0 is not None:
        hs = A * h0[:, None, :] + Bc
    else:
        hs = Bc
    return hs, hs[:, -1, :]


def rglru_step(params: Dict, x: jnp.ndarray, h: jnp.ndarray
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single decode step. x: (B,1,R); h: (B,R) f32."""
    a, b = _gates(params, x)
    h_new = a[:, 0] * h + b[:, 0]
    return h_new[:, None, :], h_new


def rglru_block(cfg: ModelConfig, params: Dict, x: jnp.ndarray, *,
                mode: str, cache: Optional[Dict] = None
                ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Full Griffin recurrent block. x: (B,S,E)."""
    cd = x.dtype
    gate = jax.nn.gelu(x @ params["w_gate_branch"].astype(cd))
    u = x @ params["w_in"].astype(cd)
    u = constrain(u, ("batch", None, "rnn_state"))

    new_cache = None
    if mode == "decode":
        conv_hist = cache["conv"]
        v = _causal_conv(u, params["conv"], conv_hist)
        hs, h_last = rglru_step(params, v, cache["h"])
        W = cfg.conv_width
        new_hist = jnp.concatenate([conv_hist[:, 1:], u.astype(conv_hist.dtype)],
                                   axis=1) if W > 1 else conv_hist
        new_cache = {"h": h_last, "conv": new_hist}
    else:
        v = _causal_conv(u, params["conv"])
        hs, h_last = rglru_scan(params, v)
        if mode == "prefill":
            W = cfg.conv_width
            hist = u[:, -(W - 1):, :] if W > 1 else u[:, :0, :]
            new_cache = {"h": h_last,
                         "conv": hist.astype(cache["conv"].dtype)}
    out = (hs.astype(cd) * gate) @ params["w_out"].astype(cd)
    return out, new_cache
