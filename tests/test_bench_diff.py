"""Unit tests for the CI benchmark regression gate (``benchmarks/diff.py``).

The gate fails PRs, so the gate itself is gated: regression detection,
skipped/null/metric-only row exemptions, vanished-row bypass detection and
the cross-machine median normalization all get direct coverage here.
``diff.py`` is a script, not a package module — load it by path.
"""

import importlib.util
import json
import os

import pytest

_DIFF_PATH = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                          "diff.py")
_spec = importlib.util.spec_from_file_location("bench_diff", _DIFF_PATH)
diff = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(diff)


def _write(tmp_path, name, rows):
    path = tmp_path / name
    path.write_text(json.dumps({"rows": rows}))
    return str(path)


def _row(name, us, **kw):
    return {"name": name, "us_per_call": us, **kw}


def _run(tmp_path, base_rows, fresh_rows, *extra):
    base = _write(tmp_path, "base.json", base_rows)
    fresh = _write(tmp_path, "fresh.json", fresh_rows)
    return diff.main([base, fresh, "--min-us", "0", *extra])


def test_unchanged_rows_pass(tmp_path):
    rows = [_row(f"b{i}", 10000.0) for i in range(6)]
    assert _run(tmp_path, rows, rows) == 0


def test_single_row_regression_fails(tmp_path):
    base = [_row(f"b{i}", 10000.0) for i in range(6)]
    fresh = [_row(f"b{i}", 10000.0) for i in range(5)]
    fresh.append(_row("b5", 14000.0))  # 1.4x > 1.25x threshold
    assert _run(tmp_path, base, fresh) == 1


def test_regression_within_threshold_passes(tmp_path):
    base = [_row(f"b{i}", 10000.0) for i in range(6)]
    fresh = [_row(f"b{i}", 10000.0) for i in range(5)]
    fresh.append(_row("b5", 12000.0))  # 1.2x < 1.25x
    assert _run(tmp_path, base, fresh) == 0


def test_skipped_and_null_rows_never_gate(tmp_path):
    """Rows skipped on either side (CPU-skipped TPU benchmarks emit
    us_per_call null + skipped true) are not comparable and never fail."""
    base = [_row("b0", 10000.0),
            _row("skipme", None, skipped=True),
            _row("metric_only", 0.0)]
    fresh = [_row("b0", 10000.0),
             _row("skipme", None, skipped=True),
             _row("metric_only", 0.0)]
    assert _run(tmp_path, base, fresh) == 0
    # a 100x "regression" on a skipped-in-baseline row still passes
    fresh2 = [_row("b0", 10000.0),
              _row("skipme", 999999.0),
              _row("metric_only", 0.0)]
    assert _run(tmp_path, base, fresh2) == 0


def test_vanished_timed_row_fails(tmp_path):
    """A timed baseline row missing from the fresh run is a gate bypass."""
    base = [_row("b0", 10000.0), _row("b1", 10000.0)]
    fresh = [_row("b0", 10000.0)]
    assert _run(tmp_path, base, fresh) == 1


def test_timed_row_coming_back_skipped_fails(tmp_path):
    """A widened skip guard (timed before, skipped now) must not pass."""
    base = [_row("b0", 10000.0), _row("b1", 10000.0)]
    fresh = [_row("b0", 10000.0), _row("b1", None, skipped=True)]
    assert _run(tmp_path, base, fresh) == 1


def test_median_normalization_absorbs_uniform_slowdown(tmp_path):
    """A uniformly 2x-slower machine shifts every ratio equally: the median
    normalization gates nothing, while --no-normalize fails everything."""
    base = [_row(f"b{i}", 10000.0) for i in range(6)]
    fresh = [_row(f"b{i}", 20000.0) for i in range(6)]
    assert _run(tmp_path, base, fresh) == 0
    assert _run(tmp_path, base, fresh, "--no-normalize") == 1


def test_median_normalization_still_catches_local_regression(tmp_path):
    """On a uniformly slower machine, one row that regressed on top of the
    machine factor still stands out against the median."""
    base = [_row(f"b{i}", 10000.0) for i in range(6)]
    fresh = [_row(f"b{i}", 20000.0) for i in range(5)]
    fresh.append(_row("b5", 40000.0))  # 4x raw = 2x normalized
    assert _run(tmp_path, base, fresh) == 1


def test_below_min_rows_gates_on_raw_ratios(tmp_path):
    """With fewer comparable pairs than --min-rows there is no population to
    estimate machine speed from: raw ratios gate."""
    base = [_row("b0", 10000.0), _row("b1", 10000.0)]
    fresh = [_row("b0", 20000.0), _row("b1", 20000.0)]
    assert _run(tmp_path, base, fresh) == 1  # 2 pairs < default min-rows 5
    assert _run(tmp_path, base, fresh, "--min-rows", "1") == 0  # normalized


def test_min_us_floor_ignores_noise_rows(tmp_path):
    """Sub-floor baseline rows are shared-runner noise: never compared, and
    their disappearance doesn't count as a vanished timed row either."""
    base = [_row("fast", 100.0), _row("slow", 10000.0)]
    fresh = [_row("slow", 10000.0)]
    baseline = _write(tmp_path, "b2.json", base)
    fresh_p = _write(tmp_path, "f2.json", fresh)
    assert diff.main([baseline, fresh_p, "--min-us", "5000"]) == 0


@pytest.mark.parametrize("bad", [None, 0.0])
def test_comparable_predicate(bad):
    assert not diff.comparable({"us_per_call": bad}, 0.0)
    assert not diff.comparable({"us_per_call": 10.0, "skipped": True}, 0.0)
    assert not diff.comparable(None, 0.0)
    assert diff.comparable({"us_per_call": 10.0}, 0.0)
    assert not diff.comparable({"us_per_call": 10.0}, 100.0)  # below floor
