"""Core butterfly-network math (paper §3).

A butterfly network over ``n = 2^p`` coordinates is a product of ``p`` sparse
stage matrices ``B = B_{p-1} · ... · B_1 · B_0``. Stage ``s`` connects every
index ``i`` with its partner ``i XOR 2^s`` through a trainable 2x2 gadget.

We parametrize each stage with two length-``n`` weight vectors ``a_s`` (self
coefficient) and ``b_s`` (partner coefficient), stacked into a single array of
shape ``(p, 2, n)``::

    (B_s x)[i] = a_s[i] * x[i] + b_s[i] * x[i ^ 2^s]

This matches the paper exactly: each stage has ``2n`` trainable weights
(Definition 3.1), and the FJLT construction (Hadamard stages + random signs)
is a particular weight assignment (``fjlt_weights``).

Everything in this file is pure jnp and differentiable; it doubles as the
oracle for the Pallas kernels in ``repro.kernels``.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "num_stages",
    "padded_dim",
    "stage_swap",
    "butterfly_apply",
    "butterfly_transpose_apply",
    "fjlt_weights",
    "identity_weights",
    "random_weights",
    "truncation_indices",
    "truncate",
    "untruncate",
    "materialize",
    "materialize_truncated",
    "effective_param_count",
    "effective_param_bound",
]


def num_stages(n: int) -> int:
    """Number of butterfly stages ``p = log2(n)`` for a power-of-two ``n``."""
    p = int(round(math.log2(n)))
    if 2**p != n:
        raise ValueError(f"butterfly dimension must be a power of two, got {n}")
    return p


def padded_dim(n: int) -> int:
    """Smallest power of two >= n (paper footnote 4)."""
    if n <= 1:
        return 1
    return 1 << (n - 1).bit_length()


def stage_swap(x: jnp.ndarray, stride: int) -> jnp.ndarray:
    """Swap each element with its stage partner: ``y[i] = x[i ^ stride]``.

    Works on the last axis. ``stride`` must be a power of two dividing ``n/2``.
    Implemented as reshape + axis-flip which lowers to cheap strided moves on
    TPU (no gather).
    """
    n = x.shape[-1]
    lead = x.shape[:-1]
    xs = x.reshape(*lead, n // (2 * stride), 2, stride)
    xs = jnp.flip(xs, axis=-2)
    return xs.reshape(*lead, n)


def _check_weights(w: jnp.ndarray) -> Tuple[int, int]:
    p, two, n = w.shape[-3:]
    if two != 2 or 2**p != n:
        raise ValueError(f"weights must have shape (log2 n, 2, n); got {w.shape}")
    return p, n


def butterfly_apply(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Apply the full butterfly ``B x`` along the last axis of ``x``.

    ``w``: (p, 2, n) stage weights. ``x``: (..., n). Stage 0 acts first.
    """
    p, n = _check_weights(w)
    if x.shape[-1] != n:
        raise ValueError(f"x last dim {x.shape[-1]} != butterfly dim {n}")
    for s in range(p):
        a = w[s, 0]
        b = w[s, 1]
        x = a * x + b * stage_swap(x, 1 << s)
    return x


def butterfly_apply_nonlinear(w: jnp.ndarray, x: jnp.ndarray,
                              act=jax.nn.gelu) -> jnp.ndarray:
    """Butterfly with non-linear gates between stages (paper §7 future
    work): ``x ← act(B_s x)`` for all but the last stage. Same parameter
    count as the linear butterfly; turns the layer into a log-depth MLP
    with fixed sparse connectivity."""
    p, n = _check_weights(w)
    if x.shape[-1] != n:
        raise ValueError(f"x last dim {x.shape[-1]} != butterfly dim {n}")
    for s in range(p):
        a = w[s, 0]
        b = w[s, 1]
        x = a * x + b * stage_swap(x, 1 << s)
        if s < p - 1:
            x = act(x)
    return x


def butterfly_transpose_apply(w: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Apply the transposed butterfly ``Bᵀ x``.

    ``Bᵀ = B_0ᵀ · B_1ᵀ · ... · B_{p-1}ᵀ`` and each transposed stage is
    ``(B_sᵀ x)[i] = a_s[i]·x[i] + b_s[i^2^s]·x[i^2^s]``, i.e.
    ``a ⊙ x + swap(b ⊙ x)``.
    """
    p, n = _check_weights(w)
    if x.shape[-1] != n:
        raise ValueError(f"x last dim {x.shape[-1]} != butterfly dim {n}")
    for s in reversed(range(p)):
        a = w[s, 0]
        b = w[s, 1]
        x = a * x + stage_swap(b * x, 1 << s)
    return x


# ---------------------------------------------------------------------------
# Weight initializers
# ---------------------------------------------------------------------------

def _hadamard_signs(n: int) -> np.ndarray:
    """Per-stage self-coefficient signs for the normalized Hadamard transform.

    Stage ``s`` gadget on pair ``(u, v)`` (bit s of u is 0, of v is 1)::

        y_u = (x_u + x_v)/sqrt(2)     y_v = (x_u - x_v)/sqrt(2)

    so ``a_s[i] = ±1/sqrt(2)`` (sign = +1 iff bit s of i is 0) and
    ``b_s[i] = 1/sqrt(2)``.
    """
    idx = np.arange(n)
    p = num_stages(n)
    signs = np.empty((p, n), dtype=np.float64)
    for s in range(p):
        signs[s] = 1.0 - 2.0 * ((idx >> s) & 1)
    return signs


def fjlt_weights(key: jax.Array, n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Sample butterfly weights from the FJLT distribution.

    Returns stage weights computing ``(1/sqrt(n)) · H · D`` where ``H`` is the
    Walsh–Hadamard transform and ``D`` a random ±1 diagonal. The diagonal is
    absorbed into stage 0 (paper footnote 5). The result is an orthogonal
    matrix, so ``butterfly_apply`` with these weights preserves norms exactly.
    """
    p = num_stages(n)
    inv_sqrt2 = 1.0 / math.sqrt(2.0)
    signs = _hadamard_signs(n)
    a = signs * inv_sqrt2                      # (p, n)
    b = np.full((p, n), inv_sqrt2)
    d = jax.random.rademacher(key, (n,), dtype=jnp.float32)
    d = np.asarray(d)
    # stage 0: (B_0 D x)[i] = a0[i]·d[i]·x[i] + b0[i]·d[i^1]·x[i^1]
    a[0] = a[0] * d
    b[0] = b[0] * d[np.arange(n) ^ 1]
    w = np.stack([a, b], axis=1)               # (p, 2, n)
    return jnp.asarray(w, dtype=dtype)


def identity_weights(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Stage weights that make the butterfly the identity map."""
    p = num_stages(n)
    w = np.zeros((p, 2, n))
    w[:, 0, :] = 1.0
    return jnp.asarray(w, dtype=dtype)


def random_weights(key: jax.Array, n: int, scale: Optional[float] = None,
                   dtype=jnp.float32) -> jnp.ndarray:
    """Gaussian stage weights; default scale keeps the product ~isometric.

    Each stage output coordinate mixes two inputs, so variance 1/2 per weight
    keeps E||B_s x||² = ||x||².
    """
    p = num_stages(n)
    if scale is None:
        scale = 1.0 / math.sqrt(2.0)
    return scale * jax.random.normal(key, (p, 2, n), dtype=dtype)


# ---------------------------------------------------------------------------
# Truncation (the "T" in the truncated butterfly network)
# ---------------------------------------------------------------------------

def truncation_indices(key: jax.Array, n: int, ell: int) -> Tuple[int, ...]:
    """Sample ``ell`` output coordinates uniformly without replacement (fixed
    for the lifetime of the layer, per §3.1)."""
    if ell > n:
        raise ValueError(f"truncation {ell} > dim {n}")
    idx = jax.random.choice(key, n, shape=(ell,), replace=False)
    return tuple(int(i) for i in np.sort(np.asarray(idx)))


def truncate(x: jnp.ndarray, idx: Sequence[int], n: int,
             jl_scale: bool = True) -> jnp.ndarray:
    """Project onto the fixed coordinate subset, with the JL normalization
    ``sqrt(n/ell)`` so that FJLT weights give an expected isometry."""
    ind = jnp.asarray(idx, dtype=jnp.int32)
    y = jnp.take(x, ind, axis=-1)
    if jl_scale:
        y = y * math.sqrt(n / len(idx))
    return y


def untruncate(y: jnp.ndarray, idx: Sequence[int], n: int,
               jl_scale: bool = True) -> jnp.ndarray:
    """Transpose of :func:`truncate`: scatter ``ell`` values into ``n`` slots."""
    ind = jnp.asarray(idx, dtype=jnp.int32)
    if jl_scale:
        y = y * math.sqrt(n / len(idx))
    shape = y.shape[:-1] + (n,)
    out = jnp.zeros(shape, dtype=y.dtype)
    return out.at[..., ind].set(y)


# ---------------------------------------------------------------------------
# Dense materialization (for oracles/analysis; O(n^2) memory, test-sized only)
# ---------------------------------------------------------------------------

def materialize(w: jnp.ndarray) -> jnp.ndarray:
    """Return the dense ``n x n`` matrix ``B`` such that ``B @ x ==
    butterfly_apply(w, x)``."""
    _, n = _check_weights(w)
    eye = jnp.eye(n, dtype=w.dtype)
    # columns of B are B @ e_j; butterfly_apply maps rows, so vmap over rows of
    # identity and transpose.
    cols = jax.vmap(lambda e: butterfly_apply(w, e))(eye)  # row j = B·e_j
    return cols.T


def materialize_truncated(w: jnp.ndarray, idx: Sequence[int],
                          jl_scale: bool = True) -> jnp.ndarray:
    """Dense ``ell x n`` matrix of the truncated butterfly ``T ∘ B``."""
    _, n = _check_weights(w)
    B = materialize(w)
    M = B[jnp.asarray(idx, dtype=jnp.int32), :]
    if jl_scale:
        M = M * math.sqrt(n / len(idx))
    return M


# ---------------------------------------------------------------------------
# Parameter accounting (paper Appendix F)
# ---------------------------------------------------------------------------

def effective_param_count(n: int, idx: Sequence[int]) -> int:
    """Exact number of weights lying on a path from some input to a kept
    output (the "effective" trainable weights of the truncated network).

    Computed by backward reachability through the stages. Appendix F proves
    this is at most ``2 n log2(ell) + 6 n``.
    """
    p = num_stages(n)
    alive = np.zeros(n, dtype=bool)
    alive[list(idx)] = True
    total = 0
    for s in reversed(range(p)):
        # each alive node at stage-output s has 2 incoming weights
        total += 2 * int(alive.sum())
        prev = alive | alive[np.arange(n) ^ (1 << s)]
        alive = prev
    return total


def effective_param_bound(n: int, ell: int) -> int:
    """Appendix F upper bound ``2 n log2(ell) + 6 n``."""
    return int(2 * n * max(math.log2(max(ell, 2)), 1) + 6 * n)
