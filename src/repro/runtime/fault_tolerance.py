"""Fault tolerance: failure detection, elastic re-mesh, straggler mitigation.

Single-host container, so hardware failures are *simulated*, but the control
logic is the real thing a 1000-node deployment needs:

* :class:`HeartbeatMonitor` — workers ping; a watchdog marks workers dead
  after ``timeout`` seconds of silence and fires a callback. Also wired
  around the serving stack: :class:`repro.serve.client.ServeClient`
  (``tick_timeout=``) registers its driver thread as a worker so a wedged
  engine tick is detected and surfaced instead of hanging futures.
* :func:`plan_elastic_mesh` — given surviving host/device counts and the
  desired axis priorities, returns the largest valid (pod, data, model) mesh
  that divides the workload; composes with
  :meth:`CheckpointManager.restore(shardings=...)` for cross-mesh restart
  (tested end-to-end on 8 simulated devices).
* :class:`StragglerMonitor` — per-worker step-time EMA; flags workers slower
  than ``threshold`` x median and emits a mitigation plan (re-balance
  microbatches away from the straggler, or evict + re-mesh when persistent).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Failure detection
# ---------------------------------------------------------------------------

class HeartbeatMonitor:
    def __init__(self, workers: Sequence[str], timeout: float = 1.0,
                 on_failure: Optional[Callable[[str], None]] = None,
                 poll: float = 0.05):
        self.timeout = timeout
        self.on_failure = on_failure
        self.poll = poll
        now = time.monotonic()
        self._last: Dict[str, float] = {w: now for w in workers}
        self._dead: set = set()
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._watch, daemon=True)
        self._thread.start()

    def ping(self, worker: str) -> None:
        with self._lock:
            if worker not in self._dead:
                self._last[worker] = time.monotonic()

    def _watch(self) -> None:
        while not self._stop.is_set():
            now = time.monotonic()
            newly_dead = []
            with self._lock:
                for w, t in self._last.items():
                    if w not in self._dead and now - t > self.timeout:
                        self._dead.add(w)
                        newly_dead.append(w)
            for w in newly_dead:
                if self.on_failure:
                    self.on_failure(w)
            time.sleep(self.poll)

    @property
    def dead(self) -> List[str]:
        with self._lock:
            return sorted(self._dead)

    @property
    def alive(self) -> List[str]:
        with self._lock:
            return sorted(set(self._last) - self._dead)

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=2)

    def __enter__(self) -> "HeartbeatMonitor":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


# ---------------------------------------------------------------------------
# Elastic re-mesh planning
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshPlan:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]
    dropped_devices: int

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))


def plan_elastic_mesh(alive_devices: int, model_parallelism: int,
                      global_batch: int,
                      pods: int = 1) -> MeshPlan:
    """Largest valid mesh from survivors.

    Keeps the ``model`` axis fixed (parameter layouts must still fit) and
    shrinks the ``data`` axis to the largest value such that
    ``pods * data * model <= alive`` and data divides the global batch.
    Surplus devices idle as hot spares (``dropped_devices``).
    """
    if alive_devices < model_parallelism:
        raise ValueError(
            f"cannot re-mesh: {alive_devices} survivors < "
            f"model parallelism {model_parallelism}")
    per_pod = alive_devices // pods
    data = max(1, per_pod // model_parallelism)
    while data > 1 and global_batch % (data * pods):
        data -= 1
    shape: Tuple[int, ...]
    if pods > 1:
        shape = (pods, data, model_parallelism)
        axes = ("pod", "data", "model")
    else:
        shape = (data, model_parallelism)
        axes = ("data", "model")
    used = int(np.prod(shape))
    return MeshPlan(shape=shape, axes=axes,
                    dropped_devices=alive_devices - used)


# ---------------------------------------------------------------------------
# Straggler mitigation
# ---------------------------------------------------------------------------

@dataclass
class MitigationAction:
    kind: str                      # "none" | "rebalance" | "evict"
    worker: str = ""
    microbatch_weights: Optional[Dict[str, float]] = None


class StragglerMonitor:
    """EMA step-time tracking + mitigation policy.

    ``threshold``: relative slowdown vs the median EMA that flags a
    straggler. ``patience``: consecutive flagged steps before eviction is
    recommended (transient slowdowns only trigger rebalancing).
    """

    def __init__(self, workers: Sequence[str], alpha: float = 0.3,
                 threshold: float = 1.5, patience: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.ema: Dict[str, float] = {w: 0.0 for w in workers}
        self.flags: Dict[str, int] = {w: 0 for w in workers}

    def record(self, step_times: Dict[str, float]) -> MitigationAction:
        for w, t in step_times.items():
            prev = self.ema.get(w, 0.0)
            self.ema[w] = t if prev == 0.0 else \
                self.alpha * t + (1 - self.alpha) * prev
        med = float(np.median(list(self.ema.values())))
        worst = max(self.ema, key=self.ema.get)
        if med <= 0 or self.ema[worst] <= self.threshold * med:
            for w in self.flags:
                self.flags[w] = 0
            return MitigationAction("none")
        self.flags[worst] += 1
        for w in self.flags:
            if w != worst:
                self.flags[w] = 0
        if self.flags[worst] >= self.patience:
            return MitigationAction("evict", worker=worst)
        # rebalance: shift work away proportionally to EMA speed
        inv = {w: 1.0 / max(e, 1e-9) for w, e in self.ema.items()}
        z = sum(inv.values())
        weights = {w: v / z for w, v in inv.items()}
        return MitigationAction("rebalance", worker=worst,
                                microbatch_weights=weights)
