"""Dry-run machinery: HLO analyzer correctness, cell applicability, and a
real (subprocess) mini dry-run on the production mesh.

The subprocess is required because XLA_FLAGS=--xla_force_host_platform_
device_count must be set before jax initializes — tests in this process see
a single device (assignment requirement: never set it globally)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES_BY_NAME, cell_applicable
from repro.configs import registry
from repro.launch import hlo_analysis as ha
from repro.launch import roofline as rl

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_analyzer_counts_scan_trip_multiplicity():
    def body(c, x):
        return c @ x, ()

    def f(c, xs):
        return jax.lax.scan(body, c, xs)[0]

    c = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    xs = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)
    compiled = jax.jit(f).lower(c, xs).compile()
    # cost_analysis undercounts (counts the body once) ...
    assert rl.cost_analysis_dict(compiled)["flops"] < 12 * 2 * 64**3 / 2
    # ... the loop-aware analyzer does not
    cost = ha.analyze(compiled.as_text())
    np.testing.assert_allclose(cost.flops, 12 * 2 * 64**3, rtol=0.05)
    assert any(t == 12 for _, t in cost.loops)


def test_analyzer_matmul_exact():
    f = lambda a, b: a @ b
    a = jax.ShapeDtypeStruct((512, 1024), jnp.float32)
    b = jax.ShapeDtypeStruct((1024, 256), jnp.float32)
    compiled = jax.jit(f).lower(a, b).compile()
    cost = ha.analyze(compiled.as_text())
    np.testing.assert_allclose(cost.flops, 2 * 512 * 1024 * 256, rtol=0.02)


def test_analyzer_collective_classification():
    text = """
HloModule test

ENTRY %main (p: f32[128]) -> f32[128] {
  %p = f32[128]{0} parameter(0)
  %ar = f32[128]{0} all-reduce(%p), channel_id=1, replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%add
  ROOT %ar2 = f32[128]{0} all-reduce(%ar), channel_id=2, replica_groups=[1,512]<=[512], use_global_device_ids=true, to_apply=%add
}
"""
    cost = ha.analyze(text, pod_boundary=256)
    assert cost.collective_counts.get("all-reduce") == 2
    assert cost.collective_dcn > 0 and cost.collective_ici > 0


def test_cell_applicability_matrix():
    long = SHAPES_BY_NAME["long_500k"]
    ok, _ = cell_applicable(registry.get("recurrentgemma-2b"), long)
    assert ok
    ok, reason = cell_applicable(registry.get("mistral-large-123b"), long)
    assert not ok and "full-attention" in reason
    for shape in ("train_4k", "prefill_32k", "decode_32k"):
        for arch in registry.names():
            ok, _ = cell_applicable(registry.get(arch),
                                    SHAPES_BY_NAME[shape])
            assert ok


@pytest.mark.slow
def test_mini_dryrun_subprocess_production_mesh():
    """Full dry-run path for one real cell on the 16x16 production mesh —
    proves lower+compile+roofline works end-to-end on 256 fake devices."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "smollm-135m", "--shape", "decode_32k", "--mesh", "both",
         "--out", "/tmp/test_dryrun_cell"],
        env=env, capture_output=True, text=True, timeout=560)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    with open("/tmp/test_dryrun_cell/"
              "smollm-135m__decode_32k__pod16x16.json") as f:
        rec = json.load(f)
    assert rec["status"] == "ok"
    assert rec["hbm_fit"] is True
    assert rec["flops_per_device"] > 0
    assert rec["t_memory"] > 0
    with open("/tmp/test_dryrun_cell/"
              "smollm-135m__decode_32k__pod2x16x16.json") as f:
        rec2 = json.load(f)
    assert rec2["status"] == "ok" and rec2["n_devices"] == 512
