"""Unified observability layer: tracer, metrics registry, validator.

Three pillars under test:

* :mod:`repro.obs.tracing` — the bounded-ring span tracer, its Chrome
  trace-event export, and the :data:`NULL_TRACER` no-op default;
* :mod:`repro.obs.registry` — the one lock-protected metrics registry
  the engine / router / pool / faults / compile cache all feed, its
  stable snapshot schema (golden-pinned here) and Prometheus exposition;
* :mod:`repro.obs.validate` — the structural Chrome-trace validator CI
  runs over the benchmark artifact.

The acceptance test drives a page-starved speculative engine through a
one-replica :class:`Router` and reconstructs one preempted request's
COMPLETE timeline from the exported trace: queue → admit →
prefill_chunk[i] → spec → preempt → queue (again) → admit →
prefill_chunk(recompute) → finish.
"""

import json
import threading

import pytest

from repro.configs import registry as arch_registry
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry,
                       NullTracer, SNAPSHOT_SCHEMA, Tracer)
from repro.obs.profiling import annotate, profiling_enabled
from repro.obs.tracing import NULL_TRACER, TRACK_ENGINE
from repro.obs.validate import TraceValidationError, validate_chrome_trace
from repro.serve import (FaultInjector, Request, Router, SamplingParams,
                         ServeEngine, loader)

ARCH = "smollm-135m-butterfly-smoke"


@pytest.fixture(scope="module")
def cfg():
    return arch_registry.get(ARCH)


@pytest.fixture(scope="module")
def params(cfg):
    return loader.init_params(cfg, seed=0)


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_tracer_ring_bound_and_drop_counter():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    assert len(tr) == 4
    assert tr.dropped == 6
    assert tr.emitted == 10
    # oldest evicted first: the ring keeps the 4 newest
    assert [e["name"] for e in tr.events()] == ["e6", "e7", "e8", "e9"]
    tr.clear()
    assert len(tr) == 0 and tr.dropped == 0 and tr.emitted == 0


def test_tracer_span_and_complete_events():
    tr = Tracer()
    with tr.span("work", pid=2, tid=5, tick=7):
        pass
    t0 = tr.now()
    tr.complete("manual", t0, t0 + 1.5, pid=1, tid=0, foo="bar")
    evs = tr.events()
    assert [e["name"] for e in evs] == ["work", "manual"]
    span = evs[0]
    assert span["ph"] == "X" and span["dur"] >= 0
    assert (span["pid"], span["tid"]) == (2, 5)
    assert span["args"] == {"tick": 7}
    assert evs[1]["dur"] == 1.5
    # negative durations clamp rather than poisoning the trace
    tr.complete("backwards", 10.0, 5.0)
    assert tr.events()[-1]["dur"] == 0.0


def test_tracer_chrome_export_metadata_and_validates():
    tr = Tracer()
    tr.name_process(0, "replica 0")
    tr.name_track(0, TRACK_ENGINE, "engine")
    tr.name_track(0, 3, "req 2")
    with tr.span("outer", pid=0, tid=3):
        with tr.span("inner", pid=0, tid=3):
            pass
    doc = tr.chrome_trace()
    assert doc["displayTimeUnit"] == "ms"
    meta = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    names = {(e["name"], e["pid"], e["tid"]) for e in meta}
    assert ("process_name", 0, 0) in names
    assert ("thread_name", 0, 3) in names
    # validator accepts the export and strips the metadata
    evs = validate_chrome_trace(doc)
    assert {e["name"] for e in evs} == {"outer", "inner"}
    # round-trips through JSON unchanged
    validate_chrome_trace(json.loads(json.dumps(doc)))


def test_null_tracer_is_inert():
    nt = NullTracer()
    nt.instant("x")
    nt.complete("y", 0.0, 1.0)
    with nt.span("z"):
        pass
    nt.name_process(0, "p")
    nt.name_track(0, 0, "t")
    assert len(nt) == 0 and nt.emitted == 0 and nt.now() == 0.0
    assert not nt.enabled and not NULL_TRACER.enabled
    assert nt.chrome_trace()["traceEvents"] == []
    # the same span object is reused — no per-call allocation
    assert nt.span("a") is nt.span("b")


def test_engine_defaults_to_null_tracer(cfg, params):
    eng = ServeEngine(cfg, params, slots=1, max_len=32, seed=0)
    assert eng.tracer is NULL_TRACER
    assert isinstance(eng.obs, MetricsRegistry)


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_registry_get_or_create_identity_and_values():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "requests")
    assert reg.counter("reqs_total") is c
    c.inc()
    c.inc(3)
    assert c.value == 4
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1)
    g = reg.gauge("depth")
    g.set(5)
    g.dec(2)
    assert g.value == 3
    h = reg.histogram("lat", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    v = h.value
    assert v["count"] == 3 and v["buckets"]["+Inf"] == 3
    assert v["buckets"][repr(0.1)] == 1 and v["buckets"][repr(1.0)] == 2


def test_registry_conflicts_raise():
    reg = MetricsRegistry()
    reg.counter("m")
    with pytest.raises(ValueError, match="already registered as counter"):
        reg.gauge("m")
    with pytest.raises(ValueError, match="primitive-backed"):
        reg.register_callback("m", lambda: 1, mtype="counter")
    reg.register_callback("cb", lambda: 1)
    with pytest.raises(ValueError, match="callback-backed"):
        reg.gauge("cb")
    # newest wins on callback re-register (engine rebuilds do this)
    reg.register_callback("cb", lambda: 42)
    sample = reg.snapshot()["metrics"]["cb"]["samples"][0]
    assert sample["value"] == 42


def test_registry_labels_and_exposition():
    reg = MetricsRegistry()
    reg.counter("hits_total", "hit count", labels={"replica": 0}).inc(7)
    reg.counter("hits_total", labels={"replica": 1}).inc(9)
    reg.histogram("tick_seconds", "per-tick wall",
                  buckets=(0.5,)).observe(0.25)
    text = reg.exposition()
    assert "# HELP hits_total hit count" in text
    assert "# TYPE hits_total counter" in text
    assert 'hits_total{replica="0"} 7' in text
    assert 'hits_total{replica="1"} 9' in text
    assert 'tick_seconds_bucket{le="0.5"} 1' in text
    assert 'tick_seconds_bucket{le="+Inf"} 1' in text
    assert "tick_seconds_sum 0.25" in text
    assert "tick_seconds_count 1" in text
    snap = reg.snapshot()
    assert snap["schema"] == SNAPSHOT_SCHEMA
    samples = snap["metrics"]["hits_total"]["samples"]
    assert [s["labels"] for s in samples] == [{"replica": "0"},
                                              {"replica": "1"}]
    # stable JSON round-trip
    assert json.loads(reg.snapshot_json()) == snap


def test_registry_hammer_concurrent_with_exposition():
    """PR-9-style storm, now against the shared registry: four threads
    mutate primitives (and one callback reads a racing plain int) while
    the main thread renders exposition + snapshot. Every render must be
    internally consistent and the final counts exact."""
    reg = MetricsRegistry()
    c = reg.counter("storm_total")
    g = reg.gauge("storm_depth")
    h = reg.histogram("storm_seconds", buckets=(0.5,))
    state = {"n": 0}
    reg.register_callback("storm_cb", lambda: state["n"])
    n_threads, n_iter = 4, 2000
    start = threading.Barrier(n_threads + 1)
    errors = []

    def storm():
        try:
            start.wait()
            for i in range(n_iter):
                c.inc()
                g.inc()
                g.dec()
                h.observe(0.25 if i % 2 else 0.75)
                state["n"] += 1
        except BaseException as e:
            errors.append(e)

    threads = [threading.Thread(target=storm) for _ in range(n_threads)]
    for t in threads:
        t.start()
    start.wait()
    renders = 0
    while any(t.is_alive() for t in threads):
        snap = reg.snapshot()["metrics"]
        hv = snap["storm_seconds"]["samples"][0]["value"]
        assert hv["buckets"]["+Inf"] == hv["count"]
        assert 0 <= snap["storm_total"]["samples"][0]["value"] \
            <= n_threads * n_iter
        assert "storm_total" in reg.exposition()
        renders += 1
    for t in threads:
        t.join()
    assert not errors, errors
    total = n_threads * n_iter
    assert c.value == total
    assert g.value == 0
    assert h.value["count"] == total
    assert renders > 0


# ---------------------------------------------------------------------------
# Validator
# ---------------------------------------------------------------------------

def test_validator_rejects_malformed_events():
    ok = [{"name": "a", "ph": "X", "ts": 0.0, "dur": 2.0, "pid": 0,
           "tid": 0, "args": {}},
          {"name": "b", "ph": "X", "ts": 0.5, "dur": 1.0, "pid": 0,
           "tid": 0}]
    assert len(validate_chrome_trace(ok)) == 2
    with pytest.raises(TraceValidationError, match="missing required"):
        validate_chrome_trace([{"name": "a", "ph": "i", "pid": 0,
                                "tid": 0}])
    with pytest.raises(TraceValidationError, match="unknown phase"):
        validate_chrome_trace([{"name": "a", "ph": "Q", "ts": 0,
                                "pid": 0, "tid": 0}])
    with pytest.raises(TraceValidationError, match="without dur"):
        validate_chrome_trace([{"name": "a", "ph": "X", "ts": 0,
                                "pid": 0, "tid": 0}])
    with pytest.raises(TraceValidationError, match="traceEvents"):
        validate_chrome_trace({"events": []})


def test_validator_rejects_partial_overlap():
    bad = [{"name": "a", "ph": "X", "ts": 0.0, "dur": 2.0, "pid": 0,
            "tid": 0},
           {"name": "b", "ph": "X", "ts": 1.0, "dur": 2.0, "pid": 0,
            "tid": 0}]
    with pytest.raises(TraceValidationError, match="partially overlaps"):
        validate_chrome_trace(bad)
    # same shapes on DIFFERENT tracks are fine
    bad[1]["tid"] = 1
    validate_chrome_trace(bad)


# ---------------------------------------------------------------------------
# Golden schema: one registry for engine + pool + faults + compile + router
# ---------------------------------------------------------------------------

# The unified snapshot's metric families. A rename/removal here is a
# telemetry schema break for every downstream consumer — change this
# list deliberately, in lockstep with README's observability table.
GOLDEN_FAMILIES = [
    "router_drains_total",
    "router_max_concurrent_slots",
    "router_passes_total",
    "router_replicas",
    "router_replicas_live",
    "router_requeued_total",
    "router_shed_total",
    "router_swaps_total",
    "serve_cancelled_total",
    "serve_chunk_ticks_total",
    "serve_compile_traces_total",
    "serve_compiles_total",
    "serve_deadline_expired_total",
    "serve_decode_steps_total",
    "serve_decode_time_seconds_total",
    "serve_decode_tokens_total",
    "serve_fault_calls_total",
    "serve_fault_fired_total",
    "serve_finished_tokens_total",
    "serve_max_concurrent_slots",
    "serve_occupied_slots",
    "serve_pages_hwm",
    "serve_pages_in_use",
    "serve_pages_total",
    "serve_pool_exhausted_total",
    "serve_preempted_total",
    "serve_prefill_time_seconds_total",
    "serve_prefill_tokens_total",
    "serve_prefills_total",
    "serve_queue_depth",
    "serve_recompute_tokens_total",
    "serve_rejected_queue_full_total",
    "serve_requests_finished_total",
    "serve_slots",
    "serve_spec_accepted_draft_tokens_total",
    "serve_spec_draft_tokens_total",
    "serve_spec_k",
    "serve_spec_ticks_total",
    "serve_tick_seconds",
    "serve_ticks_total",
    "serve_trace_dropped_total",
    "serve_trace_events",
]


def test_golden_snapshot_schema(cfg, params):
    reg = MetricsRegistry()
    eng = ServeEngine(
        cfg, params, slots=2, max_len=32, pool="paged", page_size=8,
        num_pages=5, prefill_chunk=4, admission="incremental", spec_k=2,
        faults=FaultInjector(seed=3, rates={"pool.alloc": 0.0}),
        sampling=SamplingParams(), registry=reg, replica=0, seed=0)
    router = Router([eng])
    assert reg.names() == GOLDEN_FAMILIES
    snap = router.telemetry()
    assert snap["schema"] == "repro.serve/telemetry-1"
    assert set(snap) == {"schema", "summary", "metrics"}
    assert snap["metrics"]["schema"] == SNAPSHOT_SCHEMA
    assert sorted(snap["metrics"]["metrics"]) == GOLDEN_FAMILIES
    for name, fam in snap["metrics"]["metrics"].items():
        assert fam["type"] in ("counter", "gauge", "histogram"), name
        assert fam["samples"], f"{name} has no samples"
    # per-site fault families carry the site label
    sites = {s["labels"]["site"] for s in
             snap["metrics"]["metrics"]["serve_fault_calls_total"]["samples"]}
    assert sites == {"pool.alloc", "engine.tick"}
    # the doc is pure JSON
    json.dumps(snap)


# ---------------------------------------------------------------------------
# Acceptance: preempted-request timeline through the router
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def preempt_trace(cfg, params):
    """Page-starved speculative run through a one-replica Router; returns
    (tracer, registry, engine, results)."""
    reg = MetricsRegistry()
    tr = Tracer()
    eng = ServeEngine(
        cfg, params, slots=2, max_len=32, pool="paged", page_size=8,
        num_pages=5, prefill_chunk=4, admission="incremental", spec_k=2,
        sampling=SamplingParams(), tracer=tr, registry=reg, replica=0,
        seed=0)
    router = Router([eng], tracer=tr, registry=reg)
    with router:
        futs = [router.submit(Request(prompt=list(range(1, 6)),
                                      max_new_tokens=14))
                for _ in range(2)]
        results = [f.result(timeout=300) for f in futs]
    return tr, reg, eng, results


def test_preempted_request_timeline_reconstructs(preempt_trace):
    tr, reg, eng, results = preempt_trace
    assert eng.metrics.preempted >= 1, "geometry must force a preemption"
    assert eng.metrics.draft_tokens > 0, "speculation must have run"
    doc = tr.chrome_trace()
    events = validate_chrome_trace(doc)

    # find the preempted request's lane
    pre = [e for e in events if e["name"] == "preempt"]
    assert pre, "no preempt event in trace"
    lane = [e for e in events
            if e["tid"] == pre[0]["tid"] and e["pid"] == pre[0]["pid"]]
    lane.sort(key=lambda e: (e["ts"], -e.get("dur", 0.0)))
    names = [e["name"] for e in lane]
    rid = pre[0]["args"]["rid"]
    assert all(e["args"]["rid"] == rid for e in lane)

    # complete lifecycle, in order: first admission ...
    i_queue, i_admit = names.index("queue"), names.index("admit")
    i_pre = names.index("preempt")
    assert i_queue < i_admit < i_pre
    assert any(n.startswith("prefill_chunk[") for n in names[:i_pre])
    # ... preempted mid-flight, then REQUEUED: a second queue span whose
    # admission recomputes the lost prefix ...
    tail = names[i_pre + 1:]
    assert "queue" in tail and "admit" in tail
    j = i_pre + 1 + tail.index("queue")
    assert lane[j]["args"]["resume"] is True
    recompute = [e for e in lane[i_pre + 1:]
                 if e["name"].startswith("prefill_chunk[")]
    assert recompute and all(e["args"]["recompute"] for e in recompute)
    # ... and runs to completion
    assert names[-1] == "finish"
    assert lane[-1]["args"]["new_tokens"] == 14

    # speculative spans live on the engine lane
    engine_lane = {e["name"] for e in events if e["tid"] == TRACK_ENGINE}
    assert {"tick", "spec_draft", "spec_verify", "grow_pages",
            "compile"} <= engine_lane

    # lanes are labelled for Perfetto
    meta = {(e["name"], e.get("args", {}).get("name"))
            for e in doc["traceEvents"] if e["ph"] == "M"}
    assert ("thread_name", f"req {rid}") in meta
    assert ("thread_name", "engine") in meta

    # both requests produced identical tokens (tracing never perturbs)
    assert results[0].tokens == results[1].tokens


def test_compile_cache_emits_structured_events(preempt_trace):
    tr, reg, eng, _ = preempt_trace
    events = eng.compile_cache.events
    assert len(events) == eng.compile_cache.compiles > 0
    for ev in events:
        assert set(ev) == {"key", "seconds"}
        assert isinstance(ev["key"], str) and ev["seconds"] >= 0
    spans = [e for e in tr.events() if e["name"] == "compile"]
    assert len(spans) == len(events)
    assert all(s["args"]["key"] == ev["key"]
               for s, ev in zip(spans, events))
    snap = reg.snapshot()["metrics"]
    got = snap["serve_compiles_total"]["samples"][0]["value"]
    assert got == eng.compile_cache.compiles


def test_reset_metrics_rebases_pool_hwm_and_clears_trace(preempt_trace):
    """Regression: reset_metrics() used to re-import the pool's surviving
    high-water mark through sync_pool, so `pages_hwm` (and the tracer
    ring) survived a reset. After a drained run + reset, the pool stats
    must rebase to current occupancy and the ring must be empty."""
    tr, reg, eng, _ = preempt_trace
    before = eng.metrics.snapshot()
    assert before["pool"]["pages_hwm"] > 0
    assert len(tr) > 0
    eng.reset_metrics()
    after = eng.metrics.snapshot()
    assert after["pool"]["pages_hwm"] == after["pool"]["pages_in_use"] == 0
    assert after["preempted"] == 0 and after["requests_finished"] == 0
    assert len(tr) == 0 and tr.dropped == 0
    # registry callbacks read through the engine: post-reset they report
    # the fresh EngineMetrics, not the old object
    snap = reg.snapshot()["metrics"]
    assert snap["serve_preempted_total"]["samples"][0]["value"] == 0
    assert snap["serve_pages_hwm"]["samples"][0]["value"] == 0
    # track names were re-registered after clear() wiped them
    meta = [e for e in tr.chrome_trace()["traceEvents"] if e["ph"] == "M"]
    assert any(e["name"] == "process_name" for e in meta)


# ---------------------------------------------------------------------------
# Profiling hooks
# ---------------------------------------------------------------------------

def test_annotate_gates_on_execution_context(monkeypatch):
    from repro.kernels.context import ExecutionContext, use_execution

    monkeypatch.delenv("REPRO_PROFILE", raising=False)
    assert not profiling_enabled()
    # off: the shared nullcontext, no jax.profiler import
    assert annotate("x") is annotate("y")
    with use_execution(ExecutionContext(profile=True)):
        assert profiling_enabled()
        cm = annotate("butterfly_matmul")
        assert cm is not annotate.__globals__["_NULL"]
        with cm:  # TraceAnnotation works outside an active profiler
            pass
        # explicit ctx wins over ambient
        assert not profiling_enabled(ExecutionContext(profile=False))
    monkeypatch.setenv("REPRO_PROFILE", "1")
    assert profiling_enabled()
    # a set ctx.profile beats the env fallback
    assert not profiling_enabled(ExecutionContext(profile=False))


def test_profiled_kernel_result_unchanged(cfg, params):
    import numpy as np

    from repro.kernels.context import ExecutionContext, use_execution
    from repro.models import lm

    tokens = np.arange(1, 7, dtype=np.int32)[None, :]
    caches = lm.init_caches(cfg, 1, 16)
    logits, _ = lm.prefill(cfg, params, {"tokens": tokens}, caches)
    with use_execution(ExecutionContext(profile=True)):
        caches2 = lm.init_caches(cfg, 1, 16)
        logits2, _ = lm.prefill(cfg, params, {"tokens": tokens}, caches2)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits2))
