"""repro.nn.ButterflyLinear / SandwichLinear — the drop-in module facade.

Acceptance gate: the module's forward AND gradients match the functional
``butterfly_linear_apply`` at atol 1e-5 on the jnp and pallas_interpret
backends, including non-power-of-two (n_in, n_out); plus ``from_dense``
distillation (Proposition 3.1), the context layering of the module default,
and the bounded selection-matrix cache surviving jit retraces.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.core import layers as bl
from repro.kernels.context import ExecutionContext, use_execution

BACKENDS = ["jnp", "pallas_interpret"]

# (64, 64) is the pure power-of-two path; (48, 80) and (100, 36) exercise
# the ButterflySpec pad logic on both sides (pad to 64/128 resp.)
DIMS = [(64, 64), (48, 80), (100, 36)]


def _tol(backend):
    # interpret mode accumulates the same math in a different order
    return dict(rtol=1e-5, atol=1e-5) if backend == "jnp" else \
        dict(rtol=1e-4, atol=2e-4)


def _assert_close(got, want, backend):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(backend))


@pytest.mark.parametrize("n_in,n_out", DIMS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_forward_matches_functional_layer(n_in, n_out, backend):
    layer = nn.ButterflyLinear.create(jax.random.PRNGKey(0), n_in, n_out,
                                      use_bias=True)
    params = layer.init(jax.random.PRNGKey(1))
    params["bias"] = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (n_out,))
    x = jax.random.normal(jax.random.PRNGKey(3), (7, n_in))
    got = layer.apply(params, x, context=backend)
    want = bl.butterfly_linear_apply(layer.spec, params, x, context=backend)
    assert got.shape == (7, n_out)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)  # same code path
    # and across backends the layer agrees with the jnp oracle at 1e-5/2e-4
    _assert_close(got, layer.apply(params, x, context="jnp"), backend)


@pytest.mark.parametrize("n_in,n_out", [(64, 64), (48, 80)])
@pytest.mark.parametrize("backend", BACKENDS)
def test_grads_match_functional_layer(n_in, n_out, backend):
    layer = nn.ButterflyLinear.create(jax.random.PRNGKey(4), n_in, n_out,
                                      use_bias=True)
    params = layer.init(jax.random.PRNGKey(5))
    x = jax.random.normal(jax.random.PRNGKey(6), (5, n_in))
    c = jax.random.normal(jax.random.PRNGKey(7), (5, n_out))

    def mod_loss(p, x):
        return jnp.vdot(c, layer.apply(p, x, context=backend))

    def fn_loss(p, x):
        return jnp.vdot(c, bl.butterfly_linear_apply(
            layer.spec, p, x, context="jnp"))

    gp, gx = jax.grad(mod_loss, argnums=(0, 1))(params, x)
    gp_o, gx_o = jax.grad(fn_loss, argnums=(0, 1))(params, x)
    _assert_close(gx, gx_o, backend)
    for k in gp_o:
        _assert_close(gp[k], gp_o[k], backend)


def test_callable_and_introspection():
    layer = nn.ButterflyLinear.create(jax.random.PRNGKey(8), 100, 36,
                                      use_bias=False)
    params = layer.init(jax.random.PRNGKey(9))
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 100))
    np.testing.assert_allclose(np.asarray(layer(params, x)),
                               np.asarray(layer.apply(params, x)))
    assert (layer.n_in, layer.n_out) == (100, 36)
    assert layer.param_count() < layer.dense_param_count()
    W = layer.to_dense(params)
    assert W.shape == (36, 100)
    np.testing.assert_allclose(
        np.asarray(layer.apply(params, x, context="jnp")),
        np.asarray(x @ W.T), rtol=1e-4, atol=1e-4)


def test_from_dense_matches_functional_init():
    """from_dense(W) is exactly the functional init_from_dense path: same
    spec key -> same truncation indices, same init key -> same FJLT
    butterflies and the Prop. 3.1 core ``W' = J2 W J1ᵀ``, plus the bias."""
    n_out, n_in = 36, 100                       # non-power-of-two distill
    rng = np.random.default_rng(0)
    W = (rng.normal(size=(n_out, n_in)) / np.sqrt(n_in)).astype(np.float32)
    b = rng.normal(size=(n_out,)).astype(np.float32)
    key = jax.random.PRNGKey(11)
    layer, params = nn.ButterflyLinear.from_dense(
        key, jnp.asarray(W), bias=jnp.asarray(b), k_in=16, k_out=16)
    assert layer.spec.use_bias and "bias" in params
    assert (layer.n_in, layer.n_out) == (n_in, n_out)

    k_spec, k_init = jax.random.split(key)
    ref = nn.ButterflyLinear.create(k_spec, n_in, n_out, k_in=16, k_out=16,
                                    use_bias=True)
    assert ref.spec == layer.spec
    want = bl.init_from_dense(k_init, ref.spec, jnp.asarray(W))
    for k in ("b_in", "b_out", "core"):
        np.testing.assert_allclose(np.asarray(params[k]),
                                   np.asarray(want[k]), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(params["bias"]), b)
    # the materialized dense equivalent realizes the Prop. 3.1 core exactly
    J2WJ1 = layer.to_dense(params)
    assert J2WJ1.shape == (n_out, n_in)
    x = jax.random.normal(jax.random.PRNGKey(12), (4, n_in))
    np.testing.assert_allclose(
        np.asarray(layer.apply(params, x, context="jnp")),
        np.asarray(x @ J2WJ1.T + b), rtol=1e-4, atol=1e-4)


def test_sandwich_linear_requires_explicit_core_dims():
    layer = nn.SandwichLinear.create(jax.random.PRNGKey(14), 48, 80,
                                     k_in=12, k_out=10, use_bias=False)
    assert (layer.spec.k_in, layer.spec.k_out) == (12, 10)
    params = layer.init(jax.random.PRNGKey(15))
    assert params["core"].shape == (10, 12)
    x = jax.random.normal(jax.random.PRNGKey(16), (3, 48))
    assert layer.apply(params, x).shape == (3, 80)
    with pytest.raises(TypeError, match="explicit"):
        nn.SandwichLinear.create(jax.random.PRNGKey(17), 48, 80)


def test_module_context_layering():
    """The layer default sits at the config layer: ambient use_execution and
    per-call context both override it; with neither, it applies."""
    layer = nn.ButterflyLinear.create(jax.random.PRNGKey(18), 32, 32,
                                      use_bias=False,
                                      context=ExecutionContext(backend="jnp"))
    params = layer.init(jax.random.PRNGKey(19))
    x = jax.random.normal(jax.random.PRNGKey(20), (4, 32))
    want = layer.apply(params, x)
    # per-call override
    got = layer.apply(params, x, context="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=2e-4)
    # ambient override wins over the module default too
    with use_execution(ExecutionContext(backend="pallas_interpret")):
        got2 = layer.apply(params, x)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(got),
                               rtol=1e-6, atol=1e-6)


def test_selection_cache_is_bounded_and_survives_retracing():
    """Satellite: the one-hot selection-matrix cache must be bounded and a
    re-trace of the same spec must HIT it (the matrices are jit-time
    constants; a miss per retrace would rebuild two dense (k, N) arrays)."""
    assert bl._selection_matrices.cache_info().maxsize \
        == bl.SELECTION_CACHE_SIZE

    layer = nn.ButterflyLinear.create(jax.random.PRNGKey(21), 32, 32,
                                      use_bias=False)
    params = layer.init(jax.random.PRNGKey(22))
    bl._selection_matrices.cache_clear()

    @jax.jit
    def f1(p, x):
        return layer.apply(p, x, context="pallas_interpret")

    @jax.jit
    def f2(p, x):  # a distinct jit -> a fresh trace of the same spec
        return layer.apply(p, x, context="pallas_interpret") * 2.0

    x = jax.random.normal(jax.random.PRNGKey(23), (4, 32))
    f1(p=params, x=x)
    info1 = bl._selection_matrices.cache_info()
    assert info1.misses == 1
    f2(p=params, x=x)
    info2 = bl._selection_matrices.cache_info()
    assert info2.misses == 1 and info2.hits > info1.hits
