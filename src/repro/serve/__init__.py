"""``repro.serve`` — continuous-batching inference for the butterfly LMs.

    from repro.serve import ServeEngine, ServeClient, SamplingParams, loader

    cfg = registry.get("smollm-135m-smoke")
    step, params = loader.load_for_serving(cfg, checkpoint_dir)
    engine = ServeEngine(cfg, params, slots=4, max_len=128)
    with ServeClient(engine) as client:
        fut = client.submit([1, 2, 3], max_new_tokens=16)
        print(fut.result().tokens)

See :mod:`repro.serve.engine` for the tick-loop / bucketing / compile-cache
design, and ``python -m repro.launch.serve --help`` for the workload-replay
CLI.
"""

from repro.serve import loader, metrics, sampling
from repro.serve.client import ServeClient
from repro.serve.engine import (CompileCache, GenerationResult, Request,
                                ServeEngine)
from repro.serve.metrics import EngineMetrics, RequestMetrics
from repro.serve.sampling import GREEDY, SamplingParams, sample_logits

__all__ = [
    "ServeEngine", "ServeClient", "CompileCache", "Request",
    "GenerationResult", "EngineMetrics", "RequestMetrics",
    "SamplingParams", "GREEDY", "sample_logits",
    "loader", "metrics", "sampling",
]
