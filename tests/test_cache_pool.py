"""`repro.serve.cache`: the CachePool API and the paged page allocator.

The allocator is pure host-side Python, so these tests are exact and fast:
deterministic FIFO alloc/free/recycle order, typed
:class:`~repro.serve.cache.PoolExhausted` backpressure, trash-page
invariants on the table, and a full randomized trace replay proving no
page is ever leaked or double-owned.
"""

import numpy as np
import pytest

from repro.configs import registry
from repro.kernels.paged_attention import TRASH_PAGE
from repro.serve import cache as cache_lib
from repro.serve.cache import (DenseCachePool, PagedCachePool, PoolExhausted,
                               make_pool)

ARCH = "smollm-135m-smoke"


@pytest.fixture(scope="module")
def cfg():
    return registry.get(ARCH)


def _pool(cfg, slots=4, max_len=64, page_size=8, num_pages=None):
    return PagedCachePool(cfg, slots, max_len, page_size=page_size,
                          num_pages=num_pages)


# ---------------------------------------------------------------------------
# Allocator determinism
# ---------------------------------------------------------------------------

def test_pages_allocate_in_ascending_order_from_fresh_pool(cfg):
    pool = _pool(cfg)                      # default: 4*8+1 = 33 pages
    assert pool.total_pages == 4 * 8 + 1
    assert pool.free_list() == tuple(range(1, 33))    # page 0 reserved
    pool.alloc_pages(0, 20)                # ceil(20/8) = 3 pages
    assert list(pool._table[0, :3]) == [1, 2, 3]
    assert (pool._table[0, 3:] == TRASH_PAGE).all()
    pool.alloc_pages(1, 1)
    assert pool._table[1, 0] == 4
    assert pool.pages_in_use == 4 and pool.pages_hwm == 4


def test_alloc_is_incremental_growth(cfg):
    """alloc_pages(slot, n) tops the slot up to cover n positions — the
    engine calls it once with the whole budget, but growth is legal and
    never re-allocates already-owned pages."""
    pool = _pool(cfg)
    pool.alloc_pages(0, 8)                 # 1 page
    pool.alloc_pages(0, 9)                 # +1 page
    pool.alloc_pages(0, 9)                 # no-op
    assert list(pool._table[0, :2]) == [1, 2] and pool.pages_in_use == 2


def test_free_recycles_fifo(cfg):
    """Pages recycle in the order they were freed, so two replays of the
    same trace produce identical page tables — determinism the parity
    tests implicitly rely on."""
    pool = _pool(cfg, num_pages=7)         # 6 usable
    pool.alloc_pages(0, 16)                # pages 1, 2
    pool.alloc_pages(1, 16)                # pages 3, 4
    pool.free(0)                           # free list: 5, 6, 1, 2
    assert pool.free_list() == (5, 6, 1, 2)
    pool.alloc_pages(2, 24)                # pages 5, 6, 1
    assert list(pool._table[2, :3]) == [5, 6, 1]
    assert (pool._table[0] == TRASH_PAGE).all()
    assert pool.pages_hwm == 5             # 3 + the earlier HWM of 4 -> 5


def test_replay_determinism(cfg):
    def run():
        pool = _pool(cfg, num_pages=9)
        tables = []
        pool.alloc_pages(0, 10)
        pool.alloc_pages(1, 20)
        pool.free(0)
        pool.alloc_pages(2, 30)
        tables.append(pool._table.copy())
        pool.free(1)
        pool.alloc_pages(3, 12)
        tables.append(pool._table.copy())
        return tables, pool.free_list()
    a, fa = run()
    b, fb = run()
    assert fa == fb
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# Typed backpressure
# ---------------------------------------------------------------------------

def test_pool_exhausted_is_typed_and_non_destructive(cfg):
    pool = _pool(cfg, num_pages=4)         # 3 usable
    pool.alloc_pages(0, 16)                # 2 pages
    before = (pool.free_list(), pool._table.copy())
    with pytest.raises(PoolExhausted, match="free pages"):
        pool.alloc_pages(1, 16)            # needs 2, only 1 left
    # a failed allocation must not consume pages or touch any table row
    assert pool.free_list() == before[0]
    np.testing.assert_array_equal(pool._table, before[1])
    assert isinstance(PoolExhausted("x"), RuntimeError)


def test_over_table_request_raises_even_with_free_pages(cfg):
    pool = _pool(cfg, slots=2, max_len=16, page_size=8, num_pages=64)
    with pytest.raises(PoolExhausted, match="positions"):
        pool.alloc_pages(0, 17)            # table row holds ceil(16/8)=2


def test_dense_pool_budget_check(cfg):
    pool = DenseCachePool(cfg, slots=2, max_len=32)
    pool.alloc_pages(0, 32)                # fits: no-op
    with pytest.raises(PoolExhausted, match="positions"):
        pool.alloc_pages(0, 33)


# ---------------------------------------------------------------------------
# No leaks across a full randomized trace replay
# ---------------------------------------------------------------------------

def test_no_page_leaked_or_double_owned_across_trace(cfg):
    """Randomized admission/finish trace: after every event, the owned
    sets are disjoint, owned + free covers exactly the usable pages, and
    every table entry matches ownership; after the final drain the free
    list holds every usable page exactly once."""
    pool = _pool(cfg, slots=4, max_len=64, page_size=8, num_pages=17)
    rng = np.random.default_rng(0)
    live = {}

    def check():
        owned = [p for pages in pool._owned for p in pages]
        assert len(owned) == len(set(owned)), "double-owned page"
        assert TRASH_PAGE not in owned
        universe = set(range(1, pool.total_pages))
        assert set(owned) | set(pool.free_list()) == universe
        assert len(owned) + len(pool.free_list()) == len(universe)
        for s in range(4):
            row = pool._table[s]
            assert list(row[:len(pool._owned[s])]) == pool._owned[s]
            assert (row[len(pool._owned[s]):] == TRASH_PAGE).all()

    for _ in range(200):
        if live and (len(live) == 4 or rng.random() < 0.5):
            slot = rng.choice(sorted(live))
            pool.free(int(slot))
            del live[slot]
        else:
            slot = next(s for s in range(4) if s not in live)
            try:
                pool.alloc_pages(slot, int(rng.integers(1, 65)))
                live[slot] = True
            except PoolExhausted:
                pass                       # backpressure, state untouched
        check()
    for slot in sorted(live):
        pool.free(int(slot))
    check()
    assert pool.pages_in_use == 0
    assert sorted(pool.free_list()) == list(range(1, pool.total_pages))


# ---------------------------------------------------------------------------
# Geometry, factory, capability predicates
# ---------------------------------------------------------------------------

def test_pool_geometry_and_pages_for(cfg):
    pool = _pool(cfg, slots=3, max_len=20, page_size=8)
    assert pool.pages_per_slot == 3        # ceil(20/8)
    assert pool.total_pages == 3 * 3 + 1   # + trash page
    assert [pool.pages_for(n) for n in (1, 8, 9, 16, 17)] == [1, 1, 2, 2, 3]
    with pytest.raises(ValueError, match="page_size"):
        _pool(cfg, page_size=0)
    with pytest.raises(ValueError, match="num_pages"):
        _pool(cfg, num_pages=1)            # the trash page alone is not a pool


def test_make_pool_factory_and_fallbacks(cfg):
    assert make_pool(cfg, 2, 32, kind="paged").kind == "paged"
    assert make_pool(cfg, 2, 32, kind="dense").kind == "dense"
    # sequential-state archs silently fall back to dense under "paged"
    rcfg = registry.get("recurrentgemma-2b-smoke")
    assert make_pool(rcfg, 2, 32, kind="paged").kind == "dense"
    with pytest.raises(ValueError, match="rec"):
        PagedCachePool(rcfg, 2, 32)
    with pytest.raises(ValueError, match="pool kind"):
        make_pool(cfg, 2, 32, kind="ring")


def test_capability_predicates():
    assert cache_lib.paged_supported(registry.get(ARCH))
    assert cache_lib.chunked_prefill_supported(registry.get(ARCH))
    rcfg = registry.get("recurrentgemma-2b-smoke")
    assert not cache_lib.paged_supported(rcfg)
    assert not cache_lib.chunked_prefill_supported(rcfg)


def test_paged_spec_pools_kv_leaves(cfg):
    """Paged spec: self-attention KV leaves become ONE (num_pages, ps, KV,
    D) pool shared across slots (plus the unit-repeat stack axis), while
    the dense spec keeps per-slot max_len rows."""
    pool = _pool(cfg, slots=4, max_len=64, page_size=8)
    spec = pool.spec()
    k = spec["unit"][0]["self"]["k"]
    R = cfg.unit_repeats
    assert k.shape == (R, pool.total_pages, 8, cfg.n_kv_heads,
                       cfg.head_dim_)
    dk = DenseCachePool(cfg, 4, 64).spec()["unit"][0]["self"]["k"]
    assert dk.shape == (R, 4, 64, cfg.n_kv_heads, cfg.head_dim_)
