"""Sharded fused butterfly kernels on 8 simulated devices.

Parity gate for :mod:`repro.runtime.butterfly_sharding`, driven purely
through :class:`repro.kernels.context.ExecutionContext` (``mesh_shape``
builds the mesh; no loose kwargs anywhere): batch-sharded ``shard_map``
execution of ``butterfly_apply`` / ``sandwich_apply`` /
``butterfly_linear_apply`` — forward AND ``jax.grad`` (input + every weight
cotangent, psum'd across shards) — must match the single-device jnp oracle
to atol 1e-5, on ``("data",)`` and ``("pod", "data")`` meshes, for batch
sizes that do and do not divide the data-axis product. ``conftest.py``
provides the 8 simulated host devices.

Cost note: every case compiles an 8-way SPMD program (tens of seconds on
CPU), and the ``pallas_interpret`` cases additionally run the kernel bodies
in Python per shard. The full matrix is therefore slow-marked and enforced
by the CI multi-device step (which runs this file without ``-m``); the
tier-1 ``-m "not slow"`` pass keeps a single-compile smoke plus the pure
axis-resolution tests.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import butterfly as bf
from repro.core import layers as bl
from repro.kernels import ops as kops
from repro.kernels.context import ExecutionContext, use_execution
from repro.kernels.sandwich import one_hot_select
from repro.launch.mesh import simulated_mesh
from repro.runtime import butterfly_sharding as bsh

# fused-kernel backends exercised INSIDE the shard_map region; the oracle
# side is always the single-device jnp reference. Interpret mode executes
# the exact Pallas kernel bodies (fwd + the fused custom_vjp bwd), which is
# what validates the TPU-target kernels under shard_map without hardware.
BACKENDS = ["jnp", "pallas_interpret"]

# 16 divides the 8-way data axis; 11 pads to 16 and exercises the zero-pad
# rows (forward slice + zero cotangents in backward)
BATCHES = [16, 11]

# (8,) -> ("data",) mesh; (2, 4) -> ("pod", "data") — both 8 devices, both
# built by the context itself (launch.mesh.butterfly_mesh)
MESH_SHAPES = [(8,), (2, 4)]
MESH_IDS = ["data8", "pod2xdata4"]

slow = pytest.mark.slow


def _ctx(backend, mesh_shape) -> ExecutionContext:
    return ExecutionContext(backend=backend, mesh_shape=mesh_shape)


def _assert_close(got, want, atol=1e-5):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=atol)


def _grads(loss, *args):
    return jax.grad(loss, argnums=tuple(range(len(args))))(*args)


def _butterfly_case(mesh_shape, batch, backend, transpose, n=64):
    ctx = _ctx(backend, mesh_shape)
    w = bf.random_weights(jax.random.PRNGKey(0), n)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, n))
    c = jax.random.normal(jax.random.PRNGKey(2), (batch, n))

    def sharded(x, w):
        return jnp.vdot(c, kops.butterfly_apply(
            x, w, transpose=transpose, context=ctx))

    def oracle(x, w):
        return jnp.vdot(c, kops.butterfly_apply(
            x, w, transpose=transpose, context="jnp"))

    y_sh = kops.butterfly_apply(x, w, transpose=transpose, context=ctx)
    y_o = kops.butterfly_apply(x, w, transpose=transpose, context="jnp")
    assert y_sh.shape == (batch, n)
    _assert_close(y_sh, y_o)

    gx_sh, gw_sh = _grads(sharded, x, w)
    gx_o, gw_o = _grads(oracle, x, w)
    _assert_close(gx_sh, gx_o)
    _assert_close(gw_sh, gw_o)


# ---------------------------------------------------------------------------
# tier-1 smoke: one compile on the ("data",) mesh, non-divisible batch
# ---------------------------------------------------------------------------

def test_sharded_butterfly_smoke():
    _butterfly_case((8,), batch=11, backend="jnp", transpose=False, n=32)


# ---------------------------------------------------------------------------
# butterfly_apply — full matrix (CI multi-device step)
# ---------------------------------------------------------------------------

@slow
@pytest.mark.parametrize("mesh_shape", MESH_SHAPES, ids=MESH_IDS)
@pytest.mark.parametrize("batch", BATCHES)
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("transpose", [False, True])
def test_sharded_butterfly_parity(mesh_shape, batch, backend, transpose):
    _butterfly_case(mesh_shape, batch, backend, transpose)


@slow
def test_sharded_butterfly_nd_batch():
    """Leading axes flatten into the sharded batch and are restored."""
    n = 32
    ctx = _ctx("jnp", (8,))
    w = bf.random_weights(jax.random.PRNGKey(3), n)
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 5, n))  # 15 rows: pads
    y_sh = kops.butterfly_apply(x, w, context=ctx)
    y_o = kops.butterfly_apply(x, w, context="jnp")
    assert y_sh.shape == x.shape
    _assert_close(y_sh, y_o)


@slow
def test_sharded_butterfly_under_jit_ambient_context():
    """An ambient use_execution block shards a jitted loss — no per-call
    kwargs at all."""
    n = 32
    ctx = _ctx("jnp", (8,))
    w = bf.random_weights(jax.random.PRNGKey(5), n)
    x = jax.random.normal(jax.random.PRNGKey(6), (11, n))

    @jax.jit
    def loss(x, w):
        with use_execution(ctx):
            return jnp.sum(kops.butterfly_apply(x, w) ** 2)

    want = jnp.sum(kops.butterfly_apply(x, w, context="jnp") ** 2)
    _assert_close(loss(x, w), want, atol=1e-4)
    gx = jax.jit(jax.grad(loss))(x, w)
    gx_o = jax.grad(lambda x: jnp.sum(kops.butterfly_apply(
        x, w, context="jnp") ** 2))(x)
    _assert_close(gx, gx_o, atol=1e-4)


# ---------------------------------------------------------------------------
# sandwich_apply — ("data",) matrix + one ("pod", "data") case; the
# multi-axis psum machinery is shared with the butterfly tests above
# ---------------------------------------------------------------------------

def _sandwich_case(mesh_shape, batch, backend):
    ctx = _ctx(backend, mesh_shape)
    n1, n2, k1, k2 = 32, 64, 8, 6
    spec = bl.make_spec(jax.random.PRNGKey(7), n1, n2, k_in=k1, k_out=k2,
                        use_bias=False)
    params = bl.init_butterfly_linear(jax.random.PRNGKey(8), spec)
    sel_in = one_hot_select(spec.idx_in, n1)
    sel_out = one_hot_select(spec.idx_out, n2).T
    x = jax.random.normal(jax.random.PRNGKey(9), (batch, n1))
    c = jax.random.normal(jax.random.PRNGKey(10), (batch, n2))

    def call(x, b_in, core, b_out, **kw):
        return kops.sandwich_apply(x, b_in, sel_in, core, sel_out, b_out,
                                   scale_in=1.5, scale_out=0.5, **kw)

    def sharded(x, b_in, core, b_out):
        return jnp.vdot(c, call(x, b_in, core, b_out, context=ctx))

    def oracle(x, b_in, core, b_out):
        return jnp.vdot(c, call(x, b_in, core, b_out, context="jnp"))

    args = (x, params["b_in"], params["core"], params["b_out"])
    _assert_close(call(*args, context=ctx), call(*args, context="jnp"))
    for g_sh, g_o in zip(_grads(sharded, *args), _grads(oracle, *args)):
        _assert_close(g_sh, g_o)


@slow
@pytest.mark.parametrize("batch", BATCHES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_sandwich_parity(batch, backend):
    _sandwich_case((8,), batch, backend)


@slow
def test_sharded_sandwich_pod_data_mesh():
    _sandwich_case((2, 4), 11, "jnp")


# ---------------------------------------------------------------------------
# butterfly_linear_apply (whole layer: padding + kernel + bias in-region)
# ---------------------------------------------------------------------------

def _linear_case(mesh_shape, batch, backend):
    ctx = _ctx(backend, mesh_shape)
    n_in, n_out = 48, 80  # non-power-of-two: exercises in-region padding
    spec = bl.make_spec(jax.random.PRNGKey(11), n_in, n_out, use_bias=True)
    params = bl.init_butterfly_linear(jax.random.PRNGKey(12), spec)
    params["bias"] = 0.1 * jax.random.normal(jax.random.PRNGKey(13),
                                             (n_out,))
    x = jax.random.normal(jax.random.PRNGKey(14), (batch, n_in))
    c = jax.random.normal(jax.random.PRNGKey(15), (batch, n_out))

    def sharded(params, x):
        return jnp.vdot(c, bl.butterfly_linear_apply(
            spec, params, x, context=ctx))

    def oracle(params, x):
        return jnp.vdot(c, bl.butterfly_linear_apply(
            spec, params, x, context="jnp"))

    y_sh = bl.butterfly_linear_apply(spec, params, x, context=ctx)
    y_o = bl.butterfly_linear_apply(spec, params, x, context="jnp")
    assert y_sh.shape == (batch, n_out)
    _assert_close(y_sh, y_o)

    (gp_sh, gx_sh) = _grads(sharded, params, x)
    (gp_o, gx_o) = _grads(oracle, params, x)
    _assert_close(gx_sh, gx_o)
    for k in gp_o:
        _assert_close(gp_sh[k], gp_o[k])


@slow
@pytest.mark.parametrize("batch", BATCHES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_sharded_linear_apply_parity(batch, backend):
    _linear_case((8,), batch, backend)


@slow
def test_sharded_linear_apply_pod_data_mesh():
    _linear_case((2, 4), 11, "jnp")


# ---------------------------------------------------------------------------
# repro.nn module API through the same sharded context
# ---------------------------------------------------------------------------

@slow
def test_sharded_nn_butterfly_linear():
    """ButterflyLinear.apply under a mesh context == its single-device
    self — the module facade rides the exact same sharded path."""
    from repro import nn

    layer = nn.ButterflyLinear.create(jax.random.PRNGKey(30), 48, 80,
                                      use_bias=True)
    params = layer.init(jax.random.PRNGKey(31))
    x = jax.random.normal(jax.random.PRNGKey(32), (11, 48))
    ctx = _ctx("jnp", (8,))
    _assert_close(layer.apply(params, x, context=ctx),
                  layer.apply(params, x, context="jnp"))


# ---------------------------------------------------------------------------
# encdec apply_B: shards the transposed product's leading dim (the d data
# COLUMNS of X, not its n rows) — gate that orientation explicitly
# ---------------------------------------------------------------------------

@slow
def test_sharded_encdec_apply_b_parity():
    from repro.core import encdec

    ctx = _ctx("jnp", (8,))
    spec = encdec.make_spec(jax.random.PRNGKey(18), n=50, d=22, k=4)
    params = encdec.init_params(jax.random.PRNGKey(19), spec)
    X = jax.random.normal(jax.random.PRNGKey(20), (50, 22))  # d=22 pads

    Xt_sh = encdec.apply_B(spec, params["B"], X, context=ctx)
    Xt_o = encdec.apply_B(spec, params["B"], X, context="jnp")
    assert Xt_sh.shape == (spec.ell, 22)
    _assert_close(Xt_sh, Xt_o)

    def loss(p, context="jnp"):
        return encdec.loss_fn(spec, p, X, X, context=context)

    _assert_close(loss(params, context=ctx), loss(params), atol=1e-3)
    g_sh = jax.grad(lambda p: loss(p, context=ctx))(params)
    g_o = jax.grad(loss)(params)
    for k in g_o:
        _assert_close(g_sh[k], g_o[k], atol=1e-4)


# ---------------------------------------------------------------------------
# axis resolution / degenerate meshes (cheap, tier-1)
# ---------------------------------------------------------------------------

def test_data_axes_resolution():
    mesh = simulated_mesh(8)
    assert bsh.data_axes(mesh) == ("data",)
    assert bsh.data_axes(mesh, ("data",)) == ("data",)
    assert bsh.data_axes(mesh, ("model",)) == ()
    assert bsh.data_axes(None) == ()
    pd = simulated_mesh(8, ("pod", "data"), (2, 4))
    assert bsh.data_axes(pd) == ("pod", "data")
    assert bsh.shard_count(pd, ("pod", "data")) == 8


def test_trivial_mesh_falls_back_to_local_path():
    """A context whose mesh has no data axes > 1 must not emit shard_map."""
    n = 32
    ctx = ExecutionContext(backend="jnp", mesh=simulated_mesh(1, ("data",),
                                                              (1,)))
    w = bf.random_weights(jax.random.PRNGKey(16), n)
    x = jax.random.normal(jax.random.PRNGKey(17), (5, n))
    assert bsh.data_axes(ctx.mesh) == ()
    y = kops.butterfly_apply(x, w, context=ctx)
    _assert_close(y, kops.butterfly_apply(x, w, context="jnp"))


@slow
def test_mesh_axes_restriction_in_context():
    """ExecutionContext.mesh_axes limits which axes shard: restricting the
    pod2xdata4 mesh to ("data",) still matches the oracle (4-way shard)."""
    n = 32
    ctx = ExecutionContext(backend="jnp", mesh_shape=(2, 4),
                           mesh_axes=("data",))
    w = bf.random_weights(jax.random.PRNGKey(21), n)
    x = jax.random.normal(jax.random.PRNGKey(22), (10, n))
    y = kops.butterfly_apply(x, w, context=ctx)
    _assert_close(y, kops.butterfly_apply(x, w, context="jnp"))
