"""End-to-end LM training driver.

Run: ``PYTHONPATH=src python examples/train_lm.py --arch smollm-135m-smoke \
      --steps 200``

Full pipeline: config registry → synthetic data stream with prefetch →
microbatched AdamW training → async checkpoints → resume. ``--butterfly``
swaps the LM head + MLP for the paper's sandwich (§3.2/§5.1). The full-size
assigned configs run through the same driver on a real cluster; on this CPU
container use the ``*-smoke`` variants (the default trains a ~10M-param
smollm-family model for a few hundred steps).
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m-smoke")
    ap.add_argument("--butterfly", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint-dir", default="")
    args = ap.parse_args()

    from repro.configs import registry
    from repro.configs.base import TrainConfig
    from repro.train.trainer import Trainer

    name = args.arch
    if args.butterfly:
        base = name[:-6] if name.endswith("-smoke") else name
        name = base + "-butterfly" + ("-smoke" if name.endswith("-smoke")
                                      else "")
    cfg = registry.get(name)
    ckpt = args.checkpoint_dir or tempfile.mkdtemp(prefix="repro_ckpt_")
    tc = TrainConfig(learning_rate=args.lr, warmup_steps=20,
                     total_steps=args.steps, microbatches=args.microbatches,
                     checkpoint_every=max(args.steps // 4, 1),
                     checkpoint_dir=ckpt)
    print(f"training {cfg.name}: {args.steps} steps, "
          f"seq={args.seq_len}, batch={args.global_batch} "
          f"(checkpoints → {ckpt})")
    tr = Trainer(cfg, tc, seq_len=args.seq_len,
                 global_batch=args.global_batch)
    res = tr.run(args.steps)
    w = max(len(res.losses) // 10, 1)
    for i in range(0, len(res.losses), w):
        chunk = res.losses[i:i + w]
        print(f"  step {i:4d}: loss {np.mean(chunk):.4f}")
    print(f"final loss: {np.mean(res.losses[-5:]):.4f} "
          f"(from {np.mean(res.losses[:5]):.4f}); "
          f"median step time {np.median(res.step_times) * 1e3:.0f} ms")
    print("re-run with the same --checkpoint-dir to resume from the last "
          "checkpoint.")


if __name__ == "__main__":
    main()
