"""jax version-compat shims shared across the runtime modules.

jax >= 0.6 promotes ``shard_map`` to the top level and renames
``check_rep`` -> ``check_vma``; older jax keeps it in ``jax.experimental``.
Both callers (``runtime.pipeline``, ``runtime.butterfly_sharding``) disable
the replication check on purpose: the pipeline's output psum breaks
per-shard replication tracking by construction, and the butterfly wrapper
psums its weight gradients explicitly so their semantics never depend on
the check's behavior.
"""

from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    def shard_map_compat(f, *, mesh, in_specs, out_specs):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:
    from jax.experimental.shard_map import shard_map as _exp_shard_map

    def shard_map_compat(f, *, mesh, in_specs, out_specs):
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=False)


__all__ = ["shard_map_compat"]
