"""RG-LRU and xLSTM numerics: scan vs step vs chunkwise equivalences."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import rglru as rg
from repro.models import xlstm as xl
from repro.runtime import pytree as pt


def test_rglru_scan_matches_stepwise():
    cfg = registry.get("recurrentgemma-2b-smoke").with_(
        compute_dtype="float32")
    params = pt.init_params(jax.random.PRNGKey(0), rg.rglru_specs(cfg))
    B, S, R = 2, 12, cfg.lru_width_
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, R))
    hs, h_last = rg.rglru_scan(params, x)
    h = jnp.zeros((B, R), jnp.float32)
    outs = []
    for t in range(S):
        out, h = rg.rglru_step(params, x[:, t:t + 1], h)
        outs.append(out[:, 0])
    step_hs = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(step_hs),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h),
                               rtol=1e-4, atol=1e-5)


def test_rglru_decay_bounded():
    """a_t ∈ (0, 1] so the recurrence is stable by construction."""
    cfg = registry.get("recurrentgemma-2b-smoke")
    params = pt.init_params(jax.random.PRNGKey(2), rg.rglru_specs(cfg))
    x = 5.0 * jax.random.normal(jax.random.PRNGKey(3),
                                (1, 200, cfg.lru_width_))
    hs, _ = rg.rglru_scan(params, x)
    assert bool(jnp.isfinite(hs).all())


def test_mlstm_parallel_matches_recurrent():
    B, S, H, D = 2, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 2.0
    hs_par = xl.mlstm_parallel(q, k, v, ig, fg)
    hs_rec, _ = xl.mlstm_recurrent(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(hs_par), np.asarray(hs_rec),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mlstm_chunkwise_matches_recurrent(chunk):
    B, S, H, D = 2, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 1.0
    hs_ck = xl.mlstm_chunkwise(q, k, v, ig, fg, chunk)
    hs_rec, _ = xl.mlstm_recurrent(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(hs_ck), np.asarray(hs_rec),
                               rtol=5e-4, atol=5e-4)


def test_mlstm_chunkwise_state_handoff():
    """State returned by chunkwise equals the recurrent end state, so
    prefill→decode is seamless."""
    B, S, H, D = 1, 24, 2, 4
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 1.0
    _, st_ck = xl.mlstm_chunkwise(q, k, v, ig, fg, 8, return_state=True)
    _, st_rec = xl.mlstm_recurrent(q, k, v, ig, fg)
    for a, b in zip(st_ck, st_rec):
        # C and n are stabilizer-relative; compare de-stabilized products
        pass
    # compare the *effect* of the states on a probe query instead
    qp = jax.random.normal(jax.random.PRNGKey(7), (B, 1, H, D))
    kp = jax.random.normal(jax.random.PRNGKey(8), (B, 1, H, D))
    vp = jax.random.normal(jax.random.PRNGKey(9), (B, 1, H, D))
    igp = jnp.zeros((B, 1, H))
    fgp = jnp.zeros((B, 1, H)) + 2.0
    out_ck, _ = xl.mlstm_recurrent(qp, kp, vp, igp, fgp, st_ck)
    out_rec, _ = xl.mlstm_recurrent(qp, kp, vp, igp, fgp, st_rec)
    np.testing.assert_allclose(np.asarray(out_ck), np.asarray(out_rec),
                               rtol=5e-4, atol=5e-4)


def test_slstm_finite_and_stateful():
    cfg = registry.get("xlstm-125m-smoke").with_(compute_dtype="float32")
    params = pt.init_params(jax.random.PRNGKey(10), xl.slstm_specs(cfg))
    B, S = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(11), (B, S, cfg.d_model))
    out, cache = xl.slstm_block(cfg, params, x, mode="prefill")
    assert out.shape == x.shape and bool(jnp.isfinite(out).all())
    # one more step from the cache == running S+1 from scratch
    x1 = jax.random.normal(jax.random.PRNGKey(12), (B, 1, cfg.d_model))
    out_step, _ = xl.slstm_block(cfg, params, x1, mode="decode",
                                 cache=cache)
    full, _ = xl.slstm_block(cfg, params,
                             jnp.concatenate([x, x1], axis=1), mode="train")
    np.testing.assert_allclose(np.asarray(out_step[:, 0]),
                               np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)
