"""DBRX-132B — 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab_size=100352, head_dim=128,
    n_experts=16, top_k=4,
    block_unit=("moe",),
    mlp_variant="swiglu",
    blockwise_threshold=4096,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        name="dbrx-132b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=48, vocab_size=512,
        n_experts=4, top_k=2, blockwise_threshold=64,
        attn_block_q=16, attn_block_kv=16)
