"""Paper Figures 4/5/15 (§5.2): encoder-decoder butterfly loss vs PCA (Δ_k)
and FJLT+PCA across k, on Gaussian rank-r and image-like matrices."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, gaussian_lowrank, synthetic_image_matrix
from repro.core import encdec

DATASETS = [
    ("gaussian1_r32", lambda: gaussian_lowrank(256, 256, 32, seed=0)),
    ("gaussian2_r64", lambda: gaussian_lowrank(256, 256, 64, seed=1)),
    ("mnist_like", lambda: synthetic_image_matrix(256, 256, seed=2)),
]

KS = (1, 4, 8, 16, 32)


def run(train_steps: int = 400) -> None:
    for name, make in DATASETS:
        X = make()
        n, d = X.shape
        for k in KS:
            pca = float(encdec.pca_loss(X, X, k))
            spec = encdec.make_spec(jax.random.PRNGKey(k), n=n, d=d, k=k)
            fjlt = float(encdec.fjlt_pca_loss(jax.random.PRNGKey(k + 1), X,
                                              k, spec.ell))
            params = encdec.init_params(jax.random.PRNGKey(k + 2), spec)
            # closed-form optimum for frozen B (Theorem 1) ...
            D, E = encdec.optimal_DE(spec, params["B"], X, X)
            closed = float(encdec.loss_fn(spec, dict(params, D=D, E=E),
                                          X, X))
            # ... and gradient training of all three matrices (§5.2)
            trained, _ = encdec.train(spec, params, X, X,
                                      steps=train_steps, lr=3e-3)
            gd = float(encdec.loss_fn(spec, trained, X, X))
            emit(f"autoenc/{name}_k{k}", 0.0,
                 f"pca={pca:.4f};fjlt_pca={fjlt:.4f};"
                 f"butterfly_closed={closed:.4f};butterfly_gd={gd:.4f}")


if __name__ == "__main__":
    run()
