"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs.
Also covers the butterfly variants (the paper's §3.2 replacement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm
from repro.runtime import pytree as pt

ARCHS = registry.names()


def _batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.n_enc_layers:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = registry.get(arch + "-smoke")
    params = pt.init_params(jax.random.PRNGKey(0), lm.model_specs(cfg))
    batch = _batch(cfg)
    loss, metrics = lm.loss_fn(cfg, params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: lm.loss_fn(cfg, p, batch)[0])(params)
    norms = [float(jnp.sum(jnp.abs(g)))
             for g in jax.tree_util.tree_leaves(grads)]
    assert all(np.isfinite(n) for n in norms)
    assert sum(norms) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode(arch):
    cfg = registry.get(arch + "-smoke")
    params = pt.init_params(jax.random.PRNGKey(0), lm.model_specs(cfg))
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    caches = lm.init_caches(cfg, B, S + 1)
    logits, caches = lm.prefill(cfg, params, batch, caches)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    extra = cfg.frontend_tokens if cfg.frontend == "vision" else 0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    logits2, _ = lm.decode_step(cfg, params, tok, caches,
                                jnp.asarray(S + extra, jnp.int32))
    assert logits2.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ["smollm-135m", "olmoe-1b-7b",
                                  "xlstm-125m", "seamless-m4t-medium"])
def test_smoke_butterfly_variant(arch):
    """The paper's replacement applied to lm_head+mlp trains with finite
    grads and ~10x fewer head/mlp parameters."""
    cfg = registry.get(arch + "-butterfly-smoke")
    dense_cfg = registry.get(arch + "-smoke")
    params = pt.init_params(jax.random.PRNGKey(0), lm.model_specs(cfg))
    batch = _batch(cfg)
    loss, _ = lm.loss_fn(cfg, params, batch)
    assert bool(jnp.isfinite(loss))
    n_b = pt.param_count(lm.model_specs(cfg))
    n_d = pt.param_count(lm.model_specs(
        dense_cfg.with_(tie_embeddings=False)))
    assert n_b < n_d


def test_exact_assigned_configs():
    """The full configs must match the assignment sheet exactly."""
    a = registry.get("olmoe-1b-7b")
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff,
            a.vocab_size, a.n_experts, a.top_k) == \
        (16, 2048, 16, 16, 1024, 50304, 64, 8)
    b = registry.get("dbrx-132b")
    assert (b.n_layers, b.d_model, b.n_heads, b.n_kv_heads, b.d_ff,
            b.vocab_size, b.n_experts, b.top_k) == \
        (40, 6144, 48, 8, 10752, 100352, 16, 4)
    c = registry.get("smollm-135m")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (30, 576, 9, 3, 1536, 49152)
    d = registry.get("gemma3-27b")
    assert (d.n_layers, d.d_model, d.n_heads, d.n_kv_heads, d.d_ff,
            d.vocab_size) == (62, 5376, 32, 16, 21504, 262144)
    assert d.block_unit.count("local") == 5 and "global" in d.block_unit
    e = registry.get("gemma-7b")
    assert (e.n_layers, e.d_model, e.n_heads, e.n_kv_heads, e.d_ff,
            e.vocab_size, e.head_dim) == (28, 3072, 16, 16, 24576, 256000,
                                          256)
    f = registry.get("mistral-large-123b")
    assert (f.n_layers, f.d_model, f.n_heads, f.n_kv_heads, f.d_ff,
            f.vocab_size) == (88, 12288, 96, 8, 28672, 32768)
    g = registry.get("recurrentgemma-2b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
            g.vocab_size) == (26, 2560, 10, 1, 7680, 256000)
    assert g.block_unit == ("rec", "rec", "local")
    h = registry.get("xlstm-125m")
    assert (h.n_layers, h.d_model, h.n_heads, h.vocab_size) == \
        (12, 768, 4, 50304)
    i = registry.get("internvl2-1b")
    assert (i.n_layers, i.d_model, i.n_heads, i.n_kv_heads, i.d_ff,
            i.vocab_size) == (24, 896, 14, 2, 4864, 151655)
    j = registry.get("seamless-m4t-medium")
    assert (j.n_layers, j.d_model, j.n_heads, j.n_kv_heads, j.d_ff,
            j.vocab_size) == (12, 1024, 16, 16, 4096, 256206)
    assert j.n_enc_layers == 12


def test_layer_pattern_coverage():
    """n_layers == repeats·|unit| + |tail| for every arch."""
    for name in ARCHS:
        cfg = registry.get(name)
        total = cfg.unit_repeats * len(cfg.block_unit) + len(cfg.tail_layers)
        assert total == cfg.n_layers, name
