"""The paper's dense-layer replacement (§3.2): the butterfly "sandwich".

A dense ``n2 x n1`` layer ``W`` is replaced by ``J2ᵀ · W' · J1`` where

* ``J1`` is a ``k1 x n1`` truncated butterfly network,
* ``W'`` is a small dense ``k2 x k1`` core,
* ``J2ᵀ`` is the transpose of a ``k2 x n2`` truncated butterfly network.

Proposition 3.1 guarantees that with FJLT-initialized ``J1, J2`` and core
``W' = J2 W J1ᵀ`` the sandwich approximates the action of ``W`` on any vector
w.h.p. Parameters drop from ``n1·n2`` to ``2·N1·log2(N1) + 2·N2·log2(N2) +
k1·k2`` (N = padded power-of-two dims), i.e. near-linear.

The module is functional: a hashable static :class:`ButterflySpec` plus a
params dict, so it nests anywhere in a model param tree and composes with
pjit (weights are tiny and replicated; activations shard on batch axes).
"""

from __future__ import annotations

import functools
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import butterfly as bf
from repro.kernels import context as exctx
from repro.kernels import ops as kops

__all__ = [
    "ButterflySpec",
    "make_spec",
    "init_butterfly_linear",
    "butterfly_linear_apply",
    "butterfly_linear_materialize",
    "param_count",
    "dense_param_count",
    "init_from_dense",
]


@dataclass(frozen=True)
class ButterflySpec:
    """Static configuration of one butterfly sandwich layer.

    Truncation index sets are part of the *spec* (fixed at init, never
    trained), so the spec is hashable and can be closed over by jit.
    """

    n_in: int
    n_out: int
    k_in: int
    k_out: int
    idx_in: Tuple[int, ...]
    idx_out: Tuple[int, ...]
    use_bias: bool = True
    jl_scale: bool = True

    @property
    def pad_in(self) -> int:
        return bf.padded_dim(self.n_in)

    @property
    def pad_out(self) -> int:
        return bf.padded_dim(self.n_out)


def default_k(n: int, k_factor: float = 1.0) -> int:
    """The paper's choice ``k = log2(n)``, scaled by ``k_factor`` for
    quality/perf trade-offs. Clamped to [1, n]."""
    k = max(1, int(round(k_factor * math.log2(max(n, 2)))))
    return min(k, n)


def make_spec(key: jax.Array, n_in: int, n_out: int,
              k_in: Optional[int] = None, k_out: Optional[int] = None,
              k_factor: float = 1.0, use_bias: bool = True) -> ButterflySpec:
    k_in = default_k(n_in, k_factor) if k_in is None else k_in
    k_out = default_k(n_out, k_factor) if k_out is None else k_out
    k1, k2 = jax.random.split(key)
    idx_in = bf.truncation_indices(k1, bf.padded_dim(n_in), k_in)
    idx_out = bf.truncation_indices(k2, bf.padded_dim(n_out), k_out)
    return ButterflySpec(n_in=n_in, n_out=n_out, k_in=k_in, k_out=k_out,
                         idx_in=idx_in, idx_out=idx_out, use_bias=use_bias)


def init_butterfly_linear(key: jax.Array, spec: ButterflySpec,
                          dtype=jnp.float32) -> dict:
    """FJLT init for both butterflies; PyTorch-style kaiming-uniform core."""
    kb1, kb2, kc = jax.random.split(key, 3)
    params = {
        "b_in": bf.fjlt_weights(kb1, spec.pad_in, dtype=dtype),
        "b_out": bf.fjlt_weights(kb2, spec.pad_out, dtype=dtype),
        "core": _kaiming_uniform(kc, (spec.k_out, spec.k_in), dtype=dtype),
    }
    if spec.use_bias:
        params["bias"] = jnp.zeros((spec.n_out,), dtype=dtype)
    return params


def _kaiming_uniform(key: jax.Array, shape, dtype) -> jnp.ndarray:
    fan_in = shape[1]
    bound = math.sqrt(1.0 / fan_in)
    return jax.random.uniform(key, shape, minval=-bound, maxval=bound,
                              dtype=dtype)


def init_from_dense(key: jax.Array, spec: ButterflySpec, W: jnp.ndarray,
                    dtype=jnp.float32) -> dict:
    """Initialize so the sandwich approximates a given dense ``W`` (n2 x n1):
    FJLT butterflies and core ``W' = J2 W J1ᵀ`` (Proposition 3.1)."""
    kb1, kb2 = jax.random.split(key)
    b_in = bf.fjlt_weights(kb1, spec.pad_in, dtype=jnp.float32)
    b_out = bf.fjlt_weights(kb2, spec.pad_out, dtype=jnp.float32)
    J1 = bf.materialize_truncated(b_in, spec.idx_in, spec.jl_scale)
    J1 = J1[:, : spec.n_in]
    J2 = bf.materialize_truncated(b_out, spec.idx_out, spec.jl_scale)
    J2 = J2[:, : spec.n_out]
    core = J2 @ W @ J1.T
    params = {
        "b_in": b_in.astype(dtype),
        "b_out": b_out.astype(dtype),
        "core": core.astype(dtype),
    }
    if spec.use_bias:
        params["bias"] = jnp.zeros((spec.n_out,), dtype=dtype)
    return params


# Bounded: each entry holds two dense (k, N) numpy matrices, so an unbounded
# cache grows without limit in a long-lived process that keeps creating
# fresh specs (many sites x many models x hyperparameter sweeps). 128 specs
# comfortably covers every site of the largest assigned config; eviction
# only costs a rebuild on the next trace.
SELECTION_CACHE_SIZE = 128


@functools.lru_cache(maxsize=SELECTION_CACHE_SIZE)
def _selection_matrices(spec: ButterflySpec):
    """Fixed one-hot truncate/scatter matrices for the fused kernel path.

    Cached per spec (hashable, truncation indices are frozen at init) so the
    matrices become jit-time constants instead of being rebuilt per call —
    including across jit retraces, which re-enter this function with an
    equal spec and must hit. Cached as *numpy* — this function runs inside
    jit traces, and caching a trace-created jax array would leak a tracer
    into later traces.
    """
    from repro.kernels.sandwich import one_hot_select_np
    sel_in = one_hot_select_np(spec.idx_in, spec.pad_in)
    sel_out = one_hot_select_np(spec.idx_out, spec.pad_out).T
    return sel_in, sel_out


def butterfly_linear_apply(spec: ButterflySpec, params: dict,
                           x: jnp.ndarray, *,
                           context: exctx.ContextLike = None
                           ) -> jnp.ndarray:
    """Apply the sandwich along the last axis: (..., n_in) -> (..., n_out).

    Execution policy rides ``context`` (an
    :class:`~repro.kernels.context.ExecutionContext`, a backend string, or
    ``None`` — see :mod:`repro.kernels.context` for the resolution order):
    the ``jnp`` backend runs the unfused reference ops below; the Pallas
    backends run the fused sandwich kernel — differentiable in both
    activations and weights via its custom_vjp. Unset tile knobs defer to
    the :mod:`repro.kernels.tuning` autotuner. A context with a mesh
    batch-shards the whole layer (padding, kernel, bias) over the mesh's
    data axes with replicated weights and psum'd weight grads
    (:mod:`repro.runtime.butterfly_sharding`).
    """
    if x.shape[-1] != spec.n_in:
        raise ValueError(f"expected last dim {spec.n_in}, got {x.shape[-1]}")
    ctx = exctx.resolve_execution(context)
    route = kops._sharded_route(ctx)
    if route is not None:
        bsh, axes = route
        return bsh.sharded_butterfly_linear_apply(spec, params, x,
                                                  context=ctx, axes=axes)
    return _local_linear_apply(spec, params, x, ctx)


def _local_linear_apply(spec: ButterflySpec, params: dict, x: jnp.ndarray,
                        ctx: "exctx.ExecutionContext") -> jnp.ndarray:
    """Single-device sandwich layer on a *finalized* context: no
    resolution, no mesh routing — the shard_map region closure in
    :mod:`repro.runtime.butterfly_sharding` runs this per shard, so an
    ambient mesh context can never re-route it."""
    # pad to power of two
    if spec.pad_in != spec.n_in:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, spec.pad_in - spec.n_in)]
        x = jnp.pad(x, pad)
    if ctx.backend == "jnp":
        h = bf.butterfly_apply(params["b_in"].astype(x.dtype), x)
        h = bf.truncate(h, spec.idx_in, spec.pad_in, spec.jl_scale)  # (.., k1)
        h = jnp.einsum("...i,oi->...o", h, params["core"].astype(x.dtype))
        z = bf.untruncate(h, spec.idx_out, spec.pad_out,
                          spec.jl_scale)                             # (.., N2)
        z = bf.butterfly_transpose_apply(params["b_out"].astype(x.dtype), z)
    else:
        sel_in, sel_out = _selection_matrices(spec)
        scale_in = (math.sqrt(spec.pad_in / spec.k_in)
                    if spec.jl_scale else 1.0)
        scale_out = (math.sqrt(spec.pad_out / spec.k_out)
                     if spec.jl_scale else 1.0)
        z = kops._local_sandwich(x, params["b_in"], sel_in, params["core"],
                                 sel_out, params["b_out"],
                                 scale_in=scale_in, scale_out=scale_out,
                                 ctx=ctx.local())
    if spec.pad_out != spec.n_out:
        z = z[..., : spec.n_out]
    if spec.use_bias and "bias" in params:
        z = z + params["bias"].astype(x.dtype)
    return z


def butterfly_linear_materialize(spec: ButterflySpec, params: dict
                                 ) -> jnp.ndarray:
    """Dense (n_out x n_in) equivalent of the sandwich (tests/analysis)."""
    J1 = bf.materialize_truncated(params["b_in"], spec.idx_in, spec.jl_scale)
    J1 = J1[:, : spec.n_in]
    J2 = bf.materialize_truncated(params["b_out"], spec.idx_out, spec.jl_scale)
    J2 = J2[:, : spec.n_out]
    return J2.T @ params["core"] @ J1


def param_count(spec: ButterflySpec) -> int:
    """Trainable parameter count of the sandwich (stored weights)."""
    p1 = bf.num_stages(spec.pad_in)
    p2 = bf.num_stages(spec.pad_out)
    n = 2 * spec.pad_in * p1 + 2 * spec.pad_out * p2 + spec.k_in * spec.k_out
    if spec.use_bias:
        n += spec.n_out
    return n


def effective_param_count(spec: ButterflySpec) -> int:
    """Effective (on-path) weights per Appendix F, for both butterflies."""
    return (bf.effective_param_count(spec.pad_in, spec.idx_in)
            + bf.effective_param_count(spec.pad_out, spec.idx_out)
            + spec.k_in * spec.k_out
            + (spec.n_out if spec.use_bias else 0))


def dense_param_count(n_in: int, n_out: int, use_bias: bool = True) -> int:
    return n_in * n_out + (n_out if use_bias else 0)
