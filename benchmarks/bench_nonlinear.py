"""Beyond-paper: the paper's §7 future-work question — do non-linear gates
between butterfly stages add expressivity?

Experiment: fit (a) a random *linear* map and (b) a random 2-layer MLP
(non-linear target) with equal-parameter linear vs gated butterflies.
Expected: parity on (a), advantage for the gated variant on (b)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import butterfly as bf
from repro.optim import optimizer as opt


def _fit(apply_fn, w0, X, Y, steps=300, lr=3e-3):
    tx = opt.adamw(lr)
    state = tx.init(w0)

    def loss(w):
        return jnp.mean(jnp.square(apply_fn(w, X) - Y))

    @jax.jit
    def step(w, s):
        g = jax.grad(loss)(w)
        u, s = tx.update(g, s, w)
        return opt.apply_updates(w, u), s

    w = w0
    for _ in range(steps):
        w, state = step(w, state)
    return float(loss(w))


def run(steps: int = 300) -> None:
    n, batch = 64, 512
    X = jax.random.normal(jax.random.PRNGKey(0), (batch, n))

    # (a) linear target
    W = jax.random.normal(jax.random.PRNGKey(1), (n, n)) / jnp.sqrt(n)
    Y_lin = X @ W.T
    # (b) non-linear target: 2-layer MLP
    W1 = jax.random.normal(jax.random.PRNGKey(2), (n, 2 * n)) / jnp.sqrt(n)
    W2 = jax.random.normal(jax.random.PRNGKey(3), (2 * n, n)) \
        / jnp.sqrt(2 * n)
    Y_mlp = jax.nn.gelu(X @ W1) @ W2

    for name, Y in (("linear_target", Y_lin), ("mlp_target", Y_mlp)):
        w0 = bf.fjlt_weights(jax.random.PRNGKey(4), n)
        var_y = float(jnp.var(Y))
        l_lin = _fit(bf.butterfly_apply, w0, X, Y, steps)
        l_gated = _fit(bf.butterfly_apply_nonlinear, w0, X, Y, steps)
        emit(f"nonlinear/{name}", 0.0,
             f"linear_butterfly={l_lin:.4f};gated_butterfly={l_gated:.4f};"
             f"target_var={var_y:.4f}")


if __name__ == "__main__":
    run()
