"""Continuous-batching inference engine for the butterfly LMs.

The engine owns a fixed pool of ``slots`` decode lanes over ONE pooled
cache tree (batch axis = slot index) and runs a strict tick loop:

  1. **Admit** — while a slot is free and requests are queued, pop one,
     right-pad its prompt to a power-of-two bucket and prefill it at batch 1
     (:func:`repro.train.steps.make_bucket_prefill_step`); the prefilled
     cache row is spliced into the pool at the slot index
     (:func:`repro.models.lm.write_cache_slot`) and the first token is
     sampled straight off the prefill logits — TTFT never waits for the
     co-batched decode.
  2. **Decode** — ONE fused pooled step
     (:func:`repro.train.steps.make_pool_serve_step`) advances every active
     slot by one token: per-slot positions, per-slot KV masks, per-slot
     active masks. Finished slots (stop token or length budget) resolve
     their futures and free immediately; the next tick's admission refills
     them while the in-flight requests keep decoding — no stall, no
     re-batching barrier.

Compilation is explicit: every jitted function lives in a
:class:`CompileCache` keyed on ``(kind, arch, bucket/batch, sampling,
ExecutionContext)``, with a trace counter the tests gate on — admitting ten
prompts that share a bucket compiles the prefill exactly once.

The engine is ExecutionContext-native: it resolves ONE context at
construction (explicit ``context=`` > ambient > the arch's
``ButterflyConfig``), traces everything inside ``use_execution`` (plus
``use_sharding`` when the context carries a mesh), so the same engine
serves on one CPU or batch-shards its butterfly sites across an 8-device
simulated mesh via :mod:`repro.runtime.butterfly_sharding`.

Threading model: ``submit()`` is thread-safe; ``step()`` /
``run_until_idle()`` must be driven from one thread (the
:class:`repro.serve.client.ServeClient` wraps exactly that driver thread
and hands out futures).
"""

from __future__ import annotations

import collections
import contextlib
import functools
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import context as exctx
from repro.models import lm
from repro.runtime import sharding as rsh
from repro.serve import sampling as sampling_lib
from repro.serve.metrics import EngineMetrics
from repro.train import steps as steps_lib

# Block types whose caches mix positions sequentially (recurrent state) or
# ring-buffer by position: right-padded bucket prefill would fold the pads
# into the state, so these archs prefill at exact prompt lengths instead
# (one compile per distinct length — the trade the engine makes explicit).
SEQUENTIAL_STATE_BLOCKS = ("rec", "mlstm", "slstm", "local")


class CompileCache:
    """Explicit jit cache with a trace counter.

    ``get(key, build)`` memoizes the *compiled callable* per key;
    :meth:`counted_jit` wraps the pre-jit function so every retrace bumps
    ``traces[key]`` (the function body only executes while jax traces —
    cached executions never touch it). The serving tests gate on exactly
    this counter: one trace per (bucket, context), ever.
    """

    def __init__(self):
        self._fns: Dict[Tuple, Callable] = {}
        self.traces: Dict[Tuple, int] = {}

    def get(self, key: Tuple, build: Callable[[], Callable]) -> Callable:
        fn = self._fns.get(key)
        if fn is None:
            fn = self._fns[key] = build()
        return fn

    def counted_jit(self, key: Tuple, fn: Callable, **jit_kw) -> Callable:
        def traced(*args, **kwargs):
            self.traces[key] = self.traces.get(key, 0) + 1
            return fn(*args, **kwargs)
        return jax.jit(traced, **jit_kw)

    @property
    def compiles(self) -> int:
        return len(self._fns)

    def keys(self) -> List[Tuple]:
        return list(self._fns)


@dataclass
class Request:
    """One queued generation request."""

    rid: int
    prompt: np.ndarray                     # (prompt_len,) int32
    max_new_tokens: int
    stop_token: Optional[int] = None
    extras: Optional[Dict] = None          # frontend_embeds / frames
    future: Future = field(default_factory=Future)


@dataclass
class GenerationResult:
    """What a request's future resolves to."""

    rid: int
    prompt: np.ndarray
    tokens: List[int]                      # all generated tokens, in order
    metrics: object                        # RequestMetrics


@dataclass
class _Slot:
    """Host-side state of one occupied decode lane."""

    req: Request
    tokens: List[int]                      # generated so far (>= 1)
    cur_pos: int                           # absolute cache write position
    last_token: int


class ServeEngine:
    """Continuous-batching engine over a fixed decode-slot pool.

    * ``slots`` — decode lanes (the pooled batch size of the serve step).
    * ``max_len`` — per-slot token budget: every request must satisfy
      ``prompt_len + max_new_tokens <= max_len`` (the pooled caches are
      allocated once at this length).
    * ``sampling`` — engine-wide :class:`SamplingParams` (a trace-time
      constant of the serve step; greedy by default).
    * ``context`` — execution policy; resolved once here, exactly like the
      ``Trainer`` (explicit > ambient > ``cfg.butterfly`` > env/platform).
    * ``scrub_freed_slots`` — re-init a slot's cache row when its request
      finishes (:func:`repro.models.lm.reset_cache_slot`); off by default
      since admission overwrites the full row anyway.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 128,
                 sampling: sampling_lib.SamplingParams = sampling_lib.GREEDY,
                 context: exctx.ContextLike = None, seed: int = 0,
                 min_bucket: int = 8, scrub_freed_slots: bool = False):
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        self.cfg = cfg
        self.slots = slots
        self.max_len = int(max_len)
        self.sampling = sampling
        self.min_bucket = int(min_bucket)
        self.scrub_freed_slots = scrub_freed_slots
        self.ctx = exctx.resolve_execution(
            context,
            default=exctx.ExecutionContext.from_butterfly_config(
                cfg.butterfly))
        self.mesh = self.ctx.mesh
        self._params = params
        self._n_front = (cfg.frontend_tokens if cfg.frontend == "vision"
                         else 0)
        types = set(cfg.block_unit) | set(cfg.tail_layers)
        self._exact_buckets = bool(types & set(SEQUENTIAL_STATE_BLOCKS))
        self._caches = lm.init_caches(cfg, slots, self.max_len)
        self._slots: List[Optional[_Slot]] = [None] * slots
        self._queue: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._next_rid = 0
        self._key = jax.random.PRNGKey(seed)
        self.compile_cache = CompileCache()
        self.metrics = EngineMetrics(slots=slots)
        self._sample_fn = functools.partial(sampling_lib.sample_logits,
                                            params=sampling)

    # -- execution scope ----------------------------------------------

    def _scope(self):
        """Ambient contexts live whenever a jitted fn may (re)trace: the
        frozen ExecutionContext, plus the sharding ctx for a mesh — the
        Trainer's exact pattern."""
        stack = contextlib.ExitStack()
        stack.enter_context(exctx.use_execution(self.ctx))
        if self.mesh is not None:
            stack.enter_context(rsh.use_sharding(self.mesh))
        return stack

    # -- compiled steps ------------------------------------------------

    def bucket_for(self, prompt_len: int) -> int:
        """Prefill bucket for a prompt: next power of two (>= min_bucket,
        <= max_len), or the exact length for sequential-state archs where
        padded prefill would corrupt the state."""
        if self._exact_buckets:
            return prompt_len
        b = self.min_bucket
        while b < prompt_len:
            b *= 2
        return min(b, self.max_len)

    def _prefill_fn(self, bucket: int) -> Callable:
        key = ("prefill", self.cfg.name, bucket, 1, self.ctx)
        return self.compile_cache.get(key, lambda: (
            self.compile_cache.counted_jit(
                key, steps_lib.make_bucket_prefill_step(self.cfg,
                                                        self.max_len))))

    def _decode_fn(self) -> Callable:
        key = ("decode", self.cfg.name, self.slots, self.sampling, self.ctx)
        return self.compile_cache.get(key, lambda: (
            self.compile_cache.counted_jit(
                key,
                steps_lib.make_pool_serve_step(self.cfg, self._sample_fn),
                donate_argnums=(2,))))

    def _insert_fn(self) -> Callable:
        key = ("insert", self.cfg.name, self.slots, self.ctx)
        return self.compile_cache.get(key, lambda: (
            self.compile_cache.counted_jit(
                key,
                lambda pool, sub, slot: lm.write_cache_slot(
                    self.cfg, pool, sub, slot),
                donate_argnums=(0,))))

    def _reset_fn(self) -> Callable:
        key = ("reset", self.cfg.name, self.slots, self.ctx)
        return self.compile_cache.get(key, lambda: (
            self.compile_cache.counted_jit(
                key,
                lambda pool, slot: lm.reset_cache_slot(
                    self.cfg, pool, slot, self.max_len),
                donate_argnums=(0,))))

    def _first_token_fn(self) -> Callable:
        key = ("sample", self.cfg.name, self.sampling, self.ctx)
        return self.compile_cache.get(key, lambda: (
            self.compile_cache.counted_jit(key, self._sample_fn)))

    # -- client surface ------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new_tokens: int = 16, *,
               stop_token: Optional[int] = None,
               extras: Optional[Dict] = None) -> Future:
        """Queue a request; returns a future resolving to a
        :class:`GenerationResult`. Thread-safe."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{max_new_tokens}")
        if prompt.size + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt_len {prompt.size} + max_new_tokens "
                f"{max_new_tokens} exceeds the engine's per-slot budget "
                f"max_len={self.max_len}")
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            req = Request(rid=rid, prompt=prompt,
                          max_new_tokens=int(max_new_tokens),
                          stop_token=stop_token, extras=extras)
            self.metrics.on_submit(rid, prompt.size)
            self._queue.append(req)
        return req.future

    def has_work(self) -> bool:
        with self._lock:
            queued = bool(self._queue)
        return queued or any(s is not None for s in self._slots)

    def abort_all(self, exc: BaseException) -> None:
        """Fail every queued and in-flight request with ``exc``.

        The crash path: when a tick raises (bad extras, an arch the pool
        can't serve, a device error), whoever drives the loop calls this so
        every outstanding future resolves with the real error instead of
        hanging until its timeout. The pool is left empty; the engine
        itself stays usable for new submissions.
        """
        with self._lock:
            dead = list(self._queue)
            self._queue.clear()
        for i, s in enumerate(self._slots):
            if s is not None:
                self._slots[i] = None
                dead.append(s.req)
        for req in dead:
            self.metrics.requests.pop(req.rid, None)
            if not req.future.done():
                req.future.set_exception(exc)

    def active_requests(self) -> List[int]:
        return [s.req.rid for s in self._slots if s is not None]

    @property
    def compile_stats(self) -> Dict:
        return {"compiles": self.compile_cache.compiles,
                "traces": dict(self.compile_cache.traces)}

    def reset_metrics(self) -> None:
        """Fresh metrics (tick clock included) without touching compiled
        state or the pool — a benchmark warms every bucket, resets, then
        measures a compile-free steady state. Only valid while no request
        is in flight (in-flight RequestMetrics would be orphaned)."""
        if self.has_work():
            raise RuntimeError("reset_metrics with requests in flight")
        self.metrics = EngineMetrics(
            slots=self.slots,
            max_request_history=self.metrics.max_request_history)

    # -- the tick loop -------------------------------------------------

    def step(self) -> int:
        """One engine tick: admit into free slots, then one pooled decode.
        Returns the number of slots still active after the tick."""
        self._admit()
        if any(s is not None for s in self._slots):
            self._decode_tick()
        self.metrics.ticks += 1
        return sum(s is not None for s in self._slots)

    def run_until_idle(self, max_ticks: int = 100_000) -> int:
        """Drive ticks until queue and pool drain; returns ticks spent."""
        start = self.metrics.ticks
        while self.has_work():
            self.step()
            if self.metrics.ticks - start > max_ticks:
                raise RuntimeError(
                    f"engine did not drain within {max_ticks} ticks "
                    f"(active={self.active_requests()})")
        return self.metrics.ticks - start

    # -- internals -----------------------------------------------------

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def _admit(self) -> None:
        while True:
            idx = self._free_slot()
            if idx is None:
                return
            with self._lock:
                if not self._queue:
                    return
                req = self._queue.popleft()
            self._admit_one(req, idx)

    def _admit_one(self, req: Request, idx: int) -> None:
        plen = int(req.prompt.size)
        bucket = self.bucket_for(plen)
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :plen] = req.prompt
        batch = {"tokens": jnp.asarray(tokens)}
        if req.extras:
            batch.update({k: jnp.asarray(v) for k, v in req.extras.items()})
        last_pos = jnp.asarray([plen - 1], jnp.int32)
        t0 = time.monotonic()
        with self._scope():
            logits, sub = self._prefill_fn(bucket)(self._params, batch,
                                                   last_pos)
            self._caches = self._insert_fn()(
                self._caches, sub, jnp.asarray(idx, jnp.int32))
            tok = int(self._first_token_fn()(
                logits, jax.random.fold_in(self._key, req.rid))[0])
        self.metrics.on_admit(req.rid, plen, time.monotonic() - t0)
        slot = _Slot(req=req, tokens=[tok],
                     cur_pos=self._n_front + plen, last_token=tok)
        self._slots[idx] = slot
        if self._finished(slot):
            self._finish(idx)

    def _finished(self, slot: _Slot) -> bool:
        if len(slot.tokens) >= slot.req.max_new_tokens:
            return True
        stop = slot.req.stop_token
        return stop is not None and slot.last_token == stop

    def _finish(self, idx: int) -> None:
        slot = self._slots[idx]
        self._slots[idx] = None
        rm = self.metrics.on_finish(slot.req.rid)
        if self.scrub_freed_slots:
            with self._scope():
                self._caches = self._reset_fn()(
                    self._caches, jnp.asarray(idx, jnp.int32))
        slot.req.future.set_result(GenerationResult(
            rid=slot.req.rid, prompt=slot.req.prompt,
            tokens=list(slot.tokens), metrics=rm))

    def _decode_tick(self) -> None:
        tokens = np.zeros((self.slots,), np.int32)
        cur_pos = np.zeros((self.slots,), np.int32)
        active = np.zeros((self.slots,), bool)
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            tokens[i] = s.last_token
            cur_pos[i] = s.cur_pos
            active[i] = True
        n_active = int(active.sum())
        rng = jax.random.fold_in(self._key, 0x5E57E9 + self.metrics.ticks)
        t0 = time.monotonic()
        with self._scope():
            nxt, self._caches = self._decode_fn()(
                self._params, jnp.asarray(tokens), self._caches,
                jnp.asarray(cur_pos), rng, jnp.asarray(active))
        nxt = np.asarray(nxt)
        self.metrics.on_decode_tick(n_active, n_active,
                                    time.monotonic() - t0)
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            tok = int(nxt[i])
            s.tokens.append(tok)
            s.last_token = tok
            s.cur_pos += 1
            self.metrics.on_token(s.req.rid)
            if self._finished(s):
                self._finish(i)
