"""Step functions: train (with microbatched gradient accumulation), prefill
and decode. These are the units the launcher jits/lowers — both for real
execution and for the multi-pod dry-run."""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import lm
from repro.optim import optimizer as opt
from repro.optim.compression import compress_gradients

PyTree = Any


def make_optimizer(tc: TrainConfig) -> opt.GradientTransformation:
    schedule = opt.warmup_cosine_schedule(tc.learning_rate, tc.warmup_steps,
                                          tc.total_steps)
    parts = []
    if tc.max_grad_norm:
        parts.append(opt.clip_by_global_norm(tc.max_grad_norm))
    if tc.grad_compression:
        parts.append(compress_gradients(tc.grad_compression,
                                        tc.grad_compression_ratio))
    parts.append(opt.scale_by_adam())
    if tc.weight_decay:
        parts.append(opt.add_decayed_weights(tc.weight_decay))
    parts.append(opt.scale_by_schedule(schedule))
    return opt.chain(*parts)


def make_train_step(cfg: ModelConfig, tx: opt.GradientTransformation,
                    microbatches: int = 1) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches > 1`` accumulates gradients over a lax.scan so peak
    activation memory scales with the microbatch, not the global batch —
    the standard large-model memory lever.
    """

    def loss_fn(params, mb):
        return lm.loss_fn(cfg, params, mb)

    def step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            mbs = jax.tree_util.tree_map(split, batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb_step(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), gsum, g)
                return (gsum, lsum + l), None

            (grads, lsum), _ = jax.lax.scan(mb_step, (zero, 0.0), mbs)
            inv = 1.0 / microbatches
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
            loss = lsum * inv
            metrics = {}

        gnorm = opt.clip_by_global_norm(1.0)  # reuse norm computation
        leaves = [g for g in jax.tree_util.tree_leaves(grads)
                  if g is not None]
        grad_norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in leaves))
        updates, opt_state = tx.update(grads, opt_state, params)
        params = opt.apply_updates(params, updates)
        out_metrics = {"loss": loss, "grad_norm": grad_norm}
        out_metrics.update({k: v for k, v in metrics.items()})
        return params, opt_state, out_metrics

    return step


def make_prefill_step(cfg: ModelConfig, chunks: int = 1) -> Callable:
    """Prefill, optionally processing the batch in ``chunks`` sequential
    sub-batches: full-sequence activation peaks scale 1/chunks while the
    caches assemble to the same final layout (big-model memory lever —
    prefill has no gradient so only the live set matters)."""
    if chunks <= 1:
        def step(params, batch, caches):
            return lm.prefill(cfg, params, batch, caches)
        return step

    def step(params, batch, caches):
        B = batch["tokens"].shape[0]
        assert B % chunks == 0, (B, chunks)
        Bc = B // chunks

        def split(x):
            return x.reshape((chunks, Bc) + x.shape[1:])

        mb = jax.tree_util.tree_map(split, batch)

        # Caches ride the scan CARRY with dynamic batch-slice updates —
        # reshaping/stacking them as scan ys would copy the whole KV stack
        # and break donation aliasing (measured: mistral prefill 13.5 GB ->
        # 74 GB/device with the copy formulation).
        # unit leaves: (R, B, ...) batch at axis 1; tail leaves: (B, ...).
        def body(carry, xs):
            mb_i, i = xs
            off = i * Bc
            sub = {
                "unit": jax.tree_util.tree_map(
                    lambda c: jax.lax.dynamic_slice_in_dim(c, off, Bc, 1),
                    carry["unit"]),
                "tail": jax.tree_util.tree_map(
                    lambda c: jax.lax.dynamic_slice_in_dim(c, off, Bc, 0),
                    carry["tail"]),
            }
            logits_i, new_sub = lm.prefill(cfg, params, mb_i, sub)
            carry = {
                "unit": jax.tree_util.tree_map(
                    lambda full, nc: jax.lax.dynamic_update_slice_in_dim(
                        full, nc.astype(full.dtype), off, 1),
                    carry["unit"], new_sub["unit"]),
                "tail": jax.tree_util.tree_map(
                    lambda full, nc: jax.lax.dynamic_update_slice_in_dim(
                        full, nc.astype(full.dtype), off, 0),
                    carry["tail"], new_sub["tail"]),
            }
            return carry, logits_i

        new_caches, logits = jax.lax.scan(body, caches,
                                          (mb, jnp.arange(chunks)))
        return logits.reshape((B,) + logits.shape[2:]), new_caches

    return step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def step(params, token, caches, cur_pos):
        return lm.decode_step(cfg, params, token, caches, cur_pos)
    return step


def make_serve_step(cfg: ModelConfig) -> Callable:
    """The dry-run ``serve_step``: one greedy token given a filled cache."""
    def step(params, token, caches, cur_pos):
        logits, caches = lm.decode_step(cfg, params, token, caches, cur_pos)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, caches
    return step
