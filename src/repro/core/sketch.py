"""Learned butterfly sketches for low-rank decomposition (paper §6).

Setting (Indyk–Vakilian–Yuan, NeurIPS'19): learn a pre-conditioning sketch
``B (ℓ×n)`` from training matrices ``X_i ~ D`` minimizing
``Σ_i ||X_i − B_k(X_i)||_F²`` where ``B_k(X)`` is the best rank-k
approximation of X computed *from the rows of BX* (Algorithm 1 of IVY19,
differentiable through jnp.linalg.svd). The paper's contribution: structure
``B`` as a truncated butterfly and learn its stage weights — beating both the
random and the *learned* Clarkson–Woodruff sparse sketches.

Baselines implemented here:
  * ``cw_random``     — CW'09 sparse sketch: 1 nonzero ±1 per column.
  * ``cw_learned``    — same sparsity pattern, values learned (IVY19).
  * ``dense_learned`` — N nonzeros per column at random positions, learned.
  * ``gaussian``      — ℓ×n iid N(0, 1/ℓ).
  * ``butterfly_learned`` — this paper.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import butterfly as bf
from repro.core.encdec import sketch_rank_k
from repro.optim import optimizer as opt


@dataclass(frozen=True)
class SketchSpec:
    n: int
    ell: int
    k: int
    trunc_idx: Tuple[int, ...] = ()
    jl_scale: bool = True

    @property
    def pad_n(self) -> int:
        return bf.padded_dim(self.n)


def make_spec(key: jax.Array, n: int, ell: int, k: int) -> SketchSpec:
    idx = bf.truncation_indices(key, bf.padded_dim(n), ell)
    return SketchSpec(n=n, ell=ell, k=k, trunc_idx=idx)


# ---------------------------------------------------------------------------
# Sketch application + rank-k reconstruction loss (IVY19 Algorithm 1)
# ---------------------------------------------------------------------------

def butterfly_sketch(spec: SketchSpec, w: jnp.ndarray, X: jnp.ndarray
                     ) -> jnp.ndarray:
    """``B X``: (n, d) -> (ℓ, d) through the truncated butterfly."""
    Xp = X
    if spec.pad_n != spec.n:
        Xp = jnp.pad(X, ((0, spec.pad_n - spec.n), (0, 0)))
    H = bf.butterfly_apply(w, Xp.T)
    return bf.truncate(H, spec.trunc_idx, spec.pad_n, spec.jl_scale).T


def reconstruction_loss(X: jnp.ndarray, Xt: jnp.ndarray, k: int
                        ) -> jnp.ndarray:
    """``||X − [X Π_rowspace(Xt)]_k||_F²`` (differentiable in Xt)."""
    Xk = sketch_rank_k(Xt, X, k)
    return jnp.sum(jnp.square(X - Xk))


def best_rank_k_loss(X: jnp.ndarray, k: int) -> jnp.ndarray:
    s = jnp.linalg.svd(X, compute_uv=False)
    return jnp.sum(jnp.square(s[k:]))


def test_error(sketch_fn: Callable[[jnp.ndarray], jnp.ndarray],
               Xs: Sequence[jnp.ndarray], k: int) -> float:
    """``Err = E[||X − B_k(X)||²] − E[Δ_k]`` over a test set."""
    errs, apps = [], []
    for X in Xs:
        errs.append(float(reconstruction_loss(X, sketch_fn(X), k)))
        apps.append(float(best_rank_k_loss(X, k)))
    return float(np.mean(errs) - np.mean(apps))


# ---------------------------------------------------------------------------
# Baseline sketches
# ---------------------------------------------------------------------------

def cw_pattern(key: jax.Array, n: int, ell: int, nnz_per_col: int = 1
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Random sparsity pattern: rows[i, j] = target row of the j-th nonzero of
    column i. Returns (rows (n, nnz), signs (n, nnz))."""
    kr, ks = jax.random.split(key)
    rows = jax.random.randint(kr, (n, nnz_per_col), 0, ell)
    signs = jax.random.rademacher(ks, (n, nnz_per_col), dtype=jnp.float32)
    return np.asarray(rows), np.asarray(signs)


def sparse_sketch_matrix(rows: np.ndarray, values: jnp.ndarray, ell: int
                         ) -> jnp.ndarray:
    """Materialize an ℓ×n sparse sketch from (pattern, values) — dense layout
    (test-scale ℓ·n), scatter-add semantics."""
    n, nnz = rows.shape
    M = jnp.zeros((ell, n), values.dtype)
    cols = jnp.broadcast_to(jnp.arange(n)[:, None], (n, nnz))
    return M.at[jnp.asarray(rows), cols].add(values)


def gaussian_sketch(key: jax.Array, n: int, ell: int) -> jnp.ndarray:
    return jax.random.normal(key, (ell, n)) / math.sqrt(ell)


# ---------------------------------------------------------------------------
# Training loops
# ---------------------------------------------------------------------------

def train_butterfly_sketch(spec: SketchSpec, key: jax.Array,
                           Xs: Sequence[jnp.ndarray], steps: int,
                           lr: float = 1e-3, batch: int = 1,
                           log_every: int = 0) -> Tuple[jnp.ndarray, list]:
    """Learn butterfly stage weights minimizing the empirical sketch loss."""
    w = bf.fjlt_weights(key, spec.pad_n)
    tx = opt.adamw(lr)
    state = tx.init(w)
    data = jnp.stack(list(Xs))                         # (t, n, d)

    def batch_loss(w, Xb):
        losses = jax.vmap(
            lambda X: reconstruction_loss(
                X, butterfly_sketch(spec, w, X), spec.k))(Xb)
        return jnp.mean(losses)

    @jax.jit
    def step(w, state, idx):
        loss, grads = jax.value_and_grad(batch_loss)(w, data[idx])
        updates, state = tx.update(grads, state, w)
        return opt.apply_updates(w, updates), state, loss

    rng = np.random.default_rng(0)
    history = []
    t = data.shape[0]
    for i in range(steps):
        idx = jnp.asarray(rng.choice(t, size=min(batch, t), replace=False))
        w, state, loss = step(w, state, idx)
        if log_every and (i % log_every == 0 or i == steps - 1):
            history.append(float(loss))
    return w, history


def train_sparse_sketch(key: jax.Array, Xs: Sequence[jnp.ndarray], n: int,
                        ell: int, k: int, steps: int, lr: float = 1e-3,
                        nnz_per_col: int = 1, batch: int = 1,
                        log_every: int = 0
                        ) -> Tuple[np.ndarray, jnp.ndarray, list]:
    """IVY19: learn the values of a fixed CW sparsity pattern (or the dense-N
    variant of paper Figure 8 when ``nnz_per_col > 1``)."""
    kp, kv = jax.random.split(key)
    rows, signs = cw_pattern(kp, n, ell, nnz_per_col)
    values = jnp.asarray(signs)
    tx = opt.adamw(lr)
    state = tx.init(values)
    data = jnp.stack(list(Xs))

    def batch_loss(values, Xb):
        B = sparse_sketch_matrix(rows, values, ell)
        losses = jax.vmap(
            lambda X: reconstruction_loss(X, B @ X, k))(Xb)
        return jnp.mean(losses)

    @jax.jit
    def step(values, state, idx):
        loss, grads = jax.value_and_grad(batch_loss)(values, data[idx])
        updates, state = tx.update(grads, state, values)
        return opt.apply_updates(values, updates), state, loss

    rng = np.random.default_rng(0)
    history = []
    t = data.shape[0]
    for i in range(steps):
        idx = jnp.asarray(rng.choice(t, size=min(batch, t), replace=False))
        values, state, loss = step(values, state, idx)
        if log_every and (i % log_every == 0 or i == steps - 1):
            history.append(float(loss))
    return rows, values, history
