"""ParamSpec trees: one source of truth for shapes, dtypes, sharding and init.

Every model module declares its parameters as a tree of :class:`ParamSpec`
(shape + dtype + *logical axis names* + init rule). From that single tree the
framework derives:

  * materialized parameters (``init_params``),
  * abstract ``ShapeDtypeStruct`` trees for AOT lowering (``abstract_params``),
  * ``PartitionSpec`` trees via the logical-axis rule engine
    (:mod:`repro.runtime.sharding`).

This is the MaxText "logical axis" pattern without the flax dependency.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import butterfly as bf

PyTree = Any


@dataclass(frozen=True)
class ParamSpec:
    """Declaration of a single parameter tensor.

    ``axes``: logical axis name per dim (None = never sharded). Names are
    resolved to mesh axes by :func:`repro.runtime.sharding.logical_to_pspec`.

    ``init``: one of "normal", "scaled_normal" (1/sqrt(fan_in), fan_in = dim
    matching axis name in ``fan_in_axis`` or last dim), "zeros", "ones",
    "fjlt" (butterfly stage weights), "embedding" (normal * scale).
    """

    shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    axes: Tuple[Optional[str], ...] = ()
    init: str = "scaled_normal"
    scale: float = 1.0
    fan_in_dim: int = -1   # dim index used as fan-in for scaled init

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank != shape {self.shape} rank")


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_one(key: jax.Array, spec: ParamSpec) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "fjlt":
        # shape (p, 2, n), possibly with stacked leading layer axes
        n = spec.shape[-1]
        lead = spec.shape[:-3]
        if not lead:
            return bf.fjlt_weights(key, n, dtype=spec.dtype)
        reps = int(np.prod(lead))
        keys = jax.random.split(key, reps)
        ws = jnp.stack([bf.fjlt_weights(k, n, dtype=spec.dtype)
                        for k in keys])
        return ws.reshape(spec.shape)
    if spec.init == "normal":
        return spec.scale * jax.random.normal(key, spec.shape,
                                              dtype=jnp.float32
                                              ).astype(spec.dtype)
    if spec.init == "embedding":
        return spec.scale * jax.random.normal(key, spec.shape,
                                              dtype=jnp.float32
                                              ).astype(spec.dtype)
    if spec.init == "scaled_normal":
        fan_in = spec.shape[spec.fan_in_dim]
        s = spec.scale / math.sqrt(max(fan_in, 1))
        return s * jax.random.normal(key, spec.shape,
                                     dtype=jnp.float32).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(key: jax.Array, specs: PyTree) -> PyTree:
    """Materialize a ParamSpec tree into arrays (deterministic per path)."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = [_init_one(k, s) if is_spec(s) else s
           for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(specs: PyTree) -> PyTree:
    """ShapeDtypeStruct tree for AOT lowering — no allocation."""
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype) if is_spec(s) else s,
        specs, is_leaf=is_spec)


def param_count(specs: PyTree) -> int:
    total = 0
    for s in jax.tree_util.tree_leaves(specs, is_leaf=is_spec):
        if is_spec(s):
            total += int(np.prod(s.shape))
    return total


def param_bytes(specs: PyTree) -> int:
    total = 0
    for s in jax.tree_util.tree_leaves(specs, is_leaf=is_spec):
        if is_spec(s):
            total += int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
    return total


def tree_paths(tree: PyTree) -> Dict[str, Any]:
    """Flatten a tree into {'a/b/c': leaf} path map (debug/checkpointing)."""
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(f"{prefix}/{k}" if prefix else str(k), v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(f"{prefix}/{i}" if prefix else str(i), v)
        else:
            flat[prefix] = node

    rec("", tree)
    return flat
