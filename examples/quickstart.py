"""Quickstart: the paper's butterfly sandwich as a drop-in dense replacement.

Run: ``PYTHONPATH=src python examples/quickstart.py``

Shows (1) the parameter reduction, (2) Proposition 3.1 approximation at
init, (3) trainability — the sandwich learns a random linear map.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layers as bl
from repro.optim import optimizer as opt


def main():
    n = 512
    print(f"== Butterfly sandwich replacing a dense {n}x{n} layer ==")
    spec = bl.make_spec(jax.random.PRNGKey(0), n, n, k_in=64, k_out=64,
                        use_bias=False)
    print(f"dense params:     {bl.dense_param_count(n, n, False):,}")
    print(f"butterfly params: {bl.param_count(spec):,} "
          f"(k_in={spec.k_in}, k_out={spec.k_out})")

    # --- Proposition 3.1: approximate a given W at init ---
    W = np.random.default_rng(0).normal(size=(n, n)).astype(np.float32)
    W /= np.sqrt(n)
    params = bl.init_from_dense(jax.random.PRNGKey(1), spec, jnp.asarray(W))
    x = np.random.default_rng(1).normal(size=(n,)).astype(np.float32)
    x /= np.linalg.norm(x)
    approx = np.asarray(bl.butterfly_linear_apply(spec, params,
                                                  jnp.asarray(x)))
    err = np.linalg.norm(approx - W @ x) / np.linalg.norm(W, 2)
    print(f"init approximation error (k=64): {err:.3f} · ||W||")

    # --- train to recover the map ---
    X = jax.random.normal(jax.random.PRNGKey(2), (1024, n))
    Y = X @ jnp.asarray(W).T

    def loss(p):
        return jnp.mean(jnp.square(bl.butterfly_linear_apply(spec, p, X)
                                   - Y))

    tx = opt.adamw(3e-3)
    state = tx.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(loss)(p)
        u, s = tx.update(g, s, p)
        return opt.apply_updates(p, u), s

    print(f"loss before training: {float(loss(params)):.5f}")
    for i in range(300):
        params, state = step(params, state)
    print(f"loss after 300 steps: {float(loss(params)):.5f}")


if __name__ == "__main__":
    main()
