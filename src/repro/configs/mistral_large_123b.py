"""Mistral-Large-123B — dense [hf:mistralai/Mistral-Large-Instruct-2407]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8,
    d_ff=28672, vocab_size=32768, head_dim=128,
    block_unit=("attn",),
    mlp_variant="swiglu",
    blockwise_threshold=4096,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        name="mistral-large-123b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        blockwise_threshold=64, attn_block_q=16, attn_block_kv=16)
