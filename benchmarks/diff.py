"""Benchmark regression gate: diff a fresh BENCH_*.json against a baseline.

Usage::

    python benchmarks/diff.py BASELINE.json FRESH.json [--threshold 0.25]
                              [--min-us 5000]

Compares ``us_per_call`` of rows present in both files and exits non-zero
when any comparable row regressed by more than ``--threshold`` (fractional;
0.25 = 25% slower than baseline). Rows are *not* comparable — and therefore
never gate — when either side is skipped (``"skipped": true`` /
``us_per_call`` null), is a metric-only row (``us_per_call`` 0), or is
faster than ``--min-us`` in the baseline (sub-threshold timings on shared
CI runners are noise, not signal).

Because the committed baseline and the CI runner are different machines,
ratios are normalized by the median ratio across all comparable rows before
gating (disable with ``--no-normalize``): a uniformly slower host shifts
every row equally and gates nothing, while a genuine kernel regression
stands out against the rest of the suite. Known trade-off: a regression
hitting the *majority* of timed rows moves the median itself and is
absorbed — the gate catches localized regressions, the uploaded
``BENCH_*.json`` artifacts remain the record for across-the-board drifts.
Normalization needs a population to estimate machine speed from, so with
fewer than ``--min-rows`` comparable pairs raw ratios gate instead.
"""

from __future__ import annotations

import argparse
import json
import sys


def load_rows(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    return {r["name"]: r for r in payload.get("rows", [])}


def comparable(row: dict, min_us: float) -> bool:
    if row is None or row.get("skipped"):
        return False
    us = row.get("us_per_call")
    # us == 0.0 marks a metric-only row (derived numbers, no timing): it
    # must neither gate nor enter the median-normalization population
    return us is not None and us > 0 and us >= min_us


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max allowed fractional regression (default 0.25)")
    ap.add_argument("--min-us", type=float, default=5000.0,
                    help="ignore baseline rows faster than this (noise)")
    ap.add_argument("--no-normalize", action="store_true",
                    help="gate on raw ratios (same-machine comparisons)")
    ap.add_argument("--min-rows", type=int, default=5,
                    help="min comparable pairs for median normalization; "
                         "below this, raw ratios gate")
    args = ap.parse_args(argv)

    base = load_rows(args.baseline)
    fresh = load_rows(args.fresh)

    pairs = []
    for name, brow in sorted(base.items()):
        frow = fresh.get(name)
        if not (comparable(brow, args.min_us) and comparable(frow, 0.0)):
            continue
        pairs.append((name, brow["us_per_call"], frow["us_per_call"],
                      frow["us_per_call"] / brow["us_per_call"]))

    speed = 1.0
    if len(pairs) >= args.min_rows and not args.no_normalize:
        ratios = sorted(r for _, _, _, r in pairs)
        speed = ratios[len(ratios) // 2]
        print(f"# machine-speed factor (median ratio): {speed:.2f}x")
    elif pairs and not args.no_normalize:
        print(f"# only {len(pairs)} comparable pair(s) < --min-rows "
              f"{args.min_rows}: gating on raw ratios")

    regressions = []
    compared = len(pairs)
    for name, b_us, f_us, ratio in pairs:
        norm = ratio / speed
        marker = ""
        if norm > 1.0 + args.threshold:
            marker = "  << REGRESSION"
            regressions.append((name, b_us, f_us, norm))
        print(f"{name}: {b_us:.0f}us -> {f_us:.0f}us "
              f"({ratio:.2f}x raw, {norm:.2f}x normalized){marker}")

    # A timed baseline row that vanished from the fresh run — or came back
    # skipped/untimed — is a gate bypass, not a warning: a renamed/dropped
    # benchmark, a crash before its emit, or a widened skip guard would
    # otherwise let any regression through green.
    missing = [n for n, r in base.items()
               if comparable(r, args.min_us)
               and not comparable(fresh.get(n), 0.0)]

    print(f"# compared {compared} rows, {len(regressions)} regression(s), "
          f"{len(missing)} missing, threshold {args.threshold:.0%}, "
          f"floor {args.min_us:.0f}us")
    for name, b, f, r in regressions:
        print(f"FAIL {name}: {b:.0f}us -> {f:.0f}us ({r:.2f}x)",
              file=sys.stderr)
    for n in missing:
        print(f"FAIL timed baseline row {n!r} missing or skipped in "
              f"fresh run", file=sys.stderr)
    return 1 if regressions or missing else 0


if __name__ == "__main__":
    raise SystemExit(main())
