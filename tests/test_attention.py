"""Attention equivalences: masked == blockwise == flash oracle; sliding
windows; and the critical prefill+decode == full-forward consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.kernels import ref
from repro.models import attention as attn
from repro.models import lm
from repro.runtime import pytree as pt


def _qkv(B=2, S=64, KV=2, G=2, D=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, KV, G, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    return q, k, v


@pytest.mark.parametrize("window", [0, 24])
@pytest.mark.parametrize("dynamic", [True, False])
def test_blockwise_matches_masked(window, dynamic):
    B, S, KV, G, D = 2, 64, 2, 2, 16
    q, k, v = _qkv(B, S, KV, G, D)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    want = attn._attend_masked(q, k, v, pos, pos, causal=True, window=window)
    got = attn._attend_blockwise(q, k, v, causal=True, window=window,
                                 block_q=16, block_kv=16,
                                 dynamic_bounds=dynamic)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_masked_matches_flash_oracle():
    """Grouped-query masked attention == reference flash oracle with
    explicitly repeated KV heads."""
    B, S, KV, G, D = 2, 32, 2, 3, 8
    q, k, v = _qkv(B, S, KV, G, D, seed=1)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    got = attn._attend_masked(q, k, v, pos, pos, causal=True, window=0)
    H = KV * G
    qh = q.reshape(B, S, H, D).transpose(0, 2, 1, 3)
    krep = jnp.repeat(k, G, axis=2).transpose(0, 2, 1, 3)
    vrep = jnp.repeat(v, G, axis=2).transpose(0, 2, 1, 3)
    want = ref.flash_attention_ref(qh, krep, vrep, causal=True)
    want = want.transpose(0, 2, 1, 3).reshape(B, S, KV, G, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Serving consistency: prefill + decode must reproduce the full forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", [
    "smollm-135m", "gemma3-27b", "recurrentgemma-2b", "xlstm-125m",
    "seamless-m4t-medium", "internvl2-1b", "olmoe-1b-7b",
])
def test_prefill_decode_matches_full_forward(arch):
    """Prefill S tokens, then decode token S; logits must match a full
    forward over S+1 tokens (validates every cache implementation: ring
    buffers, RG-LRU state, mLSTM/sLSTM state, cross-attention KV)."""
    cfg = registry.get(arch + "-smoke").with_(compute_dtype="float32")
    if cfg.n_experts:
        # a *dropping* MoE is not step-invariant by design (capacity depends
        # on the token count); disable drops to test cache consistency
        cfg = cfg.with_(capacity_factor=64.0)
    specs = lm.model_specs(cfg)
    params = pt.init_params(jax.random.PRNGKey(0), specs)
    B, S = 2, 24
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)),
                       jnp.int32)
    batch_full = {"tokens": toks}
    batch_prefill = {"tokens": toks[:, :S]}
    if cfg.frontend == "vision":
        fe = jnp.asarray(rng.normal(size=(B, cfg.frontend_tokens,
                                          cfg.d_model)), jnp.float32)
        batch_full["frontend_embeds"] = fe
        batch_prefill["frontend_embeds"] = fe
    if cfg.n_enc_layers:
        fr = jnp.asarray(rng.normal(size=(B, cfg.enc_seq, cfg.d_model)),
                         jnp.float32)
        batch_full["frames"] = fr
        batch_prefill["frames"] = fr

    # full forward logits at position S (predicting token S+1)
    full_logits = _forward_logits(cfg, params, batch_full)   # (B, S+1, V)

    caches = lm.init_caches(cfg, B, S + 1)
    _, caches = lm.prefill(cfg, params, batch_prefill, caches)
    extra = cfg.frontend_tokens if cfg.frontend == "vision" else 0
    logits_dec, _ = lm.decode_step(cfg, params, toks[:, S], caches,
                                   jnp.asarray(S + extra, jnp.int32))
    want = full_logits[:, S + extra]
    np.testing.assert_allclose(np.asarray(logits_dec), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def _forward_logits(cfg, params, batch):
    from repro.models import common as cm
    tokens = batch["tokens"]
    x = lm.embed_inputs(cfg, params, tokens, batch.get("frontend_embeds"))
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    enc_out = None
    if cfg.n_enc_layers:
        enc_out = lm.run_encoder(cfg, params, batch["frames"])
    x, _, _ = lm.backbone(cfg, params, x, positions=positions, mode="train",
                          enc_out=enc_out)
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return cm.head_apply(cfg, params["head"], params["embed"], x)


def test_multi_step_decode_consistency():
    """Greedy decode 4 tokens step-by-step == teacher-forced full forward."""
    cfg = registry.get("smollm-135m-smoke").with_(compute_dtype="float32")
    params = pt.init_params(jax.random.PRNGKey(1), lm.model_specs(cfg))
    B, S, T = 1, 16, 4
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    caches = lm.init_caches(cfg, B, S + T)
    logits, caches = lm.prefill(cfg, params, {"tokens": toks}, caches)
    seq = [int(jnp.argmax(logits[0]))]
    for t in range(T - 1):
        logits, caches = lm.decode_step(
            cfg, params, jnp.asarray([seq[-1]], jnp.int32), caches,
            jnp.asarray(S + t, jnp.int32))
        seq.append(int(jnp.argmax(logits[0])))
    # teacher-forced check
    all_toks = jnp.concatenate(
        [toks, jnp.asarray([seq[:-1]], jnp.int32)], axis=1)
    full = _forward_logits(cfg, params, {"tokens": all_toks})
    want = [int(jnp.argmax(full[0, S - 1 + t])) for t in range(T)]
    assert seq == want
