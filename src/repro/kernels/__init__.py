"""Fused Pallas kernels for the paper's compute hot-spots.

``repro.kernels.ops`` is the public, backend-dispatched entry point; the
per-kernel modules (``butterfly``, ``sandwich``, ``flash``) hold the kernel
bodies and ``repro.kernels.ref`` the pure-jnp oracles.
"""

from repro.kernels.ops import (Backend, butterfly_apply, one_hot_select,
                               resolve_backend, sandwich_apply)

__all__ = ["Backend", "butterfly_apply", "one_hot_select",
           "resolve_backend", "sandwich_apply"]
