"""Paper-facing behaviour: Proposition 3.1 sandwich approximation, Theorem 1
critical-point loss, two-phase training (§5.3) and learned sketching (§6)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import butterfly as bf
from repro.core import encdec
from repro.core import layers as bl
from repro.core import sketch


# ---------------------------------------------------------------------------
# §3.2 sandwich
# ---------------------------------------------------------------------------

def test_sandwich_exact_at_full_k():
    """k = n makes J orthogonal-square ⇒ sandwich reproduces W exactly."""
    n = 64
    W = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (n, n))) / 8
    spec = bl.make_spec(jax.random.PRNGKey(1), n, n, k_in=n, k_out=n,
                        use_bias=False)
    params = bl.init_from_dense(jax.random.PRNGKey(2), spec, jnp.asarray(W))
    x = jax.random.normal(jax.random.PRNGKey(3), (7, n))
    got = bl.butterfly_linear_apply(spec, params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x) @ W.T,
                               atol=2e-4)


def test_sandwich_error_decreases_with_k():
    n = 128
    W = np.array(jax.random.normal(jax.random.PRNGKey(4), (n, n)))
    W = W / np.sqrt(n)
    x = np.array(jax.random.normal(jax.random.PRNGKey(5), (n,)))
    x = x / np.linalg.norm(x)
    errs = []
    for k in (8, 32, 96, 128):
        spec = bl.make_spec(jax.random.PRNGKey(6), n, n, k_in=k, k_out=k,
                            use_bias=False)
        p = bl.init_from_dense(jax.random.PRNGKey(7), spec, jnp.asarray(W))
        approx = np.asarray(bl.butterfly_linear_apply(spec, p,
                                                      jnp.asarray(x)))
        errs.append(np.linalg.norm(approx - W @ x))
    assert errs[-1] < 1e-3
    assert errs[0] > errs[1] > errs[2] > errs[3]


def test_sandwich_param_count_near_linear():
    """Paper's headline: n² -> O(n log n) parameters."""
    for n in (256, 1024, 4096):
        spec = bl.make_spec(jax.random.PRNGKey(8), n, n)
        dense = bl.dense_param_count(n, n)
        ours = bl.param_count(spec)
        assert ours < dense / 7           # 7.7x at n=256, 84x at n=4096
        assert ours < 13 * n * np.log2(n)  # near-linear growth


def test_sandwich_trainable_recovers_linear_map():
    """Gradient training of the sandwich fits a random dense map far beyond
    its init accuracy (what §5.1 relies on)."""
    from repro.optim import optimizer as opt
    n, k = 32, 16
    W = np.asarray(jax.random.normal(jax.random.PRNGKey(9), (n, n))) \
        / np.sqrt(n)
    spec = bl.make_spec(jax.random.PRNGKey(10), n, n, k_in=k, k_out=k,
                        use_bias=False)
    params = bl.init_from_dense(jax.random.PRNGKey(11), spec, jnp.asarray(W))
    X = jax.random.normal(jax.random.PRNGKey(12), (256, n))
    Y = X @ jnp.asarray(W).T

    def loss(p):
        return jnp.mean(jnp.square(bl.butterfly_linear_apply(spec, p, X)
                                   - Y))

    tx = opt.adamw(1e-2)
    state = tx.init(params)
    l0 = float(loss(params))
    step = jax.jit(lambda p, s: _step(loss, tx, p, s))
    for _ in range(150):
        params, state = step(params, state)
    l1 = float(loss(params))
    assert l1 < 0.2 * l0


def _step(loss, tx, p, s):
    from repro.optim import optimizer as opt
    g = jax.grad(loss)(p)
    u, s = tx.update(g, s, p)
    return opt.apply_updates(p, u), s


# ---------------------------------------------------------------------------
# §4 Theorem 1
# ---------------------------------------------------------------------------

def test_theorem1_closed_form_matches_prediction():
    """Full-rank X: the loss at the closed-form (D,E) optimum equals
    tr(YYᵀ) − Σ_{i∈[k]} λ_i(Σ(B)) exactly (Theorem 1 with I=[k])."""
    n = d = 48
    X = jnp.asarray(np.random.default_rng(0).normal(size=(n, d)))
    spec = encdec.make_spec(jax.random.PRNGKey(0), n=n, d=d, k=5)
    params = encdec.init_params(jax.random.PRNGKey(1), spec)
    D, E = encdec.optimal_DE(spec, params["B"], X, X)
    loss = float(encdec.loss_fn(spec, dict(params, D=D, E=E), X, X))
    pred = float(encdec.theorem1_loss(spec, params["B"], X, X))
    np.testing.assert_allclose(loss, pred, rtol=1e-4)


def test_theorem1_suboptimal_subset_is_saddle_direction():
    """Loss with eigvecs I ≠ [k] is strictly worse (the theorem's saddle
    classification)."""
    n = d = 32
    X = jnp.asarray(np.random.default_rng(1).normal(size=(n, d)))
    spec = encdec.make_spec(jax.random.PRNGKey(2), n=n, d=d, k=4)
    params = encdec.init_params(jax.random.PRNGKey(3), spec)
    Xt = encdec.apply_B(spec, params["B"], X)
    G = Xt @ Xt.T
    Ginv = encdec._pinv(G)
    S = encdec.sigma_B(spec, params["B"], X, X)
    lam, U = jnp.linalg.eigh(S)
    U = U[:, ::-1]
    # pick I = {0,1,2,5} instead of [4]
    Uk = U[:, jnp.asarray([0, 1, 2, 5])]
    D = Uk
    E = Uk.T @ X @ Xt.T @ Ginv
    loss_bad = float(encdec.loss_fn(spec, dict(params, D=D, E=E), X, X))
    pred_opt = float(encdec.theorem1_loss(spec, params["B"], X, X))
    assert loss_bad > pred_opt + 1e-3


def test_phase1_training_reaches_theory(tmp_path):
    """§5.3 phase 1: training (D,E) with frozen B converges to the Theorem 1
    optimum (local = global when B is fixed); phase 2 (training B too) does
    not regress."""
    n, d, r, k = 32, 32, 8, 4
    U = np.linalg.qr(np.random.default_rng(0).normal(size=(n, r)))[0]
    C = np.random.default_rng(1).normal(scale=0.3, size=(r, d))
    X = jnp.asarray(U @ C)
    spec = encdec.make_spec(jax.random.PRNGKey(4), n=n, d=d, k=k)
    params = encdec.init_params(jax.random.PRNGKey(5), spec)
    pred = float(encdec.theorem1_loss(spec, params["B"], X, X))
    params1, _ = encdec.train(spec, params, X, X, steps=1500, lr=1e-2,
                              train_B=False)
    l1 = float(encdec.loss_fn(spec, params1, X, X))
    assert l1 < pred * 1.05 + 1e-3
    params2, _ = encdec.train(spec, params1, X, X, steps=300, lr=1e-3,
                              train_B=True)
    l2 = float(encdec.loss_fn(spec, params2, X, X))
    assert l2 <= l1 * 1.02 + 1e-6


def test_encdec_loss_close_to_pca():
    """§5.2 claim: encoder-decoder butterfly loss ≈ Δ_k."""
    n, d, r, k = 64, 64, 8, 8
    U = np.linalg.qr(np.random.default_rng(2).normal(size=(n, r)))[0]
    C = np.random.default_rng(3).normal(scale=0.3, size=(r, d))
    X = jnp.asarray(U @ C)
    spec = encdec.make_spec(jax.random.PRNGKey(6), n=n, d=d, k=k)
    params = encdec.init_params(jax.random.PRNGKey(7), spec)
    pca = float(encdec.pca_loss(X, X, k))       # = 0 for rank-8 data, k=8
    D, E = encdec.optimal_DE(spec, params["B"], X, X)
    loss = float(encdec.loss_fn(spec, dict(params, D=D, E=E), X, X))
    assert loss <= pca + 0.05 * float(jnp.sum(X * X))


# ---------------------------------------------------------------------------
# §6 sketching
# ---------------------------------------------------------------------------

def _sketch_dataset(n=32, d=24, t=10, seed=0):
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(n, d)) @ np.diag(np.linspace(1, 0.05, d))
    return [jnp.asarray(base + 0.1 * rng.normal(size=(n, d)))
            for _ in range(t)]


def test_learned_butterfly_sketch_beats_random():
    Xs = _sketch_dataset()
    spec = sketch.make_spec(jax.random.PRNGKey(0), n=32, ell=8, k=4)
    w, _ = sketch.train_butterfly_sketch(spec, jax.random.PRNGKey(1), Xs,
                                         steps=80, lr=3e-3, batch=4)
    err_learned = sketch.test_error(
        lambda X: sketch.butterfly_sketch(spec, w, X), Xs, 4)
    w0 = bf.fjlt_weights(jax.random.PRNGKey(2), spec.pad_n)
    err_rand = sketch.test_error(
        lambda X: sketch.butterfly_sketch(spec, w0, X), Xs, 4)
    g = sketch.gaussian_sketch(jax.random.PRNGKey(3), 32, 8)
    err_gauss = sketch.test_error(lambda X: g @ X, Xs, 4)
    assert err_learned < err_rand
    assert err_learned < err_gauss


def test_learned_sparse_baseline_trains():
    Xs = _sketch_dataset(seed=5)
    rows, values, hist = sketch.train_sparse_sketch(
        jax.random.PRNGKey(4), Xs, n=32, ell=8, k=4, steps=60, lr=3e-3,
        batch=4, log_every=59)
    assert hist[-1] <= hist[0]
