"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run must set XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit-sharding axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto on every axis
    AxisType = None


def _mesh(shape: Tuple[int, ...], axes: Tuple[str, ...], devices) -> Mesh:
    """``jax.make_mesh`` with Auto axis types when the jax version has them
    (older ``make_mesh`` signatures take no ``axis_types`` at all)."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes, devices=devices,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single-pod 16x16 (data, model) or 2-pod 2x16x16 (pod, data, model).

    256 chips/pod (TPU v5e pod slice); the multi-pod mesh prepends a DCN
    ``pod`` axis that composes with ``data`` for cross-pod data parallelism.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"mesh {shape} needs {ndev} devices but only {len(devices)} are "
            f"visible; the dry-run entrypoint must set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={ndev} before "
            f"importing jax")
    return _mesh(shape, axes, devices[:ndev])


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None) -> Mesh:
    """General mesh helper used by tests and the elastic re-mesh planner."""
    devices = list(devices if devices is not None else jax.devices())
    ndev = int(np.prod(shape))
    return _mesh(tuple(shape), tuple(axes), devices[:ndev])


def single_device_mesh() -> Mesh:
    """1-device mesh with the production axis names (smoke tests)."""
    return make_mesh((1, 1), ("data", "model"))
