"""RecurrentGemma-2B — Griffin hybrid: RG-LRU + local attention, 1:2
attn:recurrent [arXiv:2402.19427]. 26 layers = 8 x (rec, rec, attn) + 2 rec
tail; attention layers use a 2048-token sliding window (MQA, kv=1)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab_size=256000, head_dim=256,
    sliding_window=2048, lru_width=2560, conv_width=4,
    block_unit=("rec", "rec", "local"),
    mlp_variant="geglu",
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        name="recurrentgemma-2b-smoke", n_layers=5, d_model=64, n_heads=4,
        n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=512, lru_width=64,
        sliding_window=16, blockwise_threshold=64,
        attn_block_q=16, attn_block_kv=16)
