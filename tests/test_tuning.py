"""The VMEM/roofline kernel autotuner (repro.kernels.tuning).

The tuner is pure host-side Python, so these tests pin its contract: chosen
footprints fit the budget, block sizes react to n/dtype/direction, env
overrides win, and the segment default is the ⌈√p⌉ live-tile minimum.
"""

import math

import jax.numpy as jnp
import pytest

from repro.core import butterfly as bf
from repro.kernels import tuning


def test_default_segment_is_ceil_sqrt():
    assert tuning.default_segment(1) == 1
    assert tuning.default_segment(4) == 2
    assert tuning.default_segment(9) == 3
    assert tuning.default_segment(12) == 4
    assert tuning.default_segment(16) == 4
    for p in range(1, 40):
        assert tuning.default_segment(p) == math.ceil(math.sqrt(p))


@pytest.mark.parametrize("kernel", ["butterfly", "sandwich"])
@pytest.mark.parametrize("mode", ["fwd", "bwd"])
def test_choice_fits_vmem_budget(kernel, mode):
    for n in (256, 1024, 4096, 8192, 16384):
        c = tuning.tune(kernel, n, "float32", mode)
        # fits the budget, unless already clamped at the sublane floor
        # (weights alone can exceed the model budget at huge n)
        assert (c.vmem_bytes <= tuning.vmem_budget()
                or c.block_b == tuning.MIN_BLOCK_B), c.summary()
        assert tuning.MIN_BLOCK_B <= c.block_b <= tuning.MAX_BLOCK_B
        assert c.block_b & (c.block_b - 1) == 0          # power of two
        assert 1 <= c.segment <= bf.num_stages(n)


def test_block_b_shrinks_with_n_and_backward():
    prev = None
    for n in (256, 1024, 4096, 8192):
        c_fwd = tuning.tune("butterfly", n, "float32", "fwd")
        c_bwd = tuning.tune("butterfly", n, "float32", "bwd")
        # backward keeps ~2·⌈√p⌉ extra tiles live — never a larger tile
        assert c_bwd.block_b <= c_fwd.block_b
        if prev is not None:
            assert c_bwd.block_b <= prev                 # monotone in n
        prev = c_bwd.block_b
    # the hot case from the ISSUE: n=8192 backward cannot run the old flat
    # 256-row default (it would need >80 MB of VMEM)
    assert tuning.tune("butterfly", 8192, "float32", "bwd").block_b < 256


def test_resolve_overrides_beat_env_and_tuner(monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_BLOCK_B", "32")
    assert tuning.resolve_block_b("butterfly", 1024, jnp.float32,
                                  "fwd") == 32
    # explicit argument beats the env var
    assert tuning.resolve_block_b("butterfly", 1024, jnp.float32, "fwd",
                                  override=64) == 64
    monkeypatch.setenv("REPRO_TUNE_SEGMENT", "2")
    assert tuning.resolve_segment(12) == 2
    assert tuning.resolve_segment(12, override=3) == 3
    # clamped to [1, stages]
    assert tuning.resolve_segment(4, override=99) == 4
    monkeypatch.delenv("REPRO_TUNE_BLOCK_B")
    monkeypatch.delenv("REPRO_TUNE_SEGMENT")
    # without env/override, the shape-less form falls back to ⌈√p⌉
    assert tuning.resolve_segment(12) == tuning.default_segment(12)


def test_flash_blocks_divide_seq_and_env_override(monkeypatch):
    for s in (64, 1024, 4096, 8192):
        bq, bkv = tuning.flash_blocks(s, 64, "float32", "bwd")
        assert s % bq == 0 and s % bkv == 0
    # env override is read outside the cache: it wins even after the same
    # cell was already queried without it
    monkeypatch.setenv("REPRO_TUNE_BLOCK_Q", "16")
    assert tuning.flash_blocks(1024, 64, "float32") == (16, 16)
    monkeypatch.delenv("REPRO_TUNE_BLOCK_Q")
    assert tuning.flash_blocks(1024, 64, "float32") != (16, 16)


def test_vmem_budget_env_not_stale(monkeypatch):
    """REPRO_TUNE_VMEM_BUDGET set after a first query must still apply
    (the budget is part of the cache key, not trapped under it)."""
    before = tuning.tune("butterfly", 4096, "float32", "bwd").block_b
    monkeypatch.setenv("REPRO_TUNE_VMEM_BUDGET", str(2 * 2 ** 20))
    after = tuning.tune("butterfly", 4096, "float32", "bwd").block_b
    assert after < before
    monkeypatch.delenv("REPRO_TUNE_VMEM_BUDGET")
    assert tuning.tune("butterfly", 4096, "float32", "bwd").block_b == before


def test_tune_registry_and_describe():
    tuning.tune("butterfly", 2048, "bfloat16", "bwd")
    entries = tuning.cache_entries()
    assert any("n2048" in k and "bfloat16" in k for k in entries)
    assert "block_b=" in tuning.describe()


def test_bf16_sublane_floor():
    c = tuning.tune("butterfly", 256, "bfloat16", "fwd")
    assert c.block_b >= 16                               # bf16 min sublane
