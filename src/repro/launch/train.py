"""Production training entrypoint.

    python -m repro.launch.train --arch smollm-135m-smoke --steps 200 \
        --seq-len 128 --global-batch 8 --checkpoint-dir /tmp/ckpt

On a real TPU deployment this process runs per host under the cluster
launcher; ``jax.distributed.initialize()`` picks up the pod topology and the
same Trainer/step code shards across it (the dry-run proves the production
mesh compiles for every assigned config). On CPU it trains the smoke
variants end-to-end.

Compute/communication overlap: we enable XLA's latency-hiding scheduler and
async collectives by default (effective on TPU; harmless on CPU).
"""

import argparse
import os

_XLA_PERF_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_megacore_fusion_allow_ags=true "
    "--xla_enable_async_collective_permute=true "
    "--xla_tpu_enable_async_collective_fusion=true"
)
# TPU-only flags (the CPU runtime rejects them): enabled with
# --xla-perf-flags on real hardware.
if "--xla-perf-flags" in os.sys.argv:
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " "
                               + _XLA_PERF_FLAGS).strip()

# Simulated multi-device CPU run (--simulated-devices N): the host device
# count must reach XLA before jax initializes, hence the pre-import peek
# (mirrors the --xla-perf-flags pattern above; shared with launch/serve.py
# via the jax-free _prejax helper).
from repro.launch._prejax import apply_simulated_devices  # noqa: E402

apply_simulated_devices(os.sys.argv)

import jax  # noqa: E402  (after XLA_FLAGS)
import numpy as np  # noqa: E402


def main():
    # allow_abbrev=False: the pre-import argv peeks above match flags by
    # exact spelling, so abbreviations ('--simulated 8') must not be
    # silently accepted by argparse while missing the peek
    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--seq-len", type=int, default=4096)
    ap.add_argument("--global-batch", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup-steps", type=int, default=100)
    ap.add_argument("--weight-decay", type=float, default=0.1)
    ap.add_argument("--grad-compression", default="",
                    choices=["", "topk", "int8"])
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (multihost)")
    ap.add_argument("--xla-perf-flags", action="store_true",
                    help="enable TPU latency-hiding/async-collective flags")
    ap.add_argument("--mesh-shape", default="",
                    help="butterfly data-parallel mesh, e.g. '8' for a "
                         "(data,) mesh or '2x4' for (pod, data); requires "
                         "a butterfly arch (sharded via shard_map)")
    ap.add_argument("--simulated-devices", type=int, default=0,
                    help="force N simulated host devices (CPU; must be >= "
                         "the mesh size). Handled before jax import.")
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    from repro.configs import registry
    from repro.configs.base import TrainConfig
    from repro.train.trainer import Trainer

    cfg = registry.get(args.arch)
    if args.mesh_shape:
        from dataclasses import replace as dc_replace
        if cfg.butterfly is None:
            raise SystemExit(
                f"--mesh-shape needs a butterfly arch (try "
                f"{args.arch}-butterfly); {cfg.name} has no butterfly sites")
        try:
            shape = tuple(int(s) for s in args.mesh_shape.split("x"))
            if not shape or any(s <= 0 for s in shape):
                raise ValueError(shape)
        except ValueError:
            raise SystemExit(
                f"invalid --mesh-shape {args.mesh_shape!r}: expected e.g. "
                f"'8' (data mesh) or '2x4' (pod x data)")
        cfg = cfg.with_(butterfly=dc_replace(cfg.butterfly,
                                             mesh_shape=shape))
    tc = TrainConfig(
        learning_rate=args.lr, warmup_steps=args.warmup_steps,
        total_steps=args.steps, weight_decay=args.weight_decay,
        microbatches=args.microbatches, seed=args.seed,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
        grad_compression=args.grad_compression)

    print(f"[train] {cfg.name} | {jax.process_count()} process(es), "
          f"{jax.device_count()} device(s) | steps={args.steps} "
          f"seq={args.seq_len} batch={args.global_batch} "
          f"µb={args.microbatches}")
    trainer = Trainer(cfg, tc, seq_len=args.seq_len,
                      global_batch=args.global_batch)
    result = trainer.run(args.steps)
    print(f"[train] done: loss {np.mean(result.losses[:5]):.4f} → "
          f"{np.mean(result.losses[-5:]):.4f}; "
          f"median step {np.median(result.step_times) * 1e3:.0f} ms"
          # the resolved ExecutionContext of the run (backend, tiles, mesh)
          + f"; exec [{result.execution.describe()}]"
          + (f"; resumed from step {result.resumed_from}"
             if result.resumed_from else ""))


if __name__ == "__main__":
    main()
