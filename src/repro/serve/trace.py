"""Seeded load generation for the serving tier.

ONE place owns synthetic serving workloads — the trace-replay CLI
(``python -m repro.launch.serve``), the serving benchmarks
(``benchmarks/bench_serving.py``), and the router SLO row all consume
this module, so the same :class:`TraceSpec` replays a *byte-identical*
workload everywhere: same seed, same prompts, same arrival schedule.

Two independent seeded streams make that reproducibility composable:

* the **payload stream** draws prompt lengths and token ids;
* the **arrival stream** draws open-loop inter-arrival gaps.

They are split (``default_rng([seed, k])``), so changing the offered
``rate`` re-times the workload without changing a single prompt token —
an SLO sweep over rates serves the exact same requests at every point.

Arrivals are **open-loop** (the standard for latency benchmarking, e.g.
vLLM's benchmark client): request *i* is submitted at an absolute offset
``t0 + arrival_s[i]`` drawn from a Poisson process at ``rate`` req/s,
regardless of how far behind the server is — so a server slower than the
offered load accumulates queue depth and its tail latency shows it,
instead of the closed-loop failure mode where a slow server politely
throttles its own load generator.

Prompt-length mixes:

* ``"uniform"`` — lengths uniform over ``[min_prompt, max_prompt]`` (the
  PR-5 CLI/bench workload);
* ``"bimodal"`` — alternate short (``[min_prompt, chunk]``, fits one
  prefill chunk) and long (``[chunk + 1, max_prompt]``, spans several)
  prompts, exercising chunked-prefill/decode interleaving (the PR-6
  paged-bench workload).
"""

from __future__ import annotations

import time as time_lib
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.serve.engine import QueueFull, Request

MIXES = ("uniform", "bimodal")


@dataclass(frozen=True)
class TraceSpec:
    """A reproducible serving workload: fully determined by its fields.

    ``rate`` is the mean offered load in req/s (``0`` = the closed burst:
    every request arrives at t=0). ``chunk`` is the bimodal mix's
    short/long boundary — align it with the engine's ``prefill_chunk`` so
    "short" means single-chunk. ``max_new_tokens`` rides along so one
    spec describes the whole request, not just the prompt.
    """

    requests: int
    seed: int = 0
    rate: float = 0.0
    min_prompt: int = 4
    max_prompt: int = 48
    mix: str = "uniform"
    chunk: int = 16
    max_new_tokens: int = 8

    def __post_init__(self):
        if self.requests < 1:
            raise ValueError(f"requests must be >= 1, got {self.requests}")
        if self.rate < 0:
            raise ValueError(f"rate must be >= 0, got {self.rate}")
        if self.mix not in MIXES:
            raise ValueError(f"unknown mix {self.mix!r}: expected one of "
                             f"{MIXES}")
        if not 1 <= self.min_prompt <= self.max_prompt:
            raise ValueError(
                f"need 1 <= min_prompt <= max_prompt, got "
                f"[{self.min_prompt}, {self.max_prompt}]")
        if self.mix == "bimodal" and not (
                self.min_prompt <= self.chunk < self.max_prompt):
            raise ValueError(
                f"bimodal mix needs min_prompt <= chunk < max_prompt so "
                f"both modes are non-empty, got chunk={self.chunk} with "
                f"prompts in [{self.min_prompt}, {self.max_prompt}]")
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{self.max_new_tokens}")


@dataclass(frozen=True)
class TraceItem:
    """One generated request: arrival offset (seconds from trace start)
    plus the :class:`~repro.serve.Request` payload fields."""

    arrival_s: float
    prompt: Tuple[int, ...]
    max_new_tokens: int

    def request(self, **overrides) -> Request:
        kw = dict(prompt=self.prompt, max_new_tokens=self.max_new_tokens)
        kw.update(overrides)
        return Request(**kw)


def _length(rng: np.random.Generator, spec: TraceSpec, i: int) -> int:
    if spec.mix == "bimodal":
        lo, hi = ((spec.min_prompt, spec.chunk) if i % 2 == 0
                  else (spec.chunk + 1, spec.max_prompt))
    else:
        lo, hi = spec.min_prompt, spec.max_prompt
    return int(rng.integers(lo, hi + 1))


def generate(spec: TraceSpec, vocab_size: int) -> List[TraceItem]:
    """Materialize the workload a :class:`TraceSpec` describes.

    Deterministic in ``(spec, vocab_size)``. Prompts come off the payload
    stream, arrival offsets off the arrival stream — so two specs
    differing only in ``rate`` serve identical prompts on different
    schedules.
    """
    if vocab_size < 1:
        raise ValueError(f"vocab_size must be >= 1, got {vocab_size}")
    payload = np.random.default_rng([spec.seed, 0])
    arrival = np.random.default_rng([spec.seed, 1])
    items, t = [], 0.0
    for i in range(spec.requests):
        n = _length(payload, spec, i)
        prompt = tuple(int(v) for v in
                       payload.integers(0, vocab_size, size=n))
        items.append(TraceItem(arrival_s=t, prompt=prompt,
                               max_new_tokens=spec.max_new_tokens))
        if spec.rate > 0:
            t += float(arrival.exponential(1.0 / spec.rate))
    return items


def replay(submit: Callable[[Request], Future], items: List[TraceItem],
           *, request_kw: Optional[dict] = None,
           clock: Callable[[], float] = time_lib.monotonic,
           sleep: Callable[[float], None] = time_lib.sleep,
           ) -> Tuple[List[Future], int]:
    """Open-loop replay: submit each item at its absolute arrival offset.

    ``submit`` is anything with the client submit signature —
    ``ServeClient.submit``, ``Router.submit``, or a bare
    ``ServeEngine.submit`` for synchronous tests. A submit shed with
    :class:`~repro.serve.QueueFull` is *counted, not retried* (an
    open-loop generator never blocks on the server); the return is
    ``(futures, shed)`` with one future per accepted request, in
    submission order. ``request_kw`` forwards extra Request fields
    (``extras`` for frontend archs, ``deadline_s`` for SLO traces, …);
    a callable value is invoked per item (fresh per-request extras).
    """
    t0 = clock()
    futures: List[Future] = []
    shed = 0
    for item in items:
        delay = item.arrival_s - (clock() - t0)
        if delay > 0:
            sleep(delay)
        kw = {}
        for k, v in (request_kw or {}).items():
            kw[k] = v() if callable(v) else v
        try:
            futures.append(submit(item.request(**kw)))
        except QueueFull:
            shed += 1
    return futures, shed
