"""Sharded, async, fault-tolerant checkpointing (no external deps).

Layout per step::

    <dir>/step_000123/
        manifest.json      tree structure, shapes, dtypes, write fingerprint
        arrays.npz         flattened {path: array} (per-host shard on real
                           multihost runs; single file here)
        _COMMITTED         sentinel written last — a checkpoint without it is
                           torn and ignored by restore

Guarantees exercised by tests:
  * atomic commit (tmp dir + rename + sentinel),
  * retention (keep last N),
  * corruption fallback (restore skips torn/corrupt checkpoints and falls
    back to the newest valid one),
  * async save (background thread; ``wait()`` joins),
  * cross-mesh restore — arrays are saved unsharded-logical, so a job
    restarted on a *different* mesh re-sharding via ``jax.device_put`` with
    the new sharding tree (elastic re-mesh path).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SENTINEL = "_COMMITTED"


def load_latest(directory: str, template: PyTree,
                step: Optional[int] = None,
                shardings: Optional[PyTree] = None
                ) -> Tuple[Optional[int], Optional[PyTree], Dict]:
    """Restore the newest valid checkpoint from ``directory`` — the public
    one-shot read path (serving, analysis) that doesn't want to hold a
    :class:`CheckpointManager` for saves.

    Same semantics as :meth:`CheckpointManager.restore`: newest committed
    step first (or exactly ``step`` if given), torn/corrupt checkpoints
    skipped with fallback to the next older valid one. ``template`` only
    has to describe the subtree the caller wants — extra arrays in the
    checkpoint (say the optimizer state, when serving only needs params)
    are ignored. Returns ``(step, tree, extra)`` or ``(None, None, {})``.

    Strictly read-only: unlike constructing a :class:`CheckpointManager`
    (whose init makes the directory for upcoming saves), a missing
    ``directory`` — e.g. a typo'd path — is left missing, so the mistake
    stays visible on the next run instead of turning into a plausible
    empty checkpoint dir.
    """
    if not os.path.isdir(directory):
        return None, None, {}
    return CheckpointManager(directory).restore(template, step=step,
                                                shardings=shardings)


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(f"{prefix}.{k}" if prefix else str(k), node[k])
        elif isinstance(node, tuple) and hasattr(node, "_fields"):
            for f in node._fields:                # NamedTuple (before tuple!)
                rec(f"{prefix}.{f}" if prefix else f, getattr(node, f))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(f"{prefix}[{i}]", v)
        elif node is None:
            flat[prefix + "#none"] = np.zeros((), np.int8)
        else:
            flat[prefix] = np.asarray(node)

    rec("", tree)
    return flat


def _unflatten_into(template: PyTree, flat: Dict[str, np.ndarray]) -> PyTree:
    """Rebuild values following the template's structure."""

    def rec(prefix, node):
        if isinstance(node, dict):
            return {k: rec(f"{prefix}.{k}" if prefix else str(k), node[k])
                    for k in node}
        if isinstance(node, tuple) and hasattr(node, "_fields"):
            return type(node)(*[rec(f"{prefix}.{f}" if prefix else f,
                                    getattr(node, f))
                                for f in node._fields])
        if isinstance(node, (list, tuple)):
            vals = [rec(f"{prefix}[{i}]", v) for i, v in enumerate(node)]
            return type(node)(vals) if isinstance(node, tuple) else vals
        if node is None:
            return None
        if prefix + "#none" in flat:
            return None
        return flat[prefix]

    return rec("", template)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- paths ---------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, _SENTINEL)):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    # -- save ----------------------------------------------------------

    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None,
             async_: bool = False) -> None:
        # materialize on host *before* backgrounding so the live training
        # buffers can keep mutating
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(x) if x is not None else None, tree,
            is_leaf=lambda x: x is None)

        if async_:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host_tree, extra or {})

    def _write(self, step: int, tree: PyTree, extra: Dict) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "paths": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                      for k, v in flat.items()},
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(final, _SENTINEL), "w") as f:
            f.write("ok")
        self._retain()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _retain(self) -> None:
        steps = self.steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore -------------------------------------------------------

    def restore(self, template: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None
                ) -> Tuple[Optional[int], Optional[PyTree], Dict]:
        """Restore the newest valid checkpoint (or ``step``). Falls back to
        older checkpoints on corruption. Returns (step, tree, extra)."""
        candidates = ([step] if step is not None
                      else list(reversed(self.steps())))
        for s in candidates:
            try:
                d = self._step_dir(s)
                with open(os.path.join(d, "manifest.json")) as f:
                    manifest = json.load(f)
                with np.load(os.path.join(d, "arrays.npz")) as z:
                    flat = {k: z[k] for k in z.files}
                tree = _unflatten_into(template, flat)
                if shardings is not None:
                    tree = jax.tree_util.tree_map(
                        lambda a, sh: (jax.device_put(a, sh)
                                       if a is not None else None),
                        tree, shardings,
                        is_leaf=lambda x: x is None)
                return s, tree, manifest.get("extra", {})
            except Exception:
                continue
        return None, None, {}
