"""Pre-jax-import environment setup shared by the launch entry points.

MUST stay importable without touching jax: `--simulated-devices N` has to
reach ``XLA_FLAGS`` before jax initializes its backends, so
``launch/train.py`` and ``launch/serve.py`` call this on raw ``sys.argv``
at module top, before their ``import jax``. Handles both the
space-separated and ``--simulated-devices=N`` spellings; a malformed value
is left for argparse to reject with a proper usage error.
"""

from __future__ import annotations

import os
from typing import Sequence


def apply_simulated_devices(argv: Sequence[str]) -> None:
    for i, arg in enumerate(argv):
        if arg == "--simulated-devices" or arg.startswith(
                "--simulated-devices="):
            ndev = (arg.split("=", 1)[1] if "=" in arg
                    else (argv[i + 1] if i + 1 < len(argv) else ""))
            if ndev.isdigit() and int(ndev) > 0:
                os.environ["XLA_FLAGS"] = (
                    os.environ.get("XLA_FLAGS", "")
                    + f" --xla_force_host_platform_device_count={int(ndev)}"
                ).strip()
            return
