"""The seeded load generator (`repro.serve.trace`): determinism, stream
splitting, mixes, and open-loop replay. Pure-Python — no engine, no jax.
"""

import pytest

from repro.serve import QueueFull, Request
from repro.serve.trace import TraceItem, TraceSpec, generate, replay

VOCAB = 128


def test_same_spec_same_trace():
    spec = TraceSpec(requests=16, seed=5, rate=40.0, mix="bimodal",
                     chunk=16, min_prompt=4, max_prompt=32)
    assert generate(spec, VOCAB) == generate(spec, VOCAB)


def test_rate_changes_arrivals_not_prompts():
    """Payload and arrival streams are split: an SLO sweep over rates
    serves the exact same prompts on different schedules."""
    slow = generate(TraceSpec(requests=12, seed=1, rate=5.0), VOCAB)
    fast = generate(TraceSpec(requests=12, seed=1, rate=500.0), VOCAB)
    assert [i.prompt for i in slow] == [i.prompt for i in fast]
    assert [i.arrival_s for i in slow] != [i.arrival_s for i in fast]


def test_seed_changes_both_streams():
    a = generate(TraceSpec(requests=8, seed=1, rate=50.0), VOCAB)
    b = generate(TraceSpec(requests=8, seed=2, rate=50.0), VOCAB)
    assert [i.prompt for i in a] != [i.prompt for i in b]


def test_closed_burst_arrives_at_zero():
    items = generate(TraceSpec(requests=5, seed=0, rate=0.0), VOCAB)
    assert [i.arrival_s for i in items] == [0.0] * 5


def test_arrivals_are_monotone_and_start_at_zero():
    items = generate(TraceSpec(requests=10, seed=3, rate=100.0), VOCAB)
    arr = [i.arrival_s for i in items]
    assert arr[0] == 0.0
    assert arr == sorted(arr)


def test_uniform_mix_bounds():
    spec = TraceSpec(requests=64, seed=7, min_prompt=4, max_prompt=9)
    for it in generate(spec, VOCAB):
        assert 4 <= len(it.prompt) <= 9
        assert all(0 <= t < VOCAB for t in it.prompt)


def test_bimodal_mix_alternates_short_long():
    spec = TraceSpec(requests=32, seed=7, mix="bimodal", chunk=8,
                     min_prompt=4, max_prompt=24)
    for i, it in enumerate(generate(spec, VOCAB)):
        if i % 2 == 0:
            assert 4 <= len(it.prompt) <= 8       # fits one chunk
        else:
            assert 9 <= len(it.prompt) <= 24      # spans several


def test_spec_validation():
    with pytest.raises(ValueError, match="requests"):
        TraceSpec(requests=0)
    with pytest.raises(ValueError, match="rate"):
        TraceSpec(requests=1, rate=-1.0)
    with pytest.raises(ValueError, match="mix"):
        TraceSpec(requests=1, mix="zipf")
    with pytest.raises(ValueError, match="min_prompt"):
        TraceSpec(requests=1, min_prompt=9, max_prompt=4)
    with pytest.raises(ValueError, match="bimodal"):
        TraceSpec(requests=1, mix="bimodal", chunk=64, min_prompt=4,
                  max_prompt=32)
    with pytest.raises(ValueError, match="max_new_tokens"):
        TraceSpec(requests=1, max_new_tokens=0)
    with pytest.raises(ValueError, match="vocab_size"):
        generate(TraceSpec(requests=1), 0)


def test_item_request_overrides():
    it = TraceItem(arrival_s=0.0, prompt=(1, 2, 3), max_new_tokens=4)
    req = it.request(rid=9, deadline_s=1.5)
    assert isinstance(req, Request)
    assert req.rid == 9 and req.deadline_s == 1.5
    assert tuple(req.prompt) == (1, 2, 3) and req.max_new_tokens == 4


def test_replay_paces_open_loop_and_counts_shed():
    """Virtual clock: replay sleeps exactly up to each absolute arrival
    offset (open loop — lateness is never 'caught up' by shifting later
    arrivals), sheds QueueFull without retrying, and returns futures in
    submission order."""
    items = [TraceItem(arrival_s=t, prompt=(1,), max_new_tokens=1)
             for t in (0.0, 0.1, 0.25)]
    now = [0.0]
    sleeps = []

    def clock():
        return now[0]

    def sleep(dt):
        sleeps.append(round(dt, 6))
        now[0] += dt

    submitted = []

    def submit(req):
        submitted.append(req)
        if len(submitted) == 2:
            raise QueueFull(1)       # second arrival is shed
        return f"fut{len(submitted)}"

    futs, shed = replay(submit, items, clock=clock, sleep=sleep)
    assert futs == ["fut1", "fut3"]
    assert shed == 1
    assert sleeps == [0.1, 0.15]     # absolute offsets, not fixed gaps


def test_replay_forwards_request_kw_and_calls_callables():
    items = [TraceItem(arrival_s=0.0, prompt=(1, 2), max_new_tokens=1)
             for _ in range(3)]
    seen = []
    counter = iter(range(100))

    def submit(req):
        seen.append((req.deadline_s, req.extras))
        return None

    replay(submit, items,
           request_kw={"deadline_s": 9.0,
                       "extras": lambda: {"n": next(counter)}},
           clock=lambda: 0.0, sleep=lambda dt: None)
    assert [d for d, _ in seen] == [9.0] * 3
    assert [e["n"] for _, e in seen] == [0, 1, 2]   # fresh per item
