import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT-compile every (arch × shape × mesh) cell.

MUST be run as its own process (``python -m repro.launch.dryrun``): the
XLA_FLAGS line above executes before any jax import so 512 host devices are
available for the production meshes (16x16 single-pod, 2x16x16 multi-pod).

Per cell:
  * build abstract inputs (ShapeDtypeStruct only — no allocation),
  * ``jax.jit(step, in_shardings, out_shardings, donate).lower().compile()``,
  * print ``memory_analysis()`` (proves HBM fit) and ``cost_analysis()``,
  * derive the three roofline terms (repro.launch.roofline) and write
    ``<out>/<arch>__<shape>__<mesh>.json``.

Any sharding mismatch / compile OOM / unsupported collective here is a bug
in the framework, not an environment problem.
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import (SHAPES, SHAPES_BY_NAME, ModelConfig,
                                ShapeConfig, cell_applicable)
from repro.launch import roofline as rl
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.runtime import sharding as sh
from repro.train import steps as steps_lib


def _tree_shardings_like(tree, leaf_sharding):
    return jax.tree_util.tree_map(lambda _: leaf_sharding, tree)


def make_dryrun_train_step(cfg: ModelConfig, microbatches: int):
    """Explicit-state AdamW train step (params, mu, nu, count, batch):
    state trees mirror the param tree so sharding trees are trivial."""
    b1, b2, eps, lr, wd = 0.9, 0.999, 1e-8, 3e-4, 0.1

    def loss_fn(params, mb):
        return lm.loss_fn(cfg, params, mb)

    def step(params, mu, nu, count, batch):
        if microbatches == 1:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mbs = jax.tree_util.tree_map(split, batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb_step(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), gsum, g)
                return (gsum, lsum + l), None

            (grads, lsum), _ = jax.lax.scan(mb_step, (zero, 0.0), mbs)
            grads = jax.tree_util.tree_map(
                lambda g: g / microbatches, grads)
            loss = lsum / microbatches
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, 1.0 / (gnorm + 1e-9))
        count = count + 1
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)

        def upd(p, m, v, g):
            g = g * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if p.ndim > 1:
                u = u + wd * p.astype(u.dtype)
            return (p - lr * u.astype(p.dtype)).astype(p.dtype), m, v

        out = jax.tree_util.tree_map(upd, params, mu, nu, grads)
        params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree_util.tree_map(lambda t: t[1], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree_util.tree_map(lambda t: t[2], out,
                                    is_leaf=lambda x: isinstance(x, tuple))
        return params, mu, nu, count, loss

    return step


def _fits(compiled, hbm: float = 16e9) -> bool:
    ma = compiled.memory_analysis()
    tot = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
           + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    return tot <= hbm


def choose_microbatches(cfg: ModelConfig, shape: ShapeConfig,
                        n_dp: int) -> int:
    """Bound per-microbatch tokens so activations fit: ~4k tokens/µb for
    wide models, ~8k otherwise (measured: unmicrobatched 64k-token steps
    blow HBM on every arch via attention/logit buffers)."""
    local_batch = max(1, shape.global_batch // n_dp)
    target_tokens = 4096 if cfg.d_model >= 1024 else 8192
    seqs_per_mb = max(1, target_tokens // shape.seq_len)
    return max(1, local_batch // seqs_per_mb)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rules=None, out_dir: Optional[str] = None,
             verbose: bool = True) -> Dict:
    cfg = registry.get(arch)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    ok, reason = cell_applicable(cfg, shape)
    result: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        result["status"] = "skipped"
        result["reason"] = reason
        return result

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = int(np.prod(list(mesh.shape.values())))
    rules = dict(sh.DEFAULT_RULES if rules is None else rules)
    pod_boundary = 256 if multi_pod else 0
    n_dp = (mesh.shape.get("pod", 1) * mesh.shape["data"])

    t0 = time.time()
    with sh.use_sharding(mesh, rules):
        pshard = sp.param_shardings(cfg, mesh, rules)
        pabs = sp.abstract_model(cfg)
        if shape.kind == "train":
            mb = choose_microbatches(cfg, shape, n_dp)
            step = make_dryrun_train_step(cfg, mb)
            bshard = sp.batch_shardings(cfg, shape, mesh, rules)
            babs = sp.batch_specs(cfg, shape)
            count = jax.ShapeDtypeStruct((), jnp.int32)
            rep = sp.replicated(mesh)
            fn = jax.jit(
                step,
                in_shardings=(pshard, pshard, pshard, rep, bshard),
                donate_argnums=(0, 1, 2, 3))
            with mesh:
                lowered = fn.lower(pabs, pabs, pabs, count, babs)
                compiled = lowered.compile()
            result["microbatches"] = mb
        elif shape.kind == "prefill":
            # NOTE: batch-chunked prefill (make_prefill_step(chunks=2)) is
            # only profitable when the chunk boundary aligns with the DP
            # sharding — slicing a batch-sharded cache makes GSPMD gather
            # the full stack (measured 800+GB temp). Single-step prefill is
            # the production default here; see EXPERIMENTS.md §Perf iter 3.
            result["prefill_chunks"] = 1
            step = steps_lib.make_prefill_step(cfg, 1)
            bshard = sp.batch_shardings(cfg, shape, mesh, rules)
            babs = sp.batch_specs(cfg, shape)
            cshard = sp.cache_shardings(cfg, shape, mesh, rules)
            cabs = lm.cache_specs(cfg, shape.global_batch, shape.seq_len)
            fn = jax.jit(step, in_shardings=(pshard, bshard, cshard),
                         donate_argnums=(2,))
            with mesh:
                lowered = fn.lower(pabs, babs, cabs)
                compiled = lowered.compile()
        else:  # decode
            step = steps_lib.make_serve_step(cfg)
            token, cabs, cur = sp.decode_specs(cfg, shape)
            cshard = sp.cache_shardings(cfg, shape, mesh, rules)
            tshard = NamedSharding(mesh, sh.logical_to_pspec(
                ("batch",), token.shape, mesh, rules))
            rep = sp.replicated(mesh)
            fn = jax.jit(step, in_shardings=(pshard, tshard, cshard, rep),
                         donate_argnums=(2,))
            with mesh:
                lowered = fn.lower(pabs, token, cabs, cur)
                compiled = lowered.compile()

        if shape.kind == "prefill" and not _fits(compiled) \
                and shape.global_batch % (2 * n_dp) == 0:
            # production serving splits oversized prefill batches across
            # sequential engine calls; lower the half-batch step and record
            # it (the roofline terms below are per call — 2 calls/batch)
            shape = ShapeConfig(shape.name, shape.seq_len,
                                shape.global_batch // 2, shape.kind)
            result["batch_split"] = 2
            bshard = sp.batch_shardings(cfg, shape, mesh, rules)
            babs = sp.batch_specs(cfg, shape)
            cshard = sp.cache_shardings(cfg, shape, mesh, rules)
            cabs = lm.cache_specs(cfg, shape.global_batch, shape.seq_len)
            fn = jax.jit(steps_lib.make_prefill_step(cfg, 1),
                         in_shardings=(pshard, bshard, cshard),
                         donate_argnums=(2,))
            with mesh:
                compiled = fn.lower(pabs, babs, cabs).compile()

    compile_s = time.time() - t0
    mf, tokens = sp.model_flops(cfg, shape, n_devices)
    total, active = sp.param_counts(cfg)
    report = rl.build_report(
        arch, shape_name, mesh_name, n_devices, compiled,
        pod_boundary=pod_boundary, model_flops=mf,
        params_total=total, params_active=active, tokens=tokens)
    result.update(report.to_dict())
    result["status"] = "ok"
    result["compile_seconds"] = round(compile_s, 2)
    ma = compiled.memory_analysis()
    if verbose:
        print(f"[{arch} × {shape_name} × {mesh_name}] compiled in "
              f"{compile_s:.1f}s")
        print(f"  memory_analysis: {ma}")
        ca = rl.cost_analysis_dict(compiled)
        print(f"  cost: flops/dev={ca.get('flops', 0):.3e} "
              f"bytes/dev={ca.get('bytes accessed', 0):.3e}")
        print(f"  roofline: compute={report.t_compute*1e3:.2f}ms "
              f"memory={report.t_memory*1e3:.2f}ms "
              f"collective={report.t_collective*1e3:.2f}ms "
              f"dominant={report.dominant} "
              f"util={report.flops_utilization:.2f} "
              f"fit={report.hbm_fit}")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir,
                            f"{arch}__{shape_name}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--stop-on-error", action="store_true")
    args = ap.parse_args()

    archs = registry.names() if args.arch == "all" else args.arch.split(",")
    shapes = ([s.name for s in SHAPES] if args.shape == "all"
              else args.shape.split(","))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                try:
                    results.append(run_cell(arch, shape, multi,
                                            out_dir=args.out))
                except Exception as e:
                    failures += 1
                    print(f"[FAIL {arch} × {shape} × "
                          f"{'multi' if multi else 'single'}]: {e}")
                    traceback.print_exc(limit=4)
                    if args.stop_on_error:
                        raise
    ok = sum(1 for r in results if r.get("status") == "ok")
    skipped = sum(1 for r in results if r.get("status") == "skipped")
    print(f"\n=== dry-run: {ok} compiled, {skipped} skipped, "
          f"{failures} failed ===")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
