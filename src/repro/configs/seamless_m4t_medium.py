"""SeamlessM4T-medium — encoder-decoder, multimodal [arXiv:2308.11596].

Per the assignment, the audio frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (B, enc_seq, d_model) consumed by a 12-layer
bidirectional encoder; the 12-layer decoder cross-attends to it. Decode
shapes exercise the decoder with cached cross-attention KV."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab_size=256206, head_dim=64,
    n_enc_layers=12, enc_seq=1536,
    block_unit=("xdec",),
    mlp_variant="gelu_mlp",
    frontend="audio",
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        name="seamless-m4t-medium-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512,
        n_enc_layers=2, enc_seq=24, blockwise_threshold=64,
        attn_block_q=16, attn_block_kv=16)
