"""Config dataclasses: model architecture, shapes, training, runs.

All configs are frozen/hashable so they can be closed over by jit. Every
assigned architecture file in this package exports ``CONFIG`` (the exact
published configuration) and ``smoke()`` (a reduced same-family variant for
CPU tests).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ButterflyConfig:
    """Where/how to apply the paper's butterfly sandwich (§3.2).

    ``sites``: subset of {"lm_head", "mlp", "attn_out", "qkv"}.
    ``k_factor``: multiplies the paper's ``k = log2(n)`` choice.

    The execution fields (``backend``, ``block_b``, ``segment``,
    ``mesh_shape``) are the *config layer* of the
    ``repro.kernels.context.ExecutionContext`` resolution order — lifted
    via ``ExecutionContext.from_butterfly_config`` — so an explicit
    per-call context or an ambient ``use_execution`` block overrides them
    field-wise, and they in turn override the ``REPRO_*`` env vars:

    ``backend``: kernel path for the sandwich ("auto" | "jnp" | "pallas" |
    "pallas_interpret"); "auto" picks the fused Pallas kernels on TPU — for
    training too, now that they carry custom_vjp backward kernels.
    ``block_b``/``segment``: Pallas batch-tile rows and backward checkpoint
    segment; ``None`` (default) defers to the ``repro.kernels.tuning``
    VMEM/roofline autotuner instead of a magic constant.
    ``mesh_shape``: opt-in multi-device execution of the butterfly sites —
    ``(8,)`` builds a ``("data",)`` mesh, ``(2, 4)`` a ``("pod", "data")``
    mesh — and every butterfly site runs batch-sharded under ``shard_map``
    with replicated stage weights and psum'd weight gradients
    (``repro.runtime.butterfly_sharding``). ``None`` (default) keeps the
    single-device path.
    """

    sites: Tuple[str, ...] = ("lm_head",)
    k_factor: float = 1.0
    seed: int = 0
    use_bias: bool = False
    backend: str = "auto"
    block_b: Optional[int] = None
    segment: Optional[int] = None
    mesh_shape: Optional[Tuple[int, ...]] = None


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # --- attention ---
    sliding_window: int = 0        # 0 = full attention
    rope_theta: float = 10000.0
    # --- layer pattern: repeating unit of block types; n_layers =
    #     repeats * len(unit) + tail (tail = unit prefix, unrolled) ---
    block_unit: Tuple[str, ...] = ("attn",)
    # --- moe ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    load_balance_coef: float = 1e-2
    # --- hybrid (RG-LRU / Griffin) ---
    lru_width: int = 0
    conv_width: int = 4
    # --- enc-dec ---
    n_enc_layers: int = 0
    enc_seq: int = 0               # encoder (frontend) sequence length
    # --- frontend stubs (vlm/audio): precomputed embeddings ---
    frontend: str = ""             # "" | "vision" | "audio"
    frontend_tokens: int = 0
    # --- mlp ---
    mlp_variant: str = "swiglu"    # swiglu | geglu | gelu_mlp
    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0
    tie_embeddings: bool = False
    # --- paper technique ---
    butterfly: Optional[ButterflyConfig] = None
    # --- memory/compile knobs (hillclimb levers) ---
    remat: bool = True
    attn_block_q: int = 512        # blockwise attention tile sizes
    attn_block_kv: int = 1024
    blockwise_threshold: int = 8192  # use blockwise attention if S >= this
    mlstm_chunk: int = 256
    moe_token_chunk: int = 8192   # bound the EP dispatch buffer at prefill
    seq_shard_activations: bool = True   # Megatron-style SP on residual

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def unit_repeats(self) -> int:
        return self.n_layers // len(self.block_unit)

    @property
    def tail_layers(self) -> Tuple[str, ...]:
        return self.block_unit[: self.n_layers % len(self.block_unit)]

    @property
    def lru_width_(self) -> int:
        return self.lru_width or self.d_model

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (seq_len, global_batch) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


# Archs with at least one sub-quadratic / bounded-window attention path may
# run the 500k-context decode cell; pure full-attention archs skip it
# (recorded in DESIGN.md §Shape-cell skips and in the dry-run report).
LONG_CONTEXT_OK = ("recurrentgemma-2b", "xlstm-125m", "gemma3-27b")


def cell_applicable(model: "ModelConfig", shape: ShapeConfig
                    ) -> Tuple[bool, str]:
    if shape.name == "long_500k" and model.name not in LONG_CONTEXT_OK:
        return False, "skip: pure full-attention arch at 512k context"
    return True, ""


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0
    microbatches: int = 1          # gradient-accumulation factor
    seed: int = 0
    checkpoint_every: int = 200
    checkpoint_dir: str = ""
    keep_checkpoints: int = 3
    grad_compression: str = ""     # "" | "topk" | "int8"
    grad_compression_ratio: float = 0.01
    log_every: int = 10
