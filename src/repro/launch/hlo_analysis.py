"""Loop-aware cost model over post-SPMD compiled HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of its
trip count (verified in tests) — useless for scan-based models where >95% of
FLOPs live inside the layer scan. This module parses ``compiled.as_text()``
into a computation call graph with a per-computation def-use symbol table,
extracts loop trip counts from the loop conditions, and accumulates:

  * FLOPs — ``dot`` (2 · |out| · contracted dims, operand shapes resolved
    through the symbol table) and ``convolution``; elementwise/reduce ops at
    1 FLOP per output element;
  * HBM traffic — per top-level op: operand bytes read + output bytes
    written (ops *inside* fusion computations are internal and free, which
    matches XLA's fusion memory model);
  * collective payload bytes by kind (all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute), each × the loop
    multiplicity of its enclosing computation, classified ICI vs DCN by
    replica-group span.

This turns the AOT artifact into the roofline's three terms without running
anything — the point of the dry-run methodology.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
    "u64": 8,
}

_SHAPE_RE = re.compile(r"(?P<dtype>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?)\s*([\w\-]+)\(")
_NAME_RE = re.compile(r"%([\w\.\-]+)")


def _shape_elems_bytes(shape_str: str) -> Tuple[int, int, List[int]]:
    """(elements, bytes, dims-of-first-array) over all shapes in the str."""
    elems = 0
    nbytes = 0
    first_dims: List[int] = []
    for i, m in enumerate(_SHAPE_RE.finditer(shape_str)):
        d = m.group("dtype")
        if d not in _DTYPE_BYTES:
            continue
        n = 1
        dims = []
        if m.group("dims"):
            dims = [int(x) for x in m.group("dims").split(",")]
            for x in dims:
                n *= x
        if not first_dims:
            first_dims = dims
        elems += n
        nbytes += n * _DTYPE_BYTES[d]
    return elems, nbytes, first_dims


@dataclass
class OpInfo:
    name: str
    kind: str
    out_elems: int
    out_bytes: int
    out_dims: List[int]
    operands: List[str] = field(default_factory=list)
    called: List[str] = field(default_factory=list)
    line: str = ""


@dataclass
class Computation:
    name: str
    ops: List[OpInfo] = field(default_factory=list)
    symbols: Dict[str, OpInfo] = field(default_factory=dict)
    is_fusion: bool = False


_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE_FLOP_KINDS = (
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "cbrt", "negate", "abs", "sign", "compare", "select",
    "and", "or", "xor", "not", "clamp", "reduce", "reduce-window", "floor",
    "ceil", "round-nearest-afz", "cosine", "sine", "atan2", "remainder",
)

_FREE_KINDS = ("parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "add-dependency", "iota")


def _split_operand_span(line: str, kind: str) -> str:
    """Text of the operand list: between 'kind(' and its matching ')'."""
    start = line.find(kind + "(")
    if start < 0:
        return ""
    i = start + len(kind) + 1
    depth = 1
    j = i
    while j < len(line) and depth:
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            depth -= 1
        j += 1
    return line[i:j - 1]


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        stripped = line.strip()
        if (not line.startswith(" ")) and stripped.endswith("{") \
                and "=" not in stripped.split("(")[0]:
            # computation header: [ENTRY] %name (params...) -> shape {
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)", stripped)
            if m and m.group(1) != "HloModule":
                cur = Computation(name=m.group(1),
                                  is_fusion="fused" in m.group(1))
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    entry_name = cur.name
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        opname, outshape, kind = m.groups()
        out_elems, out_bytes, out_dims = _shape_elems_bytes(outshape)
        info = OpInfo(name=opname, kind=kind, out_elems=out_elems,
                      out_bytes=out_bytes, out_dims=out_dims, line=line)
        span = _split_operand_span(line, kind)
        info.operands = _NAME_RE.findall(span)
        for key in ("calls=", "to_apply=", "body=", "condition="):
            km = re.search(key + r"%?([\w\.\-]+)", line)
            if km:
                info.called.append(km.group(1))
        bm = re.search(r"branch_computations=\{([^}]*)\}", line)
        if bm:
            info.called.extend(n.strip().lstrip("%")
                               for n in bm.group(1).split(","))
        cur.ops.append(info)
        cur.symbols[opname] = info
    return comps, entry_name


def _operand_bytes(comp: Computation, op: OpInfo) -> int:
    total = 0
    for name in op.operands:
        ref = comp.symbols.get(name)
        if ref is not None:
            total += ref.out_bytes
    return total


def _op_hbm_bytes(comp: Computation, op: OpInfo) -> float:
    """HBM traffic model per top-level op.

    In-place windowed ops only touch the window, not the full buffer:
    ``dynamic-slice``/``gather`` read+write the slice; ``dynamic-update-
    slice``/``scatter`` read+write the update region (XLA performs them
    in place inside loop bodies — counting the full operand would inflate
    scanned models by the trip count).
    """
    if op.kind in ("dynamic-slice", "gather"):
        return 2.0 * op.out_bytes
    if op.kind in ("dynamic-update-slice", "scatter"):
        upd = 0
        if len(op.operands) >= 2:
            ref = comp.symbols.get(op.operands[1])
            if ref is not None:
                upd = ref.out_bytes
        return 2.0 * (upd or op.out_bytes // 2)
    if op.kind == "fusion":
        # a fusion may *contain* in-place DUS on a big carry: XLA marks
        # these with "output_to_operand_aliasing" or simply writes the
        # full output; approximate by out + operands but cap operand
        # reads at out_bytes for loop fusions updating big buffers.
        pass
    return float(op.out_bytes + _operand_bytes(comp, op))


def _dot_flops(comp: Computation, op: OpInfo) -> float:
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    lhs_dims: List[int] = []
    if op.operands:
        ref = comp.symbols.get(op.operands[0])
        if ref is not None:
            lhs_dims = ref.out_dims
    if not lhs_dims:
        # inline-shaped operand fallback
        span = _split_operand_span(op.line, "dot")
        _, _, lhs_dims = _shape_elems_bytes(span)
    contracted = 1
    if cm and lhs_dims:
        for idx in (int(i) for i in cm.group(1).split(",") if i != ""):
            if idx < len(lhs_dims):
                contracted *= lhs_dims[idx]
    return 2.0 * op.out_elems * max(contracted, 1)


def _conv_flops(comp: Computation, op: OpInfo) -> float:
    # MACs = |out| · (kernel_spatial · C_in); kernel shape is operand 1
    if len(op.operands) >= 2:
        ref = comp.symbols.get(op.operands[1])
        if ref is not None and ref.out_dims:
            km = re.search(r"dim_labels=\S*", op.line)
            kernel_elems = 1
            for d in ref.out_dims:
                kernel_elems *= d
            # divide out C_out (appears once in kernel dims); approximate
            # C_out as the largest dim matching an output dim
            cout = max((d for d in ref.out_dims if d in op.out_dims),
                       default=1)
            return 2.0 * op.out_elems * max(kernel_elems // max(cout, 1), 1)
    return 2.0 * op.out_elems


def _trip_count(cond: Computation) -> int:
    consts: Dict[str, int] = {}
    for op in cond.ops:
        if op.kind == "constant":
            cm = re.search(r"constant\((\d+)\)", op.line)
            if cm:
                consts[op.name] = int(cm.group(1))
    for op in cond.ops:
        if op.kind == "compare":
            for name in op.operands:
                if name in consts:
                    return max(consts[name], 1)
    if consts:
        return max(consts.values())
    return 1


@dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_ici: float = 0.0
    collective_dcn: float = 0.0
    collective_counts: Dict[str, float] = field(default_factory=dict)
    loops: List[Tuple[str, int]] = field(default_factory=list)

    @property
    def collective_total(self) -> float:
        return self.collective_ici + self.collective_dcn


def _crosses_pod(line: str, pod_boundary: int) -> bool:
    if not pod_boundary:
        return False
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[[0-9,]+\]", line)
    if m:
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        if "]T(" in line:
            span = (group_size - 1) * n_groups + 1
        else:
            span = group_size
        return span > pod_boundary
    g = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if g:
        ids = [int(x) for x in g.group(1).split(",") if x.strip()]
        if ids:
            return len({i // pod_boundary for i in ids}) > 1
    return False


def analyze(text: str, pod_boundary: int = 0) -> HloCost:
    comps, entry = parse_hlo(text)
    cost = HloCost()
    if entry is None:
        return cost
    budget = [500000]

    def walk(comp: Computation, mult: float):
        for op in comp.ops:
            if budget[0] <= 0:
                return
            budget[0] -= 1
            if op.kind == "while":
                body = cond = None
                for callee in op.called:
                    c = comps.get(callee)
                    if c is None:
                        continue
                    if "condition=%" + callee in op.line \
                            or f"condition={callee}" in op.line:
                        cond = c
                    else:
                        body = c
                trips = _trip_count(cond) if cond else 1
                cost.loops.append((op.name, trips))
                if not comp.is_fusion:
                    cost.hbm_bytes += (op.out_bytes
                                       + _operand_bytes(comp, op))
                if body:
                    walk(body, mult * trips)
                continue
            # descend into called computations (fusions count flops only)
            for callee in op.called:
                c = comps.get(callee)
                if c is not None and c is not comp:
                    walk(c, mult)
            # flops
            if op.kind == "dot":
                cost.flops += _dot_flops(comp, op) * mult
            elif op.kind == "convolution":
                cost.flops += _conv_flops(comp, op) * mult
            elif op.kind in _ELEMENTWISE_FLOP_KINDS:
                cost.flops += float(op.out_elems) * mult
            # collectives
            if op.kind in _COLLECTIVES:
                payload = op.out_bytes
                if op.kind == "all-gather":
                    payload = _operand_bytes(comp, op) or op.out_bytes
                cost.collective_bytes[op.kind] = cost.collective_bytes.get(
                    op.kind, 0.0) + payload * mult
                cost.collective_counts[op.kind] = \
                    cost.collective_counts.get(op.kind, 0.0) + mult
                if _crosses_pod(op.line, pod_boundary):
                    cost.collective_dcn += payload * mult
                else:
                    cost.collective_ici += payload * mult
            # HBM traffic: top-level ops only (fusion internals are free)
            if not comp.is_fusion and op.kind not in _FREE_KINDS:
                cost.hbm_bytes += _op_hbm_bytes(comp, op) * mult

    walk(comps[entry], 1.0)
    return cost
