"""Public jit'd entry points for the Pallas kernels.

Execution policy — backend, Pallas tile sizes, mesh — is carried by one
object, :class:`repro.kernels.context.ExecutionContext`, passed as the
``context=`` argument (an :class:`ExecutionContext`, a bare backend string,
or ``None``) or installed ambiently with ``with use_execution(ctx):``. The
resolution order is context > ambient > config default > ``REPRO_*`` env >
autotune/platform; see :mod:`repro.kernels.context`.

* On TPU the default resolves to the compiled Pallas kernels (Mosaic) — for
  inference *and* training: every fused kernel carries a
  :func:`jax.custom_vjp` with a fused Pallas backward pass, so ``jax.grad``
  through these entry points stays on the fast path.
* On CPU (this container) the default resolves to the *pure-jnp oracles*,
  while tests request ``context="pallas_interpret"`` to execute the kernel
  bodies — forward and backward — in Python without hardware.
* A context with ``mesh_shape``/``mesh`` routes the call through
  :mod:`repro.runtime.butterfly_sharding`: activations batch-sharded via
  ``shard_map``, stage weights replicated, weight gradients psum'd through
  the fused custom_vjp backward.
* ``block_b``/``segment`` left unset defer to the
  :mod:`repro.kernels.tuning` VMEM/roofline autotuner.

The pre-context loose kwargs (``backend=``, ``block_b=``, ``segment=``,
``mesh=``, ``mesh_axes=``) are gone — their one-release deprecation shim
was removed; ``context=`` is the only execution-policy argument.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import context as exctx
from repro.kernels import ref as _ref
from repro.kernels.butterfly import butterfly_matmul as _butterfly_pallas
from repro.kernels.context import (Backend, ExecutionContext,
                                   clear_backend_cache, resolve_backend,
                                   use_execution)
from repro.kernels.sandwich import sandwich_matmul as _sandwich_pallas
from repro.kernels.sandwich import one_hot_select
from repro.obs.profiling import annotate as _annotate


def _sharded_route(ctx: ExecutionContext):
    """Resolve a finalized context to (sharding module, axes) when it asks
    for (and the mesh supports) multi-device execution, else None. Imported
    lazily: runtime.butterfly_sharding wraps these entry points, so a
    top-level import would be circular."""
    if ctx.mesh is None:
        return None
    from repro.runtime import butterfly_sharding as bsh
    axes = bsh.data_axes(ctx.mesh, ctx.mesh_axes)
    return (bsh, axes) if axes else None


def _local_butterfly(x: jnp.ndarray, w: jnp.ndarray, *, transpose: bool,
                     ctx: ExecutionContext) -> jnp.ndarray:
    """Single-device dispatch on a *finalized* context: no resolution, no
    mesh routing. The shard_map region closures in
    :mod:`repro.runtime.butterfly_sharding` call this directly so an
    ambient mesh context can never re-route a call that is already inside
    its own shard."""
    with _annotate("butterfly_matmul", ctx):
        if ctx.backend == "jnp":
            return _ref.butterfly_ref(w.astype(x.dtype), x,
                                      transpose=transpose)
        with use_execution(ctx):  # tuning overrides (vmem_budget) see ctx
            return _butterfly_pallas(
                x, w, transpose=transpose,
                block_b=ctx.block_b, segment=ctx.segment,
                interpret=ctx.backend == "pallas_interpret")


def butterfly_apply(x: jnp.ndarray, w: jnp.ndarray, *,
                    transpose: bool = False,
                    context: exctx.ContextLike = None) -> jnp.ndarray:
    """Fused butterfly product over the last axis of ``x``.

    Differentiable under every backend; the Pallas backends use the fused
    custom_vjp backward kernel with segmented stage checkpointing. All
    execution knobs ride ``context`` (module docstring); a context with a
    mesh batch-shards the call over its data axes.
    """
    ctx = exctx.resolve_execution(context)
    route = _sharded_route(ctx)
    if route is not None:
        bsh, axes = route
        return bsh.sharded_butterfly_apply(x, w, context=ctx, axes=axes,
                                           transpose=transpose)
    return _local_butterfly(x, w, transpose=transpose, ctx=ctx)


def sandwich_apply(x: jnp.ndarray, b_in: jnp.ndarray, sel_in: jnp.ndarray,
                   core: jnp.ndarray, sel_out: jnp.ndarray,
                   b_out: jnp.ndarray, *, scale_in: float = 1.0,
                   scale_out: float = 1.0,
                   context: exctx.ContextLike = None) -> jnp.ndarray:
    """Fused butterfly sandwich (dense-layer replacement) over the last axis.

    Differentiable under every backend; the Pallas backends use the fused
    custom_vjp backward kernel with segmented stage checkpointing. All
    execution knobs ride ``context`` (module docstring).
    """
    ctx = exctx.resolve_execution(context)
    route = _sharded_route(ctx)
    if route is not None:
        bsh, axes = route
        return bsh.sharded_sandwich_apply(
            x, b_in, sel_in, core, sel_out, b_out, context=ctx, axes=axes,
            scale_in=scale_in, scale_out=scale_out)
    return _local_sandwich(x, b_in, sel_in, core, sel_out, b_out,
                           scale_in=scale_in, scale_out=scale_out, ctx=ctx)


def _local_sandwich(x, b_in, sel_in, core, sel_out, b_out, *,
                    scale_in: float, scale_out: float,
                    ctx: ExecutionContext) -> jnp.ndarray:
    """Single-device sandwich dispatch on a finalized context (see
    :func:`_local_butterfly`)."""
    with _annotate("sandwich_matmul", ctx):
        if ctx.backend == "jnp":
            return _ref.sandwich_ref(x, b_in, core, b_out, sel_in, sel_out,
                                     scale_in, scale_out)
        with use_execution(ctx):
            return _sandwich_pallas(
                x, b_in, sel_in, core, sel_out, b_out,
                scale_in=scale_in, scale_out=scale_out,
                block_b=ctx.block_b, segment=ctx.segment,
                interpret=ctx.backend == "pallas_interpret")


__all__ = ["butterfly_apply", "sandwich_apply", "one_hot_select", "Backend",
           "ExecutionContext", "use_execution", "resolve_backend",
           "clear_backend_cache"]
