"""Deterministic fault injection for the serving stack.

Every recovery path the engine claims to have — preemption on page
exhaustion, the client's abort-on-crash sweep, torn-checkpoint restore
fallback — must be *exercisable on demand* or it is folklore. A
:class:`FaultInjector` is a seeded schedule of forced failures threaded
through the engine and the page pool: the same seed and the same call
sequence fire the same faults, so a test that provokes a preemption storm
or a mid-tick crash replays bit-identically.

Sites (where a ``check(site)`` call is instrumented):

=================  ========================================================
``pool.alloc``     :meth:`PagedCachePool.alloc_pages` — fires a forced
                   :class:`~repro.serve.cache.PoolExhausted` even when free
                   pages exist. Under eager admission this defers the
                   admission (backpressure); under incremental admission it
                   drives the preemption/recompute path.
``engine.tick``    :meth:`ServeEngine.step`, after admission but before the
                   compute ticks — a mid-tick crash
                   (:class:`InjectedFault`). Whoever drives the loop (the
                   :class:`~repro.serve.client.ServeClient` driver thread)
                   must fail outstanding futures instead of stranding them.
=================  ========================================================

Faults fire either at explicit call ordinals (``at={"pool.alloc": (3, 7)}``
fires the 3rd and 7th allocation) or as a seeded Bernoulli stream
(``rates={"pool.alloc": 0.1}``); both compose. ``calls`` / ``fired``
counters expose the schedule a run actually took.

Torn checkpoints are a *filesystem* fault, so they are injected by
:func:`tear_checkpoint` — it damages the newest on-disk checkpoint the way
a killed writer would (sentinel missing, or committed-but-garbage arrays)
and the restore path must fall back to the newest older valid step.
"""

from __future__ import annotations

import collections
import os
from typing import Dict, Iterable, Mapping, Optional

import numpy as np

from repro.serve.cache import PoolExhausted

#: the instrumented sites a schedule may name (typo'd site names in a
#: schedule raise at construction instead of silently never firing)
SITES = ("pool.alloc", "engine.tick")


class InjectedFault(RuntimeError):
    """A scheduled fault modeling a crash (not backpressure): the engine
    does not catch it — the driver's abort path must. Carries the site and
    call ordinal so a test can assert exactly which scheduled fault it
    observed."""

    def __init__(self, site: str, ordinal: int):
        super().__init__(f"injected fault at {site!r} (call #{ordinal})")
        self.site = site
        self.ordinal = ordinal


class FaultInjector:
    """Seeded, reproducible fault schedule.

    * ``at`` — per-site explicit 1-based call ordinals that always fire.
    * ``rates`` — per-site Bernoulli fire probability, drawn from one
      ``numpy`` Generator seeded with ``seed``: deterministic given the
      seed and the call order (which the engine's single-threaded tick
      loop makes deterministic).
    * ``check(site)`` — instrumented code calls this; it raises the
      site's exception type when the schedule says so
      (:class:`PoolExhausted` for ``pool.alloc``, :class:`InjectedFault`
      otherwise) and returns quietly when it does not.
    """

    def __init__(self, seed: int = 0,
                 rates: Optional[Mapping[str, float]] = None,
                 at: Optional[Mapping[str, Iterable[int]]] = None):
        self.seed = int(seed)
        self.rates: Dict[str, float] = dict(rates or {})
        self.at: Dict[str, frozenset] = {
            site: frozenset(int(n) for n in ordinals)
            for site, ordinals in (at or {}).items()}
        for site in (*self.rates, *self.at):
            if site not in SITES:
                raise ValueError(
                    f"unknown fault site {site!r}: expected one of {SITES}")
        for site, p in self.rates.items():
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"rate for {site!r} must be in [0, 1], "
                                 f"got {p}")
        self._rng = np.random.default_rng(self.seed)
        self.calls: collections.Counter = collections.Counter()
        self.fired: collections.Counter = collections.Counter()

    def check(self, site: str) -> None:
        """Raise the site's fault if the schedule fires at this call."""
        self.calls[site] += 1
        n = self.calls[site]
        fire = n in self.at.get(site, ())
        rate = self.rates.get(site, 0.0)
        if rate > 0.0:
            # draw even when an explicit ordinal already fired, so the
            # stream position depends only on the call sequence
            fire = bool(self._rng.random() < rate) or fire
        if not fire:
            return
        self.fired[site] += 1
        if site == "pool.alloc":
            raise PoolExhausted(
                f"injected exhaustion at pool.alloc call #{n} "
                f"(seed={self.seed})")
        raise InjectedFault(site, n)

    def summary(self) -> Dict[str, Dict[str, int]]:
        """Plain-JSON ``{site: {calls, fired}}`` for metrics/CLI output."""
        return {site: {"calls": int(self.calls.get(site, 0)),
                       "fired": int(self.fired.get(site, 0))}
                for site in SITES
                if self.calls.get(site) or self.fired.get(site)}


# ---------------------------------------------------------------------------
# Filesystem faults: torn / corrupt checkpoints
# ---------------------------------------------------------------------------

def tear_checkpoint(checkpoint_dir: str, mode: str = "torn") -> str:
    """Damage the newest checkpoint under ``checkpoint_dir`` the way a
    killed writer would, and return the damaged step directory.

    * ``mode="torn"`` — remove the ``_COMMITTED`` sentinel: data present,
      commit missing (the writer died between array write and commit).
    * ``mode="corrupt"`` — keep the sentinel but overwrite ``arrays.npz``
      with garbage (committed, then the disk lied).

    Either way, :func:`repro.serve.loader.restore_params` /
    ``checkpoint.load_latest`` must skip the damaged step and fall back to
    the newest older valid one.
    """
    steps = sorted(
        name for name in os.listdir(checkpoint_dir)
        if name.startswith("step_")
        and os.path.isdir(os.path.join(checkpoint_dir, name)))
    if not steps:
        raise FileNotFoundError(
            f"no step_* checkpoints under {checkpoint_dir!r}")
    target = os.path.join(checkpoint_dir, steps[-1])
    sentinel = os.path.join(target, "_COMMITTED")
    if mode == "torn":
        if os.path.exists(sentinel):
            os.remove(sentinel)
    elif mode == "corrupt":
        with open(os.path.join(target, "arrays.npz"), "wb") as f:
            f.write(b"not an npz \x00 torn mid-write")
    else:
        raise ValueError(f"unknown tear mode {mode!r}: expected 'torn' or "
                         f"'corrupt'")
    return target
