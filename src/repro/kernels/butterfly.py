"""Fused multi-stage butterfly Pallas kernel (TPU target).

TPU adaptation of the paper's butterfly product (DESIGN.md §3): instead of
``log n`` separate sparse matmuls (log n HBM round trips, arithmetic
intensity ~1), a single ``pallas_call`` keeps a ``(block_b, n)`` activation
tile resident in VMEM and applies *all* stages before writing back.

Stage ``s`` is ``y = a_s ⊙ x + b_s ⊙ swap_s(x)`` where ``swap_s`` is a
reshape ``(B, n/2t, 2, t)`` + half-swap on the ``2`` axis — strided VPU FMA
traffic only, no gather/scatter. Stage count is static so the loop fully
unrolls at trace time.

VMEM budget: ``block_b · n · 4`` bytes for the tile plus ``2 · n · log n · 4``
for the weights; default ``block_b = 256`` keeps n = 8192 under 12 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.butterfly import num_stages

DEFAULT_BLOCK_B = 256


def _swap_halves(x: jnp.ndarray, stride: int) -> jnp.ndarray:
    """y[i] = x[i ^ stride] along the last axis, via reshape + concat."""
    n = x.shape[-1]
    lead = x.shape[:-1]
    xs = x.reshape(*lead, n // (2 * stride), 2, stride)
    lo = xs[..., 0:1, :]
    hi = xs[..., 1:2, :]
    return jnp.concatenate([hi, lo], axis=-2).reshape(*lead, n)


def _butterfly_kernel(x_ref, w_ref, o_ref, *, stages: int, transpose: bool):
    x = x_ref[...]
    if not transpose:
        for s in range(stages):
            a = w_ref[s, 0, :]
            b = w_ref[s, 1, :]
            x = a * x + b * _swap_halves(x, 1 << s)
    else:
        for s in reversed(range(stages)):
            a = w_ref[s, 0, :]
            b = w_ref[s, 1, :]
            x = a * x + _swap_halves(b * x, 1 << s)
    o_ref[...] = x


@functools.partial(jax.jit,
                   static_argnames=("transpose", "block_b", "interpret"))
def butterfly_matmul(x: jnp.ndarray, w: jnp.ndarray, *,
                     transpose: bool = False,
                     block_b: int = DEFAULT_BLOCK_B,
                     interpret: bool = False) -> jnp.ndarray:
    """Fused butterfly product ``B x`` (or ``Bᵀ x``) over the last axis.

    ``x``: (..., n) with n a power of two; ``w``: (p, 2, n).
    Leading axes are flattened into a batch grid.
    """
    p, two, n = w.shape
    assert two == 2 and (1 << p) == n, f"bad weight shape {w.shape}"
    stages = num_stages(n)
    lead = x.shape[:-1]
    b = 1
    for d in lead:
        b *= d
    x2 = x.reshape(b, n)
    bb = min(block_b, b)
    # pad batch to a multiple of the block
    padded_b = -(-b // bb) * bb
    if padded_b != b:
        x2 = jnp.pad(x2, ((0, padded_b - b), (0, 0)))
    grid = (padded_b // bb,)
    out = pl.pallas_call(
        functools.partial(_butterfly_kernel, stages=stages,
                          transpose=transpose),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
            pl.BlockSpec((p, 2, n), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_b, n), x.dtype),
        interpret=interpret,
    )(x2, w.astype(x.dtype))
    return out[:b].reshape(*lead, n)
