"""Gemma-7B — dense, GeGLU, head_dim 256 [arXiv:2403.08295]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
    d_ff=24576, vocab_size=256000, head_dim=256,
    block_unit=("attn",),
    mlp_variant="geglu",
    tie_embeddings=True,
    blockwise_threshold=4096,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        name="gemma-7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=32, d_ff=128, vocab_size=512,
        blockwise_threshold=64, attn_block_q=16, attn_block_kv=16)
