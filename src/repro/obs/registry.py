"""One lock-protected metrics registry for the whole process.

Typed primitives — :class:`Counter` (monotone), :class:`Gauge`
(set/inc/dec), :class:`Histogram` (bucketed observations) — plus
*callback collectors* (a zero-arg function read at collection time) live
in a single :class:`MetricsRegistry`. The serving engine, router, cache
pool, fault injector, and compile cache all register into one registry,
so there is ONE machine-readable telemetry surface:

* :meth:`MetricsRegistry.snapshot` — a stable JSON document
  (``schema == SNAPSHOT_SCHEMA``) pinned by the golden-schema test.
* :meth:`MetricsRegistry.exposition` — Prometheus-style text, one
  ``# HELP`` / ``# TYPE`` header per metric family.

Callback collectors are the key to cheap instrumentation: the engine
registers ``lambda: self.metrics.preempted`` style closures that read
its live counters, so recording costs nothing extra on the hot path and
``engine.reset_metrics()`` (which swaps the ``EngineMetrics`` object)
is transparently reflected — the closure reads through ``self``.
Re-registering a callback under the same ``(name, labels)`` replaces the
old one (newest wins), so rebuilding an engine against a shared registry
does not error.

Everything here is stdlib-only and thread-safe: one registry ``RLock``
guards structure and every primitive's mutation.
"""

from __future__ import annotations

import bisect
import json
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "SNAPSHOT_SCHEMA", "DEFAULT_BUCKETS"]

#: Version tag stamped into every :meth:`MetricsRegistry.snapshot`.
SNAPSHOT_SCHEMA = "repro.obs/v1"

#: Default histogram buckets (seconds-flavoured, Prometheus-style).
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
    5.0, 10.0,
)

_TYPES = ("counter", "gauge", "histogram")


def _label_key(labels: Optional[Dict[str, Any]]) -> Tuple[Tuple[str, str], ...]:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically increasing counter."""

    __slots__ = ("_lock", "_value")

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self._value = 0

    def inc(self, n: float = 1) -> None:
        if n < 0:
            raise ValueError(f"counters only go up (inc by {n})")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Instantaneous value: set / inc / dec."""

    __slots__ = ("_lock", "_value")

    def __init__(self, lock: threading.RLock):
        self._lock = lock
        self._value = 0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    def inc(self, n: float = 1) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Cumulative-bucket histogram (Prometheus semantics).

    ``value`` renders as ``{"count", "sum", "buckets": {le: cumulative}}``
    with a final ``"+Inf"`` bucket equal to ``count``.
    """

    __slots__ = ("_lock", "_bounds", "_counts", "_sum", "_count")

    def __init__(self, lock: threading.RLock,
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds:
            raise ValueError("histogram needs at least one bucket bound")
        self._lock = lock
        self._bounds = bounds
        self._counts = [0] * (len(bounds) + 1)  # last slot = +Inf overflow
        self._sum = 0.0
        self._count = 0

    @property
    def buckets(self) -> Tuple[float, ...]:
        return self._bounds

    def observe(self, v: float) -> None:
        with self._lock:
            self._counts[bisect.bisect_left(self._bounds, v)] += 1
            self._sum += v
            self._count += 1

    @property
    def value(self) -> Dict[str, Any]:
        with self._lock:
            cum: Dict[str, float] = {}
            running = 0
            for bound, c in zip(self._bounds, self._counts):
                running += c
                cum[repr(bound)] = running
            cum["+Inf"] = self._count
            return {"count": self._count, "sum": self._sum, "buckets": cum}


class MetricsRegistry:
    """Get-or-create registry of named, labelled metrics.

    A metric *family* is a name with a fixed type and help string; each
    distinct label set under it is one sample. Families are either
    primitive-backed (``counter()`` / ``gauge()`` / ``histogram()``
    hand out live objects) or callback-backed
    (``register_callback()`` — read lazily at collection time).
    Mixing the two under one ``(name, labels)`` key raises; so does
    re-declaring a name with a different type.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._types: Dict[str, str] = {}
        self._help: Dict[str, str] = {}
        self._prims: Dict[Tuple[str, tuple], Any] = {}
        self._callbacks: Dict[Tuple[str, tuple], Callable[[], Any]] = {}

    # -- declaration ---------------------------------------------------
    def _declare(self, name: str, mtype: str, help: str) -> None:
        if mtype not in _TYPES:
            raise ValueError(f"unknown metric type {mtype!r}")
        seen = self._types.get(name)
        if seen is not None and seen != mtype:
            raise ValueError(
                f"metric {name!r} already registered as {seen}, not {mtype}")
        self._types[name] = mtype
        if help and not self._help.get(name):
            self._help[name] = help

    def _primitive(self, name: str, mtype: str, help: str,
                   labels: Optional[Dict[str, Any]],
                   factory: Callable[[], Any], cls: type) -> Any:
        key = (name, _label_key(labels))
        with self._lock:
            self._declare(name, mtype, help)
            if key in self._callbacks:
                raise ValueError(
                    f"metric {name!r}{dict(key[1])} is callback-backed")
            prim = self._prims.get(key)
            if prim is None:
                prim = factory()
                self._prims[key] = prim
            elif not isinstance(prim, cls):
                raise ValueError(
                    f"metric {name!r}{dict(key[1])} is not a {cls.__name__}")
            return prim

    def counter(self, name: str, help: str = "",
                labels: Optional[Dict[str, Any]] = None) -> Counter:
        return self._primitive(name, "counter", help, labels,
                               lambda: Counter(self._lock), Counter)

    def gauge(self, name: str, help: str = "",
              labels: Optional[Dict[str, Any]] = None) -> Gauge:
        return self._primitive(name, "gauge", help, labels,
                               lambda: Gauge(self._lock), Gauge)

    def histogram(self, name: str, help: str = "",
                  labels: Optional[Dict[str, Any]] = None,
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._primitive(name, "histogram", help, labels,
                               lambda: Histogram(self._lock, buckets),
                               Histogram)

    def register_callback(self, name: str, fn: Callable[[], Any], *,
                          mtype: str = "gauge", help: str = "",
                          labels: Optional[Dict[str, Any]] = None) -> None:
        """Register a lazily-read collector. Newest wins on re-register."""
        key = (name, _label_key(labels))
        with self._lock:
            self._declare(name, mtype, help)
            if key in self._prims:
                raise ValueError(
                    f"metric {name!r}{dict(key[1])} is primitive-backed")
            self._callbacks[key] = fn

    # -- collection ----------------------------------------------------
    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._types)

    def _collect(self) -> Dict[str, Dict[str, Any]]:
        with self._lock:
            out: Dict[str, Dict[str, Any]] = {
                name: {"type": self._types[name],
                       "help": self._help.get(name, ""),
                       "samples": []}
                for name in sorted(self._types)
            }
            entries = [(k, p, False) for k, p in self._prims.items()]
            entries += [(k, c, True) for k, c in self._callbacks.items()]
            entries.sort(key=lambda e: (e[0][0], e[0][1]))
            for (name, lkey), obj, is_cb in entries:
                if is_cb:
                    value: Any = obj()
                    if self._types[name] != "histogram":
                        value = float(value)
                        if value == int(value):
                            value = int(value)
                else:
                    value = obj.value
                out[name]["samples"].append(
                    {"labels": dict(lkey), "value": value})
            return out

    def snapshot(self) -> Dict[str, Any]:
        """Stable JSON document: the one telemetry schema for the repo."""
        return {"schema": SNAPSHOT_SCHEMA, "metrics": self._collect()}

    def snapshot_json(self) -> str:
        return json.dumps(self.snapshot(), sort_keys=True)

    def exposition(self) -> str:
        """Prometheus-style text exposition."""
        lines: List[str] = []
        for name, fam in self._collect().items():
            if fam["help"]:
                lines.append(f"# HELP {name} {fam['help']}")
            lines.append(f"# TYPE {name} {fam['type']}")
            for sample in fam["samples"]:
                lbl = _fmt_labels(sample["labels"])
                if fam["type"] == "histogram":
                    v = sample["value"]
                    for le, c in v["buckets"].items():
                        blbl = _fmt_labels({**sample["labels"], "le": le})
                        lines.append(f"{name}_bucket{blbl} {c}")
                    lines.append(f"{name}_sum{lbl} {v['sum']}")
                    lines.append(f"{name}_count{lbl} {v['count']}")
                else:
                    lines.append(f"{name}{lbl} {sample['value']}")
        return "\n".join(lines) + "\n"


def _fmt_labels(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"
