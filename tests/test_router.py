"""The multi-replica serving tier (`repro.serve.router` + `repro.serve.
trace`): dispatch policy, typed-backpressure failover, drain/hot-swap,
replica death, and the seeded load generator.

The acceptance properties of the tier:

(a) **scale-out** — under overload, a 2-replica router with the SAME
    total page memory as one replica sustains strictly higher max
    concurrency AND drains the trace in strictly fewer driver passes
    (the scale-out claim, tick-indexed so machine speed is irrelevant);
(b) **losslessness** — drain + checkpoint hot-swap completes with zero
    dropped requests and greedy outputs token-identical to a no-swap
    oracle, with the newest checkpoint deliberately torn so the swap
    exercises the newest-*valid* fallback;
(c) **typed backpressure** — a replica shedding `QueueFull` fails over
    to the next-best replica; only when every live replica sheds does
    the router re-raise to the caller;
(d) **fault isolation** — a replica whose tick raises is marked dead and
    routed around: its in-flight futures get the real error, its queued
    requests requeue onto live replicas, and the tier keeps serving.
"""

import numpy as np
import pytest

from repro.checkpoint.checkpointing import CheckpointManager
from repro.serve import (QueueFull, Request, RequestCancelled, Router,
                         ServeEngine, loader)
from repro.serve import trace as trace_lib
from repro.serve.faults import tear_checkpoint

ARCH = "smollm-135m-smoke"


@pytest.fixture(scope="module")
def cfg():
    from repro.configs import registry
    return registry.get(ARCH)


@pytest.fixture(scope="module")
def params(cfg):
    return loader.init_params(cfg, seed=0)


def _engine(cfg, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("pool", "paged")
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("seed", 0)
    return ServeEngine(cfg, params, **kw)


def _prompts(cfg, n, length=5, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=length).astype(np.int32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# construction + dispatch policy


def test_router_validates_geometry_and_weights(cfg, params):
    e1 = _engine(cfg, params)
    e2 = _engine(cfg, params)
    with pytest.raises(ValueError, match="at least one"):
        Router([])
    with pytest.raises(ValueError, match="distinct"):
        Router([e1, e1])
    with pytest.raises(ValueError, match="uniform"):
        Router([e1, _engine(cfg, params, max_len=64)])
    with pytest.raises(ValueError, match="weights"):
        Router([e1, e2], weights=[1.0])
    with pytest.raises(ValueError, match="positive"):
        Router([e1, e2], weights=[1.0, 0.0])


def test_least_outstanding_dispatch_balances(cfg, params):
    """Equal replicas: submits alternate (scores tie at the submit
    instant only when loads match, and ties break to the lower index)."""
    router = Router([_engine(cfg, params) for _ in range(2)])
    for p in _prompts(cfg, 4):
        router.submit(Request(prompt=p, max_new_tokens=2))
    assert [r.dispatched for r in router.replicas] == [2, 2]
    router.run_until_idle()


def test_weighted_dispatch_prefers_heavy_replica(cfg, params):
    """weight=3 absorbs 3 outstanding before the weight=1 replica wins a
    tie: 4 submits split 3/1."""
    router = Router([_engine(cfg, params) for _ in range(2)],
                    weights=[3.0, 1.0])
    for p in _prompts(cfg, 4):
        router.submit(Request(prompt=p, max_new_tokens=2))
    assert [r.dispatched for r in router.replicas] == [3, 1]
    router.run_until_idle()


def test_queue_full_fails_over_then_sheds(cfg, params):
    """Property (c): per-replica QueueFull is a *routing* signal (fail
    over to the next-best replica); it reaches the caller only when every
    live replica sheds."""
    router = Router(
        [_engine(cfg, params, slots=1, queue_limit=1),
         _engine(cfg, params, slots=1, queue_limit=2)],
        weights=[4.0, 1.0])
    prompts = _prompts(cfg, 5)
    futs = [router.submit(Request(prompt=p, max_new_tokens=2))
            for p in prompts[:3]]
    # r0 (weight 4) took #0, r1 took #1; #2 shed off full r0 onto r1
    assert router.replicas[0].shed == 1
    assert [r.dispatched for r in router.replicas] == [1, 2]
    with pytest.raises(QueueFull):
        router.submit(Request(prompt=prompts[3], max_new_tokens=2))
    assert router.shed == 1          # tier-level shed: EVERY replica full
    router.run_until_idle()
    for f in futs:
        f.result(0)


# ---------------------------------------------------------------------------
# property (a): scale-out under overload at equal page memory


def test_two_replicas_beat_one_at_equal_pages(cfg, params):
    """8 usable pages as one replica vs 4+4 across two: each request
    needs 2 pages (prompt 5 + 8 new = 13 tokens @ page_size 8), so the
    single replica is slot-limited at 2 concurrent while the tier
    reaches 4 — and drains the same trace in strictly fewer driver
    passes."""
    prompts = _prompts(cfg, 8)

    single = _engine(cfg, params, slots=2, num_pages=9)   # 8 usable
    sfuts = [single.submit(Request(prompt=p, max_new_tokens=8))
             for p in prompts]
    sticks = single.run_until_idle()
    for f in sfuts:
        f.result(0)
    ssnap = single.metrics.snapshot()
    assert ssnap["max_concurrent_slots"] == 2

    router = Router([_engine(cfg, params, slots=2, num_pages=5)
                     for _ in range(2)])                  # 4+4 usable
    rfuts = [router.submit(Request(prompt=p, max_new_tokens=8))
             for p in prompts]
    rpasses = router.run_until_idle()
    for f in rfuts:
        f.result(0)
    rsnap = router.snapshot()
    assert rsnap["max_concurrent_slots"] == 4 > ssnap["max_concurrent_slots"]
    assert rpasses < sticks, (
        f"2 replicas took {rpasses} driver passes vs {sticks} single-"
        f"engine ticks for the same trace")
    # same requests, same greedy model: outputs must match exactly
    for sf, rf in zip(sfuts, rfuts):
        assert sf.result(0).tokens == rf.result(0).tokens


# ---------------------------------------------------------------------------
# property (b): drain + checkpoint hot-swap is lossless


def test_drain_requeues_and_hot_swap_is_lossless(cfg, params, tmp_path):
    """Mid-flight drain of replica 0, torn-newest checkpoint swap, then
    finish: zero dropped requests, greedy outputs identical to a no-swap
    single-engine oracle, and the torn step is skipped for the newest
    valid one."""
    prompts = _prompts(cfg, 6)

    oracle = _engine(cfg, params)
    ofuts = [oracle.submit(Request(prompt=p, max_new_tokens=6))
             for p in prompts]
    oracle.run_until_idle()
    want = [f.result(0).tokens for f in ofuts]

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"params": params})
    mgr.save(2, {"params": params})
    tear_checkpoint(str(tmp_path))   # newest (step 2) now unrestorable

    router = Router([_engine(cfg, params) for _ in range(2)])
    futs = [router.submit(Request(prompt=p, max_new_tokens=6))
            for p in prompts]
    router.step()                    # admit some work on both replicas
    assert router.replicas[0].engine.has_work()
    step = router.swap_checkpoint(0, str(tmp_path))
    assert step == 1                 # fell back past the torn step 2
    assert router.swaps == 1
    assert not router.replicas[0].draining     # back in rotation
    router.run_until_idle()
    got = [f.result(0).tokens for f in futs]   # zero dropped: all resolve
    assert got == want
    assert router.snapshot()["requests_finished"] == len(prompts)


def test_drain_moves_queued_work_and_undrain_restores(cfg, params):
    """drain() requeues the draining replica's queued requests onto the
    other replica (the SAME future — no re-submit), and new dispatch
    avoids it until undrain()."""
    router = Router([_engine(cfg, params, slots=1) for _ in range(2)])
    prompts = _prompts(cfg, 4)
    futs = [router.submit(Request(prompt=p, max_new_tokens=2))
            for p in prompts]
    assert [r.dispatched for r in router.replicas] == [2, 2]
    router.drain(0)
    router.step()
    assert router.requeued >= 1      # replica 0's queued moved over
    f = router.submit(Request(prompt=prompts[0], max_new_tokens=2))
    assert router.replicas[1].dispatched == 3   # draining replica skipped
    router.wait_drained(0)
    router.undrain(0)
    router.run_until_idle()
    for fut in futs + [f]:
        fut.result(0)


def test_swap_checkpoint_failure_keeps_replica_serving(cfg, params,
                                                       tmp_path):
    """A swap against an empty checkpoint dir raises, but the replica is
    undrained with its old params and keeps serving."""
    router = Router([_engine(cfg, params) for _ in range(2)])
    with pytest.raises(FileNotFoundError, match="no restorable"):
        router.swap_checkpoint(0, str(tmp_path / "nothing_here"))
    assert not router.replicas[0].draining
    fut = router.submit(Request(prompt=_prompts(cfg, 1)[0],
                                max_new_tokens=2))
    router.run_until_idle()
    fut.result(0)


def test_wait_drained_requires_drain(cfg, params):
    router = Router([_engine(cfg, params)])
    with pytest.raises(RuntimeError, match="not draining"):
        router.wait_drained(0)


def test_cancel_finds_requeued_request(cfg, params):
    """cancel() follows a request that drain moved across replicas."""
    router = Router([_engine(cfg, params, slots=1) for _ in range(2)])
    prompts = _prompts(cfg, 4)
    futs = [router.submit(Request(prompt=p, max_new_tokens=4))
            for p in prompts]
    assert router._owner[2] == 0     # rid 2 landed on replica 0
    router.drain(0)
    router.step()                    # replica 0's queued now on replica 1
    assert router.requeued >= 1
    assert router._owner[2] == 1     # ...and crossed to replica 1
    assert router.cancel(2)
    router.undrain(0)
    router.run_until_idle()
    results = []
    for fut in futs:
        try:
            results.append(fut.result(0).rid)
        except RequestCancelled:
            results.append("cancelled")
    assert results.count("cancelled") == 1


# ---------------------------------------------------------------------------
# property (d): replica death routes around


def test_replica_crash_fails_inflight_and_requeues_queued(cfg, params):
    """A replica whose tick raises: in-flight futures get the REAL
    exception, queued requests requeue onto live replicas, dispatch
    never selects it again, and the tier keeps serving."""
    engines = [_engine(cfg, params, slots=1) for _ in range(2)]
    router = Router(engines)
    prompts = _prompts(cfg, 4)
    futs = [router.submit(Request(prompt=p, max_new_tokens=4))
            for p in prompts]
    router.step()                    # admit one per replica
    assert engines[0].occupied_slots() == 1

    boom = RuntimeError("device melted")
    def bad_step():
        raise boom
    engines[0].step = bad_step
    router.step()                    # replica 0 dies mid-pass
    assert router.replicas[0].dead is boom
    assert router.requeued >= 1      # its queued request moved over

    router.run_until_idle()
    outcomes = []
    for fut in futs:
        try:
            outcomes.append(fut.result(0).rid)
        except RuntimeError as e:
            assert e is boom         # the real error, not a wrapper
            outcomes.append("dead")
    assert outcomes.count("dead") == 1   # only the in-flight casualty
    assert len([o for o in outcomes if o != "dead"]) == 3

    fut = router.submit(Request(prompt=prompts[0], max_new_tokens=2))
    assert router.replicas[1].dispatched >= 3   # dead replica skipped
    router.run_until_idle()
    fut.result(0)
    snap = router.snapshot()
    assert snap["per_replica"][0]["dead"] is not None


def test_all_replicas_dead_refuses_submits(cfg, params):
    engines = [_engine(cfg, params, slots=1)]
    router = Router(engines)
    fut = router.submit(Request(prompt=_prompts(cfg, 1)[0],
                                max_new_tokens=2))
    engines[0].step = lambda: (_ for _ in ()).throw(RuntimeError("rip"))
    router.step()
    with pytest.raises(RuntimeError, match="rip"):
        fut.result(0)
    with pytest.raises(RuntimeError, match="no live replica"):
        router.submit(Request(prompt=_prompts(cfg, 1)[0],
                              max_new_tokens=2))


# ---------------------------------------------------------------------------
# the async tier: one TickDriver thread over all replicas


def test_async_router_serves_open_loop_trace(cfg, params):
    """`with router:` attaches ONE driver thread multiplexing both
    replicas; an open-loop trace replayed against wall-clock arrivals
    finishes with outputs identical to the synchronous run."""
    items = trace_lib.generate(
        trace_lib.TraceSpec(requests=6, seed=3, rate=200.0, min_prompt=4,
                            max_prompt=12, max_new_tokens=4),
        cfg.vocab_size)

    sync = Router([_engine(cfg, params) for _ in range(2)])
    sfuts = [sync.submit(it.request()) for it in items]
    sync.run_until_idle()
    want = [f.result(0).tokens for f in sfuts]

    router = Router([_engine(cfg, params) for _ in range(2)])
    with router:
        futs, shed = trace_lib.replay(router.submit, items)
        got = [f.result(timeout=600).tokens for f in futs]
    assert shed == 0
    assert got == want


def test_router_submit_after_close_raises(cfg, params):
    router = Router([_engine(cfg, params)])
    with router:
        pass
    with pytest.raises(RuntimeError, match="closed"):
        router.submit(Request(prompt=_prompts(cfg, 1)[0],
                              max_new_tokens=2))


def test_snapshot_shape(cfg, params):
    """The tier snapshot is JSON-able and carries the SLO aggregates the
    benchmark row publishes."""
    import json

    router = Router([_engine(cfg, params) for _ in range(2)])
    futs = [router.submit(Request(prompt=p, max_new_tokens=3))
            for p in _prompts(cfg, 4)]
    router.run_until_idle()
    for f in futs:
        f.result(0)
    snap = router.snapshot()
    json.dumps(snap)
    assert snap["replicas"] == 2
    assert snap["requests_finished"] == 4
    assert snap["max_concurrent_slots"] >= 2
    assert snap["ttft_ms"]["p50"] <= snap["ttft_ms"]["p95"]
    assert snap["latency_ms"]["p50"] <= snap["latency_ms"]["p95"]
    assert len(snap["per_replica"]) == 2
    assert sum(p["dispatched"] for p in snap["per_replica"]) == 4
