"""GPipe pipeline parallelism: forward + gradient equivalence with the
unpipelined stack, on 8 simulated devices (subprocess — XLA_FLAGS must be
set before jax initializes)."""

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.runtime.pipeline import pipeline_apply, reference_apply

S, D, B, T = 4, 16, 8, 4
mesh = make_mesh((S, 2), ("stage", "data"))

def stage_fn(params, x):
    return jnp.tanh(x @ params["w"] + params["b"])

key = jax.random.PRNGKey(0)
params = {
    "w": jax.random.normal(key, (S, D, D)) / jnp.sqrt(D),
    "b": jnp.zeros((S, D)),
}
x = jax.random.normal(jax.random.PRNGKey(1), (B, D))

want = reference_apply(stage_fn, params, x)
got = pipeline_apply(stage_fn, params, x, mesh=mesh, microbatches=T)
err = float(jnp.abs(got - want).max())
assert err < 1e-5, f"forward mismatch {err}"

# gradient equivalence
def loss_pipe(p):
    return jnp.sum(pipeline_apply(stage_fn, p, x, mesh=mesh,
                                  microbatches=T) ** 2)
def loss_ref(p):
    return jnp.sum(reference_apply(stage_fn, p, x) ** 2)

g1 = jax.grad(loss_pipe)(params)
g2 = jax.grad(loss_ref)(params)
gerr = max(float(jnp.abs(a - b).max()) for a, b in
           zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)))
assert gerr < 1e-4, f"grad mismatch {gerr}"
print("PIPELINE_OK", err, gerr)
"""


@pytest.mark.slow
def test_gpipe_forward_and_grad_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "PIPELINE_OK" in out.stdout
