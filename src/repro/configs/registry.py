"""Architecture registry: ``get(name)`` resolves assigned archs, their smoke
variants (``<name>-smoke``) and butterfly variants (``<name>-butterfly``,
the paper's §3.2 replacement applied to the LM head + MLP projections)."""

from __future__ import annotations

from typing import Dict, List

from repro.configs import (dbrx_132b, gemma3_27b, gemma_7b, internvl2_1b,
                           mistral_large_123b, olmoe_1b_7b,
                           recurrentgemma_2b, seamless_m4t_medium,
                           smollm_135m, xlstm_125m)
from repro.configs.base import ButterflyConfig, ModelConfig

_MODULES = (olmoe_1b_7b, dbrx_132b, smollm_135m, gemma3_27b, gemma_7b,
            mistral_large_123b, recurrentgemma_2b, xlstm_125m, internvl2_1b,
            seamless_m4t_medium)

ARCHS: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}
SMOKES: Dict[str, ModelConfig] = {m.CONFIG.name: m.smoke() for m in _MODULES}


def butterfly_variant(cfg: ModelConfig, k_factor: float = 1.0,
                      sites=("lm_head", "mlp")) -> ModelConfig:
    """Paper-faithful §3.2 replacement (k = k_factor · log2 n) of the dense
    output head and MLP projections."""
    if cfg.tie_embeddings:
        cfg = cfg.with_(tie_embeddings=False)
    return cfg.with_(name=cfg.name + "-butterfly",
                     butterfly=ButterflyConfig(sites=tuple(sites),
                                               k_factor=k_factor))


def names() -> List[str]:
    return list(ARCHS)


def get(name: str) -> ModelConfig:
    if name in ARCHS:
        return ARCHS[name]
    if name.endswith("-smoke") and name[:-6] in SMOKES:
        return SMOKES[name[:-6]]
    if name.endswith("-butterfly") and name[:-10] in ARCHS:
        return butterfly_variant(ARCHS[name[:-10]])
    if name.endswith("-butterfly-smoke") and name[:-16] in SMOKES:
        return butterfly_variant(SMOKES[name[:-16]]).with_(
            name=name[:-16] + "-butterfly-smoke")
    raise KeyError(f"unknown architecture {name!r}; known: {names()}")
