"""The serving subsystem (`repro.serve`): continuous batching, cache-pool
admission (paged + dense), compile discipline, oracle parity, checkpoint
restore.

The acceptance properties of the engine:

(a) **continuous batching** — a short request admitted after a long one
    finishes first, and its freed slot is refilled from the queue while the
    long request keeps decoding (tick-indexed, so machine speed is
    irrelevant);
(b) **compile discipline** — chunked prefill on the default paged pool
    traces ONE prefill for every prompt length; the dense pool's bucketed
    prefill traces exactly once per (bucket, context) — both gated by the
    engine's CompileCache trace counter;
(c) **oracle parity** — greedy engine outputs equal the single-request
    ``prefill`` + ``decode_step`` oracle per request, on BOTH pool kinds,
    independent of co-batched neighbors (this also proves the page-table
    gather, the chunked prefill split, and the per-slot vector-``cur_pos``
    decode are exact);
(d) **paged capacity** — at equal cache memory, a paged pool sustains
    strictly more concurrent slots than dense, and exhaustion defers
    admission (backpressure) instead of crashing;
(e) **preemptible incremental admission** — a request preempted mid-decode
    (pages freed, requeued, prefix recomputed) resumes to greedy output
    token-identical to the same request on an idle engine, and at equal
    memory incremental admission co-runs a mixed trace that eager
    admission must serialize.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.kernels.context import ExecutionContext
from repro.models import lm
from repro.serve import (GREEDY, Request, SamplingParams, ServeClient,
                         ServeEngine, loader, sample_logits)

ARCH = "smollm-135m-smoke"


@pytest.fixture(scope="module")
def cfg():
    return registry.get(ARCH)


@pytest.fixture(scope="module")
def params(cfg):
    return loader.init_params(cfg, seed=0)


def _prompt(rng, cfg, n):
    return rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)


def _req(prompt, max_new=4, **kw):
    return Request(prompt=prompt, max_new_tokens=max_new, **kw)


def _oracle_generate(cfg, params, prompt, max_new, max_len):
    """Single-request greedy reference: exact-length prefill + scalar-pos
    decode loop (the pre-engine serving path)."""
    caches = lm.init_caches(cfg, 1, max_len)
    logits, caches = lm.prefill(
        cfg, params, {"tokens": jnp.asarray(prompt[None, :])}, caches)
    toks = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(max_new - 1):
        logits, caches = lm.decode_step(
            cfg, params, jnp.asarray([toks[-1]], jnp.int32), caches,
            jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
        pos += 1
    return toks


# ---------------------------------------------------------------------------
# Sampling
# ---------------------------------------------------------------------------

class TestSampling:
    def test_param_validation(self):
        with pytest.raises(ValueError):
            SamplingParams(temperature=-1.0)
        with pytest.raises(ValueError):
            SamplingParams(top_p=0.0)
        with pytest.raises(ValueError):
            SamplingParams(top_k=-1)
        assert GREEDY.greedy and not SamplingParams(temperature=0.7).greedy

    def test_greedy_is_argmax_and_ignores_rng(self):
        logits = jnp.asarray([[0.1, 2.0, -1.0], [3.0, 0.0, 1.0]])
        got = sample_logits(logits, None, GREEDY)
        np.testing.assert_array_equal(np.asarray(got), [1, 0])

    def test_top_k_restricts_support(self):
        logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0]] * 64, jnp.float32)
        sp = SamplingParams(temperature=1.0, top_k=2)
        keys = jax.random.split(jax.random.PRNGKey(0), 16)
        toks = np.concatenate([
            np.asarray(sample_logits(logits, k, sp)) for k in keys])
        assert set(toks.tolist()) <= {2, 3}

    def test_top_k_at_or_above_vocab_is_disabled(self):
        """`top_k >= V` means "no restriction" — it must sample, not crash
        (jax.lax.top_k requires k <= V), and match top_k=0 exactly."""
        logits = jax.random.normal(jax.random.PRNGKey(4), (8, 4))
        key = jax.random.PRNGKey(5)
        for k in (4, 10):
            got = sample_logits(logits, key,
                                SamplingParams(temperature=1.0, top_k=k))
            want = sample_logits(logits, key,
                                 SamplingParams(temperature=1.0, top_k=0))
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want))

    def test_top_p_keeps_nucleus_only(self):
        # one dominant token: p=0.5 nucleus is exactly {3}
        logits = jnp.asarray([[0.0, 0.0, 0.0, 10.0]] * 32, jnp.float32)
        sp = SamplingParams(temperature=1.0, top_p=0.5)
        toks = np.asarray(sample_logits(logits, jax.random.PRNGKey(1), sp))
        assert set(toks.tolist()) == {3}

    def test_stochastic_is_jittable_and_plausible(self):
        sp = SamplingParams(temperature=1.0, top_k=3, top_p=0.9)
        fn = jax.jit(lambda lg, k: sample_logits(lg, k, sp))
        logits = jax.random.normal(jax.random.PRNGKey(2), (8, 32))
        toks = np.asarray(fn(logits, jax.random.PRNGKey(3)))
        assert toks.shape == (8,) and (0 <= toks).all() and (toks < 32).all()


# ---------------------------------------------------------------------------
# (a) continuous batching: slot refill without stalling in-flight requests
# ---------------------------------------------------------------------------

def test_continuous_batching_refills_freed_slot(cfg, params):
    eng = ServeEngine(cfg, params, slots=2, max_len=64, seed=0)
    rng = np.random.default_rng(0)
    fa = eng.submit(_req(_prompt(rng, cfg, 6), max_new=12))    # long
    fb = eng.submit(_req(_prompt(rng, cfg, 5), max_new=3))     # short
    fc = eng.submit(_req(_prompt(rng, cfg, 7), max_new=3))     # queued
    eng.run_until_idle()
    a, b, c = fa.result(0).metrics, fb.result(0).metrics, fc.result(0).metrics

    # A and B were co-batched from tick 0; C had to queue behind them
    assert a.admit_tick == 0 and b.admit_tick == 0
    assert c.admit_tick > b.admit_tick
    # the short request finished first and its slot was handed to C on the
    # NEXT tick — while A was still decoding (no stall, no re-batch barrier)
    assert b.finish_tick < a.finish_tick
    assert c.admit_tick == b.finish_tick + 1
    assert c.finish_tick < a.finish_tick
    # the long request never stalled: a single-chunk prompt admits, samples
    # its first token AND takes that tick's decode in the admission tick
    # (two tokens), then one token per tick — the dense engine's exact
    # arithmetic, preserved by chunked admission for prompts <= one chunk
    assert a.finish_tick - a.admit_tick == a.new_tokens - 2
    assert [len(f.result(0).tokens) for f in (fa, fb, fc)] == [12, 3, 3]


def test_stop_token_frees_slot_early(cfg, params):
    eng = ServeEngine(cfg, params, slots=1, max_len=64, seed=0)
    rng = np.random.default_rng(1)
    prompt = _prompt(rng, cfg, 5)
    # oracle-known second token becomes the stop token
    want = _oracle_generate(cfg, params, prompt, 4, 64)
    fut = eng.submit(_req(prompt, max_new=16, stop_token=want[1]))
    eng.run_until_idle()
    assert fut.result(0).tokens == want[:2]


# ---------------------------------------------------------------------------
# (b) compile discipline
# ---------------------------------------------------------------------------

def test_chunked_prefill_compiles_once_for_all_lengths(cfg, params):
    """The paged default: prompts spanning one, two, and three chunks all
    share ONE chunk-prefill trace — there are no per-bucket prefills."""
    eng = ServeEngine(cfg, params, slots=2, max_len=64, seed=0)
    assert eng.pool.kind == "paged" and eng.prefill_chunk == 16
    rng = np.random.default_rng(2)
    futs = [eng.submit(_req(_prompt(rng, cfg, n), max_new=2))
            for n in (5, 7, 20, 3, 40)]
    eng.run_until_idle()
    for f in futs:
        f.result(0)
    traces = eng.compile_stats["traces"]
    assert not any(k[0] == "prefill" for k in traces), traces
    assert traces[("chunk_prefill", cfg.name, 2, 16, eng.ctx)] == 1
    assert traces[("decode", cfg.name, 2, "paged", GREEDY, eng.ctx)] == 1
    # chunk prefill + pooled decode + first-token sample: three compiles
    # serve every prompt length the engine will ever see
    assert eng.compile_stats["compiles"] == 3


def test_bucketed_prefill_compiles_once_per_bucket(cfg, params):
    eng = ServeEngine(cfg, params, slots=2, max_len=64, seed=0,
                      pool="dense")
    rng = np.random.default_rng(2)
    futs = [eng.submit(_req(_prompt(rng, cfg, n), max_new=2))
            for n in (5, 7, 8, 3, 6)]      # all land in the 8-bucket
    eng.run_until_idle()
    for f in futs:
        f.result(0)
    traces = eng.compile_stats["traces"]
    prefills = {k: v for k, v in traces.items() if k[0] == "prefill"}
    assert list(prefills.values()) == [1], prefills
    ((_, _, bucket, batch, ctx),) = prefills.keys()
    assert (bucket, batch) == (8, 1) and isinstance(ctx, ExecutionContext)

    # a longer prompt opens exactly one new bucket; everything else stays
    eng.submit(_req(_prompt(rng, cfg, 20), max_new=2))
    eng.run_until_idle()
    prefills = {k: v for k, v in eng.compile_stats["traces"].items()
                if k[0] == "prefill"}
    assert sorted(k[2] for k in prefills) == [8, 32]
    assert all(v == 1 for v in prefills.values())
    # the pooled decode step and the cache-splice each traced once, ever
    assert eng.compile_stats["traces"][
        ("decode", cfg.name, 2, "dense", GREEDY, eng.ctx)] == 1
    assert eng.compile_stats["traces"][
        ("insert", cfg.name, 2, "dense", eng.ctx)] == 1


def test_exact_buckets_for_sequential_state_archs():
    rcfg = registry.get("recurrentgemma-2b-smoke")
    eng = ServeEngine(rcfg, loader.init_params(rcfg, seed=0), slots=1,
                      max_len=64)
    # padding would fold into the RG-LRU state / ring buffer: exact
    # lengths, and the paged default silently falls back to a dense pool
    assert eng.pool.kind == "dense" and eng.prefill_chunk is None
    assert eng.bucket_for(5) == 5 and eng.bucket_for(13) == 13
    scfg = registry.get(ARCH)
    eng2 = ServeEngine(scfg, loader.init_params(scfg, seed=0), slots=1,
                       max_len=64)
    assert eng2.pool.kind == "paged"
    assert eng2.bucket_for(5) == 8 and eng2.bucket_for(13) == 16


def test_sequential_state_arch_serves_end_to_end():
    """The exact-bucket admission path actually serves: RG-LRU recurrent
    state + sliding-window ring buffers through the engine, with prompts
    BOTH below and above the window (below-window prefill exercises the
    short-prompt ring path in attention.py), matching the single-request
    oracle token-for-token."""
    cfg = registry.get("recurrentgemma-2b-smoke")
    assert cfg.sliding_window == 16
    params = loader.init_params(cfg, seed=0)
    rng = np.random.default_rng(7)
    prompts = [_prompt(rng, cfg, 5), _prompt(rng, cfg, 20)]
    eng = ServeEngine(cfg, params, slots=2, max_len=48, seed=0)
    futs = [eng.submit(_req(p, max_new=4)) for p in prompts]
    eng.run_until_idle()
    for p, f in zip(prompts, futs):
        assert f.result(0).tokens == _oracle_generate(cfg, params, p, 4, 48)


def test_client_driver_crash_fails_futures():
    """A tick that raises must not strand futures on a dead driver thread:
    every queued/in-flight future resolves with the real error and the
    client refuses new submissions."""
    cfg = registry.get(ARCH)
    eng = ServeEngine(cfg, loader.init_params(cfg, seed=0), slots=1,
                      max_len=64)

    def boom():
        raise RuntimeError("tick exploded")
    eng.step = boom
    with ServeClient(eng) as client:
        futs = [client.submit(_req([1, 2, 3])) for _ in range(2)]
        with pytest.raises(RuntimeError, match="tick exploded"):
            futs[0].result(timeout=30)
        with pytest.raises(RuntimeError, match="tick exploded"):
            futs[1].result(timeout=30)
        # the abort path ran, so the client is marked closed: further
        # submissions are refused loudly instead of queueing forever
        with pytest.raises(RuntimeError, match="closed"):
            client.submit(_req([1], max_new=1))
    assert not eng.metrics.requests        # aborted records were evicted
    assert eng.pool.pages_in_use == 0      # aborted slots freed their pages


# ---------------------------------------------------------------------------
# (c) oracle parity: pool layout and co-batching never change tokens
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pool", ["paged", "dense"])
def test_engine_matches_single_request_oracle(cfg, params, pool):
    """Requests of different lengths through 2 slots (so admission order,
    co-batching neighbors, and slot refill all differ per request) must
    reproduce the single-request oracle token-for-token — on the paged
    pool (where the 20-token prompt spans TWO prefill chunks, gating the
    chunked split + page-table gather) and on dense."""
    rng = np.random.default_rng(3)
    prompts = [_prompt(rng, cfg, n) for n in (5, 9, 20)]
    eng = ServeEngine(cfg, params, slots=2, max_len=64, seed=0, pool=pool)
    assert eng.pool.kind == pool
    if pool == "paged":
        assert prompts[2].size > eng.prefill_chunk   # multi-chunk coverage
    futs = [eng.submit(_req(p, max_new=6)) for p in prompts]
    eng.run_until_idle()
    for p, f in zip(prompts, futs):
        want = _oracle_generate(cfg, params, p, 6, 64)
        assert f.result(0).tokens == want
    assert eng.pool.pages_in_use == 0      # every page recycled on finish


def test_scrubbed_slots_do_not_change_outputs(cfg, params):
    """reset_slot hygiene between requests is a no-op for results — on the
    paged pool this scrubs through the slot's page row before the pages
    are recycled."""
    rng = np.random.default_rng(4)
    prompts = [_prompt(rng, cfg, n) for n in (4, 11, 6, 8)]
    eng = ServeEngine(cfg, params, slots=2, max_len=64, seed=0,
                      scrub_freed_slots=True)
    futs = [eng.submit(_req(p, max_new=5)) for p in prompts]
    eng.run_until_idle()
    for p, f in zip(prompts, futs):
        assert f.result(0).tokens == _oracle_generate(cfg, params, p, 5, 64)


def _page_content(eng, pages):
    """Concatenated flat content of physical ``pages`` across every paged
    cache leaf of the engine's pool tree."""
    import jax as _jax
    P = eng.pool.total_pages
    out = []
    for leaf in _jax.tree_util.tree_leaves(eng._caches):
        if leaf.ndim >= 2 and leaf.shape[0] == P:
            out.append(np.asarray(leaf[list(pages)]).ravel())
        elif leaf.ndim >= 3 and leaf.shape[1] == P:
            out.append(np.asarray(leaf[:, list(pages)]).ravel())
    assert out, "no paged leaves found"
    return np.concatenate(out)


@pytest.mark.parametrize("exit_path", ["cancel", "deadline", "preempt"])
def test_lifecycle_exits_scrub_freed_pages(cfg, params, exit_path):
    """Regression: cancel/deadline/preempt used to call ``pool.free``
    WITHOUT the ``scrub_freed_slots`` re-init that ``_finish`` performs,
    so a dead request's KV survived in recycled pages. All exits now run
    the shared scrub-then-free tail: the freed pages read back zero."""
    kw = dict(slots=1, max_len=64, seed=0, pool="paged",
              scrub_freed_slots=True)
    if exit_path == "preempt":
        kw.update(admission="incremental")
    eng = ServeEngine(cfg, params, **kw)
    rng = np.random.default_rng(31)
    fut = eng.submit(_req(_prompt(rng, cfg, 6), max_new=16,
                          deadline_ticks=(4 if exit_path == "deadline"
                                          else None)))
    for _ in range(3):                     # prefill + a few decode ticks
        eng.step()
    pages = eng.pool.slot_pages(0)
    assert pages and np.abs(_page_content(eng, pages)).max() > 0

    if exit_path == "cancel":
        rid = eng.active_requests()[0]
        assert eng.cancel(rid)
        eng.step()
        with pytest.raises(Exception, match="cancelled"):
            fut.result(0)
    elif exit_path == "deadline":
        while not fut.done():              # ticks reach deadline_ticks=4
            eng.step()
        with pytest.raises(Exception, match="deadline"):
            fut.result(0)
    else:
        eng._preempt(0)                    # white-box: the page-kick path

    assert eng.pool.slot_pages(0) == ()
    assert np.abs(_page_content(eng, pages)).max() == 0, \
        f"{exit_path} leaked KV content into recycled pages"
    if exit_path == "preempt":             # resumed run still exact
        eng.run_until_idle()
        want = _oracle_generate(cfg, params, fut.result(0).prompt, 16, 64)
        assert fut.result(0).tokens == want


def test_preempt_resume_metrics_survive(cfg, params):
    """Regression: a resumed (post-preemption) request's recompute used to
    re-fire ``on_prefill_done`` (inflating ``prefills``) and would have
    reset ``new_tokens``/TTFT through ``on_first_token`` on the bucketed
    path. After a preempt-and-resume cycle every counter must reflect the
    request's real life: one prefill each, every generated token counted
    once, TTFT from the FIRST admission."""
    rng = np.random.default_rng(32)
    prompts = [_prompt(rng, cfg, 5) for _ in range(2)]
    eng = ServeEngine(cfg, params, slots=2, max_len=32, seed=0,
                      pool="paged", page_size=8, num_pages=5,
                      prefill_chunk=4, admission="incremental")
    futs = [eng.submit(_req(p, max_new=14)) for p in prompts]
    eng.run_until_idle()
    results = [f.result(0) for f in futs]
    snap = eng.metrics.snapshot()
    assert snap["preempted"] >= 1          # the cycle actually happened
    assert snap["prefills"] == 2           # recompute is NOT a new prefill
    for r in results:
        assert r.metrics.new_tokens == 14  # preserved across the cycle
        assert len(r.tokens) == 14
        assert r.metrics.ttft > 0
        assert r.metrics.ttft <= r.metrics.latency


def test_percentile_is_ceil_based_nearest_rank():
    """Pin `_percentile` to the explicit ceil-based nearest-rank
    convention (rank `ceil(q*n)`, 1-based): Python's `round()` (banker's
    rounding) used to pick the lower rank inconsistently on even-length
    windows."""
    from repro.serve.metrics import _percentile
    assert _percentile([], 0.5) == 0.0
    assert _percentile([7.0], 0.5) == 7.0
    assert _percentile([7.0], 0.95) == 7.0
    # n=2: p50 -> rank ceil(1.0)=1 (lower median); p95 -> rank 2
    assert _percentile([1.0, 2.0], 0.50) == 1.0
    assert _percentile([1.0, 2.0], 0.95) == 2.0
    # n=3: p50 -> rank ceil(1.5)=2 (true median); p95 -> rank 3
    assert _percentile([1.0, 2.0, 3.0], 0.50) == 2.0
    assert _percentile([1.0, 2.0, 3.0], 0.95) == 3.0
    # n=20: p50 -> rank 10; p95 -> rank 19; extremes clamp to the sample
    vals = [float(i) for i in range(1, 21)]
    assert _percentile(vals, 0.50) == 10.0
    assert _percentile(vals, 0.95) == 19.0
    assert _percentile(vals, 0.0) == 1.0
    assert _percentile(vals, 1.0) == 20.0


def test_async_client_resolves_futures(cfg, params):
    eng = ServeEngine(cfg, params, slots=2, max_len=64, seed=0)
    rng = np.random.default_rng(5)
    prompts = [_prompt(rng, cfg, n) for n in (5, 9)]
    with ServeClient(eng) as client:
        futs = [client.submit(_req(p)) for p in prompts]
        results = [f.result(timeout=300) for f in futs]
    for p, r in zip(prompts, results):
        assert r.tokens == _oracle_generate(cfg, params, p, 4, 64)
    snap = eng.metrics.snapshot()
    assert snap["requests_finished"] == 2
    assert snap["total_tokens"] == 8
    assert snap["pool"]["kind"] == "paged"
    assert snap["pool"]["pages_in_use"] == 0
    assert snap["pool"]["pages_hwm"] > 0


def test_submit_validation(cfg, params):
    eng = ServeEngine(cfg, params, slots=1, max_len=16)
    # the removed positional form breaks loudly with the migration spelled
    # out, through the engine and the client alike
    with pytest.raises(TypeError, match="repro.serve.Request"):
        eng.submit([1, 2, 3], max_new_tokens=4)
    with pytest.raises(TypeError, match="repro.serve.Request"):
        eng.submit(_req([1, 2, 3]), 4)
    with ServeClient(eng) as client:
        with pytest.raises(TypeError, match="repro.serve.Request"):
            client.submit([1, 2, 3], max_new_tokens=4)
    # Request validates its own fields at construction
    with pytest.raises(ValueError, match="empty"):
        Request(prompt=[], max_new_tokens=2)
    with pytest.raises(ValueError, match="max_new_tokens"):
        Request(prompt=[1, 2], max_new_tokens=0)
    # engine-dependent checks stay at submit time
    with pytest.raises(ValueError, match="budget"):
        eng.submit(_req(np.arange(10), max_new=10))
    with pytest.raises(ValueError, match="sampling"):
        eng.submit(_req([1, 2], sampling=SamplingParams(temperature=0.7)))
    # an explicit rid collides with an in-flight request
    f = eng.submit(_req([1, 2], max_new=1, rid=7))
    with pytest.raises(ValueError, match="rid 7"):
        eng.submit(_req([3, 4], max_new=1, rid=7))
    eng.run_until_idle()
    f.result(0)


def test_request_is_frozen_and_normalized():
    r = Request(prompt=np.asarray([[1, 2], [3, 4]]), max_new_tokens=2)
    assert r.prompt == (1, 2, 3, 4)        # any int array-like flattens
    assert all(isinstance(t, int) for t in r.prompt)
    with pytest.raises(AttributeError):
        r.max_new_tokens = 5


# ---------------------------------------------------------------------------
# (d) paged capacity: more concurrency at equal memory, typed backpressure
# ---------------------------------------------------------------------------

def test_paged_sustains_more_slots_than_dense_at_equal_memory(cfg, params):
    """Equal KV memory — dense 2 slots x 48 rows = 96 positions vs paged
    12 usable pages x 8 = 96 positions — but the paged engine reserves per
    *request* budget (11 tokens -> 2 pages), so it runs 4 requests
    concurrently where dense can only ever co-batch 2. Outputs stay
    oracle-exact and every page drains back to the free list."""
    rng = np.random.default_rng(8)
    prompts = [_prompt(rng, cfg, 5) for _ in range(4)]
    want = [_oracle_generate(cfg, params, p, 6, 48) for p in prompts]

    dense = ServeEngine(cfg, params, slots=2, max_len=48, seed=0,
                        pool="dense")
    dfuts = [dense.submit(_req(p, max_new=6)) for p in prompts]
    dense.run_until_idle()

    paged = ServeEngine(cfg, params, slots=4, max_len=48, seed=0,
                        pool="paged", page_size=8, num_pages=13)
    assert (paged.pool.total_pages - 1) * paged.pool.page_size \
        == dense.slots * dense.max_len
    pfuts = [paged.submit(_req(p, max_new=6)) for p in prompts]
    paged.run_until_idle()

    for w, df, pf in zip(want, dfuts, pfuts):
        assert df.result(0).tokens == w
        assert pf.result(0).tokens == w
    dsnap, psnap = dense.metrics.snapshot(), paged.metrics.snapshot()
    assert dsnap["max_concurrent_slots"] == 2
    assert psnap["max_concurrent_slots"] == 4
    assert psnap["max_concurrent_slots"] > dsnap["max_concurrent_slots"]
    # 4 concurrent requests x 2 pages, all recycled after the drain
    assert psnap["pool"]["pages_hwm"] == 8
    assert paged.pool.pages_in_use == 0
    assert len(paged.pool.free_list()) == paged.pool.total_pages - 1


def test_pool_exhaustion_defers_admission(cfg, params):
    """A pool too small for every queued request admits what fits, counts
    the exhaustion, keeps the rest queued FIFO, and finishes everything
    once finished requests recycle their pages — backpressure, no crash,
    no token drift."""
    rng = np.random.default_rng(9)
    prompts = [_prompt(rng, cfg, 5) for _ in range(3)]
    # 4 usable pages x 8 = 32 positions; each request reserves 2 pages, so
    # only two of the four slots can ever be occupied at once
    eng = ServeEngine(cfg, params, slots=4, max_len=48, seed=0,
                      pool="paged", page_size=8, num_pages=5)
    futs = [eng.submit(_req(p, max_new=6)) for p in prompts]
    eng.run_until_idle()
    for p, f in zip(prompts, futs):
        assert f.result(0).tokens == _oracle_generate(cfg, params, p, 6, 48)
    snap = eng.metrics.snapshot()
    assert snap["max_concurrent_slots"] == 2       # pages, not slots, bind
    assert snap["pool"]["exhausted_events"] > 0
    assert snap["pool"]["pages_hwm"] == 4
    assert eng.pool.pages_in_use == 0
    # a request that could NEVER fit is rejected at submit, not queued
    with pytest.raises(ValueError, match="pages"):
        eng.submit(_req(_prompt(rng, cfg, 40), max_new=2))


# ---------------------------------------------------------------------------
# (e) preemptible incremental admission: preempt/recompute parity, overload
# ---------------------------------------------------------------------------

def test_preempted_request_resumes_token_identical(cfg, params):
    """The tentpole parity gate. Two 5-token prompts, 14 new tokens each,
    on 4 usable 8-token pages: both full budgets (3 pages each) cannot
    co-reside, so as decode grows page tables the younger request is
    preempted — pages freed, request requeued with its generated prefix,
    recomputed via chunked prefill. Greedy decoding makes the resumed
    output token-identical to the single-request oracle (and hence to the
    never-preempted run)."""
    rng = np.random.default_rng(11)
    prompts = [_prompt(rng, cfg, 5) for _ in range(2)]
    want = [_oracle_generate(cfg, params, p, 14, 32) for p in prompts]

    eng = ServeEngine(cfg, params, slots=2, max_len=32, seed=0,
                      pool="paged", page_size=8, num_pages=5,
                      prefill_chunk=4, admission="incremental")
    futs = [eng.submit(_req(p, max_new=14)) for p in prompts]
    eng.run_until_idle()
    results = [f.result(0) for f in futs]
    for r, w in zip(results, want):
        assert r.tokens == w
    snap = eng.metrics.snapshot()
    assert snap["preempted"] >= 1
    assert snap["recompute_tokens"] > 0
    assert sum(r.metrics.preemptions for r in results) == snap["preempted"]
    # the kick/recompute cycle leaked nothing: every page drained
    assert eng.pool.pages_in_use == 0
    assert len(eng.pool.free_list()) == eng.pool.total_pages - 1


def test_incremental_admits_mixed_trace_eager_cannot(cfg, params):
    """Equal-memory overload: a long request (3-page full budget) plus a
    short one (2 pages) on 4 usable pages. Eager admission must reserve
    whole budgets, so it can only serialize them (max 1 concurrent slot);
    incremental reserves prompt-only pages and co-runs both (2 concurrent),
    finishing the same trace with identical greedy tokens."""
    rng = np.random.default_rng(12)
    long_p, short_p = _prompt(rng, cfg, 5), _prompt(rng, cfg, 4)
    want = [_oracle_generate(cfg, params, long_p, 14, 32),
            _oracle_generate(cfg, params, short_p, 6, 32)]

    def run(admission):
        eng = ServeEngine(cfg, params, slots=2, max_len=32, seed=0,
                          pool="paged", page_size=8, num_pages=5,
                          prefill_chunk=4, admission=admission)
        futs = [eng.submit(_req(long_p, max_new=14)),
                eng.submit(_req(short_p, max_new=6))]
        eng.run_until_idle()
        return [f.result(0).tokens for f in futs], eng.metrics.snapshot()

    eager_toks, eager = run("eager")
    incr_toks, incr = run("incremental")
    assert eager_toks == want and incr_toks == want
    # eager cannot admit both concurrently (3 + 2 pages > 4 usable)...
    assert eager["max_concurrent_slots"] == 1
    assert eager["preempted"] == 0
    # ...incremental co-runs them at the same memory
    assert incr["max_concurrent_slots"] == 2
    assert incr["pool"]["admission"] == "incremental"


def test_incremental_requires_paged_chunked(cfg, params):
    """The recompute path rides chunked prefill on the paged pool — any
    other configuration is rejected loudly at construction."""
    with pytest.raises(ValueError, match="incremental"):
        ServeEngine(cfg, params, slots=2, max_len=32, pool="dense",
                    admission="incremental")
    with pytest.raises(ValueError, match="incremental"):
        ServeEngine(cfg, params, slots=2, max_len=32, pool="paged",
                    prefill_chunk=None, admission="incremental")
    with pytest.raises(ValueError, match="admission"):
        ServeEngine(cfg, params, slots=2, max_len=32, admission="lazy")


# ---------------------------------------------------------------------------
# Checkpoint -> serving restore
# ---------------------------------------------------------------------------

class TestCheckpointRestore:
    def _train(self, cfg, tmp_path, steps=2):
        from repro.configs.base import TrainConfig
        from repro.train.trainer import Trainer
        tc = TrainConfig(total_steps=steps, warmup_steps=1,
                         checkpoint_every=1, checkpoint_dir=str(tmp_path),
                         keep_checkpoints=3)
        trainer = Trainer(cfg, tc, seq_len=16, global_batch=4)
        trainer.run(steps, resume=False)
        trainer.ckpt.wait()
        return trainer

    def test_restore_matches_live_params(self, cfg, tmp_path):
        trainer = self._train(cfg, tmp_path)
        step, restored = loader.restore_params(cfg, str(tmp_path))
        assert step == 2
        batch = {"tokens": jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, (1, 8)),
            jnp.int32)}
        live, _ = lm.prefill(cfg, trainer.params, batch,
                             lm.init_caches(cfg, 1, 16))
        served, _ = lm.prefill(cfg, restored, batch,
                               lm.init_caches(cfg, 1, 16))
        np.testing.assert_allclose(np.asarray(served), np.asarray(live),
                                   atol=1e-5, rtol=1e-5)
        # and the engine on restored params reproduces the live oracle
        eng = ServeEngine(cfg, restored, slots=1, max_len=32)
        prompt = np.asarray(batch["tokens"])[0]
        fut = eng.submit(_req(prompt))
        eng.run_until_idle()
        assert fut.result(0).tokens == _oracle_generate(
            cfg, trainer.params, prompt, 4, 32)

    def test_torn_checkpoint_falls_back_to_newest_valid(self, cfg,
                                                        tmp_path):
        self._train(cfg, tmp_path)
        # a torn step-3 checkpoint: data written, commit sentinel missing
        torn = os.path.join(str(tmp_path), "step_000000003")
        os.makedirs(torn)
        with open(os.path.join(torn, "manifest.json"), "w") as f:
            f.write("{}")
        # a corrupt-but-committed step 4: sentinel present, arrays garbage
        bad = os.path.join(str(tmp_path), "step_000000004")
        os.makedirs(bad)
        with open(os.path.join(bad, "manifest.json"), "w") as f:
            f.write("{}")
        with open(os.path.join(bad, "arrays.npz"), "wb") as f:
            f.write(b"not an npz")
        with open(os.path.join(bad, "_COMMITTED"), "w") as f:
            f.write("ok")
        step, restored = loader.restore_params(cfg, str(tmp_path))
        assert step == 2 and restored is not None

    def test_load_for_serving_fresh_init_fallback(self, cfg, tmp_path):
        step, params = loader.load_for_serving(cfg, str(tmp_path / "empty"))
        assert step is None and params is not None
        want = loader.init_params(cfg, seed=0)
        leaves_a = jax.tree_util.tree_leaves(params)
        leaves_b = jax.tree_util.tree_leaves(want)
        assert all(np.allclose(a, b) for a, b in zip(leaves_a, leaves_b))


# ---------------------------------------------------------------------------
# Sharded engine (8 simulated devices; CI multi-device step)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_engine_matches_unsharded():
    """The same engine code serving under an 8-device ("data",) mesh —
    butterfly sites batch-sharded via shard_map — reproduces the
    single-device engine token-for-token, on the default PAGED pool with
    a multi-chunk prompt in the mix (page-table gather + chunked prefill
    under GSPMD).

    float32 compute: under bf16 the two GSPMD layouts can disagree by one
    rounding ulp, which is enough to flip a greedy argmax on an exact bf16
    logit tie (the sharded kernels are gated at atol 1e-5, not bitwise —
    see test_sharding_butterfly). f32 keeps layout noise ~1e-7, far below
    any real logit gap, so token equality is a sound invariant.
    """
    cfg = registry.get("smollm-135m-butterfly-smoke").with_(
        compute_dtype="float32")
    params = loader.init_params(cfg, seed=0)
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 20)]

    def run(context):
        eng = ServeEngine(cfg, params, slots=2, max_len=48, seed=0,
                          context=context)
        assert eng.pool.kind == "paged"
        assert prompts[2].size > eng.prefill_chunk   # multi-chunk coverage
        futs = [eng.submit(_req(p, max_new=5)) for p in prompts]
        eng.run_until_idle()
        return [f.result(0).tokens for f in futs], eng

    want, _ = run(None)
    got, eng = run(ExecutionContext(mesh_shape=(8,)))
    assert eng.ctx.mesh_layout() == "data=8"
    assert got == want


def test_engine_metrics_snapshot_races_recorder_storm():
    """EngineMetrics is mutated by the driver thread while `snapshot()`
    reads from the client thread; every recorder and both readers hold
    the metrics lock. Hammer: one thread runs the full recorder lifecycle
    in a tight loop while the main thread snapshots — every snapshot must
    be internally consistent (no torn reads, no dict-mutated-during-
    iteration), and the final state must count every request exactly
    once."""
    import threading

    from repro.serve.metrics import EngineMetrics

    m = EngineMetrics(slots=2)
    n_requests = 3000
    stop = threading.Event()
    start = threading.Barrier(2)
    storm_error = []

    def storm():
        try:
            start.wait()
            for rid in range(n_requests):
                m.on_submit(rid, prompt_len=8)
                m.on_tick()
                m.on_admit(rid)
                m.on_prefill_work(8, 0.001, chunked=True)
                m.on_prefill_done()
                m.on_first_token(rid)
                m.on_token(rid, 2)
                m.on_decode_tick(1, 1, 0.001)
                m.on_occupancy(1)
                m.on_pool_exhausted()
                m.on_finish(rid)
        except BaseException as e:     # surfaces in the main thread
            storm_error.append(e)
        finally:
            stop.set()

    t = threading.Thread(target=storm)
    t.start()
    snaps = 0
    start.wait()
    while not stop.is_set():
        snap = m.snapshot()
        # internal consistency under concurrent mutation: the finished
        # window and its percentiles come from one locked pass
        assert snap["ttft_ms"]["p50"] <= snap["ttft_ms"]["p95"]
        assert 0 <= snap["requests_finished"] <= n_requests
        snaps += 1
    t.join()
    assert not storm_error, storm_error
    final = m.snapshot()
    assert final["requests_finished"] == n_requests
    assert final["total_tokens"] == n_requests * 3
    assert final["max_concurrent_slots"] == 1
    assert snaps > 0
