"""Test-suite bootstrap: src/ on the path + 8 simulated XLA devices.

``XLA_FLAGS=--xla_force_host_platform_device_count=8`` must be in the
environment BEFORE jax initializes its backends, and pytest imports conftest
before any test module, so this is the one place the flag can be set
reliably. Individual test modules must NOT set it themselves — if jax was
already initialized the assignment silently no-ops and every multi-device
test "passes" on a degenerate 1-device mesh (the old ``test_pipeline.py``
import-time ordering bug). The session fixture below turns that silent
no-op into a loud failure.

Subprocess-based tests (dry-run) still own their environment: they overwrite
XLA_FLAGS before importing jax in the child, so inheriting this flag is
harmless.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SIMULATED_DEVICES = 8
_FLAG = "--xla_force_host_platform_device_count"

# Set unconditionally: jax reads XLA_FLAGS lazily at first backend use, so
# even a jax module imported earlier (by a plugin, say) still honors the
# flag as long as no devices were touched yet. The fixture below catches
# the genuinely-too-late case (backend already initialized) loudly.
_flags = os.environ.get("XLA_FLAGS", "")
if _FLAG not in _flags:
    os.environ["XLA_FLAGS"] = f"{_flags} {_FLAG}={SIMULATED_DEVICES}".strip()

import pytest  # noqa: E402


@pytest.fixture(scope="session", autouse=True)
def _assert_simulated_device_count():
    """Fail the whole session loudly when the simulated-device setup didn't
    take (jax imported before conftest, or a conflicting XLA_FLAGS): the
    sharding/pipeline tests would otherwise silently run on 1 device and
    test nothing."""
    import jax

    if jax.default_backend() == "cpu":
        got = jax.device_count()
        assert got == SIMULATED_DEVICES, (
            f"expected {SIMULATED_DEVICES} simulated host devices, got "
            f"{got}. jax initialized before tests/conftest.py could set "
            f"XLA_FLAGS={_FLAG}={SIMULATED_DEVICES} (or the environment "
            f"overrides it); multi-device tests would silently no-op.")
    yield
