"""SmolLM-135M — llama-arch small dense [hf:HuggingFaceTB/SmolLM-135M]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
    d_ff=1536, vocab_size=49152, head_dim=64,
    block_unit=("attn",),
    mlp_variant="swiglu",
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        name="smollm-135m-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        blockwise_threshold=64, attn_block_q=16, attn_block_kv=16)
