"""InternVL2-1B — InternViT + InternLM2 backbone [arXiv:2404.16821].

Per the assignment, only the transformer BACKBONE is modeled; the vision
frontend is a STUB: ``input_specs()`` provides 256 precomputed patch
embeddings per image which are projected and prepended to the text tokens."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab_size=151655, head_dim=64,
    block_unit=("attn",),
    mlp_variant="swiglu",
    frontend="vision", frontend_tokens=256,
    # 256 vision tokens prepend to the text sequence: blocks must
    # divide 32768 + 256
    attn_block_q=256, attn_block_kv=256,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        name="internvl2-1b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        frontend_tokens=8, blockwise_threshold=64,
        attn_block_q=16, attn_block_kv=16)
