"""xLSTM blocks: chunkwise mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM recurrence (stabilized, per head)::

    m_t = max(f̃_t + m_{t-1}, ĩ_t)                    stabilizer
    f'_t = exp(f̃_t + m_{t-1} - m_t),  i'_t = exp(ĩ_t - m_t)
    C_t = f'_t C_{t-1} + i'_t v_t k_tᵀ               (dv × dk) matrix memory
    n_t = f'_t n_{t-1} + i'_t k_t
    h_t = C_t q_t / max(|n_tᵀ q_t|, exp(-m_t))       (q pre-scaled 1/√dk)

Three equivalent execution paths (cross-validated in tests):
  * ``mlstm_recurrent`` — lax.scan over time (decode oracle; O(1) state)
  * ``mlstm_parallel``  — quadratic masked form (short sequences)
  * ``mlstm_chunkwise`` — scan over chunks carrying (C, n, m); within-chunk
    parallel. O(S·c) time / O(c²) live memory → the 32k/500k cells stay
    sub-quadratic. This is the TPU-native adaptation: chunk size is picked so
    the (c × c) decay matrix and (dk × dv) state tiles fit VMEM-sized blocks.

sLSTM keeps per-head scalar state with exponential gating and a *recurrent*
dependence on h_{t-1} (block-diagonal R per head) — inherently sequential,
implemented with lax.scan; decode is a single fused step.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.runtime.pytree import ParamSpec
from repro.runtime.sharding import constrain


def _dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(d_inner, heads, head_dim) for the mLSTM block (pf = 2)."""
    d_inner = 2 * cfg.d_model
    H = cfg.n_heads
    return d_inner, H, d_inner // H


# ---------------------------------------------------------------------------
# mLSTM core math
# ---------------------------------------------------------------------------

def mlstm_recurrent(q, k, v, igate, fgate, state=None):
    """q/k/v: (B,S,H,D); igate/fgate preacts: (B,S,H). Returns (h, state).

    state = (C (B,H,D,D), n (B,H,D), m (B,H)); fgate preact goes through
    log-sigmoid (xLSTM's stabilized exponential forget gate).
    """
    B, S, H, D = q.shape
    if state is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state
    qf = q.astype(jnp.float32) * (D ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(fgate.astype(jnp.float32))
    ig = igate.astype(jnp.float32)

    def step(carry, t):
        C, n, m = carry
        qt, kt, vt, ft, it = (qf[:, t], kf[:, t], vf[:, t],
                              logf[:, t], ig[:, t])
        m_new = jnp.maximum(ft + m, it)
        fp = jnp.exp(ft + m - m_new)
        ip = jnp.exp(it - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] \
            * vt[..., :, None] * kt[..., None, :]
        n = fp[..., None] * n + ip[..., None] * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)),
                          jnp.exp(-m_new))
        h = num / den[..., None]
        return (C, n, m_new), h

    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), jnp.arange(S))
    hs = hs.transpose(1, 0, 2, 3)                     # (B,S,H,D)
    return hs, (C, n, m)


def mlstm_parallel(q, k, v, igate, fgate):
    """Quadratic masked form (oracle / short sequences)."""
    B, S, H, D = q.shape
    qf = q.astype(jnp.float32) * (D ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(fgate.astype(jnp.float32))   # (B,S,H)
    ig = igate.astype(jnp.float32)
    F = jnp.cumsum(logf, axis=1)                            # (B,S,H)
    # log decay matrix: logD[i,j] = F_i - F_j + ig_j  (j <= i)
    logD = (F[:, :, None, :] - F[:, None, :, :]
            + ig[:, None, :, :])                            # (B,Sq,Sk,H)
    mask = jnp.tril(jnp.ones((S, S), bool))
    logD = jnp.where(mask[None, :, :, None], logD, -jnp.inf)
    m = jnp.max(logD, axis=2)                               # (B,S,H)
    m = jnp.maximum(m, -1e30)                               # rows with no mass
    Dmat = jnp.exp(logD - m[:, :, None, :])
    scores = jnp.einsum("bqhd,bkhd->bqkh", qf, kf) * Dmat
    num = jnp.einsum("bqkh,bkhd->bqhd", scores, vf)
    den = jnp.maximum(jnp.abs(scores.sum(axis=2)), jnp.exp(-m))
    return num / den[..., None]


def mlstm_chunkwise(q, k, v, igate, fgate, chunk: int, state=None,
                    return_state: bool = False):
    """Chunked scan: parallel within chunks, recurrent across chunks."""
    B, S, H, D = q.shape
    c = min(chunk, S)
    assert S % c == 0, (S, c)
    nc = S // c
    qf = (q.astype(jnp.float32) * (D ** -0.5)).reshape(B, nc, c, H, D)
    kf = k.astype(jnp.float32).reshape(B, nc, c, H, D)
    vf = v.astype(jnp.float32).reshape(B, nc, c, H, D)
    logf = jax.nn.log_sigmoid(fgate.astype(jnp.float32)).reshape(B, nc, c, H)
    ig = igate.astype(jnp.float32).reshape(B, nc, c, H)
    if state is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state

    mask = jnp.tril(jnp.ones((c, c), bool))

    def chunk_step(carry, xs):
        C, n, m = carry
        qc, kc, vc, fc, ic = xs          # (B,c,H,D) / (B,c,H)
        b = jnp.cumsum(fc, axis=1)                       # (B,c,H) incl.
        # intra-chunk log decays
        logD = (b[:, :, None, :] - b[:, None, :, :]
                + ic[:, None, :, :])                     # (B,ci,cj,H)
        logD = jnp.where(mask[None, :, :, None], logD, -jnp.inf)
        m_intra = jnp.max(logD, axis=2)                  # (B,c,H)
        # inter-chunk: state decayed by b_i, at stabilizer m (state scale)
        m_inter = b + m[:, None, :]                      # (B,c,H)
        m_i = jnp.maximum(jnp.maximum(m_intra, m_inter), -1e30)
        Dm = jnp.exp(logD - m_i[:, :, None, :])
        scores = jnp.einsum("bqhd,bkhd->bqkh", qc, kc) * Dm
        num = jnp.einsum("bqkh,bkhd->bqhd", scores, vc)
        den_intra = scores.sum(axis=2)                   # (B,c,H)
        w_state = jnp.exp(m_inter - m_i)                 # (B,c,H)
        num = num + w_state[..., None] * jnp.einsum(
            "bhvk,bqhk->bqhv", C, qc)
        den = den_intra + w_state * jnp.einsum("bhk,bqhk->bqh", n, qc)
        den = jnp.maximum(jnp.abs(den), jnp.exp(-m_i))
        h = num / den[..., None]
        # ---- state update to end of chunk ----
        b_tot = b[:, -1, :]                              # (B,H)
        g = b_tot[:, None, :] - b + ic                   # decay token->end
        m_next = jnp.maximum(b_tot + m, jnp.max(g, axis=1))
        w_old = jnp.exp(b_tot + m - m_next)              # (B,H)
        w_new = jnp.exp(g - m_next[:, None, :])          # (B,c,H)
        C = w_old[..., None, None] * C + jnp.einsum(
            "bchv,bchk,bch->bhvk", vc, kc, w_new)
        n = w_old[..., None] * n + jnp.einsum("bchk,bch->bhk", kc, w_new)
        return (C, n, m_next), h

    xs = (qf.transpose(1, 0, 2, 3, 4), kf.transpose(1, 0, 2, 3, 4),
          vf.transpose(1, 0, 2, 3, 4), logf.transpose(1, 0, 2, 3),
          ig.transpose(1, 0, 2, 3))
    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    hs = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)
    if return_state:
        return hs, (C, n, m)
    return hs


# ---------------------------------------------------------------------------
# mLSTM block (up-proj, conv, qkv, gates, headnorm, gated down-proj)
# ---------------------------------------------------------------------------

def mlstm_specs(cfg: ModelConfig) -> Dict:
    E = cfg.d_model
    DI, H, D = _dims(cfg)
    dt = cfg.param_dtype
    W = cfg.conv_width
    return {
        "w_up": ParamSpec((E, 2 * DI), dt, ("embed", "mlp"),
                          init="scaled_normal", fan_in_dim=0),
        "conv": ParamSpec((W, DI), dt, (None, "mlp"),
                          init="scaled_normal", scale=0.5, fan_in_dim=0),
        "wq": ParamSpec((DI, DI), dt, ("mlp", None),
                        init="scaled_normal", fan_in_dim=0),
        "wk": ParamSpec((DI, DI), dt, ("mlp", None),
                        init="scaled_normal", fan_in_dim=0),
        "wv": ParamSpec((DI, DI), dt, ("mlp", None),
                        init="scaled_normal", fan_in_dim=0),
        "w_igate": ParamSpec((DI, H), dt, ("mlp", None),
                             init="scaled_normal", scale=0.1, fan_in_dim=0),
        "b_igate": ParamSpec((H,), dt, (None,), init="zeros"),
        "w_fgate": ParamSpec((DI, H), dt, ("mlp", None),
                             init="scaled_normal", scale=0.1, fan_in_dim=0),
        "b_fgate": ParamSpec((H,), dt, (None,), init="ones"),
        "headnorm": ParamSpec((DI,), dt, (None,), init="ones"),
        "w_down": ParamSpec((DI, E), dt, ("mlp", "embed"),
                            init="scaled_normal", fan_in_dim=0),
    }


def mlstm_cache_spec(cfg: ModelConfig, batch: int) -> Dict:
    DI, H, D = _dims(cfg)
    f32 = jnp.float32
    return {
        "C": jax.ShapeDtypeStruct((batch, H, D, D), f32),
        "n": jax.ShapeDtypeStruct((batch, H, D), f32),
        "m": jax.ShapeDtypeStruct((batch, H), f32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, DI),
                                     cfg.cdtype()),
    }


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> Dict:
    specs = mlstm_cache_spec(cfg, batch)
    cache = {k: jnp.zeros(s.shape, s.dtype) for k, s in specs.items()}
    cache["m"] = jnp.full(specs["m"].shape, -1e30, jnp.float32)
    return cache


def mlstm_block(cfg: ModelConfig, params: Dict, x: jnp.ndarray, *,
                mode: str, cache: Optional[Dict] = None
                ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    from repro.models.rglru import _causal_conv
    B, S, E = x.shape
    DI, H, D = _dims(cfg)
    cd = x.dtype
    up = x @ params["w_up"].astype(cd)                    # (B,S,2DI)
    u, z = jnp.split(up, 2, axis=-1)
    u = constrain(u, ("batch", None, "mlp"))

    hist = cache["conv"] if (cache is not None and mode == "decode") else None
    uc = jax.nn.silu(_causal_conv(u, params["conv"], hist))
    q = (uc @ params["wq"].astype(cd)).reshape(B, S, H, D)
    k = (uc @ params["wk"].astype(cd)).reshape(B, S, H, D)
    v = (u @ params["wv"].astype(cd)).reshape(B, S, H, D)
    ig = uc @ params["w_igate"].astype(cd) + params["b_igate"].astype(cd)
    fg = uc @ params["w_fgate"].astype(cd) + params["b_fgate"].astype(cd)

    new_cache = None
    if mode == "decode":
        state = (cache["C"], cache["n"], cache["m"])
        hs, (C, n, m) = mlstm_recurrent(q, k, v, ig, fg, state)
        W = cfg.conv_width
        hist_new = (jnp.concatenate([cache["conv"][:, 1:],
                                     u.astype(cache["conv"].dtype)], axis=1)
                    if W > 1 else cache["conv"])
        new_cache = {"C": C, "n": n, "m": m, "conv": hist_new}
    else:
        c = cfg.mlstm_chunk
        pad = (-S) % c
        if pad and S > c:
            # pad to a chunk multiple with state-neutral steps:
            # i' = exp(-1e9) = 0 (no write), log f = log_sigmoid(1e9) = 0
            # (no decay) — outputs of pad steps are sliced off below.
            zpad = lambda a: jnp.pad(a, ((0, 0), (0, pad)) +
                                     ((0, 0),) * (a.ndim - 2))
            q, k, v = zpad(q), zpad(k), zpad(v)
            ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)),
                         constant_values=-1e9)
            fg = jnp.pad(fg, ((0, 0), (0, pad), (0, 0)),
                         constant_values=1e9)
        if S <= c:
            hs = mlstm_parallel(q, k, v, ig, fg)
            st = None
        else:
            res = mlstm_chunkwise(q, k, v, ig, fg, c,
                                  return_state=(mode == "prefill"))
            if mode == "prefill":
                hs, st = res
            else:
                hs, st = res, None
        hs = hs[:, :S]
        if mode == "prefill":
            if st is None:
                hs2, st = mlstm_recurrent(q[:, :S], k[:, :S], v[:, :S],
                                          ig[:, :S], fg[:, :S])
                del hs2
            W = cfg.conv_width
            hist_new = u[:, -(W - 1):, :] if W > 1 else u[:, :0, :]
            new_cache = {"C": st[0], "n": st[1], "m": st[2],
                         "conv": hist_new.astype(cfg.cdtype())}

    h = hs.reshape(B, S, DI).astype(cd)
    h = cm.rmsnorm(h, params["headnorm"], cfg.norm_eps)
    h = h * jax.nn.silu(z)
    out = h @ params["w_down"].astype(cd)
    return out, new_cache


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------

def slstm_specs(cfg: ModelConfig) -> Dict:
    E = cfg.d_model
    H = cfg.n_heads
    D = E // H
    dt = cfg.param_dtype
    ffn = _slstm_ffn_dim(cfg)
    return {
        "w_zifo": ParamSpec((E, 4 * E), dt, ("embed", "mlp"),
                            init="scaled_normal", fan_in_dim=0),
        "r_zifo": ParamSpec((H, D, 4 * D), dt, (None, None, None),
                            init="scaled_normal", scale=0.5, fan_in_dim=1),
        "b_zifo": ParamSpec((4 * E,), dt, (None,), init="zeros"),
        "groupnorm": ParamSpec((E,), dt, (None,), init="ones"),
        "ffn_gate": ParamSpec((E, ffn), dt, ("embed", "mlp"),
                              init="scaled_normal", fan_in_dim=0),
        "ffn_up": ParamSpec((E, ffn), dt, ("embed", "mlp"),
                            init="scaled_normal", fan_in_dim=0),
        "ffn_down": ParamSpec((ffn, E), dt, ("mlp", "embed"),
                              init="scaled_normal", fan_in_dim=0),
    }


def _slstm_ffn_dim(cfg: ModelConfig) -> int:
    return ((int(cfg.d_model * 4 / 3) + 63) // 64) * 64


def slstm_cache_spec(cfg: ModelConfig, batch: int) -> Dict:
    E = cfg.d_model
    f32 = jnp.float32
    return {t: jax.ShapeDtypeStruct((batch, E), f32)
            for t in ("c", "n", "m", "h")}


def init_slstm_cache(cfg: ModelConfig, batch: int) -> Dict:
    E = cfg.d_model
    z = jnp.zeros((batch, E), jnp.float32)
    return {"c": z, "n": z + 1e-6, "m": z - 1e30, "h": z}


def _slstm_scan(cfg: ModelConfig, params: Dict, pre: jnp.ndarray,
                state: Dict) -> Tuple[jnp.ndarray, Dict]:
    """pre: (B,S,4E) input preactivations (W x + b); recurrent R h added
    per step. Sequential by construction."""
    B, S, _ = pre.shape
    E = cfg.d_model
    H = cfg.n_heads
    D = E // H
    R = params["r_zifo"].astype(jnp.float32)             # (H, D, 4D)

    def step(carry, t):
        c, n, m, h = carry
        hh = h.reshape(B, H, D)
        rec = jnp.einsum("bhd,hdf->bhf", hh, R).reshape(B, 4 * E)
        zifo = pre[:, t].astype(jnp.float32) + _interleave(rec, E, H, D)
        z, i, f, o = jnp.split(zifo, 4, axis=-1)
        z = jnp.tanh(z)
        o = jax.nn.sigmoid(o)
        m_new = jnp.maximum(f + m, i)                    # exp forget gate
        fp = jnp.exp(f + m - m_new)
        ip = jnp.exp(i - m_new)
        c_new = fp * c + ip * z
        n_new = fp * n + ip
        h_new = o * (c_new / jnp.maximum(n_new, 1e-6))
        return (c_new, n_new, m_new, h_new), h_new

    carry = (state["c"], state["n"], state["m"], state["h"])
    carry, hs = jax.lax.scan(step, carry, jnp.arange(S))
    hs = hs.transpose(1, 0, 2)                           # (B,S,E)
    new_state = dict(zip(("c", "n", "m", "h"), carry))
    return hs, new_state


def _interleave(rec: jnp.ndarray, E: int, H: int, D: int) -> jnp.ndarray:
    """(B, 4E) recurrent preacts laid out (H, 4, D) -> (4, H, D) flat."""
    B = rec.shape[0]
    return rec.reshape(B, H, 4, D).transpose(0, 2, 1, 3).reshape(B, 4 * E)


def slstm_block(cfg: ModelConfig, params: Dict, x: jnp.ndarray, *,
                mode: str, cache: Optional[Dict] = None
                ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, E = x.shape
    cd = x.dtype
    pre = x @ params["w_zifo"].astype(cd) + params["b_zifo"].astype(cd)
    state = (cache if cache is not None and mode in ("decode",)
             else init_slstm_cache(cfg, B))
    hs, new_state = _slstm_scan(cfg, params, pre, state)
    new_cache = new_state if mode in ("decode", "prefill") else None
    h = cm.rmsnorm(hs.astype(cd), params["groupnorm"], cfg.norm_eps)
    # gated FFN (pf 4/3)
    ffn = _slstm_ffn_dim(cfg)
    g = jax.nn.gelu(h @ params["ffn_gate"].astype(cd))
    u = h @ params["ffn_up"].astype(cd)
    out = (g * u) @ params["ffn_down"].astype(cd)
    return out, new_cache
