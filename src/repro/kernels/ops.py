"""Public jit'd entry points for the Pallas kernels.

Backend selection (``auto`` | ``jnp`` | ``pallas`` | ``pallas_interpret``):

* On TPU ``auto`` resolves to the compiled Pallas kernels (Mosaic) — for
  inference *and* training: every fused kernel carries a
  :func:`jax.custom_vjp` with a fused Pallas backward pass, so ``jax.grad``
  through these entry points stays on the fast path instead of falling back
  to log n unfused HBM round trips per stage.
* On CPU (this container) ``auto`` resolves to the *pure-jnp oracles*
  (Pallas interpret mode executes the kernel body in Python — correct but
  slow), while tests explicitly request ``backend="pallas_interpret"`` to
  validate the kernel bodies — forward and backward — themselves.
* ``REPRO_KERNEL_BACKEND`` in the environment overrides what ``auto``
  resolves to (read at trace time), e.g. to force the oracle path on TPU
  when bisecting a kernel bug.

Block sizes: the Pallas entry points take optional ``block_b`` (batch-tile
rows) and ``segment`` (backward checkpoint interval) knobs. ``None`` — the
default everywhere — defers to the :mod:`repro.kernels.tuning` VMEM/roofline
autotuner, so callers never pass magic numbers; explicit ints override it
(as do the ``REPRO_TUNE_*`` env vars, see ``tuning.py``).

Multi-device: every entry point takes an optional ``mesh`` (plus
``mesh_axes``, default ``("pod", "data")`` filtered to the mesh). When given
a mesh with a non-trivial data axis, the call routes through
:mod:`repro.runtime.butterfly_sharding`: activations batch-sharded via
``shard_map``, stage weights replicated, weight gradients psum'd through the
fused custom_vjp backward. ``mesh=None`` (the default) is the single-device
path, bit-identical to before.
"""

from __future__ import annotations

import os
from typing import Literal, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.kernels import ref as _ref
from repro.kernels.butterfly import butterfly_matmul as _butterfly_pallas
from repro.kernels.sandwich import sandwich_matmul as _sandwich_pallas
from repro.kernels.sandwich import one_hot_select

Backend = Literal["auto", "jnp", "pallas", "pallas_interpret"]

_CONCRETE = ("jnp", "pallas", "pallas_interpret")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: Backend = "auto") -> str:
    """Resolve ``auto`` to a concrete backend (env override, then platform)."""
    if backend == "auto":
        env = os.environ.get("REPRO_KERNEL_BACKEND", "").strip().lower()
        if env and env != "auto":
            backend = env
        else:
            backend = "pallas" if _on_tpu() else "jnp"
    if backend not in _CONCRETE:
        raise ValueError(f"unknown kernel backend {backend!r}; expected one "
                         f"of {('auto',) + _CONCRETE}")
    return backend


def _sharded_route(mesh: Optional[Mesh], mesh_axes: Optional[Sequence[str]]):
    """Resolve the (mesh, axes) pair to shard over, or None for the local
    path. Imported lazily: runtime.butterfly_sharding wraps these entry
    points, so a top-level import would be circular."""
    if mesh is None:
        return None
    from repro.runtime import butterfly_sharding as bsh
    axes = bsh.data_axes(mesh, mesh_axes)
    return (bsh, axes) if axes else None


def butterfly_apply(x: jnp.ndarray, w: jnp.ndarray, *,
                    transpose: bool = False,
                    backend: Backend = "auto",
                    block_b: Optional[int] = None,
                    segment: Optional[int] = None,
                    mesh: Optional[Mesh] = None,
                    mesh_axes: Optional[Sequence[str]] = None
                    ) -> jnp.ndarray:
    """Fused butterfly product over the last axis of ``x``.

    Differentiable under every backend; the Pallas backends use the fused
    custom_vjp backward kernel with segmented stage checkpointing.
    ``block_b``/``segment`` default to the autotuner (``tuning.py``).
    ``mesh`` batch-shards the call over its data axes (module docstring).
    """
    backend = resolve_backend(backend)
    route = _sharded_route(mesh, mesh_axes)
    if route is not None:
        bsh, axes = route
        return bsh.sharded_butterfly_apply(x, w, mesh=mesh, axes=axes,
                                           transpose=transpose,
                                           backend=backend, block_b=block_b,
                                           segment=segment)
    if backend == "jnp":
        return _ref.butterfly_ref(w.astype(x.dtype), x, transpose=transpose)
    interpret = backend == "pallas_interpret"
    return _butterfly_pallas(x, w, transpose=transpose, block_b=block_b,
                             segment=segment, interpret=interpret)


def sandwich_apply(x: jnp.ndarray, b_in: jnp.ndarray, sel_in: jnp.ndarray,
                   core: jnp.ndarray, sel_out: jnp.ndarray,
                   b_out: jnp.ndarray, *, scale_in: float = 1.0,
                   scale_out: float = 1.0,
                   backend: Backend = "auto",
                   block_b: Optional[int] = None,
                   segment: Optional[int] = None,
                   mesh: Optional[Mesh] = None,
                   mesh_axes: Optional[Sequence[str]] = None) -> jnp.ndarray:
    """Fused butterfly sandwich (dense-layer replacement) over the last axis.

    Differentiable under every backend; the Pallas backends use the fused
    custom_vjp backward kernel with segmented stage checkpointing.
    ``block_b``/``segment`` default to the autotuner (``tuning.py``).
    ``mesh`` batch-shards the call over its data axes (module docstring).
    """
    backend = resolve_backend(backend)
    route = _sharded_route(mesh, mesh_axes)
    if route is not None:
        bsh, axes = route
        return bsh.sharded_sandwich_apply(
            x, b_in, sel_in, core, sel_out, b_out, mesh=mesh, axes=axes,
            scale_in=scale_in, scale_out=scale_out, backend=backend,
            block_b=block_b, segment=segment)
    if backend == "jnp":
        return _ref.sandwich_ref(x, b_in, core, b_out, sel_in, sel_out,
                                 scale_in, scale_out)
    interpret = backend == "pallas_interpret"
    return _sandwich_pallas(x, b_in, sel_in, core, sel_out, b_out,
                            scale_in=scale_in, scale_out=scale_out,
                            block_b=block_b, segment=segment,
                            interpret=interpret)


__all__ = ["butterfly_apply", "sandwich_apply", "one_hot_select", "Backend",
           "resolve_backend"]
