"""Training loop: prefetched data, checkpoint/resume, straggler accounting.

The Trainer is deliberately host-side thin: all math lives in the jitted
step function; the loop does data, checkpoints, failure handling, logging.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from dataclasses import replace as dc_replace
from typing import Any, Dict, List, Optional

import contextlib

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint.checkpointing import CheckpointManager
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import Prefetcher, SyntheticLM, for_model
from repro.kernels import ops as kops
from repro.kernels import tuning
from repro.launch.mesh import butterfly_mesh
from repro.models import lm
from repro.optim import optimizer as opt
from repro.runtime import pytree as pt
from repro.runtime import sharding as rsh
from repro.runtime.fault_tolerance import StragglerMonitor
from repro.train import steps as steps_lib


@dataclass
class TrainResult:
    steps_run: int
    losses: List[float]
    resumed_from: Optional[int]
    step_times: List[float] = field(default_factory=list)
    # resolved butterfly kernel backend the step function traced with
    # ("dense" when the model has no butterfly sites)
    kernel_backend: str = "dense"
    # autotuner decisions (block_b/segment per kernel cell) registered while
    # this run traced; falls back to the process-wide registry (prefixed
    # "process-wide:") when tracing hit a warm cache from an earlier run in
    # the same process. Empty on the jnp/dense paths.
    kernel_tuning: str = ""
    # mesh layout the butterfly sites ran under (e.g. "data=8" or
    # "pod=2,data=4"); "" on the single-device path
    mesh_layout: str = ""


class Trainer:
    def __init__(self, model_cfg: ModelConfig, train_cfg: TrainConfig,
                 seq_len: int, global_batch: int,
                 data: Optional[SyntheticLM] = None):
        self.cfg = model_cfg
        self.tc = train_cfg
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.data = data or for_model(model_cfg, seq_len, global_batch,
                                      seed=train_cfg.seed)
        self.tx = steps_lib.make_optimizer(train_cfg)
        # Resolve the butterfly kernel backend up front and freeze the
        # concrete value into the config the step function traces with
        # (otherwise "auto" would be re-resolved at trace time and could
        # diverge from what TrainResult reports). The train step
        # differentiates through the sandwich, and since the fused Pallas
        # kernels carry custom_vjp backward passes the fused path is safe to
        # trace under grad — "auto" keeps it on TPU end to end.
        if model_cfg.butterfly is not None:
            self.kernel_backend = kops.resolve_backend(
                model_cfg.butterfly.backend)
            model_cfg = model_cfg.with_(butterfly=dc_replace(
                model_cfg.butterfly, backend=self.kernel_backend))
            self.cfg = model_cfg
        else:
            self.kernel_backend = "dense"
        # Multi-device butterfly execution: ButterflyConfig.mesh_shape opts
        # in. Build the mesh once up front (fails loudly here — with the
        # XLA_FLAGS recipe in the message — rather than mid-trace) and
        # install it as the active sharding context while the step function
        # traces, so every butterfly site routes through the shard_map
        # wrappers of repro.runtime.butterfly_sharding.
        bc = model_cfg.butterfly
        self.mesh = (butterfly_mesh(bc.mesh_shape)
                     if bc is not None and bc.mesh_shape is not None
                     else None)
        self.step_fn = jax.jit(steps_lib.make_train_step(
            model_cfg, self.tx, train_cfg.microbatches),
            donate_argnums=(0, 1))
        self.ckpt = (CheckpointManager(train_cfg.checkpoint_dir,
                                       keep=train_cfg.keep_checkpoints)
                     if train_cfg.checkpoint_dir else None)

    def init_state(self, seed: int = 0):
        specs = lm.model_specs(self.cfg)
        params = pt.init_params(jax.random.PRNGKey(seed), specs)
        opt_state = self.tx.init(params)
        return params, opt_state

    def _sharding_scope(self):
        """Active-sharding context for trace/execution when a mesh is
        configured; no-op otherwise."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return rsh.use_sharding(self.mesh)

    def _mesh_layout(self) -> str:
        if self.mesh is None:
            return ""
        return ",".join(f"{a}={s}" for a, s in self.mesh.shape.items())

    def _put_batch(self, x: jnp.ndarray) -> jnp.ndarray:
        """Place a (batch, ...) array batch-sharded on the mesh's data axes
        (replicate when the batch doesn't divide them)."""
        spec = rsh.batch_axes(self.mesh, rsh.DEFAULT_RULES, x.shape[0])
        return jax.device_put(x, NamedSharding(self.mesh, spec))

    def _make_batch_arrays(self, batch: Dict[str, np.ndarray]
                           ) -> Dict[str, jnp.ndarray]:
        out = {k: jnp.asarray(v) for k, v in batch.items()}
        B = out["tokens"].shape[0]
        cfg = self.cfg
        rng = np.random.default_rng(1234)
        if cfg.frontend == "vision":
            out["frontend_embeds"] = jnp.asarray(rng.normal(
                size=(B, cfg.frontend_tokens, cfg.d_model)), jnp.float32)
        if cfg.n_enc_layers:
            out["frames"] = jnp.asarray(rng.normal(
                size=(B, cfg.enc_seq, cfg.d_model)), jnp.float32)
        if self.mesh is not None:
            out = {k: self._put_batch(v) for k, v in out.items()}
        return out

    def run(self, steps: int, params=None, opt_state=None,
            resume: bool = True) -> TrainResult:
        if params is None:
            params, opt_state = self.init_state(self.tc.seed)

        start_step = 0
        resumed_from = None
        if self.ckpt is not None and resume:
            tmpl = {"params": params, "opt": opt_state}
            s, tree, extra = self.ckpt.restore(tmpl)
            if s is not None:
                params = jax.tree_util.tree_map(
                    lambda t, a: jnp.asarray(a) if a is not None else t,
                    tmpl["params"], tree["params"],
                    is_leaf=lambda x: x is None)
                opt_state = jax.tree_util.tree_map(
                    lambda t, a: (jnp.asarray(a) if a is not None else None),
                    tmpl["opt"], tree["opt"], is_leaf=lambda x: x is None)
                start_step = s
                resumed_from = s

        tuning_before = set(tuning.cache_entries())
        prefetch = Prefetcher(self.data, start_step=start_step)
        straggler = StragglerMonitor(["host0"])
        losses: List[float] = []
        step_times: List[float] = []
        try:
            for i in range(start_step, start_step + steps):
                step_idx, raw = next(prefetch)
                batch = self._make_batch_arrays(raw)
                t0 = time.monotonic()
                # the sharding ctx must be live whenever the step function
                # (re)traces — butterfly sites read the active mesh then
                with self._sharding_scope():
                    params, opt_state, metrics = self.step_fn(
                        params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.monotonic() - t0
                straggler.record({"host0": dt})
                losses.append(loss)
                step_times.append(dt)
                if (self.ckpt is not None and self.tc.checkpoint_every
                        and (i + 1) % self.tc.checkpoint_every == 0):
                    self.ckpt.save(i + 1, {"params": params,
                                           "opt": opt_state},
                                   extra={"loss": loss}, async_=True)
        finally:
            prefetch.close()
            if self.ckpt is not None:
                self.ckpt.wait()
        self.params = params
        self.opt_state = opt_state
        # Tuning choices are made (and registered) at trace time. Report the
        # entries this run added; if tracing hit a warm registry (another
        # run with the same cells already happened in this process), fall
        # back to the full registry, marked as such. jnp/dense paths never
        # query the autotuner and report "".
        tuning_summary = ""
        if self.kernel_backend in ("pallas", "pallas_interpret"):
            entries = tuning.cache_entries()
            fresh = sorted(v for k, v in entries.items()
                           if k not in tuning_before)
            if fresh:
                tuning_summary = "; ".join(fresh)
            elif entries:
                tuning_summary = "process-wide: " + tuning.describe()
        return TrainResult(steps_run=steps, losses=losses,
                           resumed_from=resumed_from,
                           step_times=step_times,
                           kernel_backend=self.kernel_backend,
                           kernel_tuning=tuning_summary,
                           mesh_layout=self._mesh_layout())
