"""Paper §6: learn a butterfly sketch for low-rank decomposition and compare
with learned-sparse (IVY19), random CW and Gaussian sketches.

Run: ``PYTHONPATH=src python examples/learned_sketch.py``
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sketch


def main():
    n, d, ell, k = 64, 48, 16, 8
    rng = np.random.default_rng(0)
    base = rng.normal(size=(n, d)) @ np.diag(np.linspace(1, 0.02, d))
    Xs = [jnp.asarray((base + 0.05 * rng.normal(size=(n, d)))
                      .astype(np.float32)) for _ in range(32)]
    train, test = Xs[:24], Xs[24:]

    spec = sketch.make_spec(jax.random.PRNGKey(0), n=n, ell=ell, k=k)
    print(f"learning an {ell}x{n} butterfly sketch (k={k}) on "
          f"{len(train)} matrices ...")
    w, hist = sketch.train_butterfly_sketch(
        spec, jax.random.PRNGKey(1), train, steps=150, lr=3e-3, batch=6,
        log_every=30)
    print("  train losses:", [f"{v:.3f}" for v in hist])

    err_bfly = sketch.test_error(
        lambda X: sketch.butterfly_sketch(spec, w, X), test, k)

    rows, values, _ = sketch.train_sparse_sketch(
        jax.random.PRNGKey(2), train, n=n, ell=ell, k=k, steps=150,
        lr=3e-3, batch=6)
    Bs = sketch.sparse_sketch_matrix(rows, values, ell)
    err_sparse = sketch.test_error(lambda X: Bs @ X, test, k)

    rows0, signs0 = sketch.cw_pattern(jax.random.PRNGKey(3), n, ell)
    B0 = sketch.sparse_sketch_matrix(rows0, jnp.asarray(signs0), ell)
    err_cw = sketch.test_error(lambda X: B0 @ X, test, k)

    G = sketch.gaussian_sketch(jax.random.PRNGKey(4), n, ell)
    err_gauss = sketch.test_error(lambda X: G @ X, test, k)

    print(f"\ntest error (vs exact rank-{k}):")
    print(f"  butterfly learned : {err_bfly:.4f}   <- this paper")
    print(f"  sparse learned    : {err_sparse:.4f}   (IVY'19)")
    print(f"  CW random         : {err_cw:.4f}")
    print(f"  Gaussian          : {err_gauss:.4f}")


if __name__ == "__main__":
    main()
