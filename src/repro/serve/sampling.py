"""Token sampling for the serving engine: greedy / temperature / top-k /
top-p as ONE pure, jittable function.

:class:`SamplingParams` is frozen and hashable, so it is safe to close over
in jit and to key the engine's ``CompileCache`` on — switching sampling
policy recompiles the serve step (by design: the policy is a trace-time
constant, not a per-call branch). ``temperature == 0`` means greedy, in
which case the ``rng`` argument is ignored and no randomness enters the
trace at all (the oracle-parity tests rely on this).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclass(frozen=True)
class SamplingParams:
    """Sampling policy for one engine (trace-time constant).

    * ``temperature`` — ``0.0`` = greedy argmax; ``> 0`` scales logits
      before sampling.
    * ``top_k`` — ``0`` = disabled; else restrict to the k highest logits
      (``k >= vocab`` keeps everything, i.e. behaves as disabled).
    * ``top_p`` — ``1.0`` = disabled; else nucleus sampling: keep the
      smallest prefix of the probability-sorted vocab whose mass reaches
      ``top_p`` (the first token is always kept).

    ``top_k`` and ``top_p`` compose (k-filter first, then nucleus), matching
    the common serving-stack convention.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got "
                             f"{self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def greedy(self) -> bool:
        return self.temperature == 0.0


GREEDY = SamplingParams()


def sample_logits(logits: jnp.ndarray, rng, params: SamplingParams
                  ) -> jnp.ndarray:
    """Sample token ids from ``logits (..., V)`` under ``params``.

    Pure and jittable; ``params`` must be static (close over it or pass it
    via ``functools.partial`` — it is not a traced argument). Greedy ignores
    ``rng`` (pass anything, including None).
    """
    if params.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / params.temperature
    # top_k >= V keeps the whole vocab — same as disabled. Clamp at trace
    # time: jax.lax.top_k requires k <= V and would crash otherwise.
    if params.top_k and params.top_k < logits.shape[-1]:
        kth = jax.lax.top_k(logits, params.top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, NEG_INF, logits)
    if params.top_p < 1.0:
        order = jnp.argsort(-logits, axis=-1)
        sorted_logits = jnp.take_along_axis(logits, order, axis=-1)
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep every token whose preceding mass is < top_p: the first token
        # over the threshold stays, everything after it goes
        keep_sorted = (cum - probs) < params.top_p
        inv = jnp.argsort(order, axis=-1)
        keep = jnp.take_along_axis(keep_sorted, inv, axis=-1)
        logits = jnp.where(keep, logits, NEG_INF)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
