"""Abstract input specs + sharding trees for AOT lowering (dry-run + launch).

Everything here is ``ShapeDtypeStruct``-only: no device allocation ever
happens for the full-size configs (they are exercised exclusively through
``jit(...).lower().compile()``).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.runtime import pytree as pt
from repro.runtime import sharding as sh

PyTree = Any


# ---------------------------------------------------------------------------
# Batch input specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Training/prefill batch: ShapeDtypeStructs for every model input."""
    B, S = shape.global_batch, shape.seq_len
    out = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if shape.kind == "train":
        out["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        out["mask"] = jax.ShapeDtypeStruct((B, S), jnp.float32)
    if cfg.frontend == "vision":
        out["frontend_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.n_enc_layers:
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return out


def decode_specs(cfg: ModelConfig, shape: ShapeConfig) -> Tuple:
    """(token, caches, cur_pos) ShapeDtypeStructs for a serve step."""
    B, S = shape.global_batch, shape.seq_len
    token = jax.ShapeDtypeStruct((B,), jnp.int32)
    caches = lm.cache_specs(cfg, B, S)
    cur_pos = jax.ShapeDtypeStruct((), jnp.int32)
    return token, caches, cur_pos


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------

def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    rules) -> Dict:
    specs = batch_specs(cfg, shape)

    def shard(sds):
        axes = ("batch",) + (None,) * (len(sds.shape) - 1)
        return NamedSharding(mesh, sh.logical_to_pspec(
            axes, sds.shape, mesh, rules))

    return {k: shard(v) for k, v in specs.items()}


_CACHE_AXES = {
    "h": ("batch", "rnn_state"),
    "conv": ("batch", None, "rnn_state"),
    "C": ("batch", "heads", None, None),
    "n": ("batch", "heads", None),
    "m": ("batch", "heads"),
    "c": ("batch", None),
}


def _cache_leaf_axes(cfg: ModelConfig, key: str, ndim: int) -> Tuple:
    if key in ("k", "v"):
        # must match the attention-side constraint exactly (see
        # repro.models.attention.kv_layout): mixed layouts make GSPMD
        # reshard the whole cache stack inside the decode loop.
        from repro.models.attention import kv_layout
        axes = kv_layout(cfg, "decode")
    else:
        axes = _CACHE_AXES.get(key, ("batch",) + (None,) * (ndim - 1))
    if len(axes) < ndim:                      # stacked leading repeat axis
        axes = (None,) * (ndim - len(axes)) + tuple(axes)
    return tuple(axes[:ndim])


def cache_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    rules) -> PyTree:
    _, caches, _ = decode_specs(cfg, shape)

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if isinstance(v, jax.ShapeDtypeStruct):
                    axes = _cache_leaf_axes(cfg, k, len(v.shape))
                    out[k] = NamedSharding(mesh, sh.logical_to_pspec(
                        axes, v.shape, mesh, rules))
                else:
                    out[k] = walk(v)
            return out
        if isinstance(node, list):
            return [walk(v) for v in node]
        if node is None:
            return None
        raise TypeError(type(node))

    return walk(caches)


def param_shardings(cfg: ModelConfig, mesh: Mesh, rules) -> PyTree:
    specs = lm.model_specs(cfg)
    return sh.spec_shardings(specs, mesh, rules)


def abstract_model(cfg: ModelConfig) -> PyTree:
    return pt.abstract_params(lm.model_specs(cfg))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Parameter accounting (for roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def param_counts(cfg: ModelConfig) -> Tuple[int, int]:
    """(total, active) parameter counts; active discounts unrouted experts."""
    specs = lm.model_specs(cfg)
    total = pt.param_count(specs)
    active = total
    if cfg.n_experts and cfg.top_k:
        expert_params = (cfg.n_layers * cfg.n_experts * 3
                         * cfg.d_model * cfg.d_ff)
        active = total - expert_params \
            + cfg.n_layers * cfg.top_k * 3 * cfg.d_model * cfg.d_ff
    return total, active


def model_flops(cfg: ModelConfig, shape: ShapeConfig, n_devices: int
                ) -> Tuple[float, int]:
    """(per-device MODEL_FLOPS, tokens): 6·N_active·D for training,
    2·N_active·D forward-only for prefill/decode."""
    total, active = param_counts(cfg)
    # embedding gather is not a matmul: discount embed (and tied head) params
    embed = cfg.vocab_size * cfg.d_model
    matmul_params = active - embed
    if not cfg.tie_embeddings:
        matmul_params = matmul_params      # untied head IS a matmul
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        factor = 2.0
    return factor * matmul_params * tokens / n_devices, tokens
