"""Module-style facade over the butterfly sandwich (paper §3.2).

The repo's core API is functional — a hashable :class:`ButterflySpec` plus a
params dict — which composes with jit but makes the "drop-in dense
replacement" pitch a four-step dance. :class:`ButterflyLinear` packages the
dance: the spec, the init, the apply, the dense distillation, and a default
:class:`~repro.kernels.context.ExecutionContext`, in one frozen object that
is itself hashable (safe to close over in jit, cacheable).

Usage::

    layer = nn.ButterflyLinear.create(key, n_in=300, n_out=100)
    params = layer.init(key2)
    y = layer.apply(params, x)                  # == layer(params, x)

    # approximate an existing dense layer at init (Proposition 3.1)
    layer, params = nn.ButterflyLinear.from_dense(key, W, bias=b)

    # execution policy: per-layer default, ambient, or per-call
    layer = nn.ButterflyLinear.create(key, 512, 512, context="pallas")
    with use_execution(ExecutionContext(mesh_shape=(8,))):
        y = layer.apply(params, x)              # batch-sharded over 8 devices

The layer accepts arbitrary ``n_in``/``n_out`` — non-powers-of-two are
zero-padded to the enclosing power of two by the spec's pad logic and sliced
back, exactly like the underlying
:func:`repro.core.layers.butterfly_linear_apply` (which ``apply`` matches
bit-for-bit; gated in ``tests/test_nn.py``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import layers as blayers
from repro.kernels import context as exctx

__all__ = ["ButterflyLinear", "SandwichLinear"]


@dataclass(frozen=True)
class ButterflyLinear:
    """Drop-in dense-layer replacement: ``(..., n_in) -> (..., n_out)``.

    Internally the paper's butterfly sandwich ``J2ᵀ · W' · J1`` with the
    paper's default core size ``k = log2(n)`` (see :class:`SandwichLinear`
    for explicit core dims). ``context`` is the layer's default execution
    policy; it sits at the *config* layer of the resolution order, so an
    ambient ``with use_execution(...):`` and a per-call ``context=`` both
    override it field-wise.
    """

    spec: blayers.ButterflySpec
    context: Optional[exctx.ExecutionContext] = None

    # -- construction -----------------------------------------------------

    @classmethod
    def create(cls, key: jax.Array, n_in: int, n_out: int, *,
               k_in: Optional[int] = None, k_out: Optional[int] = None,
               k_factor: float = 1.0, use_bias: bool = True,
               context: exctx.ContextLike = None) -> "ButterflyLinear":
        """New layer with FJLT-initialized truncation indices.

        ``k_in``/``k_out`` default to the paper's ``k = log2(n)`` choice
        scaled by ``k_factor``; ``key`` only fixes the (static) truncation
        index sets — weights come from :meth:`init`.
        """
        spec = blayers.make_spec(key, n_in, n_out, k_in=k_in, k_out=k_out,
                                 k_factor=k_factor, use_bias=use_bias)
        return cls(spec=spec, context=exctx.ExecutionContext.coerce(context))

    def init(self, key: jax.Array, dtype=jnp.float32) -> dict:
        """Fresh trainable params: FJLT butterflies + kaiming-uniform core
        (+ zero bias when the spec has one)."""
        return blayers.init_butterfly_linear(key, self.spec, dtype=dtype)

    @classmethod
    def from_dense(cls, key: jax.Array, W: jnp.ndarray, *,
                   bias: Optional[jnp.ndarray] = None,
                   k_in: Optional[int] = None, k_out: Optional[int] = None,
                   k_factor: float = 1.0, dtype=jnp.float32,
                   context: exctx.ContextLike = None
                   ) -> tuple["ButterflyLinear", dict]:
        """Distill a dense ``W (n_out × n_in)`` into a sandwich at init.

        Proposition 3.1: with FJLT butterflies and core ``W' = J2 W J1ᵀ``
        the layer approximates ``W``'s action w.h.p. — the drop-in
        replacement path for a pretrained dense layer, fine-tunable from
        there. Returns ``(layer, params)``.
        """
        n_out, n_in = W.shape
        k_spec, k_init = jax.random.split(key)
        layer = cls.create(k_spec, n_in, n_out, k_in=k_in, k_out=k_out,
                           k_factor=k_factor, use_bias=bias is not None,
                           context=context)
        params = blayers.init_from_dense(k_init, layer.spec,
                                         jnp.asarray(W), dtype=dtype)
        if bias is not None:
            params["bias"] = jnp.asarray(bias, dtype=dtype)
        return layer, params

    # -- application ------------------------------------------------------

    def apply(self, params: dict, x: jnp.ndarray, *,
              context: exctx.ContextLike = None) -> jnp.ndarray:
        """Forward pass (differentiable in ``params`` and ``x`` under every
        backend). ``context`` overrides the layer default per call."""
        ctx = exctx.resolve_execution(context, default=self.context)
        return blayers.butterfly_linear_apply(self.spec, params, x,
                                              context=ctx)

    __call__ = apply

    # -- introspection ----------------------------------------------------

    @property
    def n_in(self) -> int:
        return self.spec.n_in

    @property
    def n_out(self) -> int:
        return self.spec.n_out

    def param_count(self) -> int:
        """Trainable parameter count (vs ``n_in·n_out + n_out`` dense)."""
        return blayers.param_count(self.spec)

    def dense_param_count(self) -> int:
        return blayers.dense_param_count(self.spec.n_in, self.spec.n_out,
                                         self.spec.use_bias)

    def to_dense(self, params: dict) -> jnp.ndarray:
        """Materialized dense ``(n_out × n_in)`` equivalent (analysis/tests;
        excludes the bias)."""
        return blayers.butterfly_linear_materialize(self.spec, params)


class SandwichLinear(ButterflyLinear):
    """The sandwich with explicit core dims ``(k_in, k_out)``.

    Same object as :class:`ButterflyLinear` — this subclass exists for call
    sites that tune the core size directly (quality/compression trade-off,
    paper §5.1) instead of taking the ``k = log2(n)`` default.
    """

    @classmethod
    def create(cls, key: jax.Array, n_in: int, n_out: int,  # type: ignore[override]
               k_in: Optional[int] = None, k_out: Optional[int] = None, *,
               k_factor: float = 1.0, use_bias: bool = True,
               context: exctx.ContextLike = None) -> "SandwichLinear":
        if k_in is None or k_out is None:
            raise TypeError("SandwichLinear.create requires explicit "
                            "k_in and k_out (use ButterflyLinear for the "
                            "paper's log2(n) default)")
        return super().create(key, n_in, n_out, k_in=int(k_in),
                              k_out=int(k_out), k_factor=k_factor,
                              use_bias=use_bias, context=context)
