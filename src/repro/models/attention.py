"""GQA attention: masked, blockwise (flash-style scan), decode-with-cache,
sliding windows and cross-attention.

Three execution paths, all numerically interchangeable (tested against each
other and against :func:`repro.kernels.ref.flash_attention_ref`):

* ``_attend_masked`` — materializes (Bq, Bkv) score tiles; used for short
  sequences (S < cfg.blockwise_threshold).
* ``_attend_blockwise`` — outer ``lax.scan`` over Q blocks, inner
  ``fori_loop`` over KV blocks with online softmax; activation memory is
  O(block_q · block_kv) instead of O(S²), which is what lets the 32k-prefill
  cells fit HBM. Causal + sliding-window block skipping bounds the inner trip
  count, so HLO FLOPs stay near the useful-work count.
* decode — one-token query against the KV cache (linear in S).

The KV cache for full-attention layers is (B, S_max, KV, D) sharded via the
``seq_kv`` logical axis (model axis) when KV heads don't divide the mesh;
sliding-window layers keep a ring buffer of size ``window``.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import paged_attention as pa
from repro.models import common as cm
from repro.runtime.pytree import ParamSpec
from repro.runtime.sharding import constrain

NEG_INF = -1e30


def kv_layout(cfg: ModelConfig, mode: str) -> Tuple:
    """ONE consistent KV/cache layout per config on the current mesh.

    Preference: shard KV heads over the model axis when divisible (keeps the
    decode softmax local); otherwise shard the sequence axis. Mixing layouts
    between the cache (storage) and the in-loop K/V (compute) made GSPMD
    reshard the ENTIRE cache stack with a per-layer all-to-all (measured:
    7.5 GB/layer/step on gemma-7b decode) — hence a single source of truth
    here, used by both the attention constraints and the dry-run cache
    sharding trees.
    """
    from repro.runtime.sharding import active_ctx
    ctx = active_ctx()
    kv_ok = False
    if ctx is not None and ctx.mesh is not None \
            and "model" in ctx.mesh.shape:
        kv_ok = cfg.n_kv_heads % ctx.mesh.shape["model"] == 0
    if kv_ok:
        return ("batch", None, "kv_heads", None)
    if mode == "train":
        return ("batch", None, None, None)
    return ("batch", "seq_kv", None, None)


def attn_specs(cfg: ModelConfig, site_prefix: str = "") -> Dict:
    E, H, KV, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    dt = cfg.param_dtype
    return {
        "wq": ParamSpec((E, H, D), dt, ("embed", "heads", "head_dim"),
                        init="scaled_normal", fan_in_dim=0),
        "wk": ParamSpec((E, KV, D), dt, ("embed", "kv_heads", "head_dim"),
                        init="scaled_normal", fan_in_dim=0),
        "wv": ParamSpec((E, KV, D), dt, ("embed", "kv_heads", "head_dim"),
                        init="scaled_normal", fan_in_dim=0),
        "wo": ParamSpec((H, D, E), dt, ("heads", "head_dim", "embed"),
                        init="scaled_normal", fan_in_dim=1),
    }


def cache_spec(cfg: ModelConfig, batch: int, length: int) -> Dict:
    KV, D = cfg.n_kv_heads, cfg.head_dim_
    shape = (batch, length, KV, D)
    return {
        "k": jax.ShapeDtypeStruct(shape, cfg.cdtype()),
        "v": jax.ShapeDtypeStruct(shape, cfg.cdtype()),
    }


def init_cache(cfg: ModelConfig, batch: int, length: int) -> Dict:
    KV, D = cfg.n_kv_heads, cfg.head_dim_
    shape = (batch, length, KV, D)
    return {"k": jnp.zeros(shape, cfg.cdtype()),
            "v": jnp.zeros(shape, cfg.cdtype())}


def _attend_masked(q, k, v, q_pos, k_pos, causal: bool, window: int):
    """Grouped-query attention without KV expansion.

    q: (B,Sq,KV,G,D); k/v: (B,Skv,KV,D); positions (B,S) int32. The GQA
    repeat is folded into the einsums so the expanded (B,S,H,D) KV tensor is
    never materialized (it dominated decode HBM before)."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k
                        ).astype(jnp.float32) * scale
    mask = jnp.ones((q_pos.shape[0], 1, 1, q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= k_pos[:, None, None, None, :] \
            <= q_pos[:, None, None, :, None]
    if window > 0:
        mask &= k_pos[:, None, None, None, :] \
            > q_pos[:, None, None, :, None] - window
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)


def _attend_blockwise(q, k, v, *, causal: bool, window: int,
                      block_q: int, block_kv: int,
                      dynamic_bounds: bool = True):
    """Flash-style online-softmax GQA attention, O(block²) live memory.

    q: (B,S,KV,G,D); k/v: (B,S,KV,D). Assumes aligned self-attention; S must
    divide the blocks (configs pad shapes accordingly).

    ``dynamic_bounds=True`` (inference) skips out-of-causal-window KV blocks
    with a dynamic fori_loop — no wasted FLOPs. Training uses a static-length
    inner scan with masking instead (reverse-mode differentiable; the ~2x
    causal overcompute is a known hillclimb lever, see EXPERIMENTS.md §Perf).
    """
    B, S, KV, G, D = q.shape
    nq = S // block_q
    nkv = S // block_kv
    scale = D ** -0.5
    qb = q.reshape(B, nq, block_q, KV, G, D).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(B, nkv, block_kv, KV, D).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nkv, block_kv, KV, D).transpose(1, 0, 3, 2, 4)

    def q_block(carry, inputs):
        qi, qblk = inputs                       # (), (B,KV,G,bq,D)
        q_ids = qi * block_q + jnp.arange(block_q)

        def kv_step(j, state):
            m, l, acc = state
            kblk = jax.lax.dynamic_index_in_dim(kb, j, 0, keepdims=False)
            vblk = jax.lax.dynamic_index_in_dim(vb, j, 0, keepdims=False)
            k_ids = j * block_kv + jnp.arange(block_kv)
            s = jnp.einsum("bkgqd,bkcd->bkgqc", qblk, kblk
                           ).astype(jnp.float32) * scale
            msk = jnp.ones((block_q, block_kv), bool)
            if causal:
                msk &= k_ids[None, :] <= q_ids[:, None]
            if window > 0:
                msk &= k_ids[None, :] > q_ids[:, None] - window
            s = jnp.where(msk, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqc,bkcd->bkgqd", p.astype(vblk.dtype), vblk
            ).astype(jnp.float32)
            return m_new, l_new, acc_new

        m0 = jnp.full((B, KV, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, KV, G, block_q, D), jnp.float32)
        if dynamic_bounds:
            # causal block skipping: only KV blocks intersecting
            # [qi*bq - window, (qi+1)*bq) contribute.
            hi = jnp.where(causal,
                           (qi * block_q + block_q + block_kv - 1)
                           // block_kv, nkv)
            if window > 0:
                lo = jnp.maximum(0, (qi * block_q - window) // block_kv)
            else:
                lo = jnp.zeros((), jnp.int32)
            m, l, acc = jax.lax.fori_loop(lo, hi, kv_step, (m0, l0, a0))
        else:
            def kv_scan(state, j):
                return kv_step(j, state), None
            (m, l, acc), _ = jax.lax.scan(kv_scan, (m0, l0, a0),
                                          jnp.arange(nkv))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return carry, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, (),
                           (jnp.arange(nq), qb))    # (nq,B,KV,G,bq,D)
    return outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, KV, G, D)


def attention(cfg: ModelConfig, params: Dict, x: jnp.ndarray, *,
              positions: jnp.ndarray, mode: str,
              cache: Optional[Dict] = None,
              cur_pos: Optional[jnp.ndarray] = None,
              window: int = 0,
              kv_x: Optional[jnp.ndarray] = None,
              is_cross: bool = False,
              causal: bool = True,
              use_rope: bool = True,
              page_table: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, Optional[Dict]]:
    """Unified attention entry.

    mode: "train" (no cache), "prefill" (writes cache), "decode" (Sq = 1,
    reads+writes cache at ``cur_pos``). ``kv_x`` switches to cross-attention
    (keys/values from the encoder stream; cache holds the projected enc KV).
    window > 0 = sliding-window; ring-buffer cache of size ``window``.

    ``page_table`` (B, P) int32 switches decode to the **paged** cache
    layout: ``cache`` leaves are one physical pool (num_pages, page_size,
    KV, D) shared by all rows, row ``b``'s logical page ``j`` lives at
    physical page ``page_table[b, j]``, and Sq may exceed 1 (chunked
    prefill runs prompt chunks through this same path). Sliding-window and
    cross caches stay dense even when a page table is supplied.
    """
    B, Sq, E = x.shape
    H, KV, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    groups = H // KV
    cd = x.dtype

    q = jnp.einsum("bse,ehd->bshd", x, params["wq"].astype(cd))
    if use_rope:
        q = cm.rope(q, positions, cfg.rope_theta)
    q = constrain(q, ("batch", None, "heads", None))
    q = q.reshape(B, Sq, KV, groups, D)     # grouped-query layout

    cross = is_cross or kv_x is not None
    if cross and mode == "decode":
        # cross-attention KV was projected at prefill time and cached
        k_all, v_all = cache["k"], cache["v"]
        new_cache = cache
        k_pos = jnp.broadcast_to(jnp.arange(k_all.shape[1]),
                                 (B, k_all.shape[1]))
        att = _attend_masked(q, k_all.astype(cd), v_all.astype(cd),
                             positions, k_pos, causal=False, window=0)
        return _proj_out(cfg, params, att), new_cache

    src = kv_x if cross else x
    k = jnp.einsum("bse,ekd->bskd", src, params["wk"].astype(cd))
    v = jnp.einsum("bse,ekd->bskd", src, params["wv"].astype(cd))
    if use_rope and not cross:
        k = cm.rope(k, positions, cfg.rope_theta)
    k = constrain(k, kv_layout(cfg, mode))
    v = constrain(v, kv_layout(cfg, mode))

    if mode == "decode" and not cross and page_table is not None \
            and window == 0:
        # paged pool: scatter this step's KV into the rows' pages, read
        # back through the page-table gather. ``positions`` (B, Sq) are the
        # tokens' absolute positions (Sq > 1 = a prefill chunk). Positions
        # past the table's reach — chunk pad tails — are redirected to the
        # trash page (physical page 0, never allocated), so a clamped
        # take_along_axis can never clobber a live page.
        ps_ = cache["k"].shape[1]
        logical = positions // ps_
        P = page_table.shape[1]
        pages = jnp.take_along_axis(page_table,
                                    jnp.minimum(logical, P - 1), axis=1)
        pages = jnp.where(logical < P, pages, pa.TRASH_PAGE)
        offs = positions % ps_
        k_all = cache["k"].at[pages, offs].set(k.astype(cache["k"].dtype))
        v_all = cache["v"].at[pages, offs].set(v.astype(cache["v"].dtype))
        new_cache = {"k": k_all, "v": v_all}
        if Sq == 1:
            att = pa.paged_decode_attention(
                q[:, 0], k_all, v_all, page_table, positions[:, 0])[:, None]
        else:
            att = pa.paged_attend_ref(q, k_all, v_all, page_table,
                                      positions)
        return _proj_out(cfg, params, att), new_cache

    if mode == "decode" and not cross:
        # write this step's KV into the cache (ring buffer if windowed).
        # cur_pos is a scalar (whole batch at one position) or a (B,) vector
        # (per-slot decode: the serving engine's slot pool, where every
        # request sits at its own absolute position).
        length = cache["k"].shape[1]
        slot = (cur_pos % length) if window > 0 else cur_pos
        if jnp.ndim(slot) == 0:
            k_all = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            v_all = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        else:
            bidx = jnp.arange(B)
            k_all = cache["k"].at[bidx, slot].set(
                k[:, 0].astype(cache["k"].dtype))
            v_all = cache["v"].at[bidx, slot].set(
                v[:, 0].astype(cache["v"].dtype))
        new_cache = {"k": k_all, "v": v_all}
        kpos = jnp.arange(length)[None, :]
        # (1, 1) for a scalar cur_pos, (B, 1) for per-slot positions — the
        # masks below broadcast against kpos either way
        cp = jnp.reshape(cur_pos, (-1, 1))
        if window > 0:
            # ring buffer: entry i holds absolute position p with
            # p % window == i and p <= cur_pos, p > cur_pos - window
            base = cp - (cp % length)
            abs_pos = kpos + base
            abs_pos = jnp.where(abs_pos > cp, abs_pos - length, abs_pos)
            valid = abs_pos >= jnp.maximum(0, cp - window + 1)
        else:
            valid = kpos <= cp
        scale = D ** -0.5
        ka = k_all.astype(cd)
        va = v_all.astype(cd)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", q, ka
                            ).astype(jnp.float32) * scale
        logits = jnp.where(valid[:, None, None, None, :], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        att = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(va.dtype), va)
        return _proj_out(cfg, params, att), new_cache

    # train / prefill / cross-encode paths operate on full sequences
    new_cache = None
    if mode == "prefill":
        if window > 0 and not cross:
            ring = cache["k"].shape[1]
            if Sq >= ring:
                # keep the last `ring` positions in the ring buffer, aligned
                # so that slot = pos % ring (matches the decode path)
                start = Sq - ring
                kw = jax.lax.dynamic_slice_in_dim(k, start, ring, axis=1)
                vw = jax.lax.dynamic_slice_in_dim(v, start, ring, axis=1)
                roll = (-start) % ring
                kw = jnp.roll(kw, roll, axis=1)
                vw = jnp.roll(vw, roll, axis=1)
            else:
                # prompt shorter than the ring (short-prompt serving):
                # position p lands at slot p (= p % ring) directly; the
                # zero tail is never read — the decode validity mask only
                # admits slots whose reconstructed abs position is <=
                # cur_pos, and those get overwritten before qualifying
                pad = [(0, 0), (0, ring - Sq), (0, 0), (0, 0)]
                kw = jnp.pad(k, pad)
                vw = jnp.pad(v, pad)
            new_cache = {"k": kw.astype(cache["k"].dtype),
                         "v": vw.astype(cache["v"].dtype)}
        else:
            new_cache = {"k": k.astype(cache["k"].dtype),
                         "v": v.astype(cache["v"].dtype)}
            if cache["k"].shape[1] != k.shape[1]:
                pad = cache["k"].shape[1] - k.shape[1]
                new_cache = {
                    t: jnp.pad(new_cache[t], ((0, 0), (0, pad), (0, 0),
                                              (0, 0)))
                    for t in ("k", "v")}

    use_blockwise = (not cross and Sq >= cfg.blockwise_threshold
                     and Sq % cfg.attn_block_q == 0
                     and Sq % cfg.attn_block_kv == 0)
    if use_blockwise:
        att = _attend_blockwise(q, k, v, causal=causal, window=window,
                                block_q=cfg.attn_block_q,
                                block_kv=cfg.attn_block_kv,
                                dynamic_bounds=(mode != "train"))
    else:
        kpos = (positions if not cross
                else jnp.broadcast_to(jnp.arange(k.shape[1]),
                                      (B, k.shape[1])))
        att = _attend_masked(q, k, v, positions, kpos,
                             causal=causal and not cross, window=window)
    return _proj_out(cfg, params, att), new_cache


def _proj_out(cfg: ModelConfig, params: Dict, att: jnp.ndarray
              ) -> jnp.ndarray:
    """att: (B,S,KV,G,D) grouped layout -> output projection."""
    B, S = att.shape[:2]
    H = cfg.n_heads
    D = cfg.head_dim_
    att = att.reshape(B, S, H, D)
    att = constrain(att, ("batch", None, "heads", None))
    out = jnp.einsum("bshd,hde->bse", att, params["wo"].astype(att.dtype))
    return out
