"""Generate the EXPERIMENTS.md dry-run/roofline tables from the JSON
artifacts (``python -m repro.launch.report [dir ...]``)."""

from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List


def load(d: str) -> List[Dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def fmt_bytes(b) -> str:
    return f"{b / 1e9:.2f}"


def roofline_table(records: List[Dict], mesh: str) -> str:
    lines = [
        "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | dominant "
        "| MODEL/HLO flops | roofline frac | HBM GB/dev | fit |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r["mesh"] != mesh:
            continue
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                         f"| — | — | {r['reason']} |")
            continue
        hbm = (r["argument_bytes"] + r["temp_bytes"] + r["output_bytes"]
               - r["alias_bytes"]) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute'] * 1e3:.1f} "
            f"| {r['t_memory'] * 1e3:.1f} | {r['t_collective'] * 1e3:.1f} "
            f"| {r['dominant']} | {r['flops_utilization']:.2f} "
            f"| {r['roofline_fraction']:.3f} | {hbm:.1f} "
            f"| {'✅' if r['hbm_fit'] else '❌'} |")
    return "\n".join(lines)


def dryrun_table(records: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | compile s | FLOPs/dev | HBM "
        "GB/dev | ICI GB | DCN GB | collectives |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in records:
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skipped | — | — | — | — | — | {r['reason']} |")
            continue
        colls = ", ".join(f"{k}×{v}" for k, v in
                          sorted(r["collective_counts"].items()))
        hbm = (r["argument_bytes"] + r["temp_bytes"] + r["output_bytes"]
               - r["alias_bytes"]) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {r.get('compile_seconds', 0):.1f} "
            f"| {r['flops_per_device']:.2e} | {hbm:.1f} "
            f"| {r['collective_ici_bytes'] / 1e9:.2f} "
            f"| {r['collective_dcn_bytes'] / 1e9:.2f} | {colls} |")
    return "\n".join(lines)


def diff_table(base: List[Dict], new: List[Dict], cells: List) -> str:
    bmap = {(r["arch"], r["shape"], r["mesh"]): r for r in base}
    nmap = {(r["arch"], r["shape"], r["mesh"]): r for r in new}
    lines = ["| cell | term | before | after | Δ |", "|---|---|---|---|---|"]
    for key in cells:
        b, n = bmap.get(tuple(key)), nmap.get(tuple(key))
        if not b or not n or b.get("status") != "ok":
            continue
        for term in ("t_compute", "t_memory", "t_collective"):
            tb, tn = b[term] * 1e3, n[term] * 1e3
            if tb == 0 and tn == 0:
                continue
            d = (tb - tn) / tb * 100 if tb else 0.0
            lines.append(f"| {key[0]} × {key[1]} | {term[2:]} | {tb:.1f} ms "
                         f"| {tn:.1f} ms | {d:+.0f}% |")
    return "\n".join(lines)


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    records = load(d)
    print("## Single-pod roofline (16x16)\n")
    print(roofline_table(records, "pod16x16"))
    print("\n## Multi-pod roofline (2x16x16)\n")
    print(roofline_table(records, "pod2x16x16"))
    print("\n## Dry-run detail\n")
    print(dryrun_table(records))


if __name__ == "__main__":
    main()
