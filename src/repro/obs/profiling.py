"""Device-profiler annotations gated on the ambient ExecutionContext.

:func:`annotate` wraps a kernel call site in
``jax.profiler.TraceAnnotation`` when profiling is enabled, so traces
captured with ``jax.profiler.trace()`` / TensorBoard line up with the
serving tier's span names (``butterfly_matmul``, ``flash_attention``,
``paged_attention`` …). Enablement comes from the resolution order the
kernels already use everywhere else:

* an explicit :class:`~repro.kernels.context.ExecutionContext` passed by
  the call site (the fused ops thread their finalized ``ctx`` through),
* else the ambient ``use_execution(...)`` context,
* else the ``REPRO_PROFILE=1`` environment variable.

When profiling is off — the default — :func:`annotate` returns a shared
``contextlib.nullcontext`` without importing ``jax.profiler``, so the
hot path pays one attribute check. Note these annotations fire at trace
time (the call sites run under ``jit``), so steady-state execution cost
is zero either way.
"""

from __future__ import annotations

import contextlib
import os
from typing import TYPE_CHECKING, ContextManager, Optional

if TYPE_CHECKING:  # pragma: no cover
    from repro.kernels import context as exctx

__all__ = ["annotate", "profiling_enabled"]

_NULL = contextlib.nullcontext()


def profiling_enabled(
        ctx: Optional["exctx.ExecutionContext"] = None) -> bool:
    """True when kernel call sites should emit profiler annotations."""
    if ctx is None:
        # deferred: repro.kernels.ops imports this module at load time,
        # so a module-level import here would be circular
        from repro.kernels import context as exctx
        ctx = exctx.current_execution()
    if ctx is not None and ctx.profile is not None:
        return bool(ctx.profile)
    return os.environ.get("REPRO_PROFILE", "").strip() in ("1", "true", "on")


def annotate(name: str,
             ctx: Optional["exctx.ExecutionContext"] = None
             ) -> ContextManager:
    """``jax.profiler.TraceAnnotation(name)`` if profiling, else a no-op."""
    if not profiling_enabled(ctx):
        return _NULL
    import jax.profiler
    return jax.profiler.TraceAnnotation(name)
