"""ONE cache interface for serving: dense and paged KV cache pools.

Every cache layout decision the engine, the tests and the benchmarks used
to make by reaching into raw nested cache dicts now goes through a
:class:`CachePool`:

* :class:`DenseCachePool` — the PR-5 layout: every decode slot owns a full
  ``max_len`` cache row (batch axis = slot index). Simple, exact, and the
  bisection baseline (``ServeEngine(..., pool="dense")``).
* :class:`PagedCachePool` — vLLM-style paged pool: full-attention KV leaves
  become ONE preallocated pool of fixed-size pages ``(num_pages,
  page_size, KV, D)`` plus a host-side per-slot page table and a FIFO
  free-list allocator with recycling. A slot's cache "row" is the logical
  concatenation of its pages; attention reads gather through the page
  table (:mod:`repro.kernels.paged_attention`), decode writes scatter into
  ``(page, offset)``. Capacity is reserved per *request* (``prompt +
  max_new_tokens``), not per worst-case ``max_len`` — which is why a paged
  engine sustains more concurrent slots than a dense one at equal memory.

Physical **page 0 is the trash page**: it is never allocated, every
unallocated page-table entry points at it, and the engine's pooled decode
step redirects inactive slots' whole page-table rows to it. Stray writes
(inactive lanes, right-pad tails) land there; reads from it are masked by
the positional validity mask, so its contents are never observable.

Layout rules (per block type, paged pool):

=========  =======================================================
attn/global/moe   ``self`` KV paged
xdec              ``self`` paged, ``cross`` dense (bounded enc_seq)
local             dense ring buffer (bounded at ``sliding_window``)
rec/mlstm/slstm   not pageable — sequential state; the engine keeps
                  these archs on the dense exact-length path
=========  =======================================================

The module also owns the per-block-type cache constructors that used to
live in :mod:`repro.models.lm` (``layer_cache_spec`` / ``init_layer_cache``
/ ``cache_specs`` / ``init_caches`` / ``write_cache_slot`` /
``reset_cache_slot``); ``lm`` keeps thin delegates so model-side callers
are unaffected.
"""

from __future__ import annotations

import collections
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.paged_attention import TRASH_PAGE
from repro.models import attention as attn
from repro.models import rglru as rgm
from repro.models import xlstm as xm

#: block types whose cache mixes positions sequentially (recurrent state)
#: — right-padded prefill or paged gather would corrupt them, so archs
#: containing them serve through the dense exact-length path.
SEQUENTIAL_STATE_BLOCKS = ("rec", "mlstm", "slstm")

#: per block type, the cache-dict keys whose KV moves into the page pool.
_PAGED_KEYS = {"attn": ("self",), "global": ("self",), "moe": ("self",),
               "xdec": ("self",)}


class PoolExhausted(RuntimeError):
    """The page pool cannot cover a requested allocation.

    Raised by :meth:`PagedCachePool.alloc_pages`; the serving engine
    catches it. At admission time the request stays queued until finished
    requests free pages; during incremental decode growth the engine
    preempts its youngest slot and recomputes it later — either way,
    exhaustion is backpressure, not a crash.
    """


def total_seq(cfg: ModelConfig, seq_len: int) -> int:
    """Cache length: text tokens plus any prepended frontend tokens."""
    return seq_len + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)


def paged_supported(cfg: ModelConfig) -> bool:
    """True when every cache of ``cfg`` is pageable or boundedly dense."""
    types = set(cfg.block_unit) | set(cfg.tail_layers)
    return not (types & set(SEQUENTIAL_STATE_BLOCKS))


def chunked_prefill_supported(cfg: ModelConfig) -> bool:
    """True when prompts can be admitted as fixed-size prefill chunks.

    Chunked prefill runs the decode-style cached-attention path with C
    query positions at once, so it needs every self-attention cache to be
    paged (full attention, no sliding-window ring) and a plain token
    stream (no vision frontend prefix, no encoder)."""
    types = set(cfg.block_unit) | set(cfg.tail_layers)
    return (paged_supported(cfg)
            and not (types & {"local", "xdec", "enc"})
            and not cfg.frontend and not cfg.n_enc_layers)


# ---------------------------------------------------------------------------
# Per-block-type cache constructors (dense layout — the model-layer truth,
# delegated to by repro.models.lm)
# ---------------------------------------------------------------------------

def layer_cache_spec(cfg: ModelConfig, btype: str, batch: int,
                     seq_len: int) -> Optional[Dict]:
    if btype in ("attn", "global", "moe"):
        return {"self": attn.cache_spec(cfg, batch, seq_len)}
    if btype == "local":
        length = min(cfg.sliding_window, seq_len)
        return {"self": attn.cache_spec(cfg, batch, length)}
    if btype == "rec":
        return {"rec": rgm.rglru_cache_spec(cfg, batch)}
    if btype == "mlstm":
        return {"mlstm": xm.mlstm_cache_spec(cfg, batch)}
    if btype == "slstm":
        return {"slstm": xm.slstm_cache_spec(cfg, batch)}
    if btype == "xdec":
        return {"self": attn.cache_spec(cfg, batch, seq_len),
                "cross": attn.cache_spec(cfg, batch, cfg.enc_seq)}
    if btype == "enc":
        return None
    raise ValueError(btype)


def init_layer_cache(cfg: ModelConfig, btype: str, batch: int,
                     seq_len: int) -> Optional[Dict]:
    spec = layer_cache_spec(cfg, btype, batch, seq_len)
    if spec is None:
        return None
    cache = jax.tree_util.tree_map(lambda s: jnp.zeros(s.shape, s.dtype),
                                   spec)
    if btype == "mlstm":
        cache["mlstm"]["m"] = jnp.full(spec["mlstm"]["m"].shape, -1e30,
                                       jnp.float32)
    if btype == "slstm":
        cache["slstm"]["m"] = jnp.full(spec["slstm"]["m"].shape, -1e30,
                                       jnp.float32)
        cache["slstm"]["n"] = jnp.full(spec["slstm"]["n"].shape, 1e-6,
                                       jnp.float32)
    return cache


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> Dict:
    unit = cfg.block_unit
    R = cfg.unit_repeats
    seq_len = total_seq(cfg, seq_len)

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((R,) + s.shape, s.dtype), tree)

    return {
        "unit": [stack(layer_cache_spec(cfg, t, batch, seq_len))
                 for t in unit],
        "tail": [layer_cache_spec(cfg, t, batch, seq_len)
                 for t in cfg.tail_layers],
    }


def init_caches(cfg: ModelConfig, batch: int, seq_len: int) -> Dict:
    unit = cfg.block_unit
    R = cfg.unit_repeats
    seq_len = total_seq(cfg, seq_len)

    def stack(tree):
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (R,) + a.shape).copy(), tree)

    return {
        "unit": [stack(init_layer_cache(cfg, t, batch, seq_len))
                 for t in unit],
        "tail": [init_layer_cache(cfg, t, batch, seq_len)
                 for t in cfg.tail_layers],
    }


def write_cache_slot(cfg: ModelConfig, pool: Dict, sub: Dict,
                     slot: jnp.ndarray) -> Dict:
    """Insert a batch-1 cache tree into batch index ``slot`` of a dense
    pool. Unit-stack leaves carry batch at axis 1 (axis 0 is the scan
    repeat), tail leaves at axis 0."""
    def upd(axis):
        def f(p, s):
            return jax.lax.dynamic_update_slice_in_dim(
                p, s.astype(p.dtype), slot, axis)
        return f

    return {
        "unit": jax.tree_util.tree_map(upd(1), pool["unit"], sub["unit"]),
        "tail": jax.tree_util.tree_map(upd(0), pool["tail"], sub["tail"]),
    }


def reset_cache_slot(cfg: ModelConfig, pool: Dict, slot: jnp.ndarray,
                     seq_len: int) -> Dict:
    """Reset batch index ``slot`` of a dense cache pool to its init state.
    ``seq_len`` must be the text length the pool was built with."""
    return write_cache_slot(cfg, pool, init_caches(cfg, 1, seq_len), slot)


# ---------------------------------------------------------------------------
# The pool interface
# ---------------------------------------------------------------------------

class CachePool:
    """Protocol every serving cache pool implements.

    Jittable tree transforms (``write_slot`` / ``reset_slot`` close over
    only static config; the engine wraps them in its CompileCache):

    * ``spec()`` / ``init()`` — the pool cache tree (shape-structs /
      zero-initialized arrays).
    * ``write_slot(caches, sub, slot, page_row)`` — splice a batch-1
      dense cache tree (a prefill result) into a slot.
    * ``reset_slot(caches, slot, page_row)`` — scrub a slot back to init.

    Host-side allocator lifecycle (pure Python, deterministic):

    * ``alloc_pages(slot, n_tokens)`` — ensure the slot can hold
      ``n_tokens`` cache positions; raises :class:`PoolExhausted`.
      Idempotent and *incremental*: growing an already-populated slot
      allocates only the missing pages, which is what the engine's
      incremental admission mode leans on.
    * ``free(slot)`` — return the slot's resources for recycling.
    * ``gather_args()`` — extra traced arguments the decode/chunk steps
      need (the page table for a paged pool; nothing for dense).

    ``faults`` optionally holds a :class:`repro.serve.faults.FaultInjector`
    (duck-typed to avoid an import cycle); a paged pool consults it on
    every real allocation attempt, so a seeded schedule can force
    exhaustion even while free pages exist.
    """

    kind: str = "none"
    faults = None                      # Optional[FaultInjector]

    def spec(self) -> Dict:
        raise NotImplementedError

    def init(self) -> Dict:
        raise NotImplementedError

    def write_slot(self, caches: Dict, sub: Dict, slot: jnp.ndarray,
                   page_row: Optional[jnp.ndarray] = None) -> Dict:
        raise NotImplementedError

    def reset_slot(self, caches: Dict, slot: jnp.ndarray,
                   page_row: Optional[jnp.ndarray] = None) -> Dict:
        raise NotImplementedError

    def alloc_pages(self, slot: int, n_tokens: int) -> None:
        return None

    def free(self, slot: int) -> None:
        return None

    def gather_args(self) -> Dict[str, jnp.ndarray]:
        return {}

    def page_row(self, slot: int) -> Optional[jnp.ndarray]:
        return None

    # -- introspection (metrics / tests) --------------------------------

    @property
    def pages_in_use(self) -> int:
        return 0

    @property
    def pages_hwm(self) -> int:
        return 0

    @property
    def total_pages(self) -> int:
        return 0

    def reset_stats(self) -> None:
        """Rebase high-water statistics to the current occupancy.

        ``engine.reset_metrics()`` calls this so bench warm-up artifacts
        (burn-in ``pages_hwm``) don't survive into the measured window.
        Live allocation state is untouched."""
        return None


class DenseCachePool(CachePool):
    """The PR-5 dense pooled cache: one full ``max_len`` row per slot."""

    kind = "dense"

    def __init__(self, cfg: ModelConfig, slots: int, max_len: int):
        self.cfg = cfg
        self.slots = slots
        self.max_len = int(max_len)

    def spec(self) -> Dict:
        return cache_specs(self.cfg, self.slots, self.max_len)

    def init(self) -> Dict:
        return init_caches(self.cfg, self.slots, self.max_len)

    def write_slot(self, caches, sub, slot, page_row=None):
        return write_cache_slot(self.cfg, caches, sub, slot)

    def reset_slot(self, caches, slot, page_row=None):
        return reset_cache_slot(self.cfg, caches, slot, self.max_len)

    def alloc_pages(self, slot: int, n_tokens: int) -> None:
        limit = total_seq(self.cfg, self.max_len)
        if n_tokens > limit:
            raise PoolExhausted(
                f"dense pool row holds {limit} positions, request needs "
                f"{n_tokens}")


class PagedCachePool(CachePool):
    """Fixed-size pages in one preallocated pool + per-slot page tables.

    ``num_pages`` counts *physical* pages including the trash page, so a
    pool holds ``(num_pages - 1) * page_size`` usable cache positions;
    the default matches a dense pool of the same ``slots``/``max_len``
    plus the trash page. The allocator itself is policy-free — it grows a
    slot to any requested coverage and raises :class:`PoolExhausted` when
    it cannot. The *engine* picks the reservation policy: eager admission
    reserves ``ceil((n_front + prompt + max_new) / page_size)`` pages up
    front (deadlock-free with no preemption path), incremental admission
    reserves only the prompt's pages and grows per decode tick, preempting
    on exhaustion. Either way the win over dense is that reservations
    track the *request*, not the engine-wide ``max_len``.

    The free list is a FIFO deque: pages allocate in ascending id order
    from a fresh pool and recycle in the order they were freed —
    deterministic, and stale page contents from a previous owner are
    unobservable (the new owner's validity mask only admits positions it
    has already written).
    """

    kind = "paged"

    def __init__(self, cfg: ModelConfig, slots: int, max_len: int, *,
                 page_size: int = 16, num_pages: Optional[int] = None):
        if not paged_supported(cfg):
            raise ValueError(
                f"{cfg.name}: sequential-state blocks "
                f"({SEQUENTIAL_STATE_BLOCKS}) cannot be paged; use "
                f"pool='dense'")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.cfg = cfg
        self.slots = slots
        self.max_len = int(max_len)
        self.page_size = int(page_size)
        self.max_len_total = total_seq(cfg, self.max_len)
        self.pages_per_slot = math.ceil(self.max_len_total / self.page_size)
        if num_pages is None:
            num_pages = slots * self.pages_per_slot + 1
        if num_pages < 2:
            raise ValueError(f"num_pages must be >= 2 (page 0 is the "
                             f"trash page), got {num_pages}")
        self.num_pages = int(num_pages)
        self._free: collections.deque = collections.deque(
            range(1, self.num_pages))
        self._owned: List[List[int]] = [[] for _ in range(slots)]
        self._table = np.full((slots, self.pages_per_slot), TRASH_PAGE,
                              np.int32)
        self._hwm = 0

    # -- allocator ------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        return math.ceil(n_tokens / self.page_size)

    def alloc_pages(self, slot: int, n_tokens: int) -> None:
        """Ensure ``slot`` owns pages covering positions [0, n_tokens)."""
        if n_tokens > self.max_len_total:
            raise PoolExhausted(
                f"slot page table holds {self.max_len_total} positions, "
                f"request needs {n_tokens}")
        owned = self._owned[slot]
        need = self.pages_for(n_tokens) - len(owned)
        if need <= 0:
            return
        if self.faults is not None:
            self.faults.check("pool.alloc")
        if need > len(self._free):
            raise PoolExhausted(
                f"pool has {len(self._free)} free pages, slot {slot} "
                f"needs {need} more (of {self.num_pages - 1} usable)")
        for _ in range(need):
            page = self._free.popleft()
            self._table[slot, len(owned)] = page
            owned.append(page)
        self._hwm = max(self._hwm, self.pages_in_use)

    def free(self, slot: int) -> None:
        """Recycle the slot's pages (FIFO) and trash its table row."""
        self._free.extend(self._owned[slot])
        self._owned[slot] = []
        self._table[slot, :] = TRASH_PAGE

    def gather_args(self) -> Dict[str, jnp.ndarray]:
        return {"page_table": jnp.asarray(self._table)}

    def page_row(self, slot: int) -> jnp.ndarray:
        return jnp.asarray(self._table[slot])

    @property
    def pages_in_use(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    @property
    def pages_hwm(self) -> int:
        return self._hwm

    @property
    def total_pages(self) -> int:
        return self.num_pages

    def reset_stats(self) -> None:
        self._hwm = self.pages_in_use

    def free_list(self) -> Tuple[int, ...]:
        """Snapshot of the free list (allocation order) — test surface."""
        return tuple(self._free)

    def slot_pages(self, slot: int) -> Tuple[int, ...]:
        """Physical pages ``slot`` currently owns, in logical order — test
        surface for incremental growth / preemption accounting."""
        return tuple(self._owned[slot])

    # -- cache tree -----------------------------------------------------

    def _paged_keys(self, btype: str) -> Tuple[str, ...]:
        return _PAGED_KEYS.get(btype, ())

    def _layer_spec(self, btype: str) -> Optional[Dict]:
        spec = layer_cache_spec(self.cfg, btype, self.slots,
                                self.max_len_total)
        if spec is None:
            return None
        KV, D = self.cfg.n_kv_heads, self.cfg.head_dim_
        pool_shape = (self.num_pages, self.page_size, KV, D)
        out = dict(spec)
        for key in self._paged_keys(btype):
            out[key] = {t: jax.ShapeDtypeStruct(pool_shape, s.dtype)
                        for t, s in spec[key].items()}
        return out

    def spec(self) -> Dict:
        unit = self.cfg.block_unit
        R = self.cfg.unit_repeats

        def stack(tree):
            return jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((R,) + s.shape, s.dtype),
                tree)

        return {
            "unit": [stack(self._layer_spec(t)) for t in unit],
            "tail": [self._layer_spec(t) for t in self.cfg.tail_layers],
        }

    def init(self) -> Dict:
        # every pageable leaf inits to zeros; dense leaves of pageable
        # archs (local rings, xdec cross) do too, so plain zeros is exact
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.spec())

    # -- slot surgery ---------------------------------------------------

    def _map_layers(self, caches: Dict, sub: Dict, paged_fn, dense_fn
                    ) -> Dict:
        """Apply ``paged_fn(pool_leaf, sub_leaf, stacked)`` to paged
        leaves and ``dense_fn(pool_leaf, sub_leaf, stacked)`` to dense
        ones, per block type, preserving the tree structure."""
        cfg = self.cfg

        def one(btype: str, pool_layer, sub_layer, stacked: bool):
            if pool_layer is None:
                return None
            pkeys = self._paged_keys(btype)
            out = {}
            for key, leafs in pool_layer.items():
                fn = paged_fn if key in pkeys else dense_fn
                out[key] = jax.tree_util.tree_map(
                    lambda p, s: fn(p, s, stacked), leafs, sub_layer[key])
            return out

        return {
            "unit": [one(t, caches["unit"][i], sub["unit"][i], True)
                     for i, t in enumerate(cfg.block_unit)],
            "tail": [one(t, caches["tail"][i], sub["tail"][i], False)
                     for i, t in enumerate(cfg.tail_layers)],
        }

    def write_slot(self, caches: Dict, sub: Dict, slot: jnp.ndarray,
                   page_row: Optional[jnp.ndarray] = None) -> Dict:
        """Scatter a batch-1 *dense* cache tree (a whole-prompt prefill at
        ``max_len``) into the slot's pages; dense leaves splice at the
        slot's batch index exactly like the dense pool. Positions beyond
        the slot's allocated pages route to the trash page via the
        ``page_row`` sentinel entries."""
        ps = self.page_size
        L = self.max_len_total
        pos = jnp.arange(L)
        pages = page_row[pos // ps]
        offs = pos % ps

        def paged(p, s, stacked):
            if stacked:                # (R, N, ps, KV, D) <- (R, 1, L, ...)
                return p.at[:, pages, offs].set(s[:, 0].astype(p.dtype))
            return p.at[pages, offs].set(s[0].astype(p.dtype))

        def dense(p, s, stacked):
            return jax.lax.dynamic_update_slice_in_dim(
                p, s.astype(p.dtype), slot, 1 if stacked else 0)

        return self._map_layers(caches, sub, paged, dense)

    def reset_slot(self, caches: Dict, slot: jnp.ndarray,
                   page_row: Optional[jnp.ndarray] = None) -> Dict:
        return self.write_slot(
            caches, init_caches(self.cfg, 1, self.max_len), slot, page_row)


def make_pool(cfg: ModelConfig, slots: int, max_len: int, *,
              kind: str = "paged", page_size: int = 16,
              num_pages: Optional[int] = None) -> CachePool:
    """Pool factory: ``kind`` "paged" (falls back to dense for
    sequential-state archs) or "dense" (always available, for
    bisection)."""
    if kind == "dense":
        return DenseCachePool(cfg, slots, max_len)
    if kind == "paged":
        if not paged_supported(cfg):
            return DenseCachePool(cfg, slots, max_len)
        return PagedCachePool(cfg, slots, max_len, page_size=page_size,
                              num_pages=num_pages)
    raise ValueError(f"unknown pool kind {kind!r}: expected 'paged' or "
                     f"'dense'")
