"""Gradient compression with error feedback (cross-pod/DCN axis).

Two codecs, both wrapped as :class:`GradientTransformation` so they chain
into the optimizer stack *before* the learning-rate scale:

* ``topk``  — keep the top ``ratio`` fraction of entries by magnitude;
  the residual is carried in an error-feedback buffer (Stich et al.), so the
  compressed SGD still converges (verified by test on a quadratic).
* ``int8``  — per-tensor symmetric int8 quantization with error feedback.

On a real deployment the compressed tensor is what crosses the slow DCN pod
axis; here the transform is numerically exact to that pipeline (compress →
decompress) with the bandwidth saving recorded in ``stats``.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.optim.optimizer import GradientTransformation, _tree_map

PyTree = Any


class ErrorFeedbackState(NamedTuple):
    error: PyTree


def _topk_compress(g: jnp.ndarray, ratio: float) -> jnp.ndarray:
    if g.ndim == 0:
        return g
    flat = g.reshape(-1)
    k = max(1, int(ratio * flat.size))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    return (flat * mask).reshape(g.shape)


def _int8_compress(g: jnp.ndarray) -> jnp.ndarray:
    if g.ndim == 0:
        return g
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q.astype(g.dtype) * scale


def compress_gradients(kind: str, ratio: float = 0.01
                       ) -> GradientTransformation:
    """Error-feedback compression transform. kind: "topk" | "int8"."""

    def codec(g):
        if kind == "topk":
            return _topk_compress(g, ratio)
        if kind == "int8":
            return _int8_compress(g)
        raise ValueError(kind)

    def init(params):
        err = _tree_map(
            lambda p: (jnp.zeros_like(p)
                       if p is not None and jnp.issubdtype(
                           jnp.asarray(p).dtype, jnp.inexact) else None),
            params)
        return ErrorFeedbackState(error=err)

    def update(grads, state, params=None):
        compressed = _tree_map(
            lambda g, e: None if g is None or e is None else codec(g + e),
            grads, state.error)
        new_err = _tree_map(
            lambda g, e, c: None if c is None else (g + e) - c,
            grads, state.error, compressed)
        return compressed, ErrorFeedbackState(error=new_err)

    return GradientTransformation(init, update)


def compression_stats(kind: str, g: jnp.ndarray, ratio: float = 0.01
                      ) -> Tuple[int, int]:
    """(raw_bytes, wire_bytes) for one tensor — used by the trainer metrics
    to report DCN bandwidth savings."""
    raw = g.size * g.dtype.itemsize
    if kind == "topk":
        k = max(1, int(ratio * g.size))
        wire = k * (g.dtype.itemsize + 4)     # value + index
    elif kind == "int8":
        wire = g.size + 4                     # int8 payload + scale
    else:
        wire = raw
    return raw, wire
