"""Batched serving demo: prefill a prompt batch, then step-decode greedily
with per-layer KV/state caches (same serve_step the dry-run lowers).

Run: ``PYTHONPATH=src python examples/serve_lm.py --arch smollm-135m-smoke``
Try ``--arch recurrentgemma-2b-smoke`` (RG-LRU state + ring-buffer window
cache) or ``--arch xlstm-125m-smoke`` (matrix-memory state, O(1) decode).
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()

    from repro.configs import registry
    from repro.models import lm
    from repro.runtime import pytree as pt
    from repro.train import steps as steps_lib

    cfg = registry.get(args.arch)
    params = pt.init_params(jax.random.PRNGKey(0), lm.model_specs(cfg))
    B, S, T = args.batch, args.prompt_len, args.gen_len

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.frontend == "vision":
        batch["frontend_embeds"] = jnp.asarray(rng.normal(
            size=(B, cfg.frontend_tokens, cfg.d_model)), jnp.float32)
    if cfg.n_enc_layers:
        batch["frames"] = jnp.asarray(rng.normal(
            size=(B, cfg.enc_seq, cfg.d_model)), jnp.float32)

    caches = lm.init_caches(cfg, B, S + T)
    prefill = jax.jit(steps_lib.make_prefill_step(cfg))
    serve = jax.jit(steps_lib.make_serve_step(cfg), donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch, caches)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    extra = cfg.frontend_tokens if cfg.frontend == "vision" else 0
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [np.asarray(tok)]
    t0 = time.perf_counter()
    for t in range(T - 1):
        tok, logits, caches = serve(params, tok, caches,
                                    jnp.asarray(S + extra + t, jnp.int32))
        generated.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.stack(generated, axis=1)
    print(f"arch={cfg.name}  batch={B}  prompt={S}  generated={T}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms   "
          f"decode: {t_decode / max(T - 1, 1) * 1e3:.1f} ms/token")
    for b in range(min(B, 2)):
        print(f"  seq[{b}]: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
