"""Step functions: train (with microbatched gradient accumulation), prefill
and decode. These are the units the launcher jits/lowers — both for real
execution and for the multi-pod dry-run."""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import common as cm
from repro.models import lm
from repro.optim import optimizer as opt
from repro.optim.compression import compress_gradients

PyTree = Any


def make_optimizer(tc: TrainConfig) -> opt.GradientTransformation:
    schedule = opt.warmup_cosine_schedule(tc.learning_rate, tc.warmup_steps,
                                          tc.total_steps)
    parts = []
    if tc.max_grad_norm:
        parts.append(opt.clip_by_global_norm(tc.max_grad_norm))
    if tc.grad_compression:
        parts.append(compress_gradients(tc.grad_compression,
                                        tc.grad_compression_ratio))
    parts.append(opt.scale_by_adam())
    if tc.weight_decay:
        parts.append(opt.add_decayed_weights(tc.weight_decay))
    parts.append(opt.scale_by_schedule(schedule))
    return opt.chain(*parts)


def make_train_step(cfg: ModelConfig, tx: opt.GradientTransformation,
                    microbatches: int = 1) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``microbatches > 1`` accumulates gradients over a lax.scan so peak
    activation memory scales with the microbatch, not the global batch —
    the standard large-model memory lever.
    """

    def loss_fn(params, mb):
        return lm.loss_fn(cfg, params, mb)

    def step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            mbs = jax.tree_util.tree_map(split, batch)
            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb_step(carry, mb):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), gsum, g)
                return (gsum, lsum + l), None

            (grads, lsum), _ = jax.lax.scan(mb_step, (zero, 0.0), mbs)
            inv = 1.0 / microbatches
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
            loss = lsum * inv
            metrics = {}

        gnorm = opt.clip_by_global_norm(1.0)  # reuse norm computation
        leaves = [g for g in jax.tree_util.tree_leaves(grads)
                  if g is not None]
        grad_norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                                 for g in leaves))
        updates, opt_state = tx.update(grads, opt_state, params)
        params = opt.apply_updates(params, updates)
        out_metrics = {"loss": loss, "grad_norm": grad_norm}
        out_metrics.update({k: v for k, v in metrics.items()})
        return params, opt_state, out_metrics

    return step


def make_prefill_step(cfg: ModelConfig, chunks: int = 1) -> Callable:
    """Prefill, optionally processing the batch in ``chunks`` sequential
    sub-batches: full-sequence activation peaks scale 1/chunks while the
    caches assemble to the same final layout (big-model memory lever —
    prefill has no gradient so only the live set matters)."""
    if chunks <= 1:
        def step(params, batch, caches):
            return lm.prefill(cfg, params, batch, caches)
        return step

    def step(params, batch, caches):
        B = batch["tokens"].shape[0]
        assert B % chunks == 0, (B, chunks)
        Bc = B // chunks

        def split(x):
            return x.reshape((chunks, Bc) + x.shape[1:])

        mb = jax.tree_util.tree_map(split, batch)

        # Caches ride the scan CARRY with dynamic batch-slice updates —
        # reshaping/stacking them as scan ys would copy the whole KV stack
        # and break donation aliasing (measured: mistral prefill 13.5 GB ->
        # 74 GB/device with the copy formulation).
        # unit leaves: (R, B, ...) batch at axis 1; tail leaves: (B, ...).
        def body(carry, xs):
            mb_i, i = xs
            off = i * Bc
            sub = {
                "unit": jax.tree_util.tree_map(
                    lambda c: jax.lax.dynamic_slice_in_dim(c, off, Bc, 1),
                    carry["unit"]),
                "tail": jax.tree_util.tree_map(
                    lambda c: jax.lax.dynamic_slice_in_dim(c, off, Bc, 0),
                    carry["tail"]),
            }
            logits_i, new_sub = lm.prefill(cfg, params, mb_i, sub)
            carry = {
                "unit": jax.tree_util.tree_map(
                    lambda full, nc: jax.lax.dynamic_update_slice_in_dim(
                        full, nc.astype(full.dtype), off, 1),
                    carry["unit"], new_sub["unit"]),
                "tail": jax.tree_util.tree_map(
                    lambda full, nc: jax.lax.dynamic_update_slice_in_dim(
                        full, nc.astype(full.dtype), off, 0),
                    carry["tail"], new_sub["tail"]),
            }
            return carry, logits_i

        new_caches, logits = jax.lax.scan(body, caches,
                                          (mb, jnp.arange(chunks)))
        return logits.reshape((B,) + logits.shape[2:]), new_caches

    return step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def step(params, token, caches, cur_pos):
        return lm.decode_step(cfg, params, token, caches, cur_pos)
    return step


def make_serve_step(cfg: ModelConfig,
                    sample_fn: Optional[Callable] = None) -> Callable:
    """The ``serve_step``: one token given a filled cache.

    Without ``sample_fn`` this is the dry-run's greedy step with the
    historical ``step(params, token, caches, cur_pos)`` signature. With a
    ``sample_fn(logits, rng) -> tokens`` (e.g. a bound
    :func:`repro.serve.sampling.sample_logits`) the returned step grows an
    ``rng`` argument and samples instead of argmaxing.
    """
    if sample_fn is None:
        def step(params, token, caches, cur_pos):
            logits, caches = lm.decode_step(cfg, params, token, caches,
                                            cur_pos)
            next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_token, logits, caches
        return step

    def sampled_step(params, token, caches, cur_pos, rng):
        logits, caches = lm.decode_step(cfg, params, token, caches, cur_pos)
        next_token = sample_fn(logits, rng)
        return next_token, logits, caches
    return sampled_step


def make_bucket_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    """Prefill for the serving engine's bucketed admission path.

    Returns ``step(params, batch, last_pos) -> (logits, caches)``:
    ``batch["tokens"]`` is right-padded to a bucket length, ``last_pos (B,)``
    indexes each prompt's last real token, and the caches — built fresh
    inside the step at the engine's pool length ``max_len``, so jit never
    sees (or needs donation discipline for) a caller-held buffer — come out
    at full pool length ready for :func:`repro.models.lm.write_cache_slot`.
    One jit compilation per (bucket, batch) shape; the engine's
    ``CompileCache`` keys on exactly that.
    """
    def step(params, batch, last_pos):
        caches = lm.init_caches(cfg, batch["tokens"].shape[0], max_len)
        return lm.prefill_at(cfg, params, batch, caches, last_pos)
    return step


def make_pool_serve_step(cfg: ModelConfig,
                         sample_fn: Optional[Callable] = None,
                         paged: bool = False) -> Callable:
    """One decode tick over a serving engine's whole slot pool.

    ``step(params, tokens, caches, cur_pos, rng, active) -> (next, caches)``
    with everything per-slot: ``tokens (S,)`` each slot's previous token,
    ``cur_pos (S,)`` each slot's absolute write position (vector decode —
    see :func:`repro.models.lm.decode_step`), ``active (S,)`` bool masking
    slots that hold a live request. Inactive slots are computed but inert:
    their sampled token is replaced by their input token (so host-side slot
    state never moves) and whatever they write into their own cache row is
    dead — admission overwrites the full row. Slots are independent along
    the batch axis end to end, which is what makes engine outputs match the
    single-request oracle regardless of co-batched neighbors.

    ``paged=True`` grows a trailing ``page_table (S, P)`` argument and
    runs the paged cache layout. Pages are SHARED physical state — an
    inactive lane writing through its (stale) table row would clobber a
    page a later owner still needs — so inactive rows are redirected to
    the trash page before the model ever sees the table.
    """
    def _next(logits, tokens, rng, active):
        if sample_fn is None:
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            nxt = sample_fn(logits, rng)
        return jnp.where(active, nxt, tokens)

    if paged:
        def paged_step(params, tokens, caches, cur_pos, rng, active,
                       page_table):
            page_table = jnp.where(active[:, None], page_table, 0)
            logits, caches = lm.decode_step(cfg, params, tokens, caches,
                                            cur_pos, page_table=page_table)
            return _next(logits, tokens, rng, active), caches
        return paged_step

    def step(params, tokens, caches, cur_pos, rng, active):
        logits, caches = lm.decode_step(cfg, params, tokens, caches,
                                        cur_pos)
        return _next(logits, tokens, rng, active), caches
    return step


def make_chunk_prefill_step(cfg: ModelConfig) -> Callable:
    """Chunked prefill over the serving engine's slot pool (paged only).

    ``step(params, tokens, caches, start_pos, last_idx, active, page_table)
    -> (logits, h_last, caches)``: ``tokens (S, C)`` is one fixed-size
    prompt chunk per slot (zeros for slots with nothing to prefill this
    tick), ``start_pos (S,)`` the chunk's absolute start position,
    ``last_idx (S,)`` the within-chunk readout index (meaningful on a
    prompt's final chunk), ``h_last (S, E)`` the pre-final-norm backbone
    state at that index (the speculative draft anchor). ONE compile covers
    every prompt length — the engine admits a prompt as ``ceil(len / C)``
    invocations interleaved with decode ticks. Inactive lanes are
    redirected to the trash page exactly like the paged decode tick.
    """
    def step(params, tokens, caches, start_pos, last_idx, active,
             page_table):
        page_table = jnp.where(active[:, None], page_table, 0)
        return lm.prefill_chunk(cfg, params, tokens, caches, start_pos,
                                last_idx, page_table)
    return step


def make_draft_step(cfg: ModelConfig, k: int) -> Callable:
    """Draft proposer for draft-k-verify-1 speculative decoding.

    ``draft(params, anchor, last_token) -> drafts (S, k)``: from each
    slot's residual-stream anchor — the pre-final-norm backbone state at
    its last committed input position (returned by
    :func:`repro.models.lm.prefill_chunk` / :func:`~repro.models.lm.
    verify_chunk`) — propose ``k`` greedy continuations WITHOUT running
    the backbone. The draft state advances by embedding feedback alone
    (``g <- g + embed(token)``, the same scaled embedding the real
    residual stream starts from) and reads out through the model's OWN
    output head. On butterfly-compressed archs (``cfg.butterfly.sites``
    containing ``"lm_head"``) that head is the fixed-structure butterfly
    sandwich the paper builds — at 142x–273x fewer parameters than dense
    (``BENCH_quick.json`` ``params/*-head`` rows), i.e. the near-free
    draft model already living inside the architecture. Draft quality
    only affects speed, never output: greedy verification commits exactly
    the tokens the full model would have produced.
    """
    if k < 1:
        raise ValueError(f"draft step needs k >= 1, got {k}")

    def draft(params, anchor, last_token):
        g = anchor.astype(cfg.cdtype())
        tok = jnp.asarray(last_token, jnp.int32)
        out = []
        for _ in range(k):                 # k is small; unrolled
            g = g + cm.embed(cfg, params["embed"], tok[:, None])[:, 0]
            h = cm.rmsnorm(g[:, None], params["final_norm"], cfg.norm_eps)
            logits = cm.head_apply(cfg, params["head"], params["embed"], h)
            tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            out.append(tok)
        return jnp.stack(out, axis=1)
    return draft


def make_spec_decode_step(cfg: ModelConfig, k: int) -> Callable:
    """One speculative verify tick over a serving engine's slot pool.

    ``step(params, tokens, caches, cur_pos, active, page_table) ->
    (targets, accepted, anchor, caches)`` with ``tokens (S, k+1)`` each
    slot's last committed token followed by its ``k`` draft tokens at
    absolute positions ``cur_pos .. cur_pos+k``. ONE batched pass of the
    full model (:func:`repro.models.lm.verify_chunk`) produces greedy
    targets at every position; ``accepted (S,)`` is the per-slot length
    of the leading draft prefix that matches them (``0..k``), so the host
    commits ``accepted+1`` tokens ``targets[:, :accepted+1]`` and
    advances ``cur_pos`` by exactly that — rejected positions never
    advance ``cur_pos``, leaving their stale KV writes masked out.
    ``anchor (S, E)`` is the pre-final-norm backbone state at the last
    committed input position, seeding the next tick's draft state.

    Greedy-only by construction (targets are argmax): with greedy
    sampling the committed stream is token-identical to non-speculative
    decoding, which is what the CI parity gate asserts. Inactive lanes
    are trash-redirected and their outputs pinned to their inputs, like
    the pooled decode step.
    """
    if k < 1:
        raise ValueError(f"speculative decode needs k >= 1 drafts, got {k}")

    def step(params, tokens, caches, cur_pos, active, page_table):
        page_table = jnp.where(active[:, None], page_table, 0)
        logits, x, caches = lm.verify_chunk(cfg, params, tokens, caches,
                                            cur_pos, page_table)
        targets = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # draft j+1 survives iff it equals the model's target at position j
        # AND every earlier draft survived: leading-match prefix length
        matches = targets[:, :-1] == tokens[:, 1:]
        accepted = jnp.sum(jnp.cumprod(matches.astype(jnp.int32), axis=1),
                           axis=1)
        accepted = jnp.where(active, accepted, 0)
        targets = jnp.where(active[:, None], targets, tokens)
        S = tokens.shape[0]
        anchor = x[jnp.arange(S), accepted]
        return targets, accepted, anchor, caches
    return step
