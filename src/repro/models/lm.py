"""Model assembly: pattern-scanned decoder stacks covering all 10 assigned
architectures (dense / MoE / hybrid / SSM / VLM / enc-dec audio).

The layer stack is described by ``cfg.block_unit`` — a repeating unit of
block types — so heterogeneous archs compile as ONE ``lax.scan`` over unit
repeats (plus an unrolled tail of ``n_layers % len(unit)`` layers):

  * smollm/gemma/mistral:  unit ("attn",)
  * gemma3-27b:            unit ("local",)*5 + ("global",)  (5:1, window 1024)
  * olmoe/dbrx:            unit ("moe",)
  * recurrentgemma-2b:     unit ("rec", "rec", "attn")      (2 RG-LRU : 1 attn)
  * xlstm-125m:            unit ("mlstm",)*5 + ("slstm",)
  * internvl2-1b:          unit ("attn",) + vision-frontend prefix tokens
  * seamless-m4t:          encoder unit ("enc",) + decoder unit ("xdec",)

Scanning keeps HLO size O(#block types) instead of O(n_layers) — this is
what makes the 62-layer/88-layer 512-device dry-runs compile in seconds —
and composes with per-unit-position KV/state cache stacks of *different*
shapes (local layers keep a ring buffer of window size; global layers keep
full-length caches), which is what bounds the 500k-context cell's memory.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import mlp as mlpm
from repro.models import moe as moem
from repro.models import rglru as rgm
from repro.models import xlstm as xm
from repro.runtime.pytree import ParamSpec
from repro.runtime.sharding import constrain

ATTN_TYPES = ("attn", "local", "global", "moe", "xdec", "enc")


# ---------------------------------------------------------------------------
# Per-block-type specs / caches / apply
# ---------------------------------------------------------------------------

def layer_specs(cfg: ModelConfig, btype: str) -> Dict:
    E = cfg.d_model
    out: Dict[str, Any] = {"norm1": cm.rmsnorm_spec(cfg, E)}
    if btype in ("attn", "local", "global", "moe", "enc"):
        out["attn"] = attn.attn_specs(cfg)
        out["norm2"] = cm.rmsnorm_spec(cfg, E)
        out["ffn"] = (moem.moe_specs(cfg) if btype == "moe"
                      else mlpm.mlp_specs(cfg))
    elif btype == "rec":
        out["rec"] = rgm.rglru_specs(cfg)
        out["norm2"] = cm.rmsnorm_spec(cfg, E)
        out["ffn"] = mlpm.mlp_specs(cfg)
    elif btype == "mlstm":
        out["mlstm"] = xm.mlstm_specs(cfg)
    elif btype == "slstm":
        out["slstm"] = xm.slstm_specs(cfg)
    elif btype == "xdec":
        out["attn"] = attn.attn_specs(cfg)
        out["norm_x"] = cm.rmsnorm_spec(cfg, E)
        out["xattn"] = attn.attn_specs(cfg)
        out["norm2"] = cm.rmsnorm_spec(cfg, E)
        out["ffn"] = mlpm.mlp_specs(cfg)
    else:
        raise ValueError(f"unknown block type {btype!r}")
    return out


def _cache_lib():
    """The cache layouts live in :mod:`repro.serve.cache` (one interface
    for dense and paged pools); imported lazily because ``repro.serve``'s
    package init imports the engine, which imports this module."""
    from repro.serve import cache as cache_lib
    return cache_lib


def layer_cache_spec(cfg: ModelConfig, btype: str, batch: int,
                     seq_len: int) -> Optional[Dict]:
    """Thin delegate — see :func:`repro.serve.cache.layer_cache_spec`."""
    return _cache_lib().layer_cache_spec(cfg, btype, batch, seq_len)


def init_layer_cache(cfg: ModelConfig, btype: str, batch: int,
                     seq_len: int) -> Optional[Dict]:
    """Thin delegate — see :func:`repro.serve.cache.init_layer_cache`."""
    return _cache_lib().init_layer_cache(cfg, btype, batch, seq_len)


def layer_apply(cfg: ModelConfig, btype: str, params: Dict, x: jnp.ndarray,
                *, positions: jnp.ndarray, mode: str,
                cache: Optional[Dict], cur_pos,
                enc_out: Optional[jnp.ndarray],
                page_table: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Optional[Dict] = {} if cache is not None else None
    sp = functools.partial(constrain,
                           axes=("batch", "seq_sp" if
                                 cfg.seq_shard_activations else None, None))

    def res_add(x, delta):
        return sp(x + delta)

    if btype in ("attn", "local", "global", "moe", "enc"):
        window = cfg.sliding_window if btype == "local" else 0
        h = cm.rmsnorm(x, params["norm1"], cfg.norm_eps)
        a, c_new = attn.attention(
            cfg, params["attn"], h, positions=positions, mode=mode,
            cache=None if cache is None else cache.get("self"),
            cur_pos=cur_pos, window=window, causal=(btype != "enc"),
            page_table=page_table)
        x = res_add(x, a)
        if new_cache is not None and c_new is not None:
            new_cache["self"] = c_new
        h = cm.rmsnorm(x, params["norm2"], cfg.norm_eps)
        if btype == "moe":
            f, aux = moem.moe_apply(cfg, params["ffn"], h)
        else:
            f = mlpm.mlp_apply(cfg, params["ffn"], h)
        x = res_add(x, f)
    elif btype == "rec":
        h = cm.rmsnorm(x, params["norm1"], cfg.norm_eps)
        r, c_new = rgm.rglru_block(
            cfg, params["rec"], h, mode=mode,
            cache=None if cache is None else cache.get("rec"))
        x = res_add(x, r)
        if new_cache is not None and c_new is not None:
            new_cache["rec"] = c_new
        h = cm.rmsnorm(x, params["norm2"], cfg.norm_eps)
        x = res_add(x, mlpm.mlp_apply(cfg, params["ffn"], h))
    elif btype == "mlstm":
        h = cm.rmsnorm(x, params["norm1"], cfg.norm_eps)
        r, c_new = xm.mlstm_block(
            cfg, params["mlstm"], h, mode=mode,
            cache=None if cache is None else cache.get("mlstm"))
        x = res_add(x, r)
        if new_cache is not None and c_new is not None:
            new_cache["mlstm"] = c_new
    elif btype == "slstm":
        h = cm.rmsnorm(x, params["norm1"], cfg.norm_eps)
        r, c_new = xm.slstm_block(
            cfg, params["slstm"], h, mode=mode,
            cache=None if cache is None else cache.get("slstm"))
        x = res_add(x, r)
        if new_cache is not None and c_new is not None:
            new_cache["slstm"] = c_new
    elif btype == "xdec":
        h = cm.rmsnorm(x, params["norm1"], cfg.norm_eps)
        a, c_new = attn.attention(
            cfg, params["attn"], h, positions=positions, mode=mode,
            cache=None if cache is None else cache.get("self"),
            cur_pos=cur_pos, window=0, page_table=page_table)
        x = res_add(x, a)
        if new_cache is not None and c_new is not None:
            new_cache["self"] = c_new
        h = cm.rmsnorm(x, params["norm_x"], cfg.norm_eps)
        a, c_new = attn.attention(
            cfg, params["xattn"], h, positions=positions, mode=mode,
            cache=None if cache is None else cache.get("cross"),
            cur_pos=cur_pos, kv_x=enc_out, is_cross=True, causal=False,
            use_rope=False)
        x = res_add(x, a)
        if new_cache is not None and c_new is not None:
            new_cache["cross"] = c_new
        h = cm.rmsnorm(x, params["norm2"], cfg.norm_eps)
        x = res_add(x, mlpm.mlp_apply(cfg, params["ffn"], h))
    else:
        raise ValueError(btype)
    if new_cache is not None and not new_cache:
        new_cache = None
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Stacked (scanned) pattern
# ---------------------------------------------------------------------------

def _stack_specs(specs: Dict, repeats: int) -> Dict:
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((repeats,) + s.shape, s.dtype,
                            (None,) + tuple(s.axes or (None,) * len(s.shape)),
                            init=s.init, scale=s.scale,
                            fan_in_dim=(s.fan_in_dim if s.fan_in_dim < 0
                                        else s.fan_in_dim + 1)),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def model_specs(cfg: ModelConfig) -> Dict:
    unit = cfg.block_unit
    R = cfg.unit_repeats
    out: Dict[str, Any] = {"embed": cm.embed_specs(cfg)}
    if cfg.frontend:
        out["frontend_proj"] = ParamSpec(
            (cfg.d_model, cfg.d_model), cfg.param_dtype, ("embed", None),
            init="scaled_normal", fan_in_dim=0)
    if cfg.n_enc_layers:
        out["enc_unit"] = [_stack_specs(layer_specs(cfg, "enc"),
                                        cfg.n_enc_layers)]
        out["enc_norm"] = cm.rmsnorm_spec(cfg, cfg.d_model)
    out["unit"] = [_stack_specs(layer_specs(cfg, t), R) for t in unit]
    out["tail"] = [layer_specs(cfg, t) for t in cfg.tail_layers]
    out["final_norm"] = cm.rmsnorm_spec(cfg, cfg.d_model)
    out["head"] = cm.head_specs(cfg)
    return out


def total_seq(cfg: ModelConfig, seq_len: int) -> int:
    """Cache length: text tokens plus any prepended frontend tokens."""
    return seq_len + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int) -> Dict:
    """Thin delegate — see :func:`repro.serve.cache.cache_specs`."""
    return _cache_lib().cache_specs(cfg, batch, seq_len)


def init_caches(cfg: ModelConfig, batch: int, seq_len: int) -> Dict:
    """Thin delegate — see :func:`repro.serve.cache.init_caches`."""
    return _cache_lib().init_caches(cfg, batch, seq_len)


def backbone(cfg: ModelConfig, params: Dict, x: jnp.ndarray, *,
             positions: jnp.ndarray, mode: str,
             caches: Optional[Dict] = None, cur_pos=None,
             enc_out: Optional[jnp.ndarray] = None,
             page_table: Optional[jnp.ndarray] = None
             ) -> Tuple[jnp.ndarray, Optional[Dict], jnp.ndarray]:
    """Run the full layer stack. Returns (x, new_caches, aux)."""
    unit = cfg.block_unit
    R = cfg.unit_repeats
    aux0 = jnp.zeros((), jnp.float32)
    with_cache = caches is not None

    def body(carry, xs):
        """Caches ride the scan CARRY with in-place slice updates: emitting
        them as ys would double-buffer the full KV stack (measured: +6 GB on
        mistral decode_32k); XLA aliases in-place carry updates instead."""
        if with_cache:
            x, aux, cache_stacks = carry
            layer_params, idx = xs
            layer_caches = [
                jax.tree_util.tree_map(
                    lambda c: jax.lax.dynamic_index_in_dim(
                        c, idx, 0, keepdims=False), cache_stacks[i])
                for i in range(len(unit))]
        else:
            x, aux = carry
            layer_params = xs
            layer_caches = [None] * len(unit)
        new_caches = []
        for i, t in enumerate(unit):
            x, nc, a = layer_apply(cfg, t, layer_params[i], x,
                                   positions=positions, mode=mode,
                                   cache=layer_caches[i], cur_pos=cur_pos,
                                   enc_out=enc_out, page_table=page_table)
            new_caches.append(nc if nc is not None else layer_caches[i])
            aux = aux + a
        if with_cache:
            cache_stacks = [
                jax.tree_util.tree_map(
                    lambda stack, nc: jax.lax.dynamic_update_index_in_dim(
                        stack, nc.astype(stack.dtype), idx, 0),
                    cache_stacks[i], new_caches[i])
                for i in range(len(unit))]
            return (x, aux, cache_stacks), None
        return (x, aux), None

    if R > 0:
        fn = jax.checkpoint(body) if (cfg.remat and mode == "train") else body
        if with_cache:
            xs = (params["unit"], jnp.arange(R))
            (x, aux, unit_caches), _ = jax.lax.scan(
                fn, (x, aux0, caches["unit"]), xs)
        else:
            (x, aux), _ = jax.lax.scan(fn, (x, aux0), params["unit"])
            unit_caches = None
    else:
        unit_caches = caches["unit"] if with_cache else None
        aux = aux0

    tail_caches = []
    for i, t in enumerate(cfg.tail_layers):
        c = caches["tail"][i] if with_cache else None
        x, nc, a = layer_apply(cfg, t, params["tail"][i], x,
                               positions=positions, mode=mode, cache=c,
                               cur_pos=cur_pos, enc_out=enc_out,
                               page_table=page_table)
        tail_caches.append(nc if nc is not None else c)
        aux = aux + a

    new_caches = ({"unit": unit_caches, "tail": tail_caches}
                  if with_cache else None)
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# Encoder (enc-dec archs) and input embedding incl. frontend stubs
# ---------------------------------------------------------------------------

def run_encoder(cfg: ModelConfig, params: Dict, frames: jnp.ndarray
                ) -> jnp.ndarray:
    """Bidirectional encoder over precomputed frame embeddings (stub
    frontend): frames (B, S_enc, E)."""
    x = frames.astype(cfg.cdtype())
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(carry, layer_params):
        x, = carry
        x, _, _ = layer_apply(cfg, "enc", layer_params, x,
                              positions=positions, mode="train", cache=None,
                              cur_pos=None, enc_out=None)
        return (x,), None

    (x,), _ = jax.lax.scan(body, (x,), params["enc_unit"][0])
    return cm.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def embed_inputs(cfg: ModelConfig, params: Dict, tokens: jnp.ndarray,
                 frontend_embeds: Optional[jnp.ndarray] = None
                 ) -> jnp.ndarray:
    """Token embedding; VLM archs prepend projected patch embeddings."""
    x = cm.embed(cfg, params["embed"], tokens)
    if cfg.frontend == "vision" and frontend_embeds is not None:
        fe = frontend_embeds.astype(x.dtype) @ \
            params["frontend_proj"].astype(x.dtype)
        x = jnp.concatenate([fe, x], axis=1)
    return x


# ---------------------------------------------------------------------------
# Top-level model entry points
# ---------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params: Dict, batch: Dict
            ) -> Tuple[jnp.ndarray, Dict]:
    """Training loss (mean CE over text positions) + metrics."""
    tokens = batch["tokens"]
    x = embed_inputs(cfg, params, tokens, batch.get("frontend_embeds"))
    x = constrain(x, ("batch", None, None))
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    enc_out = None
    if cfg.n_enc_layers:
        enc_out = run_encoder(cfg, params, batch["frames"])

    x, _, aux = backbone(cfg, params, x, positions=positions, mode="train",
                         enc_out=enc_out)
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.head_apply(cfg, params["head"], params["embed"], x)
    logits = constrain(logits, ("batch", None, "vocab"))

    n_front = (batch["frontend_embeds"].shape[1]
               if (cfg.frontend == "vision"
                   and batch.get("frontend_embeds") is not None) else 0)
    if n_front:
        logits = logits[:, n_front:]
    # next-token prediction
    targets = batch["targets"]
    mask = batch.get("mask")
    ce = cm.cross_entropy(logits[:, :-1], targets[:, 1:],
                          None if mask is None else mask[:, 1:])
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}


def prefill(cfg: ModelConfig, params: Dict, batch: Dict, caches: Dict
            ) -> Tuple[jnp.ndarray, Dict]:
    """Process the full prompt, fill caches, return last-position logits."""
    tokens = batch["tokens"]
    x = embed_inputs(cfg, params, tokens, batch.get("frontend_embeds"))
    x = constrain(x, ("batch", None, None))
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    enc_out = None
    if cfg.n_enc_layers:
        enc_out = run_encoder(cfg, params, batch["frames"])
    x, caches, _ = backbone(cfg, params, x, positions=positions,
                            mode="prefill", caches=caches, enc_out=enc_out)
    x = cm.rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = cm.head_apply(cfg, params["head"], params["embed"], x)
    return logits[:, 0], caches


def prefill_at(cfg: ModelConfig, params: Dict, batch: Dict, caches: Dict,
               last_pos: jnp.ndarray) -> Tuple[jnp.ndarray, Dict]:
    """Bucketed prefill: right-padded prompts, logits read at ``last_pos``.

    ``batch["tokens"]`` is ``(B, bucket)`` with each prompt right-padded to
    the bucket length and ``last_pos (B,)`` the index of its last *real*
    token. Causal attention makes the pad tail inert for every position
    ``<= last_pos`` — each position's KV is a function of that position's
    input alone, and no real position attends forward — so the caches this
    fills are usable as-is for decode: the decode-side validity mask
    (``kpos <= cur_pos``) never reaches a stale pad entry before the decode
    loop has overwritten it. The one thing plain :func:`prefill` gets wrong
    under padding is the readout position (its ``x[:, -1:]`` is a pad), so
    this variant gathers the backbone output at ``last_pos`` per row
    instead. NOT exact for architectures whose state mixes positions
    sequentially (``rec``/``mlstm``/``slstm`` blocks) or windowed ring
    buffers — the serving engine pads those archs to exact lengths instead
    (:meth:`repro.serve.engine.ServeEngine.bucket_for`).
    """
    tokens = batch["tokens"]
    x = embed_inputs(cfg, params, tokens, batch.get("frontend_embeds"))
    x = constrain(x, ("batch", None, None))
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    enc_out = None
    if cfg.n_enc_layers:
        enc_out = run_encoder(cfg, params, batch["frames"])
    x, caches, _ = backbone(cfg, params, x, positions=positions,
                            mode="prefill", caches=caches, enc_out=enc_out)
    n_front = S - tokens.shape[1]          # prepended frontend tokens
    idx = jnp.asarray(last_pos, jnp.int32) + n_front
    x_last = x[jnp.arange(B), idx][:, None, :]
    x_last = cm.rmsnorm(x_last, params["final_norm"], cfg.norm_eps)
    logits = cm.head_apply(cfg, params["head"], params["embed"], x_last)
    return logits[:, 0], caches


# ---------------------------------------------------------------------------
# Cache slot surgery (the serving engine's pool)
# ---------------------------------------------------------------------------

def write_cache_slot(cfg: ModelConfig, pool: Dict, sub: Dict,
                     slot: jnp.ndarray) -> Dict:
    """Insert a batch-1 cache tree into batch index ``slot`` of a pool.

    ``pool`` and ``sub`` must come from :func:`init_caches` (or a prefill
    thereof) with the same ``seq_len``; only the batch extent differs.
    Thin delegate — see :func:`repro.serve.cache.write_cache_slot` (the
    paged-pool equivalent is :meth:`repro.serve.cache.PagedCachePool.
    write_slot`).
    """
    return _cache_lib().write_cache_slot(cfg, pool, sub, slot)


def reset_cache_slot(cfg: ModelConfig, pool: Dict, slot: jnp.ndarray,
                     seq_len: int) -> Dict:
    """Reset batch index ``slot`` of a cache pool to its init state.

    ``seq_len`` must be the value the pool was built with (the text length
    passed to :func:`init_caches` — NOT the frontend-extended total).
    Freeing a slot is not required for correctness — admission overwrites
    the whole slot via :func:`write_cache_slot` — but scrubbing keeps a
    long-lived engine's pool free of dead request state (and of any
    stale-read bug class a future cache layout change might introduce).
    Thin delegate — see :func:`repro.serve.cache.reset_cache_slot`.
    """
    return _cache_lib().reset_cache_slot(cfg, pool, slot, seq_len)


def decode_step(cfg: ModelConfig, params: Dict, token: jnp.ndarray,
                caches: Dict, cur_pos: jnp.ndarray,
                page_table: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Dict]:
    """One decode step: token (B,) int32 at absolute position ``cur_pos``.

    ``cur_pos`` is a scalar (the whole batch decodes in lockstep) or a
    ``(B,)`` vector — the serving engine's slot pool, where every request
    sits at its own absolute position and the KV write/read masks are
    per-slot (see :mod:`repro.serve.engine`). With ``page_table`` (B, P)
    the full-attention caches are read/written through the paged pool
    layout instead (see :class:`repro.serve.cache.PagedCachePool`).
    """
    x = cm.embed(cfg, params["embed"], token[:, None])
    B = x.shape[0]
    cur_pos = jnp.asarray(cur_pos, jnp.int32)
    if cur_pos.ndim == 0:
        positions = jnp.broadcast_to(cur_pos[None, None], (B, 1))
    else:
        positions = cur_pos[:, None]
    x, caches, _ = backbone(cfg, params, x, positions=positions,
                            mode="decode", caches=caches, cur_pos=cur_pos,
                            page_table=page_table)
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.head_apply(cfg, params["head"], params["embed"], x)
    return logits[:, 0], caches


def prefill_chunk(cfg: ModelConfig, params: Dict, tokens: jnp.ndarray,
                  caches: Dict, start_pos: jnp.ndarray,
                  last_idx: jnp.ndarray, page_table: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, Dict]:
    """Process one fixed-size prompt chunk through the paged decode path.

    ``tokens`` (B, C) are C consecutive prompt tokens per row, right-padded
    on the final chunk; ``start_pos`` (B,) is the absolute position of each
    row's first chunk token, ``last_idx`` (B,) the within-chunk index of
    the last *real* token (its logits are the readout — only meaningful on
    a prompt's final chunk). One compile serves every prompt length: a
    prompt is ``ceil(len/C)`` invocations of this function instead of one
    per-bucket prefill compile. Requires :func:`repro.serve.cache.
    chunked_prefill_supported` (full-attention archs, no frontend/encoder/
    window blocks); causality makes each chunk's KV independent of the pad
    tail, and pad-position writes land in reserved-but-unread page slots
    (overwritten by decode before their positions become valid) or the
    trash page — the same inertness argument as bucketed
    :func:`prefill_at`.

    Returns ``(logits (B, V), h_last (B, E), caches)``: ``h_last`` is the
    *pre-final-norm* backbone state at ``last_idx`` — the residual-stream
    anchor speculative decoding's draft state starts from (see
    :func:`repro.train.steps.make_draft_step`).
    """
    x = cm.embed(cfg, params["embed"], tokens)
    B, C, _ = x.shape
    start_pos = jnp.asarray(start_pos, jnp.int32)
    positions = start_pos[:, None] + jnp.arange(C)[None, :]
    x, caches, _ = backbone(cfg, params, x, positions=positions,
                            mode="decode", caches=caches, cur_pos=None,
                            page_table=page_table)
    x_last = x[jnp.arange(B), jnp.asarray(last_idx, jnp.int32)][:, None]
    h = cm.rmsnorm(x_last, params["final_norm"], cfg.norm_eps)
    logits = cm.head_apply(cfg, params["head"], params["embed"], h)
    return logits[:, 0], x_last[:, 0], caches


def verify_chunk(cfg: ModelConfig, params: Dict, tokens: jnp.ndarray,
                 caches: Dict, cur_pos: jnp.ndarray,
                 page_table: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, Dict]:
    """Multi-position verify forward for speculative decoding.

    ``tokens`` (B, K) is each row's last committed token followed by K-1
    draft tokens, occupying absolute positions ``cur_pos .. cur_pos+K-1``.
    One pass of the full model through the chunked-prefill decode path
    (same causal/validity masking, same paged KV writes) yields the
    logits at ALL K positions — unlike :func:`prefill_chunk`, which reads
    out a single position — so the engine can compare each draft against
    the model's own prediction one position earlier. Returns
    ``(logits (B, K, V), x (B, K, E), caches)`` with ``x`` the
    pre-final-norm backbone states (position ``j`` is the draft anchor
    when the commit stops after input ``j``).

    Rejected positions' KV writes are left in place: their positions sit
    beyond the committed ``cur_pos``, so the validity mask (``kpos <=
    q_pos``) keeps them inert, and the next verify pass overwrites them —
    the same invariant that makes the trash page safe.
    """
    x = cm.embed(cfg, params["embed"], tokens)
    B, K, _ = x.shape
    cur_pos = jnp.asarray(cur_pos, jnp.int32)
    positions = cur_pos[:, None] + jnp.arange(K)[None, :]
    x, caches, _ = backbone(cfg, params, x, positions=positions,
                            mode="decode", caches=caches, cur_pos=None,
                            page_table=page_table)
    h = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.head_apply(cfg, params["head"], params["embed"], h)
    return logits, x, caches
