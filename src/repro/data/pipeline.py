"""Deterministic, host-sharded synthetic token pipeline with prefetch.

Production posture without external data deps:

* **Determinism / restart safety** — batch ``i`` is a pure function of
  (seed, step, host shard), so a restarted job resumes mid-stream with no
  drift and no data-state checkpointing beyond the step counter.
* **Host sharding** — each data-parallel host reads only its slice of the
  global batch (disjointness tested).
* **Prefetch** — a background thread keeps a bounded queue of ready batches
  so host data generation overlaps device compute.
* **Structure** — the token stream is a mixture of Zipf-distributed unigrams
  and repeated Markov motifs, so a real LM loss signal exists (models must
  beat the unigram entropy; tests rely on loss *decreasing*).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    motif_len: int = 16
    n_motifs: int = 64
    motif_prob: float = 0.5


class SyntheticLM:
    """Stateless batch generator: ``batch(step) -> {tokens, targets, mask}``."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.host_count:
            raise ValueError("global batch must divide host count")
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        # Zipf-ish unigram distribution over the vocab
        ranks = np.arange(1, cfg.vocab_size + 1)
        probs = 1.0 / ranks
        self.unigram = probs / probs.sum()
        self.motifs = root.integers(
            0, cfg.vocab_size, size=(cfg.n_motifs, cfg.motif_len))

    @property
    def local_batch(self) -> int:
        return self.cfg.global_batch // self.cfg.host_count

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng(
            (c.seed, step, c.host_index))           # pure function of step
        B, S = self.local_batch, c.seq_len
        toks = rng.choice(c.vocab_size, size=(B, S), p=self.unigram)
        # overwrite random spans with repeated motifs (learnable structure)
        n_spans = max(1, int(c.motif_prob * S / c.motif_len))
        for b in range(B):
            for _ in range(n_spans):
                m = rng.integers(0, c.n_motifs)
                start = rng.integers(0, max(S - c.motif_len, 1))
                toks[b, start:start + c.motif_len] = \
                    self.motifs[m][: S - start]
        toks = toks.astype(np.int32)
        return {"tokens": toks, "targets": toks.copy(),
                "mask": np.ones((B, S), np.float32)}


class Prefetcher:
    """Background-thread prefetch of a deterministic batch stream."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.queue: "queue.Queue" = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self.source.batch(step)
            while not self._stop.is_set():
                try:
                    self.queue.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        if self._stop.is_set():
            raise StopIteration
        return self.queue.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.queue.get_nowait()
        except queue.Empty:
            pass
        self.thread.join(timeout=2)


def for_model(model: ModelConfig, seq_len: int, global_batch: int,
              seed: int = 0, host_index: int = 0, host_count: int = 1
              ) -> SyntheticLM:
    return SyntheticLM(DataConfig(
        vocab_size=model.vocab_size, seq_len=seq_len,
        global_batch=global_batch, seed=seed, host_index=host_index,
        host_count=host_count))
