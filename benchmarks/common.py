"""Shared benchmark utilities: timing, CSV emission, data generators."""

from __future__ import annotations

import time
from typing import Callable, Dict, Iterable, List

import jax
import jax.numpy as jnp
import numpy as np

# every emit() lands here too, so run.py can dump the whole run as a
# machine-readable BENCH_*.json artifact (CI uploads it per PR)
ROWS: List[Dict] = []


def time_fn(fn: Callable, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall-time (µs) of a jitted callable."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def emit(name: str, us_per_call, derived: str = "") -> None:
    """Record one benchmark row (CSV line + BENCH_*.json entry).

    ``us_per_call=None`` marks a row whose timing was *not measured* (e.g.
    fused-kernel rows on CPU where interpret-mode timing is meaningless):
    the JSON gets ``"us_per_call": null`` plus ``"skipped": true`` and the
    CSV cell stays empty, so the perf trajectory and the CI regression diff
    are never polluted by fake zeros.
    """
    if us_per_call is None:
        ROWS.append({"name": name, "us_per_call": None, "skipped": True,
                     "derived": derived})
        print(f"{name},,{derived}")
        return
    ROWS.append({"name": name, "us_per_call": round(float(us_per_call), 2),
                 "derived": derived})
    print(f"{name},{us_per_call:.2f},{derived}")


def emit_skipped(name: str, reason: str, derived: str = "") -> None:
    """Emit a skipped row (no timing) with a machine-readable reason."""
    extra = f"status=skipped;reason={reason}"
    emit(name, None, f"{extra};{derived}" if derived else extra)


def gaussian_lowrank(n: int, d: int, rank: int, seed: int = 0,
                     scale: float = 0.1) -> jnp.ndarray:
    """Paper §5.2 'Gaussian 1/2' matrices: random rank-r column space."""
    rng = np.random.default_rng(seed)
    U = np.linalg.qr(rng.normal(size=(n, rank)))[0]
    C = rng.normal(scale=scale, size=(rank, d))
    return jnp.asarray(U @ C, jnp.float32)


def synthetic_image_matrix(n: int, d: int, seed: int = 0) -> jnp.ndarray:
    """MNIST-like stand-in (no offline dataset): smooth low-frequency images
    + noise, coordinates randomly permuted as in the paper (§5.2)."""
    rng = np.random.default_rng(seed)
    side = int(np.sqrt(n))
    imgs = []
    for _ in range(d):
        fx = rng.integers(1, 5, size=2)
        phase = rng.uniform(0, 2 * np.pi, size=2)
        xx, yy = np.meshgrid(np.linspace(0, 1, side),
                             np.linspace(0, 1, side))
        img = (np.sin(2 * np.pi * fx[0] * xx + phase[0])
               * np.cos(2 * np.pi * fx[1] * yy + phase[1]))
        img += 0.1 * rng.normal(size=img.shape)
        imgs.append(img.reshape(-1)[:n])
    M = np.stack(imgs, axis=1)
    perm = rng.permutation(n)
    return jnp.asarray(M[perm], jnp.float32)
