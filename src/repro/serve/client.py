"""In-process async client: ``submit() -> Future`` over a driver thread.

The engine's tick loop is single-threaded by contract; the client owns that
thread. ``submit()`` enqueues on the (thread-safe) engine and wakes the
driver, which runs ticks while work exists and parks on an event when the
engine drains — no busy-polling between bursts. Futures resolve to
:class:`repro.serve.engine.GenerationResult` as requests finish, in
completion (not submission) order, which is the whole point of continuous
batching.

    with ServeClient(engine) as client:
        futs = [client.submit(Request(prompt=p, max_new_tokens=16))
                for p in prompts]
        results = [f.result(timeout=60) for f in futs]
"""

from __future__ import annotations

import threading
from concurrent.futures import Future

from repro.serve.engine import Request, ServeEngine


class ServeClient:
    """Async facade over a :class:`ServeEngine` (one driver thread)."""

    def __init__(self, engine: ServeEngine):
        self.engine = engine
        self._wake = threading.Event()
        self._stop = threading.Event()
        # serializes submit's stop-check+enqueue against the driver's
        # post-exit sweep, so a submit racing close() either enqueues
        # before the sweep (and gets failed by it) or observes the stop
        # flag and raises — never a silently stranded future
        self._lock = threading.Lock()
        self._thread = threading.Thread(target=self._drive,
                                        name="serve-engine", daemon=True)
        self._thread.start()

    # -- public --------------------------------------------------------

    def submit(self, request: Request, *legacy_args, **legacy_kwargs
               ) -> Future:
        """Queue a :class:`repro.serve.Request`; the engine raises a
        migration ``TypeError`` for the removed positional form."""
        with self._lock:
            if self._stop.is_set():
                raise RuntimeError("client is closed")
            fut = self.engine.submit(request, *legacy_args,
                                     **legacy_kwargs)
        self._wake.set()
        return fut

    def close(self, timeout: float = 60.0) -> None:
        """Stop the driver thread after the engine drains its current
        work; idempotent."""
        self._stop.set()
        self._wake.set()
        self._thread.join(timeout=timeout)

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- driver --------------------------------------------------------

    def _drive(self) -> None:
        exc: BaseException = RuntimeError("client is closed")
        while True:
            if self.engine.has_work():
                try:
                    self.engine.step()
                except BaseException as e:
                    # a dead driver must not strand futures: fail every
                    # queued/in-flight request with the real error and
                    # refuse further submissions (submit() raises once
                    # _stop is set)
                    self._stop.set()
                    exc = e
                    break
                continue
            if self._stop.is_set():
                break
            self._wake.wait(timeout=0.05)
            self._wake.clear()
        # post-exit sweep, serialized against submit: anything that raced
        # its way into the queue after our last has_work() look resolves
        # with an error instead of hanging until a result() timeout
        with self._lock:
            if self.engine.has_work():
                self.engine.abort_all(exc)
