"""Speculative decoding (`spec_k > 0`): draft-k-verify-1 on the serving
engine.

The acceptance property is LOSSLESSNESS, not speed: greedy verification
commits the full model's own argmax targets, so a speculative engine's
output must be token-identical to the non-speculative engine AND the
single-request oracle — for every k, on multi-chunk prompts, and across
preempt-during-speculation cycles. Draft quality (the butterfly output
head over a residual-stream anchor) only moves the acceptance-rate
metric and tokens/tick, never the tokens.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm
from repro.serve import Request, SamplingParams, ServeEngine, loader
from repro.train import steps as steps_lib

# The butterfly-compressed smoke arch: its lm_head is the fixed-structure
# butterfly sandwich, so the draft head IS the paper's cheap operator.
ARCH = "smollm-135m-butterfly-smoke"


@pytest.fixture(scope="module")
def cfg():
    return registry.get(ARCH)


@pytest.fixture(scope="module")
def params(cfg):
    return loader.init_params(cfg, seed=0)


def _prompt(rng, cfg, n):
    return rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)


def _req(prompt, max_new=4, **kw):
    return Request(prompt=prompt, max_new_tokens=max_new, **kw)


def _oracle_generate(cfg, params, prompt, max_new, max_len):
    """Single-request greedy reference (same as tests/test_serve.py)."""
    caches = lm.init_caches(cfg, 1, max_len)
    logits, caches = lm.prefill(
        cfg, params, {"tokens": jnp.asarray(prompt[None, :])}, caches)
    toks = [int(jnp.argmax(logits[0]))]
    pos = len(prompt)
    for _ in range(max_new - 1):
        logits, caches = lm.decode_step(
            cfg, params, jnp.asarray([toks[-1]], jnp.int32), caches,
            jnp.asarray(pos, jnp.int32))
        toks.append(int(jnp.argmax(logits[0])))
        pos += 1
    return toks


def test_spec_constructor_validation(cfg, params):
    """Speculation needs greedy sampling + the paged pool + chunked
    prefill; anything else is rejected loudly at construction."""
    with pytest.raises(ValueError, match="spec_k"):
        ServeEngine(cfg, params, slots=2, max_len=64, spec_k=-1)
    with pytest.raises(ValueError, match="greedy"):
        ServeEngine(cfg, params, slots=2, max_len=64, spec_k=2,
                    sampling=SamplingParams(temperature=0.7))
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, slots=2, max_len=64, spec_k=2,
                    pool="dense")
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(cfg, params, slots=2, max_len=64, spec_k=2,
                    prefill_chunk=None)


def test_spec_step_builders_validate_k(cfg):
    with pytest.raises(ValueError, match="k >= 1"):
        steps_lib.make_draft_step(cfg, 0)
    with pytest.raises(ValueError, match="k >= 1"):
        steps_lib.make_spec_decode_step(cfg, 0)


@pytest.mark.parametrize("spec_k", [1, 3])
def test_spec_matches_nonspec_and_oracle(cfg, params, spec_k):
    """The CI parity gate: mixed prompt lengths (including one spanning
    TWO prefill chunks) through 2 slots, speculative output == the
    non-speculative engine == the single-request oracle, token for
    token — and the acceptance metrics actually populated."""
    rng = np.random.default_rng(21)
    prompts = [_prompt(rng, cfg, n) for n in (5, 9, 20, 7)]
    want = [_oracle_generate(cfg, params, p, 8, 64) for p in prompts]

    def run(k):
        eng = ServeEngine(cfg, params, slots=2, max_len=64, seed=0,
                          pool="paged", spec_k=k)
        if k:
            assert prompts[2].size > eng.prefill_chunk   # multi-chunk
        futs = [eng.submit(_req(p, max_new=8)) for p in prompts]
        eng.run_until_idle()
        return [f.result(0).tokens for f in futs], eng

    base_toks, _ = run(0)
    spec_toks, eng = run(spec_k)
    assert base_toks == want
    assert spec_toks == want

    sp = eng.metrics.snapshot()["spec"]
    assert sp["k"] == spec_k
    assert sp["ticks"] > 0
    assert sp["draft_tokens"] == sp["ticks"] * spec_k or \
        sp["draft_tokens"] > 0          # < S live slots on ragged ticks
    assert sp["acceptance_rate"] == pytest.approx(
        sp["accepted_draft_tokens"] / sp["draft_tokens"], abs=1e-4)
    # every page recycled; speculative overshoot leaked nothing
    assert eng.pool.pages_in_use == 0


def test_spec_commits_more_than_one_token_per_slot_tick(cfg, params):
    """The speed claim the bench row gates: even at random init the
    butterfly-head draft accepts often enough that a decode tick commits
    > 1 token per occupied slot on average (deterministic under greedy +
    fixed seed)."""
    rng = np.random.default_rng(22)
    prompts = [_prompt(rng, cfg, n) for n in (5, 23, 37, 11)]
    eng = ServeEngine(cfg, params, slots=4, max_len=128, seed=0,
                      pool="paged", spec_k=3)
    futs = [eng.submit(_req(p, max_new=16)) for p in prompts]
    eng.run_until_idle()
    assert all(len(f.result(0).tokens) == 16 for f in futs)
    sp = eng.metrics.snapshot()["spec"]
    assert sp["accepted_draft_tokens"] > 0
    assert sp["tokens_per_slot_tick"] > 1.0


def test_spec_compile_discipline(cfg, params):
    """Speculation adds exactly TWO compiled steps (draft + verify), each
    traced once, regardless of request count or prompt lengths."""
    rng = np.random.default_rng(23)
    eng = ServeEngine(cfg, params, slots=2, max_len=64, seed=0, spec_k=2)
    futs = [eng.submit(_req(_prompt(rng, cfg, n), max_new=6))
            for n in (4, 9, 17, 6, 12)]
    eng.run_until_idle()
    assert all(len(f.result(0).tokens) == 6 for f in futs)
    kinds = [k[0] for k in eng.compile_cache.keys()]
    assert kinds.count("spec_draft") == 1
    assert kinds.count("spec_verify") == 1
    assert kinds.count("decode") == 0        # spec replaces pooled decode
    for key, n in eng.compile_stats["traces"].items():
        assert n == 1, f"{key} retraced {n}x"


def test_spec_preempt_during_speculation(cfg, params):
    """Preempt-during-speculation: a page-starved incremental pool forces
    a preemption while slots are mid-speculation (draft anchors live,
    page growth covering k extra positions). The kicked request resumes
    through chunked recompute and still lands oracle-identical."""
    rng = np.random.default_rng(24)
    prompts = [_prompt(rng, cfg, 5) for _ in range(2)]
    want = [_oracle_generate(cfg, params, p, 14, 32) for p in prompts]

    eng = ServeEngine(cfg, params, slots=2, max_len=32, seed=0,
                      pool="paged", page_size=8, num_pages=5,
                      prefill_chunk=4, admission="incremental", spec_k=2)
    futs = [eng.submit(_req(p, max_new=14)) for p in prompts]
    eng.run_until_idle()
    assert [f.result(0).tokens for f in futs] == want
    snap = eng.metrics.snapshot()
    assert snap["preempted"] >= 1
    assert snap["spec"]["draft_tokens"] > 0
    assert eng.pool.pages_in_use == 0
    assert len(eng.pool.free_list()) == eng.pool.total_pages - 1


def test_spec_stop_token_truncates_mid_commit(cfg, params):
    """A stop token landing inside an accepted prefix must truncate the
    commit exactly where non-speculative decode would have stopped —
    tokens past the stop are discarded even though verification accepted
    them."""
    rng = np.random.default_rng(25)
    prompt = _prompt(rng, cfg, 6)
    full = _oracle_generate(cfg, params, prompt, 12, 64)
    stop = full[len(full) // 2]              # guaranteed to occur mid-run
    want = full[:full.index(stop) + 1]

    def run(k):
        eng = ServeEngine(cfg, params, slots=2, max_len=64, seed=0,
                          spec_k=k)
        fut = eng.submit(_req(prompt, max_new=12, stop_token=stop))
        eng.run_until_idle()
        return fut.result(0).tokens

    assert run(0) == want
    for k in (1, 2, 4):
        assert run(k) == want, f"spec_k={k} diverged on stop truncation"
