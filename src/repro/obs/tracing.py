"""Bounded-ring span tracer with Chrome trace-event JSON export.

Zero dependencies (stdlib only). The serving tier emits spans through a
:class:`Tracer` — per-request lifecycle spans (``queue``, ``admit``,
``prefill_chunk[i]``, ``decode``, ``spec``, ``preempt``, ``finish`` …)
and engine-level spans (``tick``, ``grow_pages``, ``compile``,
``swap_checkpoint``) — and exports them as Chrome trace-event JSON that
loads directly in Perfetto or ``chrome://tracing``.

Track layout: ``pid`` is the replica id (one process row per replica),
``tid`` 0 is the engine lane, and ``tid = rid + 1`` is the per-request
lane, so a request's whole timeline reads left-to-right on one row.
Events carry wall-clock timestamps (``ts``/``dur`` in microseconds since
the tracer's epoch, Chrome's native unit) *and*, where it applies, the
deterministic engine tick number in ``args["tick"]`` — wall time answers
"where did the latency go", the tick answers "was this run
deterministic".

Memory stays flat: the ring holds at most ``capacity`` events and counts
what it evicts in :attr:`Tracer.dropped`. The :data:`NULL_TRACER`
singleton is the default everywhere — every method is a no-op, so the
tracing-off hot path pays only a handful of no-op calls per engine tick.
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "TRACK_ENGINE"]

#: tid of the engine lane inside each replica's process row. Request
#: lanes use ``rid + 1`` so they never collide with it.
TRACK_ENGINE = 0


class _Span:
    """Context manager emitting one complete ("X") event on exit."""

    __slots__ = ("_tracer", "_name", "_pid", "_tid", "_args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, pid: int, tid: int,
                 args: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._pid = pid
        self._tid = tid
        self._args = args
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._t0 = self._tracer.now()
        return self

    def __exit__(self, *exc) -> None:
        self._tracer.complete(self._name, self._t0, self._tracer.now(),
                              pid=self._pid, tid=self._tid, **self._args)


class _NullSpan:
    """Reusable, reentrant no-op context manager."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        return None


_NULL_SPAN = _NullSpan()


class Tracer:
    """Thread-safe bounded ring of Chrome trace events.

    Timestamps are microseconds since the tracer's construction
    (``time.perf_counter`` based); :meth:`now` hands them out and
    :meth:`complete` / :meth:`instant` record them. The ring drops the
    oldest event once ``capacity`` is reached (``dropped`` counts the
    evictions) so long-running servers never grow without bound.
    """

    enabled = True

    def __init__(self, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._events: deque = deque()
        self._epoch = time.perf_counter()
        self._process_names: Dict[int, str] = {}
        self._track_names: Dict[tuple, str] = {}
        self.dropped = 0
        self.emitted = 0

    # -- clock ---------------------------------------------------------
    def now(self) -> float:
        """Microseconds since this tracer's epoch (wall clock)."""
        return (time.perf_counter() - self._epoch) * 1e6

    # -- emission ------------------------------------------------------
    def _emit(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._events) >= self.capacity:
                self._events.popleft()
                self.dropped += 1
            self._events.append(ev)
            self.emitted += 1

    def complete(self, name: str, t0: float, t1: float, *, pid: int = 0,
                 tid: int = TRACK_ENGINE, cat: str = "serve",
                 **args: Any) -> None:
        """Record a complete ("X") span covering ``[t0, t1]`` (µs)."""
        self._emit({
            "name": name, "cat": cat, "ph": "X",
            "ts": round(t0, 3), "dur": round(max(t1 - t0, 0.0), 3),
            "pid": int(pid), "tid": int(tid), "args": args,
        })

    def instant(self, name: str, *, pid: int = 0, tid: int = TRACK_ENGINE,
                cat: str = "serve", ts: Optional[float] = None,
                **args: Any) -> None:
        """Record an instant ("i") event (thread-scoped)."""
        t = self.now() if ts is None else ts
        self._emit({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": round(t, 3), "pid": int(pid), "tid": int(tid),
            "args": args,
        })

    def span(self, name: str, *, pid: int = 0, tid: int = TRACK_ENGINE,
             **args: Any) -> _Span:
        """Context manager recording a complete span around its body."""
        return _Span(self, name, pid, tid, args)

    # -- track naming --------------------------------------------------
    def name_process(self, pid: int, name: str) -> None:
        """Label a process row (one per replica) in the trace viewer."""
        with self._lock:
            self._process_names[int(pid)] = str(name)

    def name_track(self, pid: int, tid: int, name: str) -> None:
        """Label a thread row (engine lane / request lane)."""
        with self._lock:
            self._track_names[(int(pid), int(tid))] = str(name)

    # -- introspection / export ----------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def events(self) -> List[Dict[str, Any]]:
        """Copy of the ring contents (oldest first)."""
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        """Drop all buffered events and name maps; reset drop counters."""
        with self._lock:
            self._events.clear()
            self._process_names.clear()
            self._track_names.clear()
            self.dropped = 0
            self.emitted = 0

    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event document: ``{"traceEvents": [...]}``.

        Track/process labels are synthesized as metadata ("M") events at
        export time, so naming a track is just a dict write on the hot
        path.
        """
        with self._lock:
            evs = list(self._events)
            pnames = dict(self._process_names)
            tnames = dict(self._track_names)
        meta: List[Dict[str, Any]] = []
        for pid, pname in sorted(pnames.items()):
            meta.append({"name": "process_name", "ph": "M", "ts": 0,
                         "pid": pid, "tid": 0, "args": {"name": pname}})
        for (pid, tid), tname in sorted(tnames.items()):
            meta.append({"name": "thread_name", "ph": "M", "ts": 0,
                         "pid": pid, "tid": tid, "args": {"name": tname}})
            # request lanes sort by rid, engine lane first
            meta.append({"name": "thread_sort_index", "ph": "M", "ts": 0,
                         "pid": pid, "tid": tid,
                         "args": {"sort_index": tid}})
        evs.sort(key=lambda e: (e["ts"], -e.get("dur", 0.0)))
        return {"traceEvents": meta + evs, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> None:
        """Serialize :meth:`chrome_trace` to ``path`` as JSON."""
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(), fh)
            fh.write("\n")


class NullTracer(Tracer):
    """No-op tracer: every method returns immediately.

    Installed by default on every engine/router so the tracing-off hot
    path stays unmeasurably slow — no locks, no allocation, no clock
    reads. ``now()`` returns 0.0 (callers only ever feed it back into
    the no-op ``complete``).
    """

    enabled = False

    def __init__(self):
        super().__init__(capacity=1)

    def now(self) -> float:
        return 0.0

    def _emit(self, ev: Dict[str, Any]) -> None:
        return None

    def complete(self, name, t0, t1, *, pid=0, tid=TRACK_ENGINE,
                 cat="serve", **args) -> None:
        return None

    def instant(self, name, *, pid=0, tid=TRACK_ENGINE, cat="serve",
                ts=None, **args) -> None:
        return None

    def span(self, name, *, pid=0, tid=TRACK_ENGINE, **args) -> _NullSpan:
        return _NULL_SPAN

    def name_process(self, pid, name) -> None:
        return None

    def name_track(self, pid, tid, name) -> None:
        return None

    def clear(self) -> None:
        return None


#: Shared no-op singleton — the default ``tracer=`` everywhere.
NULL_TRACER = NullTracer()
