"""End-to-end trainer integration: loss decreases, grad-accum equivalence,
checkpoint resume, compression path, sharded-butterfly mesh path."""

from dataclasses import replace as dc_replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.models import lm
from repro.optim import optimizer as opt
from repro.runtime import pytree as pt
from repro.train import steps as steps_lib
from repro.train.trainer import Trainer


def test_training_reduces_loss(tmp_path):
    cfg = registry.get("smollm-135m-smoke")
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60,
                     checkpoint_every=0, checkpoint_dir="")
    tr = Trainer(cfg, tc, seq_len=64, global_batch=8)
    res = tr.run(30)
    assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5]) - 0.3


def test_checkpoint_resume_continues(tmp_path):
    cfg = registry.get("smollm-135m-smoke")
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60,
                     checkpoint_every=10, checkpoint_dir=str(tmp_path))
    Trainer(cfg, tc, seq_len=64, global_batch=8).run(12)
    res2 = Trainer(cfg, tc, seq_len=64, global_batch=8).run(3)
    assert res2.resumed_from == 10


def test_grad_accumulation_equivalence():
    """k microbatches must produce the same update as one big batch."""
    cfg = registry.get("smollm-135m-smoke").with_(compute_dtype="float32")
    params = pt.init_params(jax.random.PRNGKey(0), lm.model_specs(cfg))
    tx = opt.sgd(0.1)
    rng = np.random.default_rng(0)
    B, S = 4, 32
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    step1 = steps_lib.make_train_step(cfg, tx, microbatches=1)
    step4 = steps_lib.make_train_step(cfg, tx, microbatches=4)
    p1, _, m1 = step1(params, tx.init(params), batch)
    p4, _, m4 = step4(params, tx.init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    flat1 = jax.tree_util.tree_leaves(p1)
    flat4 = jax.tree_util.tree_leaves(p4)
    for a, b in zip(flat1, flat4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


@pytest.mark.slow
def test_sharded_butterfly_training_on_8_devices():
    """ButterflyConfig.mesh_shape=(8,) routes every butterfly site through
    the shard_map wrappers on the simulated 8-device mesh (conftest): the
    run must report the layout, train to finite loss, and the loss curve
    must track the unsharded run (same data, same init; float32 compute so
    only reduction-order noise separates the two)."""
    assert jax.device_count() >= 8
    cfg = registry.get("smollm-135m-butterfly-smoke").with_(
        compute_dtype="float32")
    cfg_sh = cfg.with_(butterfly=dc_replace(cfg.butterfly, mesh_shape=(8,)))
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=2, total_steps=20,
                     checkpoint_every=0)
    res_sh = Trainer(cfg_sh, tc, seq_len=32, global_batch=8).run(4)
    assert res_sh.mesh_layout == "data=8"
    assert np.all(np.isfinite(res_sh.losses))
    res_1d = Trainer(cfg, tc, seq_len=32, global_batch=8).run(4)
    assert res_1d.mesh_layout == ""
    np.testing.assert_allclose(res_sh.losses[0], res_1d.losses[0],
                               rtol=1e-4)
    np.testing.assert_allclose(res_sh.losses, res_1d.losses, rtol=5e-3,
                               atol=1e-4)


def test_compressed_training_still_learns():
    cfg = registry.get("smollm-135m-smoke")
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60,
                     grad_compression="topk", grad_compression_ratio=0.2)
    tr = Trainer(cfg, tc, seq_len=64, global_batch=8)
    res = tr.run(30)
    assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5])
