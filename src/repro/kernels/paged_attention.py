"""Paged-gather decode attention: jnp oracle + Pallas TPU kernel.

The serving engine's :class:`repro.serve.cache.PagedCachePool` stores KV in
one physical pool of fixed-size pages, ``(num_pages, page_size, KV, D)``,
with a per-slot page table mapping logical page ``j`` (absolute positions
``[j*page_size, (j+1)*page_size)``) to a physical page id. Decode reads a
slot's KV through that indirection.

Two implementations, numerically interchangeable:

* :func:`paged_attend_ref` — the jnp gather oracle: materialize the
  logical view ``pool[page_table]`` (B, P·page_size, KV, D) and run plain
  masked GQA attention in f32. This is what XLA executes on CPU and what
  every parity test measures against; it supports ``Sq >= 1`` query
  positions, which is how chunked prefill reuses the decode path.
* :func:`_paged_decode_pallas` — the Pallas kernel (single-query decode):
  grid ``(B, P)`` with the page table and per-slot positions as **scalar
  prefetch** operands, so each KV BlockSpec's ``index_map`` reads the
  physical page id straight from the prefetched table — the gather never
  materializes, HBM traffic is one read of the *live* pages only (pages
  past ``cur_pos`` are skipped via ``pl.when``), and the online-softmax
  state (m, l, acc) stays in VMEM scratch across the page sweep.

Like the flash kernels, the Pallas path is validated in interpret mode on
CPU (``backend="pallas_interpret"``); Mosaic compilation on real TPUs is
part of the standing TPU-validation item in ROADMAP.md.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# physical page 0 is reserved: never handed out by the allocator, the
# target of every unmapped page-table entry and every out-of-range scatter.
# Its contents are garbage by design — the positional validity mask
# (kpos <= q_pos) keeps it unobservable.
TRASH_PAGE = 0


def gather_pages(pool: jnp.ndarray, page_table: jnp.ndarray) -> jnp.ndarray:
    """Logical per-slot KV view: (N, ps, KV, D) + (B, P) -> (B, P·ps, KV, D).

    Logical position ``p`` of row ``b`` lives at
    ``pool[page_table[b, p // ps], p % ps]`` — i.e. gathered order IS
    absolute-position order, which is what lets the validity mask below be
    a plain ``kpos <= q_pos``.
    """
    B, P = page_table.shape
    _, ps, KV, D = pool.shape
    return pool[page_table].reshape(B, P * ps, KV, D)


def paged_attend_ref(q: jnp.ndarray, k_pool: jnp.ndarray,
                     v_pool: jnp.ndarray, page_table: jnp.ndarray,
                     q_pos: jnp.ndarray) -> jnp.ndarray:
    """jnp gather oracle. q: (B, Sq, KV, G, D) grouped-query layout;
    pools: (N, ps, KV, D); page_table: (B, P) int32; q_pos: (B, Sq)
    absolute positions of the queries. Returns (B, Sq, KV, G, D).

    Causal over absolute positions: query at position ``t`` attends to
    every cached position ``<= t``. Entries beyond a slot's written prefix
    (trash-page garbage, recycled-page leftovers, right-pad tails) all sit
    at positions ``> t`` by the pool's allocation invariant, so the single
    mask keeps them inert.
    """
    B, Sq, KV, G, D = q.shape
    ka = gather_pages(k_pool, page_table).astype(q.dtype)
    va = gather_pages(v_pool, page_table).astype(q.dtype)
    L = ka.shape[1]
    scale = D ** -0.5
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, ka
                        ).astype(jnp.float32) * scale
    kpos = jnp.arange(L)
    valid = kpos[None, None, :] <= q_pos[:, :, None]      # (B, Sq, L)
    logits = jnp.where(valid[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(va.dtype), va)


# ---------------------------------------------------------------------------
# Pallas kernel (single-query decode)
# ---------------------------------------------------------------------------

def _decode_kernel(pt_ref, cp_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, page_size: int,
                   pages_per_slot: int):
    b = pl.program_id(0)
    p = pl.program_id(1)
    cur = cp_ref[b]

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # pages strictly past the written prefix contribute nothing: skip the
    # FLOPs (the DMA for their block still lands, but on the trash page /
    # a stale page, both inert)
    @pl.when(p * page_size <= cur)
    def _attend():
        q = q_ref[0].astype(jnp.float32)               # (KV, G, D)
        k = k_ref[0].astype(jnp.float32)               # (ps, KV, D)
        v = v_ref[0].astype(jnp.float32)
        D = q.shape[-1]
        s = jax.lax.dot_general(
            q, k, (((2,), (2,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)        # (KV, G, ps)
        s = s * (D ** -0.5)
        ids = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, page_size), 2)
        s = jnp.where(ids <= cur, s, NEG_INF)
        m_prev = m_ref[...]                            # (KV, G)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        pexp = jnp.exp(s - m_new[..., None])           # (KV, G, ps)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + pexp.sum(axis=-1)
        pv = jax.lax.dot_general(
            pexp, v, (((2,), (0,)), ((0,), (1,))),
            preferred_element_type=jnp.float32)        # (KV, G, D)
        acc_ref[...] = acc_ref[...] * corr[..., None] + pv
        m_ref[...] = m_new

    @pl.when(p == pages_per_slot - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def _paged_decode_pallas(q, k_pool, v_pool, page_table, cur_pos, *,
                         interpret: bool) -> jnp.ndarray:
    B, KV, G, D = q.shape
    N, ps, _, _ = k_pool.shape
    P = page_table.shape[1]
    kernel = functools.partial(_decode_kernel, page_size=ps,
                               pages_per_slot=P)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, KV, G, D), lambda b, p, pt, cp: (b, 0, 0, 0)),
            # the paged gather: the physical page id comes straight from
            # the scalar-prefetched page table
            pl.BlockSpec((1, ps, KV, D),
                         lambda b, p, pt, cp: (pt[b, p], 0, 0, 0)),
            pl.BlockSpec((1, ps, KV, D),
                         lambda b, p, pt, cp: (pt[b, p], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, KV, G, D),
                               lambda b, p, pt, cp: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, G), jnp.float32),          # running max
            pltpu.VMEM((KV, G), jnp.float32),          # running denom
            pltpu.VMEM((KV, G, D), jnp.float32),       # accumulator
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
        interpret=interpret,
    )(page_table, cur_pos, q, k_pool, v_pool)


def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, page_table: jnp.ndarray,
                           cur_pos: jnp.ndarray, *,
                           backend: Optional[str] = None) -> jnp.ndarray:
    """Single-query paged decode attention. q: (B, KV, G, D); pools
    (N, ps, KV, D); page_table (B, P); cur_pos (B,) absolute positions.

    ``backend=None`` resolves from the ambient
    :class:`~repro.kernels.context.ExecutionContext` (jnp oracle on CPU,
    Pallas on TPU, ``pallas_interpret`` under the test contexts).
    """
    from repro.obs.profiling import annotate
    if backend is None:
        from repro.kernels import context as exctx
        ctx = exctx.current_execution()
        backend = exctx.resolve_backend(ctx.backend if ctx else "auto")
    with annotate("paged_attention"):
        if backend == "jnp":
            out = paged_attend_ref(q[:, None], k_pool, v_pool, page_table,
                                   cur_pos[:, None])
            return out[:, 0]
        return _paged_decode_pallas(q, k_pool, v_pool, page_table,
                                    jnp.asarray(cur_pos, jnp.int32),
                                    interpret=(backend == "pallas_interpret"))
