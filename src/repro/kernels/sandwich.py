"""Fused butterfly-sandwich Pallas kernels (TPU target), forward and backward.

Computes the paper's full dense-layer replacement ``J2ᵀ · W' · J1 · x`` in a
single VMEM residency per activation tile:

    butterfly(b_in) → truncate (one-hot MXU matmul) → small dense core (MXU)
    → scatter (one-hot MXU matmul) → transposed butterfly(b_out)

Truncation/scatter are lowered as multiplications with fixed one-hot matrices
(``sel_in``: (n1, k1), ``sel_out``: (k2, n2)) — TPU has no fast dynamic
gather across lanes, but one-hot matmuls ride the MXU (DESIGN.md §3).

Five HBM round trips (one per op in the unfused jnp path) collapse into one.

Training support: ``sandwich_matmul`` carries a :func:`jax.custom_vjp` whose
backward pass is one fused Pallas kernel chaining, per activation tile:

    recompute forward intermediates from the saved input tile
    → butterfly-transpose VJP (per-stage ``da/db`` reductions)
    → one-hot scatter/selection transposes
    → small-dense-core gradient ``dW' = dh₂ᵀ h₁`` (MXU)
    → input-butterfly VJP → dx

Both butterfly VJPs use the segmented stage checkpointing of
:func:`repro.kernels.butterfly._butterfly_bwd_block` — each butterfly gets
its own VMEM scratch buffer for the ⌈p/segment⌉ boundary activations, so
per-tile stage applications stay O(p) instead of the old O(p²) full-prefix
recompute. ``block_b`` and the checkpoint segments default to the
:mod:`repro.kernels.tuning` autotuner.

Weight gradients (both butterflies + core) accumulate in float32 across the
sequential batch grid into revisited output blocks. The fixed one-hot
selection matrices get zero cotangents (they are structural, never trained).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.butterfly import num_stages
from repro.kernels import tuning
from repro.kernels.butterfly import (_butterfly_bwd_block, _flatten_batch,
                                     _stage_apply)

__all__ = ["sandwich_matmul", "one_hot_select"]


def _sandwich_forward_block(x, w_in_ref, sel_in_ref, core_ref, sel_out_ref,
                            *, stages_in: int, scale_in: float,
                            scale_out: float):
    """Shared forward math up to the scatter output ``z`` (pre out-butterfly).

    Returns ``(h1, z)``; ``h1`` is needed by the core gradient in backward.
    """
    for s in range(stages_in):
        x = _stage_apply(x, w_in_ref[s, 0, :], w_in_ref[s, 1, :], 1 << s,
                         transpose=False)
    h1 = jnp.dot(x, sel_in_ref[...],
                 preferred_element_type=jnp.float32)      # (bb, k1)
    h1 = h1 * scale_in
    h2 = jnp.dot(h1, core_ref[...].T.astype(h1.dtype),
                 preferred_element_type=jnp.float32)      # (bb, k2)
    z = jnp.dot(h2, sel_out_ref[...].astype(h2.dtype),
                preferred_element_type=jnp.float32)       # (bb, n2)
    z = z * scale_out
    return h1, z


def _sandwich_kernel(x_ref, w_in_ref, sel_in_ref, core_ref, sel_out_ref,
                     w_out_ref, o_ref, *, stages_in: int, stages_out: int,
                     scale_in: float, scale_out: float):
    x = x_ref[...]
    _, z = _sandwich_forward_block(x, w_in_ref, sel_in_ref, core_ref,
                                   sel_out_ref, stages_in=stages_in,
                                   scale_in=scale_in, scale_out=scale_out)
    z = z.astype(x.dtype)
    for s in reversed(range(stages_out)):
        z = _stage_apply(z, w_out_ref[s, 0, :], w_out_ref[s, 1, :], 1 << s,
                         transpose=True)
    o_ref[...] = z


def _sandwich_bwd_kernel(x_ref, w_in_ref, sel_in_ref, core_ref, sel_out_ref,
                         w_out_ref, g_ref, dx_ref, dwin_ref, dcore_ref,
                         dwout_ref, ckpt_out_ref, ckpt_in_ref, *,
                         stages_in: int, stages_out: int, seg_in: int,
                         seg_out: int, scale_in: float, scale_out: float):
    x = x_ref[...]
    g = g_ref[...]
    # --- recompute forward intermediates (VMEM-resident, no stash) ---
    h1, z = _sandwich_forward_block(x, w_in_ref, sel_in_ref, core_ref,
                                    sel_out_ref, stages_in=stages_in,
                                    scale_in=scale_in, scale_out=scale_out)
    z = z.astype(x.dtype)
    # --- VJP through the output (transposed) butterfly ---
    gz, dwout = _butterfly_bwd_block(z, w_out_ref, g, stages_out,
                                     transpose=True, segment=seg_out,
                                     ckpt_ref=ckpt_out_ref)
    # --- scatter / core / selection chain (float32 on the MXU) ---
    gzf = gz.astype(jnp.float32) * scale_out
    dh2 = jnp.dot(gzf, sel_out_ref[...].astype(jnp.float32).T,
                  preferred_element_type=jnp.float32)     # (bb, k2)
    dcore = jnp.dot(dh2.T, h1,
                    preferred_element_type=jnp.float32)   # (k2, k1)
    dh1 = jnp.dot(dh2, core_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)     # (bb, k1)
    du = jnp.dot(dh1 * scale_in, sel_in_ref[...].astype(jnp.float32).T,
                 preferred_element_type=jnp.float32)      # (bb, n1)
    du = du.astype(x.dtype)
    # --- VJP through the input butterfly ---
    dx, dwin = _butterfly_bwd_block(x, w_in_ref, du, stages_in,
                                    transpose=False, segment=seg_in,
                                    ckpt_ref=ckpt_in_ref)
    dx_ref[...] = dx.astype(dx_ref.dtype)

    @pl.when(pl.program_id(0) == 0)
    def _():
        dwin_ref[...] = dwin
        dcore_ref[...] = dcore
        dwout_ref[...] = dwout

    @pl.when(pl.program_id(0) > 0)
    def _():
        dwin_ref[...] += dwin
        dcore_ref[...] += dcore
        dwout_ref[...] += dwout


@functools.lru_cache(maxsize=None)
def one_hot_select_np(idx: tuple, n: int) -> np.ndarray:
    """Cached numpy (n, k) one-hot with column j selecting idx[j].

    The cache deliberately holds *numpy* arrays: a jax array built inside a
    jit trace is a tracer, and caching one at module level leaks it into
    later traces (UnexpectedTracerError). Callers convert per use — the
    scatter construction is the cached part.
    """
    sel = np.zeros((n, len(idx)), dtype=np.float32)
    sel[np.asarray(idx), np.arange(len(idx))] = 1.0
    return sel


def one_hot_select(idx, n: int, dtype=jnp.float32) -> jnp.ndarray:
    """(n, k) one-hot matrix with column j selecting coordinate idx[j].

    Backed by a module-level cache on ``(idx, n)``: the index sets are
    frozen at layer init, so non-layers callers (benchmarks, encdec, tests)
    stop rebuilding the numpy one-hot on every trace.
    """
    sel = one_hot_select_np(tuple(int(i) for i in idx), int(n))
    return jnp.asarray(sel, dtype=dtype)


def _sandwich_specs(bb, n1, n2, p1, p2, k1, k2):
    return [
        pl.BlockSpec((bb, n1), lambda i: (i, 0)),
        pl.BlockSpec((p1, 2, n1), lambda i: (0, 0, 0)),
        pl.BlockSpec((n1, k1), lambda i: (0, 0)),
        pl.BlockSpec((k2, k1), lambda i: (0, 0)),
        pl.BlockSpec((k2, n2), lambda i: (0, 0)),
        pl.BlockSpec((p2, 2, n2), lambda i: (0, 0, 0)),
    ]


def _sandwich_fwd_call(x, b_in, sel_in, core, sel_out, b_out, scale_in,
                       scale_out, block_b, interpret):
    p1, _, n1 = b_in.shape
    p2, _, n2 = b_out.shape
    k1 = sel_in.shape[1]
    k2 = sel_out.shape[0]
    assert core.shape == (k2, k1), (core.shape, k1, k2)
    block_b = tuning.resolve_block_b("sandwich", max(n1, n2), x.dtype,
                                     "fwd", block_b)
    x2, lead, b, bb, padded_b = _flatten_batch(x, block_b)
    grid = (padded_b // bb,)
    out = pl.pallas_call(
        functools.partial(_sandwich_kernel, stages_in=num_stages(n1),
                          stages_out=num_stages(n2),
                          scale_in=scale_in, scale_out=scale_out),
        grid=grid,
        in_specs=_sandwich_specs(bb, n1, n2, p1, p2, k1, k2),
        out_specs=pl.BlockSpec((bb, n2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_b, n2), x.dtype),
        interpret=interpret,
    )(x2, b_in.astype(x.dtype), sel_in.astype(x.dtype), core,
      sel_out, b_out.astype(x.dtype))
    return out[:b].reshape(*lead, n2)


def _sandwich_bwd_call(x, b_in, sel_in, core, sel_out, b_out, g, scale_in,
                       scale_out, block_b, segment, interpret):
    p1, _, n1 = b_in.shape
    p2, _, n2 = b_out.shape
    k1 = sel_in.shape[1]
    k2 = sel_out.shape[0]
    stages_in = num_stages(n1)
    stages_out = num_stages(n2)
    block_b = tuning.resolve_block_b("sandwich", max(n1, n2), x.dtype,
                                     "bwd", block_b)
    seg_in = tuning.resolve_segment(stages_in, segment, kernel="sandwich",
                                    n=max(n1, n2), dtype=x.dtype)
    seg_out = tuning.resolve_segment(stages_out, segment, kernel="sandwich",
                                     n=max(n1, n2), dtype=x.dtype)
    x2, lead, b, bb, padded_b = _flatten_batch(x, block_b)
    g2, _, _, _, _ = _flatten_batch(g.astype(x.dtype), block_b)
    grid = (padded_b // bb,)
    in_specs = _sandwich_specs(bb, n1, n2, p1, p2, k1, k2)
    in_specs.append(pl.BlockSpec((bb, n2), lambda i: (i, 0)))
    n_ckpt_in = len(range(0, stages_in, seg_in))
    n_ckpt_out = len(range(0, stages_out, seg_out))
    dx, dwin, dcore, dwout = pl.pallas_call(
        functools.partial(_sandwich_bwd_kernel, stages_in=stages_in,
                          stages_out=stages_out, seg_in=seg_in,
                          seg_out=seg_out, scale_in=scale_in,
                          scale_out=scale_out),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bb, n1), lambda i: (i, 0)),
            pl.BlockSpec((p1, 2, n1), lambda i: (0, 0, 0)),
            pl.BlockSpec((k2, k1), lambda i: (0, 0)),
            pl.BlockSpec((p2, 2, n2), lambda i: (0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded_b, n1), x.dtype),
            jax.ShapeDtypeStruct((p1, 2, n1), jnp.float32),
            jax.ShapeDtypeStruct((k2, k1), jnp.float32),
            jax.ShapeDtypeStruct((p2, 2, n2), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((n_ckpt_out, bb, n2), x2.dtype),
            pltpu.VMEM((n_ckpt_in, bb, n1), x2.dtype),
        ],
        interpret=interpret,
    )(x2, b_in.astype(x.dtype), sel_in.astype(x.dtype), core,
      sel_out, b_out.astype(x.dtype), g2)
    return dx[:b].reshape(*lead, n1), dwin, dcore, dwout


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10))
def _sandwich_diff(x, b_in, sel_in, core, sel_out, b_out, scale_in,
                   scale_out, block_b, segment, interpret):
    return _sandwich_fwd_call(x, b_in, sel_in, core, sel_out, b_out,
                              scale_in, scale_out, block_b, interpret)


def _sandwich_diff_fwd(x, b_in, sel_in, core, sel_out, b_out, scale_in,
                       scale_out, block_b, segment, interpret):
    out = _sandwich_fwd_call(x, b_in, sel_in, core, sel_out, b_out,
                             scale_in, scale_out, block_b, interpret)
    return out, (x, b_in, sel_in, core, sel_out, b_out)


def _sandwich_diff_bwd(scale_in, scale_out, block_b, segment, interpret,
                       res, g):
    x, b_in, sel_in, core, sel_out, b_out = res
    dx, dwin, dcore, dwout = _sandwich_bwd_call(
        x, b_in, sel_in, core, sel_out, b_out, g, scale_in, scale_out,
        block_b, segment, interpret)
    # one-hot selection matrices are structural constants — zero cotangent
    return (dx, dwin.astype(b_in.dtype), jnp.zeros_like(sel_in),
            dcore.astype(core.dtype), jnp.zeros_like(sel_out),
            dwout.astype(b_out.dtype))


_sandwich_diff.defvjp(_sandwich_diff_fwd, _sandwich_diff_bwd)


@functools.partial(jax.jit, static_argnames=("scale_in", "scale_out",
                                             "block_b", "segment",
                                             "interpret"))
def sandwich_matmul(x: jnp.ndarray, b_in: jnp.ndarray, sel_in: jnp.ndarray,
                    core: jnp.ndarray, sel_out: jnp.ndarray,
                    b_out: jnp.ndarray, *, scale_in: float = 1.0,
                    scale_out: float = 1.0, block_b=None, segment=None,
                    interpret: bool = False) -> jnp.ndarray:
    """Fused sandwich over the last axis: (..., n1) -> (..., n2).

    ``b_in``: (p1, 2, n1); ``sel_in``: (n1, k1); ``core``: (k2, k1);
    ``sel_out``: (k2, n2); ``b_out``: (p2, 2, n2). n1/n2 powers of two.
    Differentiable in ``x``, ``b_in``, ``core`` and ``b_out`` via a fused
    Pallas backward kernel (custom_vjp) with segmented stage checkpointing
    for both butterflies; the one-hot selection matrices get zero
    cotangents. ``block_b``/``segment`` default to the
    :mod:`repro.kernels.tuning` autotuner.
    """
    return _sandwich_diff(x, b_in, sel_in, core, sel_out, b_out,
                          scale_in, scale_out, block_b, segment, interpret)
