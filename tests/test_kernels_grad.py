"""Gradients through the fused Pallas kernels (custom_vjp backward passes).

``jax.grad`` through ``butterfly_apply`` / ``sandwich_apply`` under
``context="pallas_interpret"`` must match the jnp-oracle gradients — input
*and* weight cotangents, forward and transpose variants — to atol 1e-5.
The interpret backend executes the exact backward kernel bodies (grid
accumulation included) in Python on CPU, which is what validates the
TPU-target kernels without hardware.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import butterfly as bf
from repro.core import layers as bl
from repro.kernels import ops, ref, tuning
from repro.kernels import butterfly as bkern
from repro.kernels.butterfly import butterfly_matmul, count_stage_applies
from repro.kernels.sandwich import one_hot_select, sandwich_matmul


def _assert_close(got, want, atol=1e-5):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-5, atol=atol)


# ---------------------------------------------------------------------------
# Butterfly VJP vs oracle autodiff
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [8, 64, 256])
@pytest.mark.parametrize("transpose", [False, True])
def test_butterfly_grad_matches_oracle(n, transpose):
    w = bf.random_weights(jax.random.PRNGKey(0), n)
    x = jax.random.normal(jax.random.PRNGKey(1), (11, n))
    c = jax.random.normal(jax.random.PRNGKey(2), (11, n))

    def loss(backend):
        return lambda x, w: jnp.vdot(c, ops.butterfly_apply(
            x, w, transpose=transpose, context=backend))

    gx_k, gw_k = jax.grad(loss("pallas_interpret"), argnums=(0, 1))(x, w)
    gx_o, gw_o = jax.grad(loss("jnp"), argnums=(0, 1))(x, w)
    _assert_close(gx_k, gx_o)
    _assert_close(gw_k, gw_o)


@pytest.mark.parametrize("transpose", [False, True])
def test_butterfly_grad_multiblock_accumulation(transpose):
    """Batch spanning several grid blocks plus a padded remainder exercises
    the in-place float32 dw accumulation across the sequential grid."""
    n = 32
    w = bf.random_weights(jax.random.PRNGKey(3), n)
    x = jax.random.normal(jax.random.PRNGKey(4), (10, n))
    c = jax.random.normal(jax.random.PRNGKey(5), (10, n))

    gx_k, gw_k = jax.grad(
        lambda x, w: jnp.vdot(c, butterfly_matmul(
            x, w, transpose=transpose, block_b=4, interpret=True)),
        argnums=(0, 1))(x, w)
    gx_o, gw_o = jax.grad(
        lambda x, w: jnp.vdot(c, ref.butterfly_ref(w, x,
                                                   transpose=transpose)),
        argnums=(0, 1))(x, w)
    _assert_close(gx_k, gx_o)
    _assert_close(gw_k, gw_o)


def test_butterfly_grad_nd_batch():
    n = 64
    w = bf.random_weights(jax.random.PRNGKey(6), n)
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 3, 5, n))
    c = jax.random.normal(jax.random.PRNGKey(8), (2, 3, 5, n))
    gx, gw = jax.grad(
        lambda x, w: jnp.vdot(c, ops.butterfly_apply(
            x, w, context="pallas_interpret")), argnums=(0, 1))(x, w)
    gx_o, gw_o = jax.grad(
        lambda x, w: jnp.vdot(c, ref.butterfly_ref(w, x)),
        argnums=(0, 1))(x, w)
    assert gx.shape == x.shape and gw.shape == w.shape
    _assert_close(gx, gx_o)
    _assert_close(gw, gw_o)


def test_butterfly_grad_bf16_finite():
    """bf16 activations: backward runs, weight grads come back in the weight
    dtype, everything finite (tolerances are meaningless at bf16)."""
    n = 64
    w = bf.random_weights(jax.random.PRNGKey(9), n)
    x = jax.random.normal(jax.random.PRNGKey(10), (5, n)).astype(jnp.bfloat16)
    gx, gw = jax.grad(
        lambda x, w: jnp.sum(ops.butterfly_apply(
            x, w, context="pallas_interpret").astype(jnp.float32) ** 2),
        argnums=(0, 1))(x, w)
    assert gx.dtype == jnp.bfloat16
    assert gw.dtype == w.dtype
    assert bool(jnp.isfinite(gx.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(gw).all())


# ---------------------------------------------------------------------------
# Sandwich VJP vs oracle autodiff
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n1,n2,k1,k2", [(64, 64, 8, 8), (32, 128, 16, 12)])
def test_sandwich_grad_matches_oracle(n1, n2, k1, k2):
    spec = bl.make_spec(jax.random.PRNGKey(11), n1, n2, k_in=k1, k_out=k2,
                        use_bias=False)
    params = bl.init_butterfly_linear(jax.random.PRNGKey(12), spec)
    x = jax.random.normal(jax.random.PRNGKey(13), (9, n1))
    c = jax.random.normal(jax.random.PRNGKey(14), (9, n2))
    sel_in = one_hot_select(spec.idx_in, n1)
    sel_out = one_hot_select(spec.idx_out, n2).T
    si, so = math.sqrt(n1 / k1), math.sqrt(n2 / k2)

    def loss(backend):
        return lambda x, b_in, core, b_out: jnp.vdot(c, ops.sandwich_apply(
            x, b_in, sel_in, core, sel_out, b_out,
            scale_in=si, scale_out=so, context=backend))

    got = jax.grad(loss("pallas_interpret"), argnums=(0, 1, 2, 3))(
        x, params["b_in"], params["core"], params["b_out"])
    want = jax.grad(loss("jnp"), argnums=(0, 1, 2, 3))(
        x, params["b_in"], params["core"], params["b_out"])
    for g_k, g_o in zip(got, want):
        _assert_close(g_k, g_o, atol=1e-5)


def test_sandwich_sel_matrices_zero_cotangent():
    """The fixed one-hot selection matrices are structural: their cotangents
    are identically zero (they must never receive training signal)."""
    n1 = n2 = 32
    spec = bl.make_spec(jax.random.PRNGKey(15), n1, n2, k_in=4, k_out=4,
                        use_bias=False)
    params = bl.init_butterfly_linear(jax.random.PRNGKey(16), spec)
    x = jax.random.normal(jax.random.PRNGKey(17), (3, n1))
    sel_in = one_hot_select(spec.idx_in, n1)
    sel_out = one_hot_select(spec.idx_out, n2).T

    g_sel = jax.grad(lambda s: jnp.sum(ops.sandwich_apply(
        x, params["b_in"], s, params["core"], sel_out, params["b_out"],
        context="pallas_interpret") ** 2))(sel_in)
    np.testing.assert_array_equal(np.asarray(g_sel), 0.0)


# ---------------------------------------------------------------------------
# Layer/encdec threading: fused path gradients == jnp path gradients
# ---------------------------------------------------------------------------

def test_butterfly_linear_backend_grads_agree():
    """butterfly_linear_apply(context="pallas_interpret") must train exactly
    like the jnp path — including bias and non-power-of-two dims (padding)."""
    spec = bl.make_spec(jax.random.PRNGKey(18), 48, 100, k_in=6, k_out=7,
                        use_bias=True)
    params = bl.init_butterfly_linear(jax.random.PRNGKey(19), spec)
    x = jax.random.normal(jax.random.PRNGKey(20), (5, 48))
    c = jax.random.normal(jax.random.PRNGKey(21), (5, 100))

    def loss(backend):
        return lambda p: jnp.vdot(c, bl.butterfly_linear_apply(
            spec, p, x, context=backend))

    g_k = jax.grad(loss("pallas_interpret"))(params)
    g_o = jax.grad(loss("jnp"))(params)
    assert set(g_k) == set(g_o)
    for name in g_o:
        _assert_close(g_k[name], g_o[name])


def test_encdec_train_step_fused_backend():
    """One encoder-decoder Adam step through the fused kernel path moves the
    loss the same way as the oracle path."""
    from repro.core import encdec
    key = jax.random.PRNGKey(22)
    spec = encdec.make_spec(key, n=16, d=12, k=2)
    params = encdec.init_params(jax.random.PRNGKey(23), spec)
    X = jax.random.normal(jax.random.PRNGKey(24), (16, 12))
    g_k = jax.grad(lambda p: encdec.loss_fn(
        spec, p, X, X, context="pallas_interpret"))(params)
    g_o = jax.grad(lambda p: encdec.loss_fn(
        spec, p, X, X, context="jnp"))(params)
    for name in g_o:
        _assert_close(g_k[name], g_o[name], atol=2e-5)


# ---------------------------------------------------------------------------
# Segmented stage checkpointing: complexity gate + parity across segments
# ---------------------------------------------------------------------------

def test_backward_stage_applies_linear_bound():
    """CI gate for the segmented-checkpoint complexity claim: per-tile stage
    applications in the butterfly backward at n = 4096 must stay within
    3·p·⌈√p⌉ (the ISSUE acceptance bound) — and in fact within 3·p, since
    each segment is recomputed exactly once. The stage loops unroll at trace
    time, so counting _stage_apply invocations while building the kernel
    body *is* the per-tile count."""
    n = 4096
    p = bf.num_stages(n)
    x = jnp.ones((8, n))
    g = jnp.ones((8, n))
    w = jnp.ones((p, 2, n))
    with count_stage_applies() as applied:
        bkern._butterfly_bwd_block(x, w, g, p, transpose=False)
    assert applied() <= 3 * p * tuning.default_segment(p)  # acceptance bound
    assert applied() <= 3 * p                        # actual linear bound
    # strictly better than the old O(p²) full-prefix recompute
    assert applied() < p * (p - 1) // 2 + p


def test_backward_stage_applies_bounded_for_all_segments():
    """Every segment size stays within the 3·p linear bound: the forward
    checkpoint sweep applies < p stages, each segment is recomputed exactly
    once (< p total), and the dual cotangent sweep applies exactly p."""
    n = 1024
    p = bf.num_stages(n)
    x = jnp.ones((4, n))
    g = jnp.ones((4, n))
    w = jnp.ones((p, 2, n))
    for seg in (1, 2, 4, p):
        with count_stage_applies() as applied:
            bkern._butterfly_bwd_block(x, w, g, p, transpose=False,
                                       segment=seg)
        assert p <= applied() <= 3 * p, (seg, applied())


@pytest.mark.parametrize("transpose", [False, True])
def test_segmented_checkpoint_grad_matches_oracle(transpose):
    """Gradient parity across the whole segment knob range, including the
    VMEM-scratch checkpoint path inside the Pallas kernel (interpret)."""
    n = 64
    p = bf.num_stages(n)
    w = bf.random_weights(jax.random.PRNGKey(30), n)
    x = jax.random.normal(jax.random.PRNGKey(31), (9, n))
    c = jax.random.normal(jax.random.PRNGKey(32), (9, n))
    gx_o, gw_o = jax.grad(
        lambda x, w: jnp.vdot(c, ref.butterfly_ref(w, x,
                                                   transpose=transpose)),
        argnums=(0, 1))(x, w)
    for seg in sorted({1, 2, tuning.default_segment(p), p}):
        gx_k, gw_k = jax.grad(
            lambda x, w: jnp.vdot(c, butterfly_matmul(
                x, w, transpose=transpose, block_b=4, segment=seg,
                interpret=True)), argnums=(0, 1))(x, w)
        _assert_close(gx_k, gx_o)
        _assert_close(gw_k, gw_o)


@settings(max_examples=8, deadline=None)
@given(logn=st.integers(2, 5), seed=st.integers(0, 2 ** 30),
       transpose=st.booleans())
def test_property_segmented_backward_equals_oracle(logn, seed, transpose):
    """Hypothesis sweep: segmented-checkpoint backward equals the jnp-oracle
    gradient for every segment size in {1, 2, ⌈√p⌉, p}."""
    n = 1 << logn
    p = bf.num_stages(n)
    kw, kx, kc = jax.random.split(jax.random.PRNGKey(seed), 3)
    w = bf.random_weights(kw, n)
    x = jax.random.normal(kx, (5, n))
    c = jax.random.normal(kc, (5, n))
    gx_o, gw_o = jax.grad(
        lambda x, w: jnp.vdot(c, ref.butterfly_ref(w, x,
                                                   transpose=transpose)),
        argnums=(0, 1))(x, w)
    for seg in sorted({1, 2, tuning.default_segment(p), p}):
        gx_k, gw_k = jax.grad(
            lambda x, w: jnp.vdot(c, butterfly_matmul(
                x, w, transpose=transpose, block_b=4, segment=seg,
                interpret=True)), argnums=(0, 1))(x, w)
        _assert_close(gx_k, gx_o)
        _assert_close(gw_k, gw_o)


# ---------------------------------------------------------------------------
# bf16 forward/backward parity (relaxed tolerances)
# ---------------------------------------------------------------------------

def _assert_close_bf16(got, want, frac=0.05):
    """bf16 parity: absolute tolerance scaled to the oracle's magnitude."""
    want = np.asarray(want, np.float32)
    got = np.asarray(got, np.float32)
    atol = frac * max(float(np.abs(want).max()), 1e-3)
    np.testing.assert_allclose(got, want, rtol=frac, atol=atol)


@pytest.mark.parametrize("transpose", [False, True])
def test_butterfly_bf16_fwd_bwd_parity(transpose):
    n = 128
    w = bf.random_weights(jax.random.PRNGKey(33), n)
    x = jax.random.normal(jax.random.PRNGKey(34), (7, n)).astype(jnp.bfloat16)
    c = jax.random.normal(jax.random.PRNGKey(35), (7, n)).astype(jnp.bfloat16)
    out = butterfly_matmul(x, w, transpose=transpose, interpret=True)
    want = ref.butterfly_ref(w.astype(jnp.float32),
                             x.astype(jnp.float32), transpose=transpose)
    _assert_close_bf16(out, want)

    def loss(backend_fn):
        return lambda x, w: jnp.vdot(
            c.astype(jnp.float32),
            backend_fn(x, w).astype(jnp.float32))

    gx_k, gw_k = jax.grad(
        loss(lambda x, w: butterfly_matmul(x, w, transpose=transpose,
                                           interpret=True)),
        argnums=(0, 1))(x, w)
    gx_o, gw_o = jax.grad(
        loss(lambda x, w: ref.butterfly_ref(
            w.astype(jnp.float32), x.astype(jnp.float32),
            transpose=transpose)),
        argnums=(0, 1))(x, w)
    assert gx_k.dtype == jnp.bfloat16 and gw_k.dtype == w.dtype
    _assert_close_bf16(gx_k, gx_o)
    _assert_close_bf16(gw_k, gw_o)


def test_sandwich_bf16_fwd_bwd_parity():
    n1, n2, k1, k2 = 64, 128, 8, 8
    spec = bl.make_spec(jax.random.PRNGKey(36), n1, n2, k_in=k1, k_out=k2,
                        use_bias=False)
    params = bl.init_butterfly_linear(jax.random.PRNGKey(37), spec)
    x = jax.random.normal(jax.random.PRNGKey(38), (6, n1)).astype(jnp.bfloat16)
    c = jax.random.normal(jax.random.PRNGKey(39), (6, n2))
    sel_in = one_hot_select(spec.idx_in, n1)
    sel_out = one_hot_select(spec.idx_out, n2).T
    si, so = math.sqrt(n1 / k1), math.sqrt(n2 / k2)

    def fused(x, b_in, core, b_out):
        return sandwich_matmul(x, b_in, sel_in, core, sel_out, b_out,
                               scale_in=si, scale_out=so, interpret=True)

    def oracle(x, b_in, core, b_out):
        return ref.sandwich_ref(x.astype(jnp.float32), b_in, core, b_out,
                                sel_in, sel_out, si, so)

    out = fused(x, params["b_in"], params["core"], params["b_out"])
    want = oracle(x, params["b_in"], params["core"], params["b_out"])
    assert out.dtype == jnp.bfloat16
    _assert_close_bf16(out, want, frac=0.08)

    def loss(f):
        return lambda *a: jnp.vdot(c, f(*a).astype(jnp.float32))

    got = jax.grad(loss(fused), argnums=(0, 1, 2, 3))(
        x, params["b_in"], params["core"], params["b_out"])
    wantg = jax.grad(loss(oracle), argnums=(0, 1, 2, 3))(
        x, params["b_in"], params["core"], params["b_out"])
    for g_k, g_o in zip(got, wantg):
        _assert_close_bf16(g_k, g_o, frac=0.08)


# ---------------------------------------------------------------------------
# Flash attention VJP vs oracle autodiff
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24),
                                           (False, 0)])
def test_flash_grad_matches_oracle(causal, window):
    from repro.kernels.flash import flash_attention
    B, H, S, D = 2, 3, 64, 16
    ks = jax.random.split(jax.random.PRNGKey(40), 4)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    c = jax.random.normal(ks[3], (B, H, S, D))

    def loss_kernel(q, k, v):
        return jnp.vdot(c, flash_attention(q, k, v, causal=causal,
                                           window=window, block_q=16,
                                           block_kv=16, interpret=True))

    def loss_oracle(q, k, v):
        return jnp.vdot(c, ref.flash_attention_ref(q, k, v, causal=causal,
                                                   window=window))

    got = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(loss_oracle, argnums=(0, 1, 2))(q, k, v)
    for g_k, g_o in zip(got, want):
        _assert_close(g_k, g_o)


def test_flash_grad_mixed_block_shapes():
    """Backward parity when block_q != block_kv (independent sweep bounds
    in the dq and dkv kernels)."""
    from repro.kernels.flash import flash_attention
    B, H, S, D = 1, 2, 128, 8
    ks = jax.random.split(jax.random.PRNGKey(41), 4)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    c = jax.random.normal(ks[3], (B, H, S, D))
    want = jax.grad(lambda q, k, v: jnp.vdot(c, ref.flash_attention_ref(
        q, k, v, causal=True)), argnums=(0, 1, 2))(q, k, v)
    for bq, bkv in [(32, 64), (64, 32)]:
        got = jax.grad(lambda q, k, v: jnp.vdot(c, flash_attention(
            q, k, v, causal=True, block_q=bq, block_kv=bkv,
            interpret=True)), argnums=(0, 1, 2))(q, k, v)
        for g_k, g_o in zip(got, want):
            _assert_close(g_k, g_o)


def test_flash_autotuned_blocks_divide_seq():
    """The default (tuned) block sizes must divide S and keep the fwd/bwd
    kernels runnable end to end."""
    from repro.kernels.flash import flash_attention
    B, H, S, D = 1, 1, 64, 8
    bq, bkv = tuning.flash_blocks(S, D, "float32", "bwd")
    assert S % bq == 0 and S % bkv == 0
    ks = jax.random.split(jax.random.PRNGKey(42), 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    g = jax.grad(lambda q: jnp.sum(flash_attention(
        q, k, v, interpret=True) ** 2))(q)
    assert bool(jnp.isfinite(g).all())


# ---------------------------------------------------------------------------
# Property test: VJP vs finite differences
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(logn=st.integers(1, 4), seed=st.integers(0, 2**30))
def test_property_butterfly_vjp_finite_differences(logn, seed):
    """Directional derivative from the fused VJP matches central finite
    differences in (x, w) jointly on small n (float32 tolerances)."""
    n = 1 << logn
    kw, kx, kc, kdw, kdx = jax.random.split(jax.random.PRNGKey(seed), 5)
    w = bf.random_weights(kw, n)
    x = jax.random.normal(kx, (3, n))
    c = jax.random.normal(kc, (3, n))
    dw = bf.random_weights(kdw, n)
    dx = jax.random.normal(kdx, (3, n))

    def f(x, w):
        return jnp.vdot(c, ops.butterfly_apply(x, w,
                                               context="pallas_interpret"))

    gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
    directional = float(jnp.vdot(gx, dx) + jnp.vdot(gw, dw))
    eps = 1e-3
    fplus = float(f(x + eps * dx, w + eps * dw))
    fminus = float(f(x - eps * dx, w - eps * dw))
    fd = (fplus - fminus) / (2 * eps)
    scale = max(1.0, abs(fd), abs(directional))
    assert abs(directional - fd) <= 5e-3 * scale
