"""Public jit'd entry points for the Pallas kernels.

Backend selection:

* On TPU the compiled Pallas kernels run (Mosaic).
* On CPU (this container) the *pure-jnp oracles* run for production paths
  (Pallas interpret mode executes the kernel body in Python — correct but
  slow), while tests explicitly request ``backend="pallas_interpret"`` to
  validate the kernel bodies themselves.
"""

from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.butterfly import butterfly_matmul as _butterfly_pallas
from repro.kernels.sandwich import sandwich_matmul as _sandwich_pallas
from repro.kernels.sandwich import one_hot_select

Backend = Literal["auto", "jnp", "pallas", "pallas_interpret"]


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def butterfly_apply(x: jnp.ndarray, w: jnp.ndarray, *,
                    transpose: bool = False,
                    backend: Backend = "auto") -> jnp.ndarray:
    """Fused butterfly product over the last axis of ``x``."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "jnp"
    if backend == "jnp":
        return _ref.butterfly_ref(w.astype(x.dtype), x, transpose=transpose)
    interpret = backend == "pallas_interpret"
    return _butterfly_pallas(x, w, transpose=transpose, interpret=interpret)


def sandwich_apply(x: jnp.ndarray, b_in: jnp.ndarray, sel_in: jnp.ndarray,
                   core: jnp.ndarray, sel_out: jnp.ndarray,
                   b_out: jnp.ndarray, *, scale_in: float = 1.0,
                   scale_out: float = 1.0,
                   backend: Backend = "auto") -> jnp.ndarray:
    """Fused butterfly sandwich (dense-layer replacement) over the last axis."""
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "jnp"
    if backend == "jnp":
        return _ref.sandwich_ref(x, b_in, core, b_out, sel_in, sel_out,
                                 scale_in, scale_out)
    interpret = backend == "pallas_interpret"
    return _sandwich_pallas(x, b_in, sel_in, core, sel_out, b_out,
                            scale_in=scale_in, scale_out=scale_out,
                            interpret=interpret)


__all__ = ["butterfly_apply", "sandwich_apply", "one_hot_select", "Backend"]
