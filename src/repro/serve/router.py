"""Multi-replica serving tier: one front door over N engine replicas.

The :class:`~repro.serve.engine.ServeEngine` scales *within* one process
(pooled slots, paged cache, shard_map meshes); this module scales *out*:
a :class:`Router` dispatches frozen :class:`~repro.serve.Request`\\ s
across multiple in-process engine replicas — the simulated-mesh trick
that made distributed training CPU-testable, applied to serving. One
:class:`~repro.serve.client.TickDriver` thread drives
:meth:`Router.step`, which round-robins a tick over every replica, so
the whole tier stays single-driver deterministic: tests drive
``step()``/``run_until_idle()`` synchronously, production wraps the
router in its driver via ``with router: ...``.

**Dispatch** is weighted least-outstanding-requests over the health
signals the engines already emit: each live replica is scored
``(outstanding + page_pressure) / weight`` — ``outstanding`` is queued +
in-flight requests, ``page_pressure`` is the pool's
``pages_in_use / total_pages`` gauge (a tie-break nudge away from
memory-pressured replicas), ``weight`` the replica's static capacity
multiplier — and the submit goes to the lowest score (ties to the lowest
index). Backpressure is *typed*: a replica shedding with
:class:`~repro.serve.QueueFull` fails over to the next-best replica; only
when EVERY live replica sheds does the router re-raise ``QueueFull`` to
the caller (tier-level load shedding, counted in the snapshot).
``PoolExhausted`` never reaches the router — it is the engine-internal
defer/preempt signal — but its pressure shows up in the score.

**Drain / hot-swap** (`drain` → `wait_drained` → `set_params` →
`undrain`, packaged as :meth:`swap_checkpoint`): draining a replica stops
new dispatch to it, *requeues* its not-yet-admitted requests onto the
other replicas (the internal slot travels whole — Request, Future, and
preemption-recompute state — so nothing is dropped and wall-clock
TTFT/latency still span from the original submit), and lets in-flight
requests *finish* in place. Once drained, the newest *valid* checkpoint
swaps in (torn/corrupt ones fall back via the loader — tear one with
:func:`repro.serve.faults.tear_checkpoint` to drill it) while the other
replicas keep serving; greedy outputs across a swap are token-identical
to a no-swap run (CI-gated). With no other live replica, a drain
degrades to finish-everything: queued work stays put rather than being
dropped.

**Replica death**: a replica whose tick *raises* (device error, injected
fault) is marked dead and routed around — its in-flight futures fail
with the real error, its queued requests requeue onto live replicas, and
dispatch never selects it again. The tier keeps serving as long as one
replica lives.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.obs.tracing import TRACK_ENGINE
from repro.serve.client import TickDriver
from repro.serve.engine import QueueFull, Request, ServeEngine
from repro.serve.loader import restore_params
from repro.serve.metrics import _percentile


@dataclass
class _Replica:
    """Router-side state of one engine replica."""

    engine: ServeEngine
    weight: float = 1.0
    draining: bool = False
    dead: Optional[BaseException] = None
    dispatched: int = 0              # submits routed here
    shed: int = 0                    # QueueFull failovers away from here

    @property
    def live(self) -> bool:
        """Eligible for new dispatch."""
        return self.dead is None and not self.draining


class Router:
    """Weighted least-outstanding-requests dispatch over engine replicas.

    * ``engines`` — the replicas; geometry must be uniform (same arch and
      ``max_len``, checked here) so any request — including a preempted
      one mid-recompute — can be requeued onto any replica.
    * ``weights`` — optional per-replica capacity multipliers (default
      all 1.0): a replica with weight 2 absorbs twice the outstanding
      load before losing a tie.
    * ``tick_timeout`` — heartbeat watchdog bound for the driver thread
      (see :class:`~repro.serve.client.TickDriver`), armed by
      :meth:`start` / ``with router:``.

    The router is created *passive*: drive it synchronously with
    :meth:`step` / :meth:`run_until_idle` (deterministic tests), or call
    :meth:`start` (or enter the context manager) to attach the one
    driver thread. ``submit()`` is thread-safe either way.

    Observability: ``tracer``/``registry`` default to replica 0's, so a
    tier built over engines sharing one :class:`repro.obs.Tracer` and
    one :class:`repro.obs.MetricsRegistry` gets router lifecycle events
    (``drain``/``undrain``/``swap_checkpoint``/``replica_dead`` on the
    target replica's engine lane) and the tier counters
    (``router_*`` callbacks) on the same unified surface.
    """

    def __init__(self, engines: Sequence[ServeEngine], *,
                 weights: Optional[Sequence[float]] = None,
                 tick_timeout: Optional[float] = None,
                 tracer=None, registry=None):
        engines = list(engines)
        if not engines:
            raise ValueError("need at least one engine replica")
        if len(set(map(id, engines))) != len(engines):
            raise ValueError("replicas must be distinct engines")
        names = {e.cfg.name for e in engines}
        lens = {e.max_len for e in engines}
        if len(names) > 1 or len(lens) > 1:
            raise ValueError(
                f"replica geometry must be uniform so requests can "
                f"requeue across replicas: got archs {sorted(names)}, "
                f"max_len {sorted(lens)}")
        if weights is None:
            weights = [1.0] * len(engines)
        if len(weights) != len(engines):
            raise ValueError(f"{len(weights)} weights for "
                             f"{len(engines)} engines")
        if any(w <= 0 for w in weights):
            raise ValueError(f"weights must be positive, got {weights}")
        self.replicas = [_Replica(engine=e, weight=float(w))
                         for e, w in zip(engines, weights)]
        self.tick_timeout = tick_timeout
        self._driver: Optional[TickDriver] = None
        # one lock for dispatch bookkeeping (owner map, counters); the
        # engines have their own locks and the driver its own
        self._lock = threading.Lock()
        self._next_rid = 0
        self._owner: Dict[int, int] = {}       # rid -> replica index
        # tier-level counters (all mutated under self._lock)
        self.requeued = 0                      # drain/death queue moves
        self.shed = 0                          # QueueFull from EVERY replica
        self.drains = 0
        self.swaps = 0
        self.passes = 0                        # step() calls that found work
        self.max_concurrent = 0                # aggregate occupied-slot HWM
        self.tracer = tracer if tracer is not None else engines[0].tracer
        self.obs = registry if registry is not None else engines[0].obs
        self._register_obs()

    def _register_obs(self) -> None:
        """Tier-level callbacks into the shared registry (newest wins on
        re-register, so rebuilding a router over the same registry is
        fine)."""
        reg = self.obs

        def cb(name, fn, mtype, help):
            reg.register_callback(name, fn, mtype=mtype, help=help)

        cb("router_requeued_total", lambda: self.requeued, "counter",
           "queued requests moved across replicas (drain/death)")
        cb("router_shed_total", lambda: self.shed, "counter",
           "submits shed by EVERY live replica (tier-level QueueFull)")
        cb("router_drains_total", lambda: self.drains, "counter",
           "replica drains initiated")
        cb("router_swaps_total", lambda: self.swaps, "counter",
           "checkpoint hot-swaps completed")
        cb("router_passes_total", lambda: self.passes, "counter",
           "round-robin passes that found work")
        cb("router_max_concurrent_slots", lambda: self.max_concurrent,
           "gauge", "aggregate occupied-slot high-water mark")
        cb("router_replicas", lambda: len(self.replicas), "gauge",
           "configured replicas")
        cb("router_replicas_live",
           lambda: sum(r.live for r in self.replicas), "gauge",
           "replicas eligible for dispatch (not dead, not draining)")

    # -- lifecycle ------------------------------------------------------

    def start(self) -> "Router":
        """Attach the one driver thread (idempotent; a closed router
        stays closed — make a new one rather than resurrecting a tier
        whose replicas may hold swept state)."""
        if self._driver is not None and self._driver.stopped:
            raise RuntimeError("router was closed; build a new Router")
        if self._driver is None:
            self._driver = TickDriver(self, tick_timeout=self.tick_timeout,
                                      name="serve-router")
        return self

    def close(self, timeout: float = 60.0) -> None:
        """Stop the driver after the tier drains its current work;
        idempotent. Further submits raise (the driver reference is kept
        so `submit_scope` can refuse them)."""
        if self._driver is not None:
            self._driver.close(timeout=timeout)

    def __enter__(self) -> "Router":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- client surface -------------------------------------------------

    def submit(self, request: Request) -> Future:
        """Dispatch to the lowest-scored live replica; fail over on
        :class:`QueueFull`; re-raise it only when every live replica
        sheds. Thread-safe. Raises ``RuntimeError`` when no replica is
        live (all dead or draining)."""
        scope = (self._driver.submit_scope() if self._driver is not None
                 else contextlib.nullcontext())
        with scope:
            fut = self._dispatch(request)
        if self._driver is not None:
            self._driver.wake()
        return fut

    def _dispatch(self, request: Request) -> Future:
        with self._lock:
            if request.rid is None:
                request = dataclasses.replace(request, rid=self._next_rid)
            rid = int(request.rid)
            if rid in self._owner:
                raise ValueError(f"rid {rid} is already in flight on "
                                 f"replica {self._owner[rid]}")
            self._next_rid = max(self._next_rid, rid) + 1
        ranked = self._ranked(exclude=None)
        if not ranked:
            raise RuntimeError(
                "no live replica: every replica is dead or draining")
        last: Optional[QueueFull] = None
        for i in ranked:
            r = self.replicas[i]
            try:
                fut = r.engine.submit(request)
            except QueueFull as e:
                with self._lock:
                    r.shed += 1
                last = e
                continue
            with self._lock:
                r.dispatched += 1
                self._owner[rid] = i
            fut.add_done_callback(
                lambda _f, rid=rid: self._forget(rid))
            return fut
        with self._lock:
            self.shed += 1
        raise last

    def cancel(self, rid: int) -> bool:
        """Cancel wherever the request currently lives (it may have been
        requeued across replicas since submit). Thread-safe."""
        with self._lock:
            i = self._owner.get(rid)
        order = ([i] if i is not None else []) + [
            j for j in range(len(self.replicas)) if j != i]
        for j in order:
            if self.replicas[j].engine.cancel(rid):
                if self._driver is not None:
                    self._driver.wake()
                return True
        return False

    def _forget(self, rid: int) -> None:
        with self._lock:
            self._owner.pop(rid, None)

    # -- dispatch policy ------------------------------------------------

    def _score(self, r: _Replica) -> float:
        total = r.engine.pool.total_pages
        pressure = (r.engine.pool.pages_in_use / total) if total else 0.0
        return (r.engine.outstanding() + pressure) / r.weight

    def _ranked(self, exclude: Optional[int]) -> List[int]:
        """Live replica indices, best dispatch candidate first
        (deterministic: score, then index)."""
        cands = [(self._score(r), i)
                 for i, r in enumerate(self.replicas)
                 if r.live and i != exclude]
        return [i for _, i in sorted(cands)]

    def outstanding(self, i: Optional[int] = None) -> int:
        if i is not None:
            return self.replicas[i].engine.outstanding()
        return sum(r.engine.outstanding() for r in self.replicas)

    # -- drain / hot-swap ----------------------------------------------

    def drain(self, i: int) -> None:
        """Stop dispatching to replica ``i``; its queued requests requeue
        onto the other live replicas at the next driver pass and its
        in-flight requests finish in place. Idempotent; undo with
        :meth:`undrain`."""
        r = self.replicas[i]
        with self._lock:
            if not r.draining:
                r.draining = True
                self.drains += 1
                self.tracer.instant("drain", pid=r.engine.replica,
                                    tid=TRACK_ENGINE, replica=i)
        if self._driver is not None:
            self._driver.wake()

    def undrain(self, i: int) -> None:
        """Return replica ``i`` to the dispatch rotation."""
        with self._lock:
            if self.replicas[i].draining:
                self.tracer.instant(
                    "undrain", pid=self.replicas[i].engine.replica,
                    tid=TRACK_ENGINE, replica=i)
            self.replicas[i].draining = False

    def drained(self, i: int) -> bool:
        """Is replica ``i`` draining AND empty (nothing queued or in
        flight)?"""
        r = self.replicas[i]
        return r.draining and not r.engine.has_work()

    def wait_drained(self, i: int, timeout: float = 300.0) -> None:
        """Block until replica ``i`` is drained. With a driver attached
        this just waits; without one it drives :meth:`step` itself, so
        synchronous tests need no thread."""
        if not self.replicas[i].draining:
            raise RuntimeError(f"replica {i} is not draining — call "
                               f"drain({i}) first")
        deadline = time.monotonic() + timeout
        while not self.drained(i):
            if self._driver is None:
                self.step()
            else:
                time.sleep(0.005)
            if time.monotonic() > deadline:
                raise TimeoutError(
                    f"replica {i} did not drain within {timeout}s "
                    f"(outstanding={self.outstanding(i)})")

    def swap_checkpoint(self, i: int, checkpoint_dir: str, *,
                        timeout: float = 300.0) -> int:
        """Checkpoint hot-swap on replica ``i`` while the others serve:
        drain it, restore the newest *valid* checkpoint under
        ``checkpoint_dir`` (torn/corrupt steps fall back to older valid
        ones), swap the params in, return the replica to rotation.
        Returns the restored step. The replica is undrained even when
        the restore fails — it still holds its old, consistent params."""
        r = self.replicas[i]
        tt0 = self.tracer.now()
        self.drain(i)
        try:
            self.wait_drained(i, timeout=timeout)
            step, params = restore_params(r.engine.cfg, checkpoint_dir)
            if params is None:
                raise FileNotFoundError(
                    f"no restorable checkpoint under {checkpoint_dir!r} "
                    f"(every candidate torn, corrupt, or absent)")
            r.engine.set_params(params)
            with self._lock:
                self.swaps += 1
            self.tracer.complete("swap_checkpoint", tt0, self.tracer.now(),
                                 pid=r.engine.replica, tid=TRACK_ENGINE,
                                 replica=i, step=int(step))
        finally:
            self.undrain(i)
        return step

    # -- the tick loop (TickDriver's tickable surface) -------------------

    def has_work(self) -> bool:
        return any(r.dead is None and r.engine.has_work()
                   for r in self.replicas)

    def step(self) -> int:
        """One round-robin pass: requeue off draining replicas, then tick
        every replica that has work (one engine tick each). Returns the
        aggregate number of occupied slots after the pass. Single-driver
        contract: call from one thread only (the TickDriver's, or the
        test's)."""
        self._process_drains()
        worked = False
        for i, r in enumerate(self.replicas):
            if r.dead is not None or not r.engine.has_work():
                continue
            worked = True
            try:
                r.engine.step()
            except BaseException as e:
                self._on_replica_error(i, e)
        occupied = sum(r.engine.occupied_slots() for r in self.replicas
                       if r.dead is None)
        with self._lock:
            if worked:
                self.passes += 1
            self.max_concurrent = max(self.max_concurrent, occupied)
        return occupied

    def run_until_idle(self, max_passes: int = 100_000) -> int:
        """Drive passes until every replica drains; returns passes spent
        (the tier's deterministic clock, as engine ticks are per
        replica)."""
        start = self.passes
        while self.has_work():
            self.step()
            if self.passes - start > max_passes:
                raise RuntimeError(
                    f"router did not drain within {max_passes} passes "
                    f"(outstanding={self.outstanding()})")
        return self.passes - start

    def abort_all(self, exc: BaseException) -> None:
        """Fail every queued and in-flight request on every replica (the
        driver's crash/wedge sweep)."""
        for r in self.replicas:
            if r.engine.has_work():
                r.engine.abort_all(exc)
        with self._lock:
            self._owner.clear()

    # -- internals ------------------------------------------------------

    def _process_drains(self) -> None:
        """Requeue queued requests off draining replicas onto live ones
        (driver thread). With no live replica to take them, they stay —
        the drain degrades to finish-everything rather than dropping
        accepted work."""
        for i, r in enumerate(self.replicas):
            if not r.draining or r.dead is not None:
                continue
            if r.engine.queued() == 0 or not self._ranked(exclude=i):
                continue
            for slot, record in r.engine.drain_queued():
                self._requeue(i, slot, record)

    def _requeue(self, src: int, slot, record) -> bool:
        """Adopt a drained slot onto the best live replica (never sheds:
        the tier already accepted this request). Returns whether a new
        home was found; otherwise the slot goes back to the head of the
        source replica's queue."""
        ranked = self._ranked(exclude=src)
        if ranked:
            j = ranked[0]
            self.replicas[j].engine.adopt(slot, record)
            with self._lock:
                self._owner[slot.rid] = j
                self.requeued += 1
            return True
        self.replicas[src].engine.adopt(slot, record, front=True)
        return False

    def _on_replica_error(self, i: int, exc: BaseException) -> None:
        """A replica's tick raised: mark it dead, requeue its queued
        requests onto live replicas (or fail them when none exists), fail
        its in-flight futures with the real error, and route around it
        from now on."""
        r = self.replicas[i]
        with self._lock:
            r.dead = exc
        self.tracer.instant("replica_dead", pid=r.engine.replica,
                            tid=TRACK_ENGINE, replica=i, error=repr(exc))
        stolen = r.engine.drain_queued()
        r.engine.abort_all(exc)          # fails in-flight futures
        for slot, record in stolen:
            ranked = self._ranked(exclude=i)
            if ranked:
                j = ranked[0]
                self.replicas[j].engine.adopt(slot, record)
                with self._lock:
                    self._owner[slot.rid] = j
                    self.requeued += 1
            elif not slot.future.done():
                slot.future.set_exception(exc)

    # -- reporting ------------------------------------------------------

    def snapshot(self) -> Dict:
        """JSON-able tier summary: aggregate SLO percentiles (TTFT and
        end-to-end latency over every replica's recent finished window),
        tier counters, and the per-replica engine snapshots."""
        done = []
        per = []
        with self._lock:
            counters = dict(requeued=self.requeued, shed=self.shed,
                            drains=self.drains, swaps=self.swaps,
                            passes=self.passes,
                            max_concurrent_slots=self.max_concurrent)
            states = [(r.dispatched, r.shed, r.weight, r.draining,
                       r.dead) for r in self.replicas]
        for r, (disp, shed, w, draining, dead) in zip(self.replicas,
                                                      states):
            done.extend(r.engine.metrics.finished())
            per.append({
                "dispatched": disp, "shed": shed, "weight": w,
                "draining": draining,
                "dead": repr(dead) if dead is not None else None,
                "engine": r.engine.metrics.snapshot(),
            })
        ttfts = sorted(rm.ttft for rm in done)
        lats = sorted(rm.latency for rm in done)
        return {
            "replicas": len(self.replicas),
            "requests_finished": len(done),
            **counters,
            "ttft_ms": {
                "p50": round(_percentile(ttfts, 0.50) * 1e3, 3),
                "p95": round(_percentile(ttfts, 0.95) * 1e3, 3),
            },
            "latency_ms": {
                "p50": round(_percentile(lats, 0.50) * 1e3, 3),
                "p95": round(_percentile(lats, 0.95) * 1e3, 3),
            },
            "per_replica": per,
        }

    def telemetry(self) -> Dict:
        """Unified telemetry doc: the tier ``snapshot()`` summary plus the
        shared registry's stable-schema metrics dump (same shape as
        :meth:`ServeEngine.telemetry`)."""
        return {
            "schema": "repro.serve/telemetry-1",
            "summary": self.snapshot(),
            "metrics": self.obs.snapshot(),
        }
