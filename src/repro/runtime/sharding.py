"""Logical-axis sharding rules with divisibility-aware fallback.

A *rule set* maps logical axis names (``"embed"``, ``"heads"``, ``"vocab"``,
``"experts"``, ``"batch"``, ``"seq_kv"``, ...) to mesh axes (a name, a tuple
of names, or None). ``logical_to_pspec`` resolves a ParamSpec/activation axis
tuple into a ``PartitionSpec``, enforcing:

  * divisibility — if a dim is not divisible by the mesh-axis product, the
    mesh axes are dropped for that dim (replicate rather than mis-shard;
    e.g. 8 KV heads on a 16-way model axis);
  * uniqueness — a mesh axis may appear at most once per spec; later uses
    are dropped.

Rule sets are plain dicts so hillclimbing a sharding layout = editing a dict.
"""

from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.runtime import pytree as pt

MeshAxes = Union[None, str, Tuple[str, ...]]
RuleSet = Mapping[str, MeshAxes]

# Default production rule set: DP(+pod) on batch, FSDP on embed, TP on
# heads/mlp/vocab, EP on experts, SP on sequence, KV-cache seq on model.
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "embed": ("pod", "data"),   # FSDP/ZeRO shard (incl. the DCN pod axis)
    "embed_no_fsdp": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    # fallback TP shard for GQA weights when kv_heads doesn't divide the
    # model axis (e.g. kv=8 on a 16-way axis): shard the head_dim instead
    "head_dim": "model",
    "mlp": "model",
    "experts": "model",         # EP
    "expert_mlp": None,
    "seq": None,                # activation seq (train): replicated
    "seq_sp": "model",          # sequence-parallel residual stream
    "seq_kv": "model",          # KV-cache sequence shard
    "rnn_state": "model",
    "conv": None,
    # Butterfly sandwich params (repro.core.layers): O(n log n) weights,
    # deliberately replicated on every device — the distributed path shards
    # the *batch* via shard_map and psums the weight grads instead
    # (repro.runtime.butterfly_sharding). Explicit entries for every logical
    # axis the butterfly ParamSpecs use, so logical_to_pspec resolves them
    # without the unknown-name fallback.
    "stages": None,             # butterfly stage axis — replicated, tiny
    "butterfly_pair": None,     # the (a, b) coefficient pair per stage
    "butterfly_n": None,        # padded feature dim of the stage weights
    "butterfly_core_out": None,  # k2 x k1 dense core of the sandwich
    "butterfly_core_in": None,
    "butterfly_bias": None,
}

# Logical axis names introduced by the butterfly layers — one place for the
# property tests (and future rule sets) to enumerate them.
BUTTERFLY_AXES: Tuple[str, ...] = (
    "stages", "butterfly_pair", "butterfly_n", "butterfly_core_out",
    "butterfly_core_in", "butterfly_bias")


def _axes_tuple(entry: MeshAxes) -> Tuple[str, ...]:
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def resolve_axis(name: Optional[str], dim: int, mesh: Mesh,
                 rules: RuleSet, used: set) -> MeshAxes:
    """Resolve one logical axis to mesh axes honoring divisibility/uniqueness."""
    if name is None:
        return None
    entry = rules.get(name, None)
    axes = [a for a in _axes_tuple(entry)
            if a in mesh.shape and a not in used]
    # greedy prefix that divides the dim
    chosen = []
    prod = 1
    for a in axes:
        if dim % (prod * mesh.shape[a]) == 0:
            chosen.append(a)
            prod *= mesh.shape[a]
    if not chosen:
        return None
    used.update(chosen)
    return chosen[0] if len(chosen) == 1 else tuple(chosen)


def logical_to_pspec(axes: Sequence[Optional[str]], shape: Sequence[int],
                     mesh: Mesh, rules: RuleSet) -> P:
    used: set = set()
    out = [resolve_axis(n, d, mesh, rules, used)
           for n, d in zip(axes, shape)]
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def spec_pspecs(specs: Any, mesh: Mesh, rules: RuleSet = DEFAULT_RULES
                ) -> Any:
    """ParamSpec tree -> PartitionSpec tree."""
    return jax.tree_util.tree_map(
        lambda s: logical_to_pspec(s.axes or (None,) * len(s.shape),
                                   s.shape, mesh, rules)
        if pt.is_spec(s) else s,
        specs, is_leaf=pt.is_spec)


def spec_shardings(specs: Any, mesh: Mesh, rules: RuleSet = DEFAULT_RULES
                   ) -> Any:
    """ParamSpec tree -> NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, logical_to_pspec(
            s.axes or (None,) * len(s.shape), s.shape, mesh, rules))
        if pt.is_spec(s) else s,
        specs, is_leaf=pt.is_spec)


class ShardingCtx:
    """Explicit (mesh, rules) context threaded into model code.

    The trainer/dryrun installs it with :func:`use_sharding`; model code
    calls :func:`constrain` which is a no-op when no context is active (so
    smoke tests and single-device runs trace cleanly).
    """

    def __init__(self, mesh: Optional[Mesh], rules: RuleSet):
        self.mesh = mesh
        self.rules = dict(rules)


_ACTIVE: list = []


class use_sharding:
    def __init__(self, mesh: Optional[Mesh], rules: RuleSet = DEFAULT_RULES):
        self.ctx = ShardingCtx(mesh, rules)

    def __enter__(self):
        _ACTIVE.append(self.ctx)
        return self.ctx

    def __exit__(self, *exc):
        _ACTIVE.pop()
        return False


def active_ctx() -> Optional[ShardingCtx]:
    return _ACTIVE[-1] if _ACTIVE else None


def constrain(x, axes: Sequence[Optional[str]]):
    """``with_sharding_constraint`` by logical axes (no-op w/o context)."""
    ctx = active_ctx()
    if ctx is None or ctx.mesh is None:
        return x
    mesh = ctx.mesh
    if np.prod(list(mesh.shape.values())) == 1:
        return x
    pspec = logical_to_pspec(axes, x.shape, mesh, ctx.rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))


def batch_axes(mesh: Mesh, rules: RuleSet, batch: int) -> P:
    """PartitionSpec for a (batch, ...) array sharded on the batch dim."""
    used: set = set()
    b = resolve_axis("batch", batch, mesh, rules, used)
    return P(b)
