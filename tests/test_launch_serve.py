"""In-process smoke tests for the serving CLI (`python -m
repro.launch.serve`): the full entrypoint — arg parsing, engine/router
construction, trace generation + open-loop replay, fault arming, metrics
JSON — driven by calling `main()` with a patched argv, so CI catches CLI
breakage without a subprocess (and without re-importing jax)."""

import json
import sys

import pytest

ARCH = "smollm-135m-smoke"


def _run_cli(monkeypatch, *argv):
    import repro.launch.serve as serve_cli

    monkeypatch.setattr(sys, "argv", ["repro.launch.serve", *argv])
    serve_cli.main()


def test_cli_paged_trace_with_armed_faults(monkeypatch, tmp_path, capsys):
    """Small paged trace with the fault injector armed at a rate high
    enough to actually fire recovery paths; the metrics JSON must land
    and parse."""
    out = tmp_path / "metrics.json"
    _run_cli(monkeypatch,
             "--arch", ARCH, "--requests", "3", "--slots", "2",
             "--max-len", "48", "--max-new", "4", "--pool", "paged",
             "--fault-seed", "0", "--fault-rate", "0.05",
             "--metrics-json", str(out))
    text = capsys.readouterr().out
    assert "[serve]" in text and "ttft" in text
    snap = json.loads(out.read_text())
    assert snap["requests_finished"] == 3
    assert snap["pool"]["kind"] == "paged"
    assert snap["ttft_ms"]["p50"] <= snap["ttft_ms"]["p95"]


def test_cli_two_replicas_writes_router_snapshot(monkeypatch, tmp_path,
                                                 capsys):
    """--replicas 2 routes the same trace through the Router; the JSON
    is the tier snapshot (aggregate SLO percentiles + per-replica
    engine detail)."""
    out = tmp_path / "router.json"
    _run_cli(monkeypatch,
             "--arch", ARCH, "--requests", "4", "--slots", "2",
             "--max-len", "48", "--max-new", "4", "--replicas", "2",
             "--rate", "50", "--mix", "bimodal",
             "--metrics-json", str(out))
    text = capsys.readouterr().out
    assert "replicas=2" in text and "[serve] router:" in text
    snap = json.loads(out.read_text())
    assert snap["replicas"] == 2
    assert snap["requests_finished"] == 4
    assert len(snap["per_replica"]) == 2
    assert sum(p["dispatched"] for p in snap["per_replica"]) == 4
    assert {"p50", "p95"} <= set(snap["latency_ms"])


def test_cli_rejects_bad_geometry(monkeypatch, tmp_path):
    with pytest.raises(SystemExit, match="no valid prompt length"):
        _run_cli(monkeypatch, "--arch", ARCH, "--requests", "2",
                 "--max-len", "16", "--max-new", "14",
                 "--min-prompt", "8")
    with pytest.raises(SystemExit, match="--replicas"):
        _run_cli(monkeypatch, "--arch", ARCH, "--replicas", "0")
