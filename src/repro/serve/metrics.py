"""Serving metrics: per-request latency decomposition + engine counters.

Two clocks run through every record:

* **wall time** (``time.monotonic``) — what an operator cares about: TTFT,
  TPOT, end-to-end latency, steady-state tokens/s.
* **engine ticks** — the deterministic clock the tests assert against:
  one tick = one :meth:`ServeEngine.step` (admissions + one pooled decode).
  Tick ordering proves scheduling properties (continuous batching, slot
  refill) without depending on machine speed.

``EngineMetrics.snapshot()`` returns a plain-JSON dict (the CLI's
``--metrics-json`` artifact and the serving benchmark both consume it).
"""

from __future__ import annotations

import collections
import math
import threading
import time
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional


@dataclass
class RequestMetrics:
    """Lifecycle of one request through the engine."""

    rid: int
    prompt_len: int
    submit_t: float
    submit_tick: int
    admit_t: float = 0.0
    admit_tick: int = -1
    first_token_t: float = 0.0
    finish_t: float = 0.0
    finish_tick: int = -1
    new_tokens: int = 0
    preemptions: int = 0             # times this request was kicked+requeued

    @property
    def ttft(self) -> float:
        """Time to first token (s): submit -> first sampled token (which the
        engine emits at admission, straight off the prefill logits)."""
        return self.first_token_t - self.submit_t

    @property
    def tpot(self) -> float:
        """Time per output token (s) across the decode phase; 0 for
        single-token requests."""
        if self.new_tokens <= 1:
            return 0.0
        return (self.finish_t - self.first_token_t) / (self.new_tokens - 1)

    @property
    def latency(self) -> float:
        return self.finish_t - self.submit_t

    def to_dict(self) -> Dict:
        return {
            "rid": self.rid, "prompt_len": self.prompt_len,
            "new_tokens": self.new_tokens,
            "ttft_ms": round(self.ttft * 1e3, 3),
            "tpot_ms": round(self.tpot * 1e3, 3),
            "latency_ms": round(self.latency * 1e3, 3),
            "queue_ticks": self.admit_tick - self.submit_tick,
            "admit_tick": self.admit_tick, "finish_tick": self.finish_tick,
            "preemptions": self.preemptions,
        }


def _percentile(sorted_vals: List[float], q: float) -> float:
    """Nearest-rank percentile on a pre-sorted list (no numpy dependency
    in the snapshot path).

    Explicit ceil-based nearest rank — the smallest value with at least a
    ``q`` fraction of the sample at or below it: rank ``ceil(q * n)``
    (1-based), clamped to the sample. Python's ``round()`` (banker's
    rounding) picked the lower rank inconsistently on even-length
    windows; the ceil convention is deterministic and standard (pinned by
    unit tests over 1/2/3/20-element windows in ``tests/test_serve.py``).
    """
    if not sorted_vals:
        return 0.0
    rank = math.ceil(q * len(sorted_vals))
    return sorted_vals[min(len(sorted_vals) - 1, max(0, rank - 1))]


@dataclass
class EngineMetrics:
    """Engine-level counters, accumulated by :class:`ServeEngine`.

    Memory is bounded for a long-lived engine: only *in-flight* requests
    live in ``requests``; finished ones move into a
    ``max_request_history``-bounded deque (their :class:`RequestMetrics`
    object stays alive on the caller's ``GenerationResult`` regardless),
    while the lifetime totals (``requests_finished`` / ``finished_tokens``)
    keep counting. Percentiles in :meth:`snapshot` are therefore over the
    most recent ``max_request_history`` finished requests.

    Thread-safety: the driver thread mutates these counters while a client
    thread may call :meth:`snapshot` (the CLI's periodic dump, the
    router's health probe) — every recorder and every reader therefore
    takes one internal re-entrant lock. Mutate ONLY through the ``on_*``
    recorders; bare ``metrics.field += 1`` from outside this class would
    bypass the lock (the hammer test in ``tests/test_serve.py`` drives a
    recorder storm against a snapshot loop to keep this honest).
    """

    slots: int
    max_request_history: int = 1024
    ticks: int = 0
    decode_steps: int = 0
    decode_tokens: int = 0           # tokens emitted by pooled decode ticks
    prefill_tokens: int = 0          # prompt tokens processed (pre-padding)
    prefills: int = 0
    chunk_ticks: int = 0             # chunked-prefill pool invocations
    occupied_slot_ticks: int = 0     # Σ active slots over decode ticks
    decode_time_s: float = 0.0       # wall time inside pooled decode calls
    prefill_time_s: float = 0.0      # wall time inside prefill calls
    requests_finished: int = 0       # lifetime total
    finished_tokens: int = 0         # lifetime total over finished requests
    max_concurrent_slots: int = 0    # high-water mark of occupied slots
    pool_kind: str = "dense"         # cache pool flavor ("dense"/"paged")
    admission: str = "eager"         # page reservation policy
    total_pages: int = 0             # physical pages incl. the trash page
    pages_in_use: int = 0            # gauge, engine-synced after alloc/free
    pages_hwm: int = 0               # allocator high-water mark
    pool_exhausted_events: int = 0   # admissions/growth deferred or kicked
    preempted: int = 0               # slots kicked mid-flight for pages
    recompute_tokens: int = 0        # already-computed tokens re-prefilled
    cancelled: int = 0               # requests cancelled by the client
    rejected_queue_full: int = 0     # submits shed by the bounded queue
    deadline_expired: int = 0        # requests failed on their deadline
    spec_k: int = 0                  # draft tokens proposed per slot tick
    spec_ticks: int = 0              # speculative decode pool invocations
    draft_tokens: int = 0            # Σ draft proposals over live slots
    accepted_draft_tokens: int = 0   # Σ verified-accepted draft proposals
    requests: Dict[int, RequestMetrics] = field(default_factory=dict)
    clock: object = time.monotonic

    def __post_init__(self):
        self._history: Deque[RequestMetrics] = collections.deque(
            maxlen=self.max_request_history)
        # re-entrant: snapshot() composes finished() under the same lock
        self._lock = threading.RLock()

    # -- recording (engine-internal) -----------------------------------

    def request(self, rid: int) -> Optional[RequestMetrics]:
        with self._lock:
            return self.requests.get(rid)

    def on_submit(self, rid: int, prompt_len: int) -> RequestMetrics:
        with self._lock:
            rm = RequestMetrics(rid=rid, prompt_len=prompt_len,
                                submit_t=self.clock(),
                                submit_tick=self.ticks)
            self.requests[rid] = rm
            return rm

    def on_admit(self, rid: int) -> None:
        with self._lock:
            rm = self.requests[rid]
            rm.admit_t = self.clock()
            rm.admit_tick = self.ticks

    def on_tick(self) -> None:
        """One engine tick completed (the deterministic clock)."""
        with self._lock:
            self.ticks += 1

    def on_prefill_work(self, tokens: int, dt: float,
                        chunked: bool = False) -> None:
        """Prompt tokens pushed through a prefill call (whole-bucket or one
        chunked-prefill pool tick)."""
        with self._lock:
            self.prefill_tokens += tokens
            self.prefill_time_s += dt
            if chunked:
                self.chunk_ticks += 1

    def on_prefill_done(self) -> None:
        with self._lock:
            self.prefills += 1

    def on_first_token(self, rid: int) -> None:
        """The request's first token was sampled (straight off the prefill
        logits — at admission for bucketed prefill, at final-chunk
        completion for chunked prefill)."""
        with self._lock:
            rm = self.requests[rid]
            rm.first_token_t = self.clock()
            rm.new_tokens = 1

    def on_decode_tick(self, active_slots: int, new_tokens: int,
                       dt: float) -> None:
        with self._lock:
            self.decode_steps += 1
            self.occupied_slot_ticks += active_slots
            self.decode_tokens += new_tokens
            self.decode_time_s += dt

    def on_occupancy(self, occupied_slots: int) -> None:
        with self._lock:
            self.max_concurrent_slots = max(self.max_concurrent_slots,
                                            occupied_slots)

    def on_pool_exhausted(self) -> None:
        """An admission or page-growth attempt hit ``PoolExhausted``."""
        with self._lock:
            self.pool_exhausted_events += 1

    def sync_pool(self, pool) -> None:
        """Refresh the page-pool gauges from a
        :class:`repro.serve.cache.CachePool`."""
        with self._lock:
            self.pages_in_use = pool.pages_in_use
            self.pages_hwm = pool.pages_hwm

    def on_token(self, rid: int, n: int = 1) -> None:
        """``n`` tokens committed to the request's output stream (n > 1
        only under speculative decoding, where a tick can commit up to
        ``spec_k + 1`` tokens per slot)."""
        with self._lock:
            self.requests[rid].new_tokens += n

    def on_spec_tick(self, drafted: int, accepted: int) -> None:
        """One speculative decode tick: ``drafted`` proposals went into the
        verify pass across live slots, ``accepted`` survived it. The bonus
        token each slot gets from the verify logits themselves is *not* a
        draft token and is excluded from both counters, so
        ``acceptance_rate`` isolates draft-head quality."""
        with self._lock:
            self.spec_ticks += 1
            self.draft_tokens += drafted
            self.accepted_draft_tokens += accepted

    def on_preempt(self, rid: int, computed_tokens: int) -> None:
        """A slot was kicked for pages; ``computed_tokens`` is the prefix
        (prompt positions prefilled + tokens decoded) that must be
        recomputed via chunked prefill on re-admission."""
        with self._lock:
            self.preempted += 1
            self.recompute_tokens += computed_tokens
            rm = self.requests.get(rid)
            if rm is not None:
                rm.preemptions += 1

    def on_cancel(self, rid: int) -> None:
        """The request was cancelled: evict its record without entering the
        finished history (it produced no result to aggregate)."""
        with self._lock:
            self.cancelled += 1
            self.requests.pop(rid, None)

    def on_deadline(self, rid: int) -> None:
        """The request blew its deadline: evict like a cancel."""
        with self._lock:
            self.deadline_expired += 1
            self.requests.pop(rid, None)

    def on_queue_full(self) -> None:
        with self._lock:
            self.rejected_queue_full += 1

    def evict(self, rid: int) -> Optional[RequestMetrics]:
        """Remove and return an in-flight record without counting it
        anywhere — the abort sweep and the router's drain-requeue path
        (where :meth:`adopt` re-registers it on another replica)."""
        with self._lock:
            return self.requests.pop(rid, None)

    def adopt(self, rm: RequestMetrics) -> None:
        """Re-register a record evicted from another replica (router
        requeue). Wall-clock fields survive the move, so TTFT/latency
        still span from the ORIGINAL submit; ``submit_tick`` is rebased
        to this engine's tick clock (tick clocks are per-engine, and
        ``deadline_ticks`` is measured against it)."""
        with self._lock:
            rm.submit_tick = self.ticks
            rm.admit_tick = -1
            self.requests[rm.rid] = rm

    def on_finish(self, rid: int) -> RequestMetrics:
        """Finalize + evict a request's record (bounded-history move);
        returns it so the engine can attach it to the GenerationResult."""
        with self._lock:
            rm = self.requests.pop(rid)
            rm.finish_t = self.clock()
            rm.finish_tick = self.ticks
            self._history.append(rm)
            self.requests_finished += 1
            self.finished_tokens += rm.new_tokens
            return rm

    # -- reporting -----------------------------------------------------

    def finished(self) -> List[RequestMetrics]:
        """The most recent ``max_request_history`` finished requests."""
        with self._lock:
            return list(self._history)

    def snapshot(self) -> Dict:
        """JSON-able summary: throughput, latency percentiles, occupancy.
        Percentiles and the per-request list cover the bounded recent
        window; the ``requests_finished``/``total_tokens`` counters are
        lifetime totals. Safe to call from any thread while the driver
        records (one consistent cut under the metrics lock)."""
        with self._lock:
            return self._snapshot_locked()

    def _snapshot_locked(self) -> Dict:
        done = self.finished()
        ttfts = sorted(r.ttft for r in done)
        tpots = sorted(r.tpot for r in done if r.new_tokens > 1)
        occupancy = (self.occupied_slot_ticks
                     / (self.slots * max(1, self.decode_steps)))
        return {
            "slots": self.slots,
            "ticks": self.ticks,
            "requests_finished": self.requests_finished,
            "prefills": self.prefills,
            "prefill_tokens": self.prefill_tokens,
            "chunk_ticks": self.chunk_ticks,
            "max_concurrent_slots": self.max_concurrent_slots,
            "cancelled": self.cancelled,
            "rejected_queue_full": self.rejected_queue_full,
            "deadline_expired": self.deadline_expired,
            "preempted": self.preempted,
            "recompute_tokens": self.recompute_tokens,
            "pool": {
                "kind": self.pool_kind,
                "admission": self.admission,
                "total_pages": self.total_pages,
                "pages_in_use": self.pages_in_use,
                "pages_hwm": self.pages_hwm,
                "exhausted_events": self.pool_exhausted_events,
                "preempted": self.preempted,
                "recompute_tokens": self.recompute_tokens,
            },
            "decode_steps": self.decode_steps,
            "decode_tokens": self.decode_tokens,
            "total_tokens": self.finished_tokens,
            "spec": {
                "k": self.spec_k,
                "ticks": self.spec_ticks,
                "draft_tokens": self.draft_tokens,
                "accepted_draft_tokens": self.accepted_draft_tokens,
                "acceptance_rate": round(
                    self.accepted_draft_tokens / self.draft_tokens, 4)
                    if self.draft_tokens else 0.0,
                "tokens_per_slot_tick": round(
                    self.decode_tokens / max(1, self.occupied_slot_ticks), 4),
            },
            "decode_tok_per_s": (self.decode_tokens / self.decode_time_s
                                 if self.decode_time_s else 0.0),
            "slot_occupancy": round(occupancy, 4),
            "ttft_ms": {
                "p50": round(_percentile(ttfts, 0.50) * 1e3, 3),
                "p95": round(_percentile(ttfts, 0.95) * 1e3, 3),
            },
            "tpot_ms": {
                "p50": round(_percentile(tpots, 0.50) * 1e3, 3),
                "p95": round(_percentile(tpots, 0.95) * 1e3, 3),
            },
            "requests": [r.to_dict() for r in
                         sorted(done, key=lambda r: r.rid)],
        }
