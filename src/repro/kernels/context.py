"""One object for every kernel-execution knob: :class:`ExecutionContext`.

Before this module, running a butterfly kernel anywhere above
:mod:`repro.kernels` meant threading four loose kwargs (``backend``,
``block_b``, ``segment``, ``mesh``/``mesh_axes``) through every call site
from ``kernels/ops.py`` up to the ``Trainer``, plus three env-var families.
All of that policy now lives in one frozen, hashable dataclass with a single
resolution order:

    explicit ``context=`` arg
      > ambient ``with use_execution(ctx):``
        > layer/config default (``ButterflyConfig`` via
          :meth:`ExecutionContext.from_butterfly_config`)
          > ``REPRO_*`` environment variables
            > autotuner / platform default

Per *field*: an unset field (``backend="auto"``, everything else ``None``)
falls through to the next layer, so a context only ever has to say what it
wants to change. :func:`resolve_execution` folds the layers and finalizes the
result — concrete backend (env override read once per process, see
:func:`resolve_backend`/:func:`clear_backend_cache`) and a built
:class:`~jax.sharding.Mesh` — into a context that is safe to close over in
jit and to use as an lru/jit cache key.

The pre-context loose kwargs (``backend=``, ``block_b=``, ``segment=``,
``mesh=``, ``mesh_axes=``) had a one-release deprecation shim; it is gone
— the entry points now reject unknown kwargs with a plain ``TypeError``,
and the CI examples step still runs under ``-W error::DeprecationWarning``
as a tripwire for any future shim.
"""

from __future__ import annotations

import dataclasses
import os
from dataclasses import dataclass
from typing import Literal, Optional, Tuple, Union

import jax
from jax.sharding import Mesh

__all__ = [
    "Backend",
    "CONCRETE_BACKENDS",
    "ExecutionContext",
    "use_execution",
    "current_execution",
    "resolve_execution",
    "resolve_backend",
    "clear_backend_cache",
]

Backend = Literal["auto", "jnp", "pallas", "pallas_interpret"]

CONCRETE_BACKENDS = ("jnp", "pallas", "pallas_interpret")

ContextLike = Union["ExecutionContext", str, None]


# ---------------------------------------------------------------------------
# Backend resolution (cached REPRO_KERNEL_BACKEND read)
# ---------------------------------------------------------------------------

_ENV_UNREAD = "\x00unread"
_env_backend_cache: str = _ENV_UNREAD


def _env_backend() -> str:
    """``REPRO_KERNEL_BACKEND``, read from the environment once per process.

    The kernels resolve their backend at trace time on every call; hitting
    ``os.environ`` each time is both a per-call cost and a door for the env
    var to flip mid-process and silently split a model across two backends.
    """
    global _env_backend_cache
    if _env_backend_cache == _ENV_UNREAD:
        _env_backend_cache = os.environ.get(
            "REPRO_KERNEL_BACKEND", "").strip().lower()
    return _env_backend_cache


def clear_backend_cache() -> None:
    """Forget the cached ``REPRO_KERNEL_BACKEND`` read (tests only).

    Production code sets the env var before the process starts; a test that
    monkeypatches it must call this before and after, or the first resolver
    call in the process pins the old value.
    """
    global _env_backend_cache
    _env_backend_cache = _ENV_UNREAD


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: Backend = "auto") -> str:
    """Resolve ``auto`` to a concrete backend.

    A concrete ``backend`` (from an :class:`ExecutionContext` or a
    ``ButterflyConfig``) is validated and returned as-is — the context chain
    is the only override path. ``auto`` falls through to the cached
    ``REPRO_KERNEL_BACKEND`` env read, then the platform default (fused
    Pallas on TPU, the jnp oracle elsewhere).
    """
    if backend == "auto":
        env = _env_backend()
        if env and env != "auto":
            backend = env
        else:
            backend = "pallas" if _on_tpu() else "jnp"
    if backend not in CONCRETE_BACKENDS:
        raise ValueError(f"unknown kernel backend {backend!r}; expected one "
                         f"of {('auto',) + CONCRETE_BACKENDS}")
    return backend


# ---------------------------------------------------------------------------
# The context object
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ExecutionContext:
    """Execution policy for the fused butterfly/sandwich/flash kernels.

    Every field has an "unset" default that falls through to the next layer
    of the resolution order (module docstring); a context therefore composes:
    ``ctx.over(base)`` keeps ``ctx``'s set fields and fills the rest from
    ``base``.

    * ``backend`` — kernel path: ``"auto" | "jnp" | "pallas" |
      "pallas_interpret"`` (``"auto"`` = unset: env var, then platform).
    * ``block_b`` / ``segment`` — Pallas batch-tile rows and backward
      checkpoint interval; ``None`` = ``REPRO_TUNE_*`` env, then the
      :mod:`repro.kernels.tuning` autotuner.
    * ``mesh_shape`` — opt-in multi-device execution: ``(8,)`` builds a
      ``("data",)`` mesh, ``(2, 4)`` a ``("pod", "data")`` mesh
      (:func:`repro.launch.mesh.butterfly_mesh`); activations batch-shard
      under ``shard_map`` with replicated weights and psum'd weight grads.
    * ``mesh`` — an explicit prebuilt Mesh; wins over ``mesh_shape``.
    * ``mesh_axes`` — which mesh axes to batch-shard over (default: the
      ``("pod", "data")`` candidates filtered to the mesh).
    * ``vmem_budget`` / ``flash_block_q`` — autotuner overrides: VMEM bytes
      the footprint model may spend, and a forced flash q/kv block size
      (``None`` = ``REPRO_TUNE_VMEM_BUDGET`` / ``REPRO_TUNE_BLOCK_Q`` env,
      then the model defaults). Read ambiently by
      :mod:`repro.kernels.tuning`.
    * ``profile`` — emit ``jax.profiler.TraceAnnotation`` around the fused
      kernel call sites (:mod:`repro.obs.profiling`) so device profiles
      line up with the serving tier's span names. ``None`` = unset: falls
      through to the ``REPRO_PROFILE`` env var, default off.

    Hashable and frozen: safe to close over in jit, to key lru caches on,
    and to store on a module (:class:`repro.nn.ButterflyLinear`).
    """

    backend: str = "auto"
    block_b: Optional[int] = None
    segment: Optional[int] = None
    mesh_shape: Optional[Tuple[int, ...]] = None
    mesh_axes: Optional[Tuple[str, ...]] = None
    mesh: Optional[Mesh] = None
    vmem_budget: Optional[int] = None
    flash_block_q: Optional[int] = None
    profile: Optional[bool] = None

    def __post_init__(self):
        if self.backend not in ("auto",) + CONCRETE_BACKENDS:
            raise ValueError(
                f"unknown kernel backend {self.backend!r}; expected one of "
                f"{('auto',) + CONCRETE_BACKENDS}")
        if self.mesh_shape is not None:
            object.__setattr__(self, "mesh_shape",
                               tuple(int(s) for s in self.mesh_shape))
        if self.mesh_axes is not None:
            object.__setattr__(self, "mesh_axes",
                               tuple(str(a) for a in self.mesh_axes))

    # -- composition ------------------------------------------------------

    @classmethod
    def coerce(cls, value: ContextLike) -> Optional["ExecutionContext"]:
        """``None`` | backend string | context -> context (or ``None``).

        Accepting a bare backend string keeps the common case terse:
        ``butterfly_apply(x, w, context="pallas_interpret")``.
        """
        if value is None or isinstance(value, cls):
            return value
        if isinstance(value, str):
            return cls(backend=value)
        raise TypeError(
            f"context must be an ExecutionContext, a backend string, or "
            f"None; got {type(value).__name__}")

    @classmethod
    def from_butterfly_config(cls, bc) -> "ExecutionContext":
        """The config layer of the resolution order: lift the execution
        fields of a :class:`repro.configs.base.ButterflyConfig` (or ``None``)
        into a context."""
        if bc is None:
            return cls()
        return cls(backend=bc.backend, block_b=bc.block_b,
                   segment=bc.segment, mesh_shape=bc.mesh_shape)

    def over(self, base: Optional["ExecutionContext"]
             ) -> "ExecutionContext":
        """This context's set fields over ``base``'s (field-wise overlay)."""
        if base is None:
            return self
        kw = {}
        for f in dataclasses.fields(self):
            mine = getattr(self, f.name)
            kw[f.name] = mine if mine != f.default else getattr(base, f.name)
        return ExecutionContext(**kw)

    def local(self) -> "ExecutionContext":
        """The same policy without the mesh: what one shard of a sharded
        region runs (prevents the shard_map wrappers from re-routing)."""
        if self.mesh is None and self.mesh_shape is None:
            return self
        return dataclasses.replace(self, mesh=None, mesh_shape=None)

    # -- introspection ----------------------------------------------------

    def mesh_layout(self) -> str:
        """``"data=8"``-style summary of the resolved mesh ("" if none)."""
        if self.mesh is None:
            return ""
        return ",".join(f"{a}={s}" for a, s in self.mesh.shape.items())

    def describe(self) -> str:
        """One-line summary of every set field (logs, ``TrainResult``)."""
        parts = [f"backend={self.backend}"]
        for name in ("block_b", "segment", "vmem_budget", "flash_block_q"):
            v = getattr(self, name)
            if v is not None:
                parts.append(f"{name}={v}")
        layout = self.mesh_layout()
        if layout:
            parts.append(f"mesh={layout}")
        elif self.mesh_shape is not None:
            parts.append(f"mesh_shape={self.mesh_shape}")
        if self.mesh_axes is not None:
            parts.append(f"mesh_axes={self.mesh_axes}")
        if self.profile is not None:
            parts.append(f"profile={self.profile}")
        return " ".join(parts)


# ---------------------------------------------------------------------------
# Ambient context (mirrors runtime.sharding.use_sharding)
# ---------------------------------------------------------------------------

_STACK: list = []


class use_execution:
    """``with use_execution(ctx):`` — install an ambient execution context.

    Everything traced inside the block (every kernel entry point, layer,
    model, and the autotuner) sees ``ctx`` at the ambient layer of the
    resolution order. Blocks nest: the inner context's set fields win, unset
    fields fall through to the outer block.

    The ambient context is *trace-time* state, like ``use_sharding``: it is
    baked in when a function traces and is not part of jax's jit cache key.
    A function jitted and first called under one ambient context will NOT
    retrace when later called under another — wrap the ``use_execution``
    block *inside* the jitted function (so the context is a trace-time
    constant of that function), or pass an explicit ``context=`` argument,
    when a call site needs to switch policies across calls. The ``Trainer``
    freezes one resolved context per run for exactly this reason.
    """

    def __init__(self, context: ContextLike):
        ctx = ExecutionContext.coerce(context)
        self.ctx = ctx if ctx is not None else ExecutionContext()

    def __enter__(self) -> ExecutionContext:
        _STACK.append(self.ctx)
        return self.ctx

    def __exit__(self, *exc):
        _STACK.pop()
        return False


def current_execution() -> Optional[ExecutionContext]:
    """The folded ambient context (innermost set fields win), or ``None``."""
    if not _STACK:
        return None
    merged = _STACK[0]
    for ctx in _STACK[1:]:
        merged = ctx.over(merged)
    return merged


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

def _resolve_mesh(merged: ExecutionContext) -> Optional[Mesh]:
    if merged.mesh is not None:
        return merged.mesh
    if merged.mesh_shape is None:
        return None
    # a live sharding context's mesh (the Trainer installs one built from
    # this same shape) is reused instead of building a fresh one — but only
    # when its layout actually IS the requested shape: a context that
    # explicitly asks for a different mesh_shape must win over the ambient
    # mesh (the documented resolution order). butterfly_mesh is lru-cached,
    # so both roads usually lead to the same Mesh object anyway.
    from repro.runtime import sharding as rsharding
    sctx = rsharding.active_ctx()
    if (sctx is not None and sctx.mesh is not None
            and tuple(sctx.mesh.shape.values()) == merged.mesh_shape):
        return sctx.mesh
    from repro.launch.mesh import butterfly_mesh
    return butterfly_mesh(merged.mesh_shape)


def resolve_execution(context: ContextLike = None,
                      default: ContextLike = None) -> ExecutionContext:
    """Fold the resolution order into one finalized context.

    ``context`` is the explicit per-call layer, ``default`` the layer/config
    layer (e.g. :meth:`ExecutionContext.from_butterfly_config`); the ambient
    :func:`use_execution` stack sits between them. The result has a concrete
    ``backend`` and a built ``mesh`` (or ``None``); ``block_b``/``segment``
    may remain ``None``, meaning the ``REPRO_TUNE_*`` env vars and then the
    autotuner decide at kernel-call time. Idempotent: resolving an already
    finalized context returns it unchanged.
    """
    merged = ExecutionContext.coerce(context) or ExecutionContext()
    merged = merged.over(current_execution())
    merged = merged.over(ExecutionContext.coerce(default))
    return dataclasses.replace(merged,
                               backend=resolve_backend(merged.backend),
                               mesh=_resolve_mesh(merged))
