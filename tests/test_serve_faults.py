"""Fault injection + request lifecycle robustness (`repro.serve.faults`).

Every recovery path the serving stack claims is driven here on a
deterministic schedule:

* **seeded injection** — a `FaultInjector` replays bit-identically given
  (seed, call sequence); explicit ordinals compose with Bernoulli rates;
* **forced exhaustion** — injected `PoolExhausted` defers admission under
  eager admission and drives preempt/recompute under incremental, with
  greedy output identical to the un-faulted run either way;
* **mid-tick crash** — an `engine.tick` fault propagates through the
  `ServeClient` driver, failing every outstanding future with the real
  `InjectedFault` instead of stranding them;
* **wedged driver** — a tick that never *returns* is caught by the
  heartbeat watchdog (`tick_timeout`): futures fail with `EngineWedged`;
* **torn/corrupt checkpoints** — `tear_checkpoint` damages the newest
  step the way a killed writer would; restore falls back to the older
  valid one;
* **deadlines / cancellation / bounded queue** — the typed lifecycle
  failures (`DeadlineExceeded`, `RequestCancelled`, `QueueFull`) fire on
  schedule, free slot+pages, and keep the engine serving.
"""

import threading
import time

import numpy as np
import pytest

from repro.checkpoint.checkpointing import CheckpointManager
from repro.configs import registry
from repro.serve import (DeadlineExceeded, EngineWedged, FaultInjector,
                         InjectedFault, PoolExhausted, QueueFull, Request,
                         RequestCancelled, ServeClient, ServeEngine,
                         loader)
from repro.serve.faults import tear_checkpoint

ARCH = "smollm-135m-smoke"


@pytest.fixture(scope="module")
def cfg():
    return registry.get(ARCH)


@pytest.fixture(scope="module")
def params(cfg):
    return loader.init_params(cfg, seed=0)


def _engine(cfg, params, **kw):
    kw.setdefault("slots", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 4)
    return ServeEngine(cfg, params, **kw)


def _prompt(cfg, n=5, seed=0):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, size=n).astype(np.int32)


# ---------------------------------------------------------------------------
# The injector itself
# ---------------------------------------------------------------------------

class TestFaultInjector:
    def _fired_ordinals(self, inj, calls=200):
        out = []
        for i in range(1, calls + 1):
            try:
                inj.check("pool.alloc")
            except PoolExhausted:
                out.append(i)
        return out

    def test_same_seed_same_schedule(self):
        a = self._fired_ordinals(FaultInjector(seed=7,
                                               rates={"pool.alloc": 0.1}))
        b = self._fired_ordinals(FaultInjector(seed=7,
                                               rates={"pool.alloc": 0.1}))
        assert a and a == b

    def test_different_seed_different_schedule(self):
        a = self._fired_ordinals(FaultInjector(seed=7,
                                               rates={"pool.alloc": 0.1}))
        b = self._fired_ordinals(FaultInjector(seed=8,
                                               rates={"pool.alloc": 0.1}))
        assert a != b

    def test_explicit_ordinals_fire_exactly(self):
        inj = FaultInjector(at={"engine.tick": (2, 5)})
        fired = []
        for i in range(1, 8):
            try:
                inj.check("engine.tick")
            except InjectedFault as e:
                assert e.site == "engine.tick" and e.ordinal == i
                fired.append(i)
        assert fired == [2, 5]
        assert inj.summary() == {"engine.tick": {"calls": 7, "fired": 2}}

    def test_ordinals_compose_with_rates_deterministically(self):
        def run():
            inj = FaultInjector(seed=3, rates={"pool.alloc": 0.05},
                                at={"pool.alloc": (4,)})
            return self._fired_ordinals(inj)
        a, b = run(), run()
        assert a == b and 4 in a

    def test_unknown_site_and_bad_rate_raise(self):
        with pytest.raises(ValueError, match="unknown fault site"):
            FaultInjector(rates={"pool.allocate": 0.1})
        with pytest.raises(ValueError, match="unknown fault site"):
            FaultInjector(at={"tick": (1,)})
        with pytest.raises(ValueError, match=r"\[0, 1\]"):
            FaultInjector(rates={"pool.alloc": 1.5})

    def test_pool_alloc_raises_pool_exhausted_type(self):
        inj = FaultInjector(at={"pool.alloc": (1,)})
        with pytest.raises(PoolExhausted, match="injected"):
            inj.check("pool.alloc")


# ---------------------------------------------------------------------------
# Injected exhaustion through the engine
# ---------------------------------------------------------------------------

class TestInjectedExhaustion:
    def test_eager_defers_admission_same_output(self, cfg, params):
        """A forced PoolExhausted at the 2nd allocation defers the 2nd
        request's admission one tick (backpressure), then everything
        completes with tokens identical to the un-faulted engine."""
        def run(faults):
            eng = _engine(cfg, params, faults=faults)
            futs = [eng.submit(Request(prompt=_prompt(cfg, seed=s),
                                       max_new_tokens=6))
                    for s in (0, 1)]
            eng.run_until_idle()
            return [f.result().tokens for f in futs], eng

        clean, _ = run(None)
        inj = FaultInjector(at={"pool.alloc": (2,)})
        faulted, eng = run(inj)
        assert faulted == clean
        assert inj.fired["pool.alloc"] == 1
        assert eng.metrics.pool_exhausted_events >= 1
        assert eng.metrics.snapshot()["preempted"] == 0  # eager never kicks

    def test_incremental_forced_preemption_token_parity(self, cfg, params):
        """A forced PoolExhausted during incremental growth preempts the
        (only, hence youngest) slot mid-decode; the recompute path resumes
        it to greedy tokens identical to the un-faulted run."""
        def run(faults):
            eng = _engine(cfg, params, admission="incremental",
                          num_pages=9, faults=faults)
            fut = eng.submit(Request(prompt=_prompt(cfg), max_new_tokens=10))
            eng.run_until_idle()
            return fut.result(), eng

        clean, _ = run(None)
        # call 1 = prompt reservation at admission; call 2 = the first
        # decode-growth allocation -> fires mid-decode
        faulted, eng = run(FaultInjector(at={"pool.alloc": (2,)}))
        assert faulted.tokens == clean.tokens
        snap = eng.metrics.snapshot()
        assert snap["preempted"] == 1
        assert snap["recompute_tokens"] > 0
        assert faulted.metrics.preemptions == 1
        # the pool fully drained: no leaked pages after the kick/resume
        assert snap["pool"]["pages_in_use"] == 0


# ---------------------------------------------------------------------------
# Mid-tick crash through the client
# ---------------------------------------------------------------------------

class TestMidTickCrash:
    def test_driver_fails_futures_and_refuses_submits(self, cfg, params):
        eng = _engine(cfg, params,
                      faults=FaultInjector(at={"engine.tick": (1,)}))
        with ServeClient(eng) as client:
            fut = client.submit(Request(prompt=_prompt(cfg),
                                        max_new_tokens=4))
            with pytest.raises(InjectedFault) as ei:
                fut.result(timeout=60)
            assert ei.value.site == "engine.tick"
            # the driver is dead: further submissions are refused loudly
            deadline = time.monotonic() + 5
            while time.monotonic() < deadline:
                try:
                    client.submit(Request(prompt=_prompt(cfg)))
                except RuntimeError:
                    break
                time.sleep(0.01)
            else:
                pytest.fail("submit kept succeeding after a dead driver")
        # no request leaked into the slots
        assert eng.active_requests() == []


# ---------------------------------------------------------------------------
# Wedged driver: the tick that never returns
# ---------------------------------------------------------------------------

class TestWedgedDriver:
    def test_heartbeat_surfaces_wedged_tick(self, cfg, params):
        eng = _engine(cfg, params)
        release = threading.Event()
        real_step = eng.step

        def wedged_step():
            release.wait(timeout=30)       # a hung device call
            return real_step()

        client = ServeClient(eng, tick_timeout=0.3)
        try:
            eng.step = wedged_step
            fut = client.submit(Request(prompt=_prompt(cfg),
                                        max_new_tokens=4))
            with pytest.raises(EngineWedged):
                fut.result(timeout=30)
            assert client.wedged
            with pytest.raises(RuntimeError, match="wedged"):
                client.submit(Request(prompt=_prompt(cfg)))
        finally:
            release.set()
            eng.step = real_step
            client.close()

    def test_healthy_driver_never_trips_watchdog(self, cfg, params):
        eng = _engine(cfg, params)
        with ServeClient(eng, tick_timeout=30.0) as client:
            fut = client.submit(Request(prompt=_prompt(cfg),
                                        max_new_tokens=4))
            assert len(fut.result(timeout=120).tokens) == 4
            assert not client.wedged

    def test_tick_timeout_validation(self, cfg, params):
        eng = _engine(cfg, params)
        with pytest.raises(ValueError, match="tick_timeout"):
            ServeClient(eng, tick_timeout=0.0)


# ---------------------------------------------------------------------------
# Torn / corrupt checkpoints
# ---------------------------------------------------------------------------

class TestTornCheckpoint:
    def _save_steps(self, cfg, params, directory, steps):
        mgr = CheckpointManager(str(directory), keep=len(steps))
        for s in steps:
            mgr.save(s, {"params": params})
        return mgr

    def test_torn_newest_falls_back(self, cfg, params, tmp_path):
        self._save_steps(cfg, params, tmp_path, (1, 2))
        assert loader.restore_params(cfg, str(tmp_path))[0] == 2
        damaged = tear_checkpoint(str(tmp_path), mode="torn")
        assert damaged.endswith("step_000000002")
        step, restored = loader.restore_params(cfg, str(tmp_path))
        assert step == 1 and restored is not None

    def test_corrupt_newest_falls_back(self, cfg, params, tmp_path):
        self._save_steps(cfg, params, tmp_path, (1, 2))
        tear_checkpoint(str(tmp_path), mode="corrupt")
        step, restored = loader.restore_params(cfg, str(tmp_path))
        assert step == 1 and restored is not None

    def test_all_damaged_restores_nothing(self, cfg, params, tmp_path):
        self._save_steps(cfg, params, tmp_path, (1,))
        tear_checkpoint(str(tmp_path), mode="torn")
        assert loader.restore_params(cfg, str(tmp_path)) == (None, None)

    def test_validation(self, tmp_path):
        with pytest.raises(FileNotFoundError, match="no step_"):
            tear_checkpoint(str(tmp_path))
        (tmp_path / "step_000000001").mkdir()
        with pytest.raises(ValueError, match="unknown tear mode"):
            tear_checkpoint(str(tmp_path), mode="shred")


# ---------------------------------------------------------------------------
# Deadlines
# ---------------------------------------------------------------------------

class TestDeadlines:
    def test_queued_request_expires_on_tick_deadline(self, cfg, params):
        eng = _engine(cfg, params, slots=1)
        f_long = eng.submit(Request(prompt=_prompt(cfg), max_new_tokens=20))
        f_dead = eng.submit(Request(prompt=_prompt(cfg, seed=1),
                                    max_new_tokens=4, deadline_ticks=3))
        eng.run_until_idle()
        assert len(f_long.result().tokens) == 20
        with pytest.raises(DeadlineExceeded, match="deadline_ticks=3"):
            f_dead.result()
        assert eng.metrics.snapshot()["deadline_expired"] == 1

    def test_in_flight_request_expires_and_frees_pages(self, cfg, params):
        eng = _engine(cfg, params)
        fut = eng.submit(Request(prompt=_prompt(cfg), max_new_tokens=20,
                                 deadline_ticks=5))
        eng.run_until_idle()
        with pytest.raises(DeadlineExceeded):
            fut.result()
        assert eng.active_requests() == []
        assert eng.metrics.pages_in_use == 0

    def test_wall_deadline(self, cfg, params):
        eng = _engine(cfg, params)
        fut = eng.submit(Request(prompt=_prompt(cfg), max_new_tokens=4,
                                 deadline_s=0.001))
        time.sleep(0.01)                  # blow the SLO before any tick
        eng.run_until_idle()
        with pytest.raises(DeadlineExceeded, match="deadline_s"):
            fut.result()

    def test_generous_deadline_finishes_normally(self, cfg, params):
        eng = _engine(cfg, params)
        fut = eng.submit(Request(prompt=_prompt(cfg), max_new_tokens=4,
                                 deadline_ticks=10_000, deadline_s=600.0))
        eng.run_until_idle()
        assert len(fut.result().tokens) == 4

    def test_deadline_validation(self):
        with pytest.raises(ValueError, match="deadline_ticks"):
            Request(prompt=[1], deadline_ticks=0)
        with pytest.raises(ValueError, match="deadline_s"):
            Request(prompt=[1], deadline_s=-1.0)


# ---------------------------------------------------------------------------
# Cancellation + bounded queue
# ---------------------------------------------------------------------------

class TestCancelAndQueue:
    def test_cancel_queued_request(self, cfg, params):
        eng = _engine(cfg, params, slots=1)
        f_run = eng.submit(Request(prompt=_prompt(cfg), max_new_tokens=6))
        f_cxl = eng.submit(Request(prompt=_prompt(cfg, seed=1),
                                   max_new_tokens=6, rid=42))
        assert eng.cancel(42) is True
        eng.run_until_idle()
        with pytest.raises(RequestCancelled, match="42"):
            f_cxl.result()
        assert len(f_run.result().tokens) == 6
        assert eng.metrics.snapshot()["cancelled"] == 1

    def test_cancel_in_flight_frees_slot_and_pages(self, cfg, params):
        eng = _engine(cfg, params)
        fut = eng.submit(Request(prompt=_prompt(cfg), max_new_tokens=20,
                                 rid=7))
        for _ in range(4):
            eng.step()
        assert 7 in eng.active_requests()
        assert eng.cancel(7) is True
        eng.run_until_idle()
        with pytest.raises(RequestCancelled):
            fut.result()
        assert eng.active_requests() == []
        assert eng.metrics.pages_in_use == 0

    def test_cancel_unknown_rid_is_noop(self, cfg, params):
        eng = _engine(cfg, params)
        assert eng.cancel(12345) is False

    def test_client_cancel_passthrough(self, cfg, params):
        eng = _engine(cfg, params, slots=1)
        with ServeClient(eng) as client:
            f_run = client.submit(Request(prompt=_prompt(cfg),
                                          max_new_tokens=6))
            f_cxl = client.submit(Request(prompt=_prompt(cfg, seed=1),
                                          max_new_tokens=6, rid=11))
            assert client.cancel(11) is True
            with pytest.raises(RequestCancelled):
                f_cxl.result(timeout=120)
            assert len(f_run.result(timeout=120).tokens) == 6

    def test_queue_full_sheds_typed(self, cfg, params):
        eng = _engine(cfg, params, queue_limit=2)
        eng.submit(Request(prompt=_prompt(cfg), max_new_tokens=2))
        eng.submit(Request(prompt=_prompt(cfg, seed=1), max_new_tokens=2))
        with pytest.raises(QueueFull, match="2 requests waiting"):
            eng.submit(Request(prompt=_prompt(cfg, seed=2),
                               max_new_tokens=2))
        assert eng.metrics.snapshot()["rejected_queue_full"] == 1
        eng.run_until_idle()              # the queued two still complete
        assert eng.metrics.requests_finished == 2

    def test_queue_limit_validation(self, cfg, params):
        with pytest.raises(ValueError, match="queue_limit"):
            _engine(cfg, params, queue_limit=0)
