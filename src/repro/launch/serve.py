"""Serving entrypoint: replay a synthetic request trace through the engine.

    python -m repro.launch.serve --arch smollm-135m-smoke --requests 16 \
        --slots 4 --max-new 16 --rate 20

Generates a seeded open-loop workload via :mod:`repro.serve.trace`
(Poisson arrivals at ``--rate`` req/s, ``--mix`` prompt lengths — the
byte-identical trace the serving benchmarks replay for the same seed),
submits it through the async :class:`~repro.serve.client.ServeClient` —
or, with ``--replicas N``, through the multi-replica
:class:`~repro.serve.router.Router` — and prints per-request TTFT/TPOT
plus the JSON metrics snapshot (per-engine, or the router's aggregate
with per-replica detail). ``--checkpoint-dir`` restores the newest valid
:mod:`repro.checkpoint` checkpoint (fresh init otherwise);
``--mesh-shape 8`` serves over an 8-device ``("data",)`` mesh —
``--simulated-devices 8`` simulates one on CPU.

Robustness knobs: ``--admission incremental`` switches to prompt-only page
reservation with preempt-youngest/recompute (vLLM's policy);
``--queue-limit N`` sheds submits beyond N waiting with ``QueueFull``;
``--fault-seed S`` arms a seeded ``FaultInjector`` forcing ``PoolExhausted``
at ``--fault-rate`` per allocation, so recovery paths run under load.

Observability: ``--trace-out trace.json`` records per-request span
timelines through one shared :class:`repro.obs.Tracer` (replica ``i`` is
``pid i``) and exports Chrome trace-event JSON loadable in Perfetto;
``--metrics-json`` writes the unified ``repro.serve/telemetry-1`` doc
(lifecycle summary + metrics-registry snapshot), rewritten atomically
every ``--metrics-interval`` seconds while serving.
"""

import argparse
import json
import os
import sys
import threading

# Simulated multi-device serving: the host device count must reach XLA
# before jax initializes (jax-free helper shared with launch/train.py).
from repro.launch._prejax import apply_simulated_devices

apply_simulated_devices(sys.argv)

import numpy as np  # noqa: E402


def _write_json_atomic(path: str, doc) -> None:
    """Write-then-rename so a reader polling the path never sees a torn
    doc (the periodic flusher rewrites it mid-run)."""
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
    os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser(allow_abbrev=False)
    ap.add_argument("--arch", default="smollm-135m-smoke")
    ap.add_argument("--requests", type=int, default=16,
                    help="number of synthetic requests to replay")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve over N in-process engine replicas behind "
                         "the Router (weighted least-outstanding dispatch,"
                         " QueueFull failover); 1 = plain ServeClient")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128,
                    help="per-slot budget: prompt + generated tokens")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--pool", default="paged", choices=("paged", "dense"),
                    help="cache pool kind (paged falls back to dense for "
                         "sequential-state archs)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged pool: tokens per page")
    ap.add_argument("--num-pages", type=int, default=0,
                    help="paged pool: physical pages incl. the trash page "
                         "(0 = dense-equivalent capacity)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="chunked-prefill chunk size; 0 = whole-bucket "
                         "admission")
    ap.add_argument("--admission", default="eager",
                    choices=("eager", "incremental"),
                    help="page reservation policy: eager = whole-budget at "
                         "admission (no preemption); incremental = prompt-"
                         "only + per-tick growth with preempt-youngest/"
                         "recompute on exhaustion")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft tokens proposed per "
                         "slot per tick through the model's own (butterfly) "
                         "output head, verified in one batched full-model "
                         "pass (0 = off; needs greedy sampling + paged "
                         "pool + chunked prefill)")
    ap.add_argument("--queue-limit", type=int, default=0,
                    help="bounded admission queue: submits beyond this "
                         "many waiting requests are shed with QueueFull "
                         "(0 = unbounded)")
    ap.add_argument("--fault-seed", type=int, default=-1,
                    help="arm a FaultInjector with this seed: forced "
                         "PoolExhausted at pool.alloc on a Bernoulli "
                         "schedule (-1 = no injection)")
    ap.add_argument("--fault-rate", type=float, default=0.05,
                    help="per-call fire probability for --fault-seed")
    ap.add_argument("--min-prompt", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=48)
    ap.add_argument("--mix", default="uniform",
                    choices=("uniform", "bimodal"),
                    help="prompt-length mix (see repro.serve.trace): "
                         "uniform over [min,max], or bimodal short/long "
                         "around the prefill chunk")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="mean arrival rate (req/s); 0 = submit all "
                         "up front")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-json", default="",
                    help="write the unified telemetry doc here "
                         "(repro.serve/telemetry-1: lifecycle summary + "
                         "metrics-registry snapshot)")
    ap.add_argument("--metrics-interval", type=float, default=0.0,
                    help="with --metrics-json: atomically rewrite the "
                         "telemetry doc every S seconds while serving "
                         "(0 = final write only)")
    ap.add_argument("--trace-out", default="",
                    help="record per-request span timelines and write a "
                         "Chrome trace-event JSON here (load in Perfetto); "
                         "tracing stays off without this flag")
    ap.add_argument("--mesh-shape", default="",
                    help="serve over a butterfly data mesh, e.g. '8' or "
                         "'2x4' (requires a butterfly arch)")
    ap.add_argument("--simulated-devices", type=int, default=0,
                    help="force N simulated host devices (CPU). Handled "
                         "before jax import.")
    args = ap.parse_args()

    from repro.configs import registry
    from repro.kernels.context import ExecutionContext
    from repro.obs import MetricsRegistry, Tracer
    from repro.obs.tracing import NULL_TRACER
    from repro.serve import (FaultInjector, Router, SamplingParams,
                             ServeClient, ServeEngine, loader, trace)

    cfg = registry.get(args.arch)
    context = None
    if args.mesh_shape:
        try:
            shape = tuple(int(s) for s in args.mesh_shape.split("x"))
            if not shape or any(s <= 0 for s in shape):
                raise ValueError(shape)
        except ValueError:
            raise SystemExit(
                f"invalid --mesh-shape {args.mesh_shape!r}: expected e.g. "
                f"'8' (data mesh) or '2x4' (pod x data)")
        context = ExecutionContext(mesh_shape=shape)

    step, params = loader.load_for_serving(cfg, args.checkpoint_dir,
                                           seed=args.seed)
    src = f"checkpoint step {step}" if step is not None else "fresh init"
    if args.replicas < 1:
        raise SystemExit(f"--replicas must be >= 1, got {args.replicas}")
    # each replica gets its OWN injector (same seed => same per-replica
    # schedule) so one replica's allocations don't advance another's dice
    injectors = [FaultInjector(seed=args.fault_seed,
                               rates={"pool.alloc": args.fault_rate})
                 if args.fault_seed >= 0 else None
                 for _ in range(args.replicas)]
    # one registry and (when --trace-out) one tracer span every replica:
    # replica i is pid i in the Chrome trace, and the registry keeps the
    # per-replica families apart via the {"replica": i} label
    obs_registry = MetricsRegistry()
    tracer = Tracer() if args.trace_out else NULL_TRACER
    engines = [ServeEngine(
        cfg, params, slots=args.slots, max_len=args.max_len,
        pool=args.pool, page_size=args.page_size,
        num_pages=args.num_pages or None,
        prefill_chunk=args.prefill_chunk or None,
        sampling=SamplingParams(temperature=args.temperature,
                                top_k=args.top_k, top_p=args.top_p),
        admission=args.admission, spec_k=args.spec_k,
        queue_limit=args.queue_limit or None,
        faults=faults, context=context, seed=args.seed,
        tracer=tracer, registry=obs_registry, replica=i)
        for i, faults in enumerate(injectors)]
    engine, faults = engines[0], injectors[0]
    print(f"[serve] {cfg.name} | params: {src} | slots={args.slots} "
          f"max_len={args.max_len} pool={engine.pool.kind} "
          f"chunk={engine.prefill_chunk} admission={engine.admission} "
          f"spec_k={engine.spec_k} "
          f"sampling=(T={args.temperature}, "
          f"k={args.top_k}, p={args.top_p})"
          + (f" | replicas={args.replicas}" if args.replicas > 1 else "")
          + (f" | mesh={engine.ctx.mesh_layout()}" if engine.mesh else ""))

    hi = min(args.max_prompt, args.max_len - args.max_new)
    if hi < args.min_prompt:
        raise SystemExit(
            f"no valid prompt length: min-prompt {args.min_prompt} > "
            f"min(max-prompt {args.max_prompt}, max-len {args.max_len} - "
            f"max-new {args.max_new}) = {hi}; raise --max-len or lower "
            f"--max-new/--min-prompt")
    try:
        spec = trace.TraceSpec(
            requests=args.requests, seed=args.seed, rate=args.rate,
            min_prompt=args.min_prompt, max_prompt=hi, mix=args.mix,
            chunk=engine.prefill_chunk or 16,
            max_new_tokens=args.max_new)
    except ValueError as e:
        raise SystemExit(f"invalid trace: {e}")
    items = trace.generate(spec, cfg.vocab_size)

    # extras come off their own stream ([seed, 2]; the trace owns 0 and
    # 1) so arming a frontend arch doesn't perturb the token workload
    xrng = np.random.default_rng([args.seed, 2])
    def extras():
        # frontend-stub archs (VLM / enc-dec audio): per-request
        # precomputed embeddings, like the training pipeline's stubs
        out = {}
        if cfg.frontend == "vision":
            out["frontend_embeds"] = xrng.normal(
                size=(1, cfg.frontend_tokens, cfg.d_model)).astype("float32")
        if cfg.n_enc_layers:
            out["frames"] = xrng.normal(
                size=(1, cfg.enc_seq, cfg.d_model)).astype("float32")
        return out or None

    def show(fut):
        r = fut.result(timeout=600)
        m = r.metrics
        pre = f" preempt={m.preemptions}" if m.preemptions else ""
        print(f"  req[{r.rid:03d}] prompt={m.prompt_len:3d} "
              f"new={m.new_tokens:3d} ttft={m.ttft * 1e3:7.1f} ms "
              f"tpot={m.tpot * 1e3:6.1f} ms "
              f"latency={m.latency * 1e3:7.1f} ms{pre}")

    stop_flush = threading.Event()

    def start_flusher(doc_fn):
        # periodic telemetry flush: atomically rewrite --metrics-json
        # every --metrics-interval seconds while the workload drains
        if not (args.metrics_json and args.metrics_interval > 0):
            return None
        def loop():
            while not stop_flush.wait(args.metrics_interval):
                _write_json_atomic(args.metrics_json, doc_fn())
        t = threading.Thread(target=loop, daemon=True,
                             name="metrics-flush")
        t.start()
        return t

    if args.replicas == 1:
        with ServeClient(engine) as client:
            flusher = start_flusher(engine.telemetry)
            futs, shed = trace.replay(client.submit, items,
                                      request_kw={"extras": extras})
            for fut in futs:
                show(fut)
            stop_flush.set()
            if flusher is not None:
                flusher.join(timeout=10)
        out = snap = engine.metrics.snapshot()
        print(f"[serve] {snap['requests_finished']} requests, "
              f"{snap['total_tokens']} tokens | decode "
              f"{snap['decode_tok_per_s']:.1f} tok/s | occupancy "
              f"{snap['slot_occupancy']:.2f} | ttft p50/p95 "
              f"{snap['ttft_ms']['p50']:.1f}/{snap['ttft_ms']['p95']:.1f} "
              f"ms | pool={snap['pool']['kind']} pages_hwm="
              f"{snap['pool']['pages_hwm']}/{snap['pool']['total_pages']} "
              f"| compiles={engine.compile_stats['compiles']}")
        if snap["spec"]["k"]:
            sp = snap["spec"]
            print(f"[serve] speculative: k={sp['k']} "
                  f"acceptance={sp['acceptance_rate']:.3f} "
                  f"({sp['accepted_draft_tokens']}/{sp['draft_tokens']} "
                  f"drafts) "
                  f"tokens/slot-tick={sp['tokens_per_slot_tick']:.3f}")
        if (shed or snap["preempted"] or snap["cancelled"]
                or snap["deadline_expired"] or faults is not None):
            inj = (f" | faults={faults.summary()}" if faults is not None
                   else "")
            print(f"[serve] lifecycle: preempted={snap['preempted']} "
                  f"(recompute={snap['recompute_tokens']} tok) "
                  f"shed={shed} cancelled={snap['cancelled']} "
                  f"deadline_expired={snap['deadline_expired']}{inj}")
    else:
        router = Router(engines)
        with router:
            flusher = start_flusher(router.telemetry)
            futs, shed = trace.replay(router.submit, items,
                                      request_kw={"extras": extras})
            for fut in futs:
                show(fut)
            stop_flush.set()
            if flusher is not None:
                flusher.join(timeout=10)
        out = rsnap = router.snapshot()
        print(f"[serve] router: {rsnap['requests_finished']} requests "
              f"over {rsnap['replicas']} replicas | dispatched="
              f"{[p['dispatched'] for p in rsnap['per_replica']]} "
              f"requeued={rsnap['requeued']} shed={shed} | ttft p50/p95 "
              f"{rsnap['ttft_ms']['p50']:.1f}/"
              f"{rsnap['ttft_ms']['p95']:.1f} ms | latency p50/p95 "
              f"{rsnap['latency_ms']['p50']:.1f}/"
              f"{rsnap['latency_ms']['p95']:.1f} ms | max_concurrent="
              f"{rsnap['max_concurrent_slots']}")
        for i, p in enumerate(rsnap["per_replica"]):
            e = p["engine"]
            print(f"  replica[{i}] finished="
                  f"{e['requests_finished']} occupancy="
                  f"{e['slot_occupancy']:.2f} pages_hwm="
                  f"{e['pool']['pages_hwm']}/{e['pool']['total_pages']} "
                  f"preempted={e['preempted']}")
    if args.metrics_json:
        _write_json_atomic(args.metrics_json, {
            "schema": "repro.serve/telemetry-1",
            "summary": out,
            "metrics": obs_registry.snapshot(),
        })
        print(f"[serve] wrote {args.metrics_json}")
    if args.trace_out:
        tracer.write_chrome_trace(args.trace_out)
        print(f"[serve] wrote {args.trace_out} "
              f"({len(tracer)} trace events)")


if __name__ == "__main__":
    main()
