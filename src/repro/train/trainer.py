"""Training loop: prefetched data, checkpoint/resume, straggler accounting.

The Trainer is deliberately host-side thin: all math lives in the jitted
step function; the loop does data, checkpoints, failure handling, logging.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import contextlib

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint.checkpointing import CheckpointManager
from repro.configs.base import ModelConfig, TrainConfig
from repro.data.pipeline import Prefetcher, SyntheticLM, for_model
from repro.kernels import context as exctx
from repro.kernels import tuning
from repro.models import lm
from repro.optim import optimizer as opt
from repro.runtime import pytree as pt
from repro.runtime import sharding as rsh
from repro.runtime.fault_tolerance import StragglerMonitor
from repro.train import steps as steps_lib


@dataclass(frozen=True)
class ExecutionRecord:
    """How the butterfly sites of a run actually executed.

    * ``context`` — the finalized :class:`ExecutionContext` the step
      function traced under (``None`` when the model has no butterfly
      sites).
    * ``backend`` — its resolved kernel backend ("dense" without butterfly
      sites).
    * ``tuning`` — autotuner decisions (block_b/segment per kernel cell)
      registered while this run traced; falls back to the process-wide
      registry (prefixed "process-wide:") when tracing hit a warm cache
      from an earlier run in the same process. Empty on jnp/dense paths.
    * ``mesh_layout`` — e.g. "data=8" or "pod=2,data=4"; "" single-device.
    """

    backend: str = "dense"
    tuning: str = ""
    mesh_layout: str = ""
    context: Optional[exctx.ExecutionContext] = None

    def describe(self) -> str:
        return (self.context.describe() if self.context is not None
                else "dense")


@dataclass
class TrainResult:
    steps_run: int
    losses: List[float]
    resumed_from: Optional[int]
    step_times: List[float] = field(default_factory=list)
    # the resolved execution policy of the run (supersedes the old
    # kernel_backend / kernel_tuning / mesh_layout fields, which live on as
    # read-only aliases below)
    execution: ExecutionRecord = field(default_factory=ExecutionRecord)

    @property
    def kernel_backend(self) -> str:
        """Alias for ``execution.backend`` (pre-ExecutionContext name)."""
        return self.execution.backend

    @property
    def kernel_tuning(self) -> str:
        """Alias for ``execution.tuning`` (pre-ExecutionContext name)."""
        return self.execution.tuning

    @property
    def mesh_layout(self) -> str:
        """Alias for ``execution.mesh_layout`` (pre-ExecutionContext name)."""
        return self.execution.mesh_layout


class Trainer:
    def __init__(self, model_cfg: ModelConfig, train_cfg: TrainConfig,
                 seq_len: int, global_batch: int,
                 data: Optional[SyntheticLM] = None):
        self.cfg = model_cfg
        self.tc = train_cfg
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.data = data or for_model(model_cfg, seq_len, global_batch,
                                      seed=train_cfg.seed)
        self.tx = steps_lib.make_optimizer(train_cfg)
        # Resolve the run's ExecutionContext up front — concrete backend and
        # a built mesh — and freeze it: the step function traces inside
        # `use_execution(self.exec_ctx)`, so "auto" can't re-resolve
        # differently at trace time and diverge from what TrainResult
        # reports. The train step differentiates through the sandwich, and
        # since the fused Pallas kernels carry custom_vjp backward passes
        # the fused path is safe to trace under grad — "auto" keeps it on
        # TPU end to end. Mesh construction (ButterflyConfig.mesh_shape
        # opts in) fails loudly here — with the XLA_FLAGS recipe in the
        # message — rather than mid-trace.
        bc = model_cfg.butterfly
        if bc is not None:
            self.exec_ctx = exctx.resolve_execution(
                default=exctx.ExecutionContext.from_butterfly_config(bc))
            self.kernel_backend = self.exec_ctx.backend
            self.mesh = self.exec_ctx.mesh
        else:
            self.exec_ctx = None
            self.kernel_backend = "dense"
            self.mesh = None
        self.step_fn = jax.jit(steps_lib.make_train_step(
            model_cfg, self.tx, train_cfg.microbatches),
            donate_argnums=(0, 1))
        self.ckpt = (CheckpointManager(train_cfg.checkpoint_dir,
                                       keep=train_cfg.keep_checkpoints)
                     if train_cfg.checkpoint_dir else None)

    def init_state(self, seed: int = 0):
        specs = lm.model_specs(self.cfg)
        params = pt.init_params(jax.random.PRNGKey(seed), specs)
        opt_state = self.tx.init(params)
        return params, opt_state

    def _sharding_scope(self):
        """Ambient contexts for trace/execution: the run's ExecutionContext
        (so every butterfly site sees the frozen policy) plus the sharding
        context when a mesh is configured; no-op for dense models."""
        stack = contextlib.ExitStack()
        if self.exec_ctx is not None:
            stack.enter_context(exctx.use_execution(self.exec_ctx))
        if self.mesh is not None:
            stack.enter_context(rsh.use_sharding(self.mesh))
        return stack

    def _mesh_layout(self) -> str:
        return self.exec_ctx.mesh_layout() if self.exec_ctx else ""

    def _put_batch(self, x: jnp.ndarray) -> jnp.ndarray:
        """Place a (batch, ...) array batch-sharded on the mesh's data axes
        (replicate when the batch doesn't divide them)."""
        spec = rsh.batch_axes(self.mesh, rsh.DEFAULT_RULES, x.shape[0])
        return jax.device_put(x, NamedSharding(self.mesh, spec))

    def _make_batch_arrays(self, batch: Dict[str, np.ndarray]
                           ) -> Dict[str, jnp.ndarray]:
        out = {k: jnp.asarray(v) for k, v in batch.items()}
        B = out["tokens"].shape[0]
        cfg = self.cfg
        rng = np.random.default_rng(1234)
        if cfg.frontend == "vision":
            out["frontend_embeds"] = jnp.asarray(rng.normal(
                size=(B, cfg.frontend_tokens, cfg.d_model)), jnp.float32)
        if cfg.n_enc_layers:
            out["frames"] = jnp.asarray(rng.normal(
                size=(B, cfg.enc_seq, cfg.d_model)), jnp.float32)
        if self.mesh is not None:
            out = {k: self._put_batch(v) for k, v in out.items()}
        return out

    def run(self, steps: int, params=None, opt_state=None,
            resume: bool = True) -> TrainResult:
        if params is None:
            params, opt_state = self.init_state(self.tc.seed)

        start_step = 0
        resumed_from = None
        if self.ckpt is not None and resume:
            tmpl = {"params": params, "opt": opt_state}
            s, tree, extra = self.ckpt.restore(tmpl)
            if s is not None:
                params = jax.tree_util.tree_map(
                    lambda t, a: jnp.asarray(a) if a is not None else t,
                    tmpl["params"], tree["params"],
                    is_leaf=lambda x: x is None)
                opt_state = jax.tree_util.tree_map(
                    lambda t, a: (jnp.asarray(a) if a is not None else None),
                    tmpl["opt"], tree["opt"], is_leaf=lambda x: x is None)
                start_step = s
                resumed_from = s

        tuning_before = set(tuning.cache_entries())
        prefetch = Prefetcher(self.data, start_step=start_step)
        straggler = StragglerMonitor(["host0"])
        losses: List[float] = []
        step_times: List[float] = []
        try:
            for i in range(start_step, start_step + steps):
                step_idx, raw = next(prefetch)
                batch = self._make_batch_arrays(raw)
                t0 = time.monotonic()
                # the sharding ctx must be live whenever the step function
                # (re)traces — butterfly sites read the active mesh then
                with self._sharding_scope():
                    params, opt_state, metrics = self.step_fn(
                        params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.monotonic() - t0
                straggler.record({"host0": dt})
                losses.append(loss)
                step_times.append(dt)
                if (self.ckpt is not None and self.tc.checkpoint_every
                        and (i + 1) % self.tc.checkpoint_every == 0):
                    self.ckpt.save(i + 1, {"params": params,
                                           "opt": opt_state},
                                   extra={"loss": loss}, async_=True)
        finally:
            prefetch.close()
            if self.ckpt is not None:
                self.ckpt.wait()
        self.params = params
        self.opt_state = opt_state
        # Tuning choices are made (and registered) at trace time. Report the
        # entries this run added; if tracing hit a warm registry (another
        # run with the same cells already happened in this process), fall
        # back to the full registry, marked as such. jnp/dense paths never
        # query the autotuner and report "".
        tuning_summary = ""
        if self.kernel_backend in ("pallas", "pallas_interpret"):
            entries = tuning.cache_entries()
            fresh = sorted(v for k, v in entries.items()
                           if k not in tuning_before)
            if fresh:
                tuning_summary = "; ".join(fresh)
            elif entries:
                tuning_summary = "process-wide: " + tuning.describe()
        return TrainResult(steps_run=steps, losses=losses,
                           resumed_from=resumed_from,
                           step_times=step_times,
                           execution=ExecutionRecord(
                               backend=self.kernel_backend,
                               tuning=tuning_summary,
                               mesh_layout=self._mesh_layout(),
                               context=self.exec_ctx))
