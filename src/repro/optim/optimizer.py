"""Optimizers, schedules and gradient transformations (pure JAX, no optax).

Implements the optax-style ``(init, update)`` GradientTransformation protocol
so transforms chain, but with a tiny surface owned by this repo. All state is
a pytree shardable like the params (ZeRO-style: optimizer state inherits the
parameter PartitionSpecs).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class GradientTransformation(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, Optional[PyTree]], Tuple[PyTree, PyTree]]


def _tree_map(f, *trees):
    # None marks frozen/non-trainable leaves; keep it as a leaf so tree
    # structures stay aligned between params, grads and optimizer state.
    return jax.tree_util.tree_map(f, *trees, is_leaf=lambda x: x is None)


def _is_trainable(x) -> bool:
    return jnp.issubdtype(jnp.asarray(x).dtype, jnp.inexact)


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------

def constant_schedule(lr: float) -> Callable[[jnp.ndarray], jnp.ndarray]:
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine_schedule(peak_lr: float, warmup_steps: int,
                           total_steps: int, final_frac: float = 0.1
                           ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(math.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule


def linear_warmup_schedule(peak_lr: float, warmup_steps: int
                           ) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        return peak_lr * jnp.minimum(1.0, step / max(warmup_steps, 1))
    return schedule


# ---------------------------------------------------------------------------
# Core transforms
# ---------------------------------------------------------------------------

class ScaleByAdamState(NamedTuple):
    count: jnp.ndarray
    mu: PyTree
    nu: PyTree


def scale_by_adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8
                  ) -> GradientTransformation:
    def init(params):
        def zeros():
            # distinct trees: mu/nu must not alias (buffer donation)
            return _tree_map(
                lambda p: jnp.zeros_like(p) if _is_trainable(p) else None,
                params)
        return ScaleByAdamState(count=jnp.zeros((), jnp.int32),
                                mu=zeros(), nu=zeros())

    def update(grads, state, params=None):
        count = state.count + 1
        mu = _tree_map(
            lambda g, m: None if m is None else b1 * m + (1 - b1) * g,
            grads, state.mu)
        nu = _tree_map(
            lambda g, v: None if v is None else b2 * v + (1 - b2) * g * g,
            grads, state.nu)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        updates = _tree_map(
            lambda m, v: None if m is None
            else (m / c1) / (jnp.sqrt(v / c2) + eps), mu, nu)
        return updates, ScaleByAdamState(count=count, mu=mu, nu=nu)

    return GradientTransformation(init, update)


class ClipState(NamedTuple):
    pass


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        return ClipState()

    def update(grads, state, params=None):
        leaves = [g for g in jax.tree_util.tree_leaves(grads)
                  if g is not None]
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in leaves))
        scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-12))
        return _tree_map(
            lambda g: None if g is None else g * scale, grads), state

    return GradientTransformation(init, update)


class ScaleByScheduleState(NamedTuple):
    count: jnp.ndarray


def scale_by_schedule(schedule) -> GradientTransformation:
    def init(params):
        return ScaleByScheduleState(count=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        lr = schedule(state.count)
        return (_tree_map(lambda g: None if g is None else -lr * g, grads),
                ScaleByScheduleState(count=state.count + 1))

    return GradientTransformation(init, update)


def add_decayed_weights(weight_decay: float) -> GradientTransformation:
    def init(params):
        return ClipState()

    def update(grads, state, params=None):
        if weight_decay == 0.0 or params is None:
            return grads, state
        return _tree_map(
            lambda g, p: None if g is None
            else g + weight_decay * (p.astype(g.dtype) if p.ndim > 1
                                     else jnp.zeros_like(g)),
            grads, params), state

    return GradientTransformation(init, update)


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


# ---------------------------------------------------------------------------
# User-facing optimizers
# ---------------------------------------------------------------------------

def adamw(learning_rate, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0,
          max_grad_norm: float = 0.0) -> GradientTransformation:
    schedule = (learning_rate if callable(learning_rate)
                else constant_schedule(learning_rate))
    parts = []
    if max_grad_norm:
        parts.append(clip_by_global_norm(max_grad_norm))
    parts.append(scale_by_adam(b1, b2, eps))
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay))
    parts.append(scale_by_schedule(schedule))
    return chain(*parts)


class MomentumState(NamedTuple):
    count: jnp.ndarray
    trace: PyTree


def sgd(learning_rate, momentum: float = 0.0) -> GradientTransformation:
    schedule = (learning_rate if callable(learning_rate)
                else constant_schedule(learning_rate))

    def init(params):
        trace = _tree_map(
            lambda p: jnp.zeros_like(p) if _is_trainable(p) else None, params)
        return MomentumState(count=jnp.zeros((), jnp.int32), trace=trace)

    def update(grads, state, params=None):
        lr = schedule(state.count)
        if momentum:
            trace = _tree_map(
                lambda g, t: None if t is None else momentum * t + g,
                grads, state.trace)
            updates = _tree_map(
                lambda t: None if t is None else -lr * t, trace)
        else:
            trace = state.trace
            updates = _tree_map(
                lambda g: None if g is None else -lr * g, grads)
        return updates, MomentumState(count=state.count + 1, trace=trace)

    return GradientTransformation(init, update)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return _tree_map(
        lambda p, u: p if u is None or not _is_trainable(p)
        else (p + u.astype(p.dtype)),
        params, updates)
