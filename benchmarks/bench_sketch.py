"""Paper Figures 7/8 + Table 4 (§6): learned butterfly sketch vs learned
sparse (IVY19), random CW, Gaussian, and the dense-N learned variant."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import butterfly as bf
from repro.core import sketch


def _datasets(n=64, d=48, t_train=24, t_test=8):
    out = {}
    rng = np.random.default_rng(0)
    # HS-SOD-like: smooth spectra + noise
    base = rng.normal(size=(n, d)) @ np.diag(np.linspace(1, 0.02, d))
    out["hyper_like"] = [jnp.asarray(base + 0.05 * rng.normal(size=(n, d)))
                         for _ in range(t_train + t_test)]
    # CIFAR-like: block-structured
    base2 = rng.normal(size=(n, 8)) @ rng.normal(size=(8, d))
    out["cifar_like"] = [jnp.asarray(base2 + 0.2 * rng.normal(size=(n, d)))
                         for _ in range(t_train + t_test)]
    return out, t_train


def run(steps: int = 120) -> None:
    data, t_train = _datasets()
    ell, k = 16, 8
    for name, Xs in data.items():
        train, test = Xs[:t_train], Xs[t_train:]
        n = train[0].shape[0]

        spec = sketch.make_spec(jax.random.PRNGKey(0), n=n, ell=ell, k=k)
        w, _ = sketch.train_butterfly_sketch(
            spec, jax.random.PRNGKey(1), train, steps=steps, lr=3e-3,
            batch=6)
        err_bfly = sketch.test_error(
            lambda X: sketch.butterfly_sketch(spec, w, X), test, k)

        rows, values, _ = sketch.train_sparse_sketch(
            jax.random.PRNGKey(2), train, n=n, ell=ell, k=k, steps=steps,
            lr=3e-3, batch=6)
        Bs = sketch.sparse_sketch_matrix(rows, values, ell)
        err_sparse = sketch.test_error(lambda X: Bs @ X, test, k)

        rows0, signs0 = sketch.cw_pattern(jax.random.PRNGKey(3), n, ell)
        B0 = sketch.sparse_sketch_matrix(rows0, jnp.asarray(signs0), ell)
        err_cw = sketch.test_error(lambda X: B0 @ X, test, k)

        G = sketch.gaussian_sketch(jax.random.PRNGKey(4), n, ell)
        err_gauss = sketch.test_error(lambda X: G @ X, test, k)

        rowsN, valuesN, _ = sketch.train_sparse_sketch(
            jax.random.PRNGKey(5), train, n=n, ell=ell, k=k, steps=steps,
            lr=3e-3, nnz_per_col=ell, batch=6)
        BN = sketch.sparse_sketch_matrix(rowsN, valuesN, ell)
        err_dense = sketch.test_error(lambda X: BN @ X, test, k)

        emit(f"sketch/{name}_l{ell}_k{k}", 0.0,
             f"butterfly_learned={err_bfly:.4f};"
             f"sparse_learned={err_sparse:.4f};cw_random={err_cw:.4f};"
             f"gaussian={err_gauss:.4f};dense_learned_N{ell}={err_dense:.4f}")


def run_ell_sweep(steps: int = 80) -> None:
    """Figure 17: error vs ell at k=8."""
    data, t_train = _datasets()
    Xs = data["hyper_like"]
    train, test = Xs[:t_train], Xs[t_train:]
    n = train[0].shape[0]
    k = 8
    for ell in (8, 16, 32):
        spec = sketch.make_spec(jax.random.PRNGKey(ell), n=n, ell=ell, k=k)
        w, _ = sketch.train_butterfly_sketch(
            spec, jax.random.PRNGKey(ell + 1), train, steps=steps, lr=3e-3,
            batch=6)
        err_bfly = sketch.test_error(
            lambda X: sketch.butterfly_sketch(spec, w, X), test, k)
        rows, values, _ = sketch.train_sparse_sketch(
            jax.random.PRNGKey(ell + 2), train, n=n, ell=ell, k=k,
            steps=steps, lr=3e-3, batch=6)
        Bs = sketch.sparse_sketch_matrix(rows, values, ell)
        err_sparse = sketch.test_error(lambda X: Bs @ X, test, k)
        emit(f"sketch_ell/l{ell}_k{k}", 0.0,
             f"butterfly_learned={err_bfly:.4f};"
             f"sparse_learned={err_sparse:.4f}")


if __name__ == "__main__":
    run()
    run_ell_sweep()
