"""Decoration-time-safe hypothesis shim.

CI installs ``hypothesis`` (a declared dev dependency) and runs the real
property tests. The minimal container may not have it — importing it at
module scope used to kill collection of every test in the file, so this shim
substitutes stubs that merely mark the property tests as skipped while
letting the rest of the module collect and run.
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _DummyStrategy:
        """Inert stand-in for a strategy object: absorbs chained calls like
        ``st.integers(1, 8).map(f).filter(g)`` at decoration time."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    class _AnyStrategy:
        """Accepts any strategy constructor call at decoration time."""

        def __getattr__(self, name):
            return _DummyStrategy()

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        def deco(f):
            @pytest.mark.skip(reason="hypothesis not installed")
            def stub():
                pass

            # keep the original name for test reports; do NOT functools.wraps
            # (pytest would follow __wrapped__ and demand strategy args as
            # fixtures)
            stub.__name__ = f.__name__
            stub.__doc__ = f.__doc__
            return stub

        return deco
