"""Paper §5.2/§5.3: encoder-decoder butterfly network vs PCA / FJLT+PCA,
including two-phase learning and the Theorem 1 prediction.

Run: ``PYTHONPATH=src python examples/butterfly_autoencoder.py``
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core import encdec
from repro.kernels import use_execution


def main():
    n = d = 256
    rank, k = 32, 8
    rng = np.random.default_rng(0)
    U = np.linalg.qr(rng.normal(size=(n, rank)))[0]
    X = jax.numpy.asarray(
        (U @ rng.normal(scale=0.1, size=(rank, d))).astype(np.float32))

    spec = encdec.make_spec(jax.random.PRNGKey(0), n=n, d=d, k=k)
    params = encdec.init_params(jax.random.PRNGKey(1), spec)
    print(f"auto-encoder: n={n}, d={d}, k={k}, ell={spec.ell} "
          f"(butterfly encoder params ≈ {spec.ell}·{k} + 2n·log n)")

    pca = float(encdec.pca_loss(X, X, k))
    fjlt = float(encdec.fjlt_pca_loss(jax.random.PRNGKey(2), X, k,
                                      spec.ell))
    pred = float(encdec.theorem1_loss(spec, params["B"], X, X))
    print(f"PCA Δ_k                 : {pca:.5f}")
    print(f"FJLT+PCA (Prop. 4.1)    : {fjlt:.5f}")
    print(f"Theorem 1 prediction    : {pred:.5f}  (optimal loss, B frozen)")

    # one ambient ExecutionContext covers both phases — swap "jnp" for
    # "pallas" (TPU) or add mesh_shape=(8,) and nothing else changes
    with use_execution("jnp"):
        print("\n-- phase 1: train (D,E), B frozen at FJLT init --")
        p1, hist1 = encdec.train(spec, params, X, X, steps=500, lr=3e-3,
                                 train_B=False, log_every=100)
        print("  losses:", [f"{v:.4f}" for v in hist1])
        print("\n-- phase 2: fine-tune D, E and the butterfly B --")
        p2, hist2 = encdec.train(spec, p1, X, X, steps=300, lr=1e-3,
                                 train_B=True, log_every=100)
        print("  losses:", [f"{v:.4f}" for v in hist2])
    final = float(encdec.loss_fn(spec, p2, X, X))
    print(f"\nfinal loss {final:.5f} vs PCA {pca:.5f} "
          f"(paper §5.2: ≈ Δ_k for all k)")


if __name__ == "__main__":
    main()
