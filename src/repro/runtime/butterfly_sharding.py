"""Multi-device sharded execution of the fused butterfly kernels.

This is the distributed-runtime integration for :mod:`repro.kernels`: the
fused ``butterfly_matmul`` / ``sandwich_matmul`` / ``butterfly_linear_apply``
entry points wrapped in ``shard_map`` over the data-parallel mesh axes
(``("data",)`` on a single pod, ``("pod", "data")`` across pods):

  * **activations** are batch-sharded — the flattened leading axes of ``x``
    split across the data axes, each shard running the single-device fused
    kernel on its rows;
  * **stage weights stay replicated** — a butterfly layer is ``O(n log n)``
    parameters, tiny next to its activations, so every device holds the full
    ``(p, 2, n)`` stack (the ``stages``/``butterfly_pair``/``butterfly_n``
    and ``butterfly_core_*``/``butterfly_bias`` rules in
    :mod:`repro.runtime.sharding` say the same thing declaratively);
  * **weight gradients are psum'd**: the backward region runs the kernels'
    existing fused ``custom_vjp`` per shard (each shard sees only its batch
    rows, so its ``dw`` is a partial sum) and all-reduces the weight
    cotangents over the data axes before returning them replicated.

The psum lives in an explicit outer :func:`jax.custom_vjp` rather than in
``shard_map``'s transpose so the replicated-weight gradient semantics never
depend on per-version replication-checking behavior (``check_rep`` /
``check_vma``) — the same reason :mod:`repro.runtime.pipeline` disables the
check around its ppermute schedule.

Execution policy arrives as a finalized
:class:`repro.kernels.context.ExecutionContext` (``context.mesh`` is the
mesh to shard over); each shard runs the kernel under ``context.local()`` —
the same policy with the mesh stripped — which also keys the lru-cached
region closures, keeping jit cache keys stable.

Batch sizes that do not divide the data-axis product are zero-padded up to
the next multiple and sliced back after the region; the pad/slice pair is
linear, so autodiff routes zero cotangents through the padding rows and
gradients are exact (validated against the single-device jnp oracle in
``tests/test_sharding_butterfly.py`` on 8 simulated devices).
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.kernels import context as exctx
from repro.kernels import ops as kops
from repro.runtime.compat import shard_map_compat

__all__ = [
    "shard_map_compat",
    "data_axes",
    "shard_count",
    "shard_batch_apply",
    "sharded_butterfly_apply",
    "sharded_sandwich_apply",
    "sharded_butterfly_linear_apply",
]

# Candidate batch axes, outermost first — matches the DEFAULT_RULES "batch"
# entry in repro.runtime.sharding.
BATCH_AXIS_CANDIDATES: Tuple[str, ...] = ("pod", "data")


def data_axes(mesh: Optional[Mesh],
              axes: Optional[Sequence[str]] = None) -> Tuple[str, ...]:
    """Mesh axes to batch-shard over: the requested ``axes`` (default
    ``("pod", "data")``) filtered to axes the mesh actually has with size
    > 1. Empty tuple means "don't shard" (callers fall back to the
    single-device path)."""
    if mesh is None:
        return ()
    cand = BATCH_AXIS_CANDIDATES if axes is None else tuple(axes)
    return tuple(a for a in cand if mesh.shape.get(a, 1) > 1)


def shard_count(mesh: Mesh, axes: Sequence[str]) -> int:
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) \
        if axes else 1


def _shard_ctx(context: exctx.ContextLike,
               axes: Optional[Sequence[str]]):
    """(finalized ctx, per-shard local ctx, axes to shard over)."""
    ctx = exctx.resolve_execution(context)
    axes = data_axes(ctx.mesh, ctx.mesh_axes if axes is None else axes)
    return ctx, ctx.local(), axes


# ---------------------------------------------------------------------------
# Generic batch-sharded wrapper with explicit psum'd weight gradients
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _sharded_core(closure, x2, weights):
    """``closure = (fn, mesh, axes)``; ``fn(x_shard, weights) -> y_shard``
    on 2-D ``(rows, n)`` batches. All static pieces ride the hashable
    closure so jit caching keys on them."""
    fn, mesh, axes = closure
    wspecs = jax.tree_util.tree_map(lambda _: P(), weights)
    return shard_map_compat(
        fn, mesh=mesh, in_specs=(P(axes), wspecs),
        out_specs=P(axes))(x2, weights)


def _sharded_core_fwd(closure, x2, weights):
    # Residuals are (x2, weights): the inner kernels' custom_vjp recomputes
    # everything else from the input tile, so nothing extra crosses HBM.
    return _sharded_core(closure, x2, weights), (x2, weights)


def _sharded_core_bwd(closure, res, g2):
    fn, mesh, axes = closure
    x2, weights = res
    wspecs = jax.tree_util.tree_map(lambda _: P(), weights)

    def region(xl, gl, wl):
        _, vjp = jax.vjp(fn, xl, wl)
        dx, dw = vjp(gl)
        # each shard's dw is the partial sum over its batch rows — the fused
        # backward kernels already reduce over the local batch grid, so one
        # all-reduce over the data axes finishes the global reduction
        dw = jax.tree_util.tree_map(lambda d: jax.lax.psum(d, axes), dw)
        return dx, dw

    return shard_map_compat(
        region, mesh=mesh,
        in_specs=(P(axes), P(axes), wspecs),
        out_specs=(P(axes), wspecs))(x2, g2, weights)


_sharded_core.defvjp(_sharded_core_fwd, _sharded_core_bwd)


def shard_batch_apply(fn, x: jnp.ndarray, weights, mesh: Mesh,
                      axes: Sequence[str]) -> jnp.ndarray:
    """Run ``fn(x2, weights)`` with the flattened batch of ``x`` sharded
    over ``axes`` and ``weights`` replicated.

    ``fn`` maps ``(rows, n_in) -> (rows, n_out)`` and must be a stable
    (cached) callable — its identity is part of the jit cache key. Batches
    that don't divide the shard count are zero-padded and sliced back;
    leading axes of ``x`` are restored on the output.
    """
    nsh = shard_count(mesh, tuple(axes))
    lead = x.shape[:-1]
    b = int(np.prod(lead, dtype=np.int64)) if lead else 1
    x2 = x.reshape(b, x.shape[-1])
    padded_b = -(-b // nsh) * nsh
    if padded_b != b:
        x2 = jnp.pad(x2, ((0, padded_b - b), (0, 0)))
    y2 = _sharded_core((fn, mesh, tuple(axes)), x2, weights)
    return y2[:b].reshape(*lead, y2.shape[-1])


# ---------------------------------------------------------------------------
# Kernel-specific wrappers (cached closures keep jit keys stable; the
# per-shard ExecutionContext is hashable and part of the closure key)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _butterfly_fn(transpose, local_ctx):
    # the region runs the non-routing local dispatch: re-entering the public
    # entry point here would re-resolve the ambient context and try to
    # shard_map again from inside the shard
    def fn(x2, w):
        return kops._local_butterfly(x2, w, transpose=transpose,
                                     ctx=local_ctx)
    return fn


def sharded_butterfly_apply(x: jnp.ndarray, w: jnp.ndarray, *,
                            context: exctx.ContextLike,
                            axes: Optional[Sequence[str]] = None,
                            transpose: bool = False) -> jnp.ndarray:
    """Batch-sharded fused butterfly product (see module docstring)."""
    ctx, local_ctx, axes = _shard_ctx(context, axes)
    if not axes:
        return kops._local_butterfly(x, w, transpose=transpose,
                                     ctx=local_ctx)
    fn = _butterfly_fn(transpose, local_ctx)
    return shard_batch_apply(fn, x, w, ctx.mesh, axes)


@functools.lru_cache(maxsize=None)
def _sandwich_fn(scale_in, scale_out, local_ctx):
    def fn(x2, weights):
        b_in, sel_in, core, sel_out, b_out = weights
        return kops._local_sandwich(x2, b_in, sel_in, core, sel_out, b_out,
                                    scale_in=scale_in, scale_out=scale_out,
                                    ctx=local_ctx)
    return fn


def sharded_sandwich_apply(x: jnp.ndarray, b_in: jnp.ndarray,
                           sel_in: jnp.ndarray, core: jnp.ndarray,
                           sel_out: jnp.ndarray, b_out: jnp.ndarray, *,
                           context: exctx.ContextLike,
                           axes: Optional[Sequence[str]] = None,
                           scale_in: float = 1.0, scale_out: float = 1.0
                           ) -> jnp.ndarray:
    """Batch-sharded fused butterfly sandwich (see module docstring)."""
    ctx, local_ctx, axes = _shard_ctx(context, axes)
    if not axes:
        return kops._local_sandwich(x, b_in, sel_in, core, sel_out, b_out,
                                    scale_in=scale_in, scale_out=scale_out,
                                    ctx=local_ctx)
    fn = _sandwich_fn(scale_in, scale_out, local_ctx)
    return shard_batch_apply(fn, x, (b_in, sel_in, core, sel_out, b_out),
                             ctx.mesh, axes)


@functools.lru_cache(maxsize=None)
def _linear_fn(spec, local_ctx):
    # deferred import: core.layers routes back here when a mesh is set
    from repro.core import layers as blayers

    def fn(x2, params):
        return blayers._local_linear_apply(spec, params, x2, local_ctx)
    return fn


def sharded_butterfly_linear_apply(spec, params: dict, x: jnp.ndarray, *,
                                   context: exctx.ContextLike,
                                   axes: Optional[Sequence[str]] = None
                                   ) -> jnp.ndarray:
    """Batch-sharded whole-sandwich layer: padding, kernel dispatch and bias
    all run inside the shard_map region, so the bias gradient is psum'd with
    the other weights."""
    ctx, local_ctx, axes = _shard_ctx(context, axes)
    if not axes:
        from repro.core import layers as blayers
        return blayers._local_linear_apply(spec, params, x, local_ctx)
    fn = _linear_fn(spec, local_ctx)
    return shard_batch_apply(fn, x, dict(params), ctx.mesh, axes)
