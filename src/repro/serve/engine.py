"""Continuous-batching inference engine for the butterfly LMs.

The engine owns a fixed pool of ``slots`` decode lanes over ONE pooled
cache tree — a :class:`repro.serve.cache.CachePool`, paged by default
(``pool="paged"``, dense rows via ``pool="dense"`` for bisection; archs
with sequential-state blocks fall back to dense automatically) — and runs
a strict tick loop:

  1. **Admit** — while a slot is free and requests are queued, pop one and
     reserve pages for it. Under ``admission="eager"`` (the PR-6 policy,
     kept for bisection) the reservation is the request's *full* token
     budget (``alloc_pages(slot, n_front + prompt + max_new)``) —
     deadlock-free with no preemption path. Under
     ``admission="incremental"`` (vLLM's actual policy) only the prompt's
     pages are reserved; the decode budget is allocated page-by-page as
     the slot actually decodes (step 2b), so slots whose requests *could
     not all co-reside at full budget* still run concurrently. Either
     way a pool that cannot cover the reservation raises
     :class:`~repro.serve.cache.PoolExhausted` and the engine leaves the
     request queued, retrying after pages free — exhaustion is
     backpressure, never a crash.
  2b. **Grow / preempt** (incremental admission only) — before the compute
     ticks, every live slot's page table is grown to cover this tick's
     writes (the next decode position; ``+1`` for a prompt whose final
     chunk lands this tick), oldest slot first. When the pool exhausts
     mid-growth the engine *preempts its youngest slot*: frees its pages,
     re-queues the request at the queue head with its already-generated
     tokens appended to the prompt, and recomputes the whole prefix via
     the ordinary chunked-prefill path on re-admission. Greedy decoding
     is deterministic, so the resumed request's output is token-identical
     to the never-preempted run (CI-gated in ``tests/test_serve.py``).
  2. **Chunked prefill** (paged, full-attention archs) — admitted prompts
     are processed as fixed-size chunks (``prefill_chunk`` tokens) through
     ONE compiled pool-wide step (:func:`repro.train.steps.
     make_chunk_prefill_step`), interleaved with decode ticks, so a long
     prompt never stalls in-flight decodes and every prompt length shares
     a single compile. Archs the chunk path can't serve (vision frontend,
     encoders, sliding-window or cross-attention caches) admit through the
     PR-5 whole-bucket prefill instead, scattered into the pool via
     :meth:`CachePool.write_slot`.
  3. **Decode** — ONE fused pooled step (:func:`repro.train.steps.
     make_pool_serve_step`) advances every decoding slot by one token:
     per-slot positions, per-slot page tables (inactive lanes redirected
     to the trash page), per-slot active masks. Finished slots resolve
     their futures, free their pages for recycling, and the next tick's
     admission refills them — no stall, no re-batching barrier.
  3b. **Speculative decode** (``spec_k > 0``, greedy + paged + chunked
     prefill only) — draft-k-verify-1 replaces step 3: a near-free draft
     (the model's own output head — a fixed-structure butterfly sandwich
     on butterfly-compressed archs — over a residual-stream state
     advanced by embedding feedback; :func:`repro.train.steps.
     make_draft_step`) proposes ``spec_k`` tokens per slot, then ONE
     batched pass of the full model verifies all positions
     (:func:`repro.train.steps.make_spec_decode_step`) and each slot
     commits its accepted prefix — 1 to ``spec_k + 1`` tokens per tick.
     Rejected positions never advance ``cur_pos``, so their stale KV
     writes stay inert under the validity mask (the same invariant the
     trash page relies on), and greedy verification makes the committed
     stream token-identical to non-speculative decoding (CI-gated).

Requests are frozen :class:`Request` values — ``submit()`` takes exactly
one of them; the pre-paging positional ``submit(prompt, max_new_tokens=…)``
shape raises ``TypeError`` with the migration spelled out (repo policy
post-PR 5: renamed surfaces break loudly, no loose-kwarg shims).

Request lifecycle failures are *typed*, so callers can tell load-shedding
from bugs: :class:`QueueFull` (bounded admission queue, raised at
``submit``), :class:`DeadlineExceeded` (per-request ``deadline_ticks`` /
``deadline_s`` blown — queued or mid-decode, the slot and its pages free
immediately), :class:`RequestCancelled` (``cancel(rid)``), and the pool's
:class:`~repro.serve.cache.PoolExhausted` (internal backpressure, never
surfaced to a future). A :class:`repro.serve.faults.FaultInjector` passed
as ``faults=`` forces these paths on a seeded schedule.

Compilation is explicit: every jitted function lives in a
:class:`CompileCache` keyed on ``(kind, arch, shape/bucket, pool kind,
sampling, ExecutionContext)``, with a trace counter the tests gate on —
chunked admission traces ONE prefill for every prompt length; bucketed
admission traces once per bucket.

The engine is ExecutionContext-native: it resolves ONE context at
construction (explicit ``context=`` > ambient > the arch's
``ButterflyConfig``), traces everything inside ``use_execution`` (plus
``use_sharding`` when the context carries a mesh), so the same engine
serves on one CPU or batch-shards its butterfly sites across an 8-device
simulated mesh via :mod:`repro.runtime.butterfly_sharding`.

Threading model: ``submit()`` is thread-safe; ``step()`` /
``run_until_idle()`` must be driven from one thread (the
:class:`repro.serve.client.ServeClient` wraps exactly that driver thread
and hands out futures).
"""

from __future__ import annotations

import collections
import contextlib
import functools
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import (Callable, Dict, List, Mapping, Optional, Sequence,
                    Tuple)

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import context as exctx
from repro.obs.registry import MetricsRegistry
from repro.obs.tracing import NULL_TRACER, TRACK_ENGINE
from repro.runtime import sharding as rsh
from repro.serve import cache as cache_lib
from repro.serve import sampling as sampling_lib
from repro.serve.cache import PoolExhausted
from repro.serve.metrics import EngineMetrics
from repro.train import steps as steps_lib

# Block types whose caches mix positions sequentially (recurrent state) or
# ring-buffer by position: right-padded bucket prefill would fold the pads
# into the state, so these archs prefill at exact prompt lengths instead
# (one compile per distinct length — the trade the engine makes explicit).
SEQUENTIAL_STATE_BLOCKS = ("rec", "mlstm", "slstm", "local")


def _fmt_compile_key(key: Tuple) -> str:
    """Human/JSON-safe rendering of a compile key — ExecutionContext
    members render through their one-line ``describe()``."""
    return " | ".join(
        k.describe() if hasattr(k, "describe") else str(k) for k in key)


class CompileCache:
    """Explicit jit cache with a trace counter and structured events.

    ``get(key, build)`` memoizes the *compiled callable* per key;
    :meth:`counted_jit` wraps the pre-jit function so every retrace bumps
    ``traces[key]`` (the function body only executes while jax traces —
    cached executions never touch it). The serving tests gate on exactly
    this counter: one trace per (shape, context), ever.

    Cold compiles are structured events: the first call through a key is
    timed and emitted as a ``compile`` span on the tracer's engine lane
    (args carry the formatted key + wall seconds) and appended to
    ``events``, so compile storms are visible per-replica in the Chrome
    trace. The timing wrapper replaces itself after the first call, so
    warm calls pay nothing.
    """

    def __init__(self, tracer=None, pid: int = 0):
        self._fns: Dict[Tuple, Callable] = {}
        self.traces: Dict[Tuple, int] = {}
        self.events: List[Dict] = []
        self._tracer = tracer if tracer is not None else NULL_TRACER
        self._pid = int(pid)

    def get(self, key: Tuple, build: Callable[[], Callable]) -> Callable:
        fn = self._fns.get(key)
        if fn is None:
            inner = build()

            def cold(*args, __key=key, __inner=inner, **kwargs):
                t0 = time.monotonic()
                tt0 = self._tracer.now()
                out = __inner(*args, **kwargs)
                self._fns[__key] = __inner   # warm path: straight through
                dt = time.monotonic() - t0
                self.events.append(
                    {"key": _fmt_compile_key(__key),
                     "seconds": round(dt, 6)})
                self._tracer.complete(
                    "compile", tt0, self._tracer.now(), pid=self._pid,
                    tid=TRACK_ENGINE, cat="compile",
                    key=_fmt_compile_key(__key), seconds=round(dt, 6))
                return out

            fn = self._fns[key] = cold
        return fn

    def counted_jit(self, key: Tuple, fn: Callable, **jit_kw) -> Callable:
        def traced(*args, **kwargs):
            self.traces[key] = self.traces.get(key, 0) + 1
            return fn(*args, **kwargs)
        return jax.jit(traced, **jit_kw)

    @property
    def compiles(self) -> int:
        return len(self._fns)

    def keys(self) -> List[Tuple]:
        return list(self._fns)


class QueueFull(RuntimeError):
    """The bounded admission queue shed this submit (``queue_limit``
    queued requests already waiting). Typed so a client can distinguish
    load-shedding (retry later, against another replica) from a bug."""

    def __init__(self, limit: int):
        super().__init__(
            f"admission queue full ({limit} requests waiting); retry "
            f"later or raise queue_limit")
        self.limit = limit


class DeadlineExceeded(RuntimeError):
    """The request blew its ``deadline_ticks``/``deadline_s`` budget —
    queued or mid-decode — and was dropped, its slot and pages freed."""

    def __init__(self, rid: int, reason: str):
        super().__init__(f"request {rid} deadline exceeded: {reason}")
        self.rid = rid


class RequestCancelled(RuntimeError):
    """The request was cancelled via ``cancel(rid)`` before finishing."""

    def __init__(self, rid: int):
        super().__init__(f"request {rid} cancelled")
        self.rid = rid


_SUBMIT_MIGRATION = (
    "takes a single repro.serve.Request — the positional "
    "submit(prompt, max_new_tokens=..., stop_token=..., extras=...) form "
    "was removed. Migrate:\n"
    "    submit(Request(prompt=prompt, max_new_tokens=16,\n"
    "                   stop_token=None, extras=None))")


@dataclass(frozen=True, eq=False)
class Request:
    """One generation request — the frozen value ``submit()`` takes.

    ``prompt`` is normalized to a tuple of ints at construction (any int
    sequence/array is accepted). ``sampling=None`` means the engine-wide
    policy; a non-None value must equal it — the pooled decode step bakes
    sampling in at trace time, so heterogeneous per-request sampling is
    rejected loudly rather than silently ignored. ``rid=None`` lets the
    engine assign its sequence number; an explicit rid must be unique
    among live requests.

    Deadlines are measured from submission: ``deadline_ticks`` in the
    deterministic engine-tick clock (what tests assert against),
    ``deadline_s`` in wall seconds (what an operator's SLO means). A
    request past either resolves its future with
    :class:`DeadlineExceeded`, freeing its slot and pages — a stuck or
    abandoned caller can no longer hold capacity forever.
    """

    prompt: Tuple[int, ...]
    max_new_tokens: int = 16
    sampling: Optional[sampling_lib.SamplingParams] = None
    stop_token: Optional[int] = None
    extras: Optional[Mapping] = None       # frontend_embeds / frames
    rid: Optional[int] = None
    deadline_ticks: Optional[int] = None   # engine ticks after submit
    deadline_s: Optional[float] = None     # wall seconds after submit

    def __post_init__(self):
        prompt = tuple(int(t) for t in
                       np.asarray(self.prompt, np.int32).reshape(-1))
        object.__setattr__(self, "prompt", prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{self.max_new_tokens}")
        for name in ("deadline_ticks", "deadline_s"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be positive, got {v}")


@dataclass
class GenerationResult:
    """What a request's future resolves to."""

    rid: int
    prompt: np.ndarray
    tokens: List[int]                      # all generated tokens, in order
    metrics: object                        # RequestMetrics


@dataclass
class _Slot:
    """Host-side state of one occupied decode lane (or queued request).

    ``prompt`` is the *original* prompt the result reports;
    ``prefill_seq`` is what the next admission actually prefills — equal
    to ``prompt`` on first admission, ``prompt + tokens generated so
    far`` after a preemption (the recompute path). ``tokens`` survives
    preemption, so the resumed run appends where the kicked run stopped.
    """

    req: Request
    rid: int
    future: Future
    prompt: np.ndarray
    prefill_seq: np.ndarray = None         # defaults to prompt (submit)
    tokens: List[int] = field(default_factory=list)
    cur_pos: int = 0                       # absolute cache write position
    last_token: int = -1
    prefilled: int = -1                    # prefill_seq tokens chunk-
    #                                        prefilled so far; -1 = not in
    #                                        chunk phase
    admit_seq: int = -1                    # admission order; youngest =
    #                                        highest = preemption victim
    anchor: Optional[np.ndarray] = None    # (E,) pre-final-norm backbone
    #                                        state at the last committed
    #                                        input position — the draft
    #                                        state seed (spec_k > 0)
    trace_t0: float = 0.0                  # tracer timestamp of the last
    #                                        queue entry (submit / preempt
    #                                        requeue / adopt) — the start
    #                                        of the next "queue" span

    def __post_init__(self):
        if self.prefill_seq is None:
            self.prefill_seq = self.prompt

    @property
    def prefilling(self) -> bool:
        return 0 <= self.prefilled < self.prefill_seq.size

    @property
    def decoding(self) -> bool:
        return not self.prefilling


class ServeEngine:
    """Continuous-batching engine over a fixed decode-slot pool.

    * ``slots`` — decode lanes (the pooled batch size of the serve step).
    * ``max_len`` — per-slot token budget: every request must satisfy
      ``prompt_len + max_new_tokens <= max_len``.
    * ``pool`` — cache pool kind: ``"paged"`` (default; falls back to
      dense for sequential-state archs) or ``"dense"`` (the PR-5 layout,
      kept for bisection). See :mod:`repro.serve.cache`.
    * ``page_size`` / ``num_pages`` — paged-pool geometry; ``num_pages``
      defaults to dense-equivalent capacity plus the trash page.
    * ``prefill_chunk`` — chunked-prefill chunk size (paged, full-attention
      archs only; ``None``/0 disables chunking and admits through the
      whole-bucket path even on a paged pool).
    * ``sampling`` — engine-wide :class:`SamplingParams` (a trace-time
      constant of the serve step; greedy by default).
    * ``admission`` — page reservation policy: ``"eager"`` (default; the
      PR-6 whole-budget reservation, deadlock-free, no preemption) or
      ``"incremental"`` (prompt-only reservation + per-tick decode growth
      + preempt-youngest/recompute on exhaustion — vLLM's policy; needs
      the paged pool with chunked prefill, since recompute rides the
      chunked-prefill path).
    * ``spec_k`` — speculative decoding: number of draft tokens proposed
      per slot per tick (0 = off). Each decode tick drafts ``spec_k``
      tokens through the model's own output head (butterfly on
      butterfly-compressed archs), verifies all of them in ONE batched
      full-model pass, and commits the accepted prefix — 1 to
      ``spec_k + 1`` tokens per slot per tick. Requires greedy sampling
      (exactly lossless — acceptance only affects speed) and the paged
      pool with chunked prefill (the verify pass and the draft anchor
      ride that machinery).
    * ``queue_limit`` — bounded admission queue: a submit arriving while
      ``queue_limit`` requests already wait raises :class:`QueueFull`
      instead of growing the queue unboundedly. ``None`` = unbounded.
    * ``faults`` — optional :class:`repro.serve.faults.FaultInjector`;
      threaded into the page pool (``pool.alloc``) and the tick loop
      (``engine.tick``) so tests drive every recovery path on a seeded,
      reproducible schedule.
    * ``context`` — execution policy; resolved once here, exactly like the
      ``Trainer`` (explicit > ambient > ``cfg.butterfly`` > env/platform).
    * ``tracer`` — a :class:`repro.obs.Tracer` recording the span
      timeline (per-request lanes ``tid = rid + 1``, engine lane
      ``tid = 0``, process row ``pid = replica``). Default: the no-op
      :data:`~repro.obs.NULL_TRACER` — tracing off costs nothing
      measurable (gated by the ``serve/trace_e2e`` bench row).
    * ``registry`` — a :class:`repro.obs.MetricsRegistry` this engine
      registers its collectors into (callbacks reading the live
      counters, labelled ``{"replica": str(replica)}``); pass one shared
      registry across replicas for a single exposition surface. Default:
      a private registry (``engine.obs``).
    * ``replica`` — replica id: the trace ``pid`` and the ``replica``
      metric label.
    * ``scrub_freed_slots`` — re-init a slot's cache state when its request
      finishes; off by default since admission overwrites it anyway.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_len: int = 128, pool: str = "paged",
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = 16,
                 sampling: sampling_lib.SamplingParams = sampling_lib.GREEDY,
                 admission: str = "eager", spec_k: int = 0,
                 queue_limit: Optional[int] = None,
                 faults=None,
                 context: exctx.ContextLike = None, seed: int = 0,
                 min_bucket: int = 8, scrub_freed_slots: bool = False,
                 tracer=None, registry: Optional[MetricsRegistry] = None,
                 replica: int = 0):
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        if admission not in ("eager", "incremental"):
            raise ValueError(f"unknown admission policy {admission!r}: "
                             f"expected 'eager' or 'incremental'")
        if queue_limit is not None and queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1 or None, got "
                             f"{queue_limit}")
        self.cfg = cfg
        self.slots = slots
        self.max_len = int(max_len)
        self.sampling = sampling
        self.min_bucket = int(min_bucket)
        self.scrub_freed_slots = scrub_freed_slots
        self.ctx = exctx.resolve_execution(
            context,
            default=exctx.ExecutionContext.from_butterfly_config(
                cfg.butterfly))
        self.mesh = self.ctx.mesh
        self._params = params
        self._n_front = (cfg.frontend_tokens if cfg.frontend == "vision"
                         else 0)
        types = set(cfg.block_unit) | set(cfg.tail_layers)
        self._exact_buckets = bool(types & set(SEQUENTIAL_STATE_BLOCKS))
        self.pool = cache_lib.make_pool(cfg, slots, self.max_len,
                                        kind=pool, page_size=page_size,
                                        num_pages=num_pages)
        self.prefill_chunk = (
            int(prefill_chunk)
            if (prefill_chunk and self.pool.kind == "paged"
                and cache_lib.chunked_prefill_supported(cfg)) else None)
        self.admission = admission
        if admission == "incremental" and (
                self.pool.kind != "paged" or self.prefill_chunk is None):
            raise ValueError(
                "admission='incremental' needs the paged pool with chunked "
                "prefill (preempted requests recompute through the chunk "
                f"path); this engine resolved pool={self.pool.kind!r}, "
                f"prefill_chunk={self.prefill_chunk!r} — use "
                "admission='eager' for this arch/pool")
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        self.spec_k = int(spec_k)
        if self.spec_k:
            if not sampling.greedy:
                raise ValueError(
                    "spec_k > 0 requires greedy sampling (temperature=0): "
                    "speculative verification commits the model's argmax "
                    "targets, which is only lossless under greedy — got "
                    f"{sampling}")
            if self.pool.kind != "paged" or self.prefill_chunk is None:
                raise ValueError(
                    "spec_k > 0 needs the paged pool with chunked prefill "
                    "(the multi-position verify pass and the draft anchor "
                    "ride the chunk machinery); this engine resolved "
                    f"pool={self.pool.kind!r}, "
                    f"prefill_chunk={self.prefill_chunk!r} — use spec_k=0 "
                    "for this arch/pool")
        self.queue_limit = queue_limit
        self.faults = faults
        self.pool.faults = faults
        self._caches = self.pool.init()
        self._slots: List[Optional[_Slot]] = [None] * slots
        self._queue: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._next_rid = 0
        self._admit_seq = 0
        self._cancels: set = set()
        self._key = jax.random.PRNGKey(seed)
        self.replica = int(replica)
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.obs = registry if registry is not None else MetricsRegistry()
        self._name_tracks()
        self.compile_cache = CompileCache(tracer=self.tracer,
                                          pid=self.replica)
        self.metrics = self._fresh_metrics()
        self._sample_fn = functools.partial(sampling_lib.sample_logits,
                                            params=sampling)
        self._tick_hist = self.obs.histogram(
            "serve_tick_seconds", "wall time per engine tick",
            labels={"replica": str(self.replica)})
        self._register_obs()

    def _fresh_metrics(self, history: int = 1024) -> EngineMetrics:
        return EngineMetrics(slots=self.slots, max_request_history=history,
                             pool_kind=self.pool.kind,
                             admission=self.admission,
                             total_pages=self.pool.total_pages,
                             spec_k=self.spec_k)

    # -- observability ------------------------------------------------

    def _name_tracks(self) -> None:
        self.tracer.name_process(
            self.replica, f"replica {self.replica} · {self.cfg.name}")
        self.tracer.name_track(self.replica, TRACK_ENGINE, "engine")

    def _register_obs(self) -> None:
        """Register this engine's collectors into ``self.obs``.

        Everything is a callback closing over ``self`` — NOT over the
        current ``EngineMetrics`` object — so ``reset_metrics()``'s
        object swap is transparently reflected, and recording costs the
        hot path nothing (values are read lazily at collection time).
        Re-registering under the same ``(name, labels)`` replaces the
        old callback, so rebuilding an engine against a shared registry
        (checkpoint swap, tests) never errors.
        """
        reg = self.obs
        labels = {"replica": str(self.replica)}

        def counter(name, fn, help):
            reg.register_callback(name, fn, mtype="counter", help=help,
                                  labels=labels)

        def gauge(name, fn, help):
            reg.register_callback(name, fn, mtype="gauge", help=help,
                                  labels=labels)

        counter("serve_ticks_total", lambda: self.metrics.ticks,
                "engine ticks (the deterministic clock)")
        counter("serve_requests_finished_total",
                lambda: self.metrics.requests_finished,
                "requests finished (lifetime)")
        counter("serve_finished_tokens_total",
                lambda: self.metrics.finished_tokens,
                "tokens over finished requests (lifetime)")
        counter("serve_decode_steps_total",
                lambda: self.metrics.decode_steps,
                "pooled decode tick invocations")
        counter("serve_decode_tokens_total",
                lambda: self.metrics.decode_tokens,
                "tokens emitted by pooled decode ticks")
        counter("serve_prefills_total", lambda: self.metrics.prefills,
                "prompts prefilled")
        counter("serve_prefill_tokens_total",
                lambda: self.metrics.prefill_tokens,
                "prompt tokens processed (pre-padding)")
        counter("serve_chunk_ticks_total",
                lambda: self.metrics.chunk_ticks,
                "chunked-prefill pool invocations")
        counter("serve_preempted_total", lambda: self.metrics.preempted,
                "slots kicked mid-flight for pages")
        counter("serve_recompute_tokens_total",
                lambda: self.metrics.recompute_tokens,
                "already-computed tokens re-prefilled after preemption")
        counter("serve_cancelled_total", lambda: self.metrics.cancelled,
                "requests cancelled by the client")
        counter("serve_deadline_expired_total",
                lambda: self.metrics.deadline_expired,
                "requests failed on their deadline")
        counter("serve_rejected_queue_full_total",
                lambda: self.metrics.rejected_queue_full,
                "submits shed by the bounded queue")
        counter("serve_pool_exhausted_total",
                lambda: self.metrics.pool_exhausted_events,
                "admissions/growth deferred or kicked on PoolExhausted")
        counter("serve_spec_ticks_total", lambda: self.metrics.spec_ticks,
                "speculative decode pool invocations")
        counter("serve_spec_draft_tokens_total",
                lambda: self.metrics.draft_tokens,
                "draft proposals into the verify pass")
        counter("serve_spec_accepted_draft_tokens_total",
                lambda: self.metrics.accepted_draft_tokens,
                "draft proposals that survived verification")
        counter("serve_decode_time_seconds_total",
                lambda: self.metrics.decode_time_s,
                "wall seconds inside pooled decode calls")
        counter("serve_prefill_time_seconds_total",
                lambda: self.metrics.prefill_time_s,
                "wall seconds inside prefill calls")
        counter("serve_compiles_total",
                lambda: self.compile_cache.compiles,
                "cold compiles through the CompileCache")
        counter("serve_compile_traces_total",
                lambda: sum(self.compile_cache.traces.values()),
                "jit (re)traces across all compile keys")
        counter("serve_trace_dropped_total", lambda: self.tracer.dropped,
                "trace events evicted from the bounded ring")
        gauge("serve_slots", lambda: self.slots, "decode lanes")
        gauge("serve_occupied_slots", lambda: self.occupied_slots(),
              "lanes currently holding an admitted request")
        gauge("serve_queue_depth", lambda: self.queued(),
              "requests waiting for admission")
        gauge("serve_max_concurrent_slots",
              lambda: self.metrics.max_concurrent_slots,
              "high-water mark of occupied slots")
        gauge("serve_spec_k", lambda: self.spec_k,
              "draft tokens proposed per slot tick (0 = off)")
        gauge("serve_pages_total", lambda: self.pool.total_pages,
              "physical pages incl. the trash page")
        gauge("serve_pages_in_use", lambda: self.pool.pages_in_use,
              "pages currently allocated to slots")
        gauge("serve_pages_hwm", lambda: self.pool.pages_hwm,
              "allocator high-water mark (rebased by reset_metrics)")
        gauge("serve_trace_events", lambda: len(self.tracer),
              "events currently buffered in the trace ring")
        inj = self.faults
        if inj is not None and hasattr(inj, "calls") \
                and hasattr(inj, "fired"):
            from repro.serve.faults import SITES
            for site in SITES:
                reg.register_callback(
                    "serve_fault_calls_total",
                    (lambda s=site: self.faults.calls.get(s, 0)),
                    mtype="counter",
                    help="instrumented fault-site checks",
                    labels={**labels, "site": site})
                reg.register_callback(
                    "serve_fault_fired_total",
                    (lambda s=site: self.faults.fired.get(s, 0)),
                    mtype="counter",
                    help="fault-site checks that fired",
                    labels={**labels, "site": site})

    def telemetry(self) -> Dict:
        """The unified telemetry document: the registry snapshot (ONE
        schema across engine/pool/faults/compile-cache) plus the
        human-oriented summary dict."""
        return {"schema": "repro.serve/telemetry-1",
                "summary": self.metrics.snapshot(),
                "metrics": self.obs.snapshot()}

    # -- execution scope ----------------------------------------------

    def _scope(self):
        """Ambient contexts live whenever a jitted fn may (re)trace: the
        frozen ExecutionContext, plus the sharding ctx for a mesh — the
        Trainer's exact pattern."""
        stack = contextlib.ExitStack()
        stack.enter_context(exctx.use_execution(self.ctx))
        if self.mesh is not None:
            stack.enter_context(rsh.use_sharding(self.mesh))
        return stack

    # -- compiled steps ------------------------------------------------

    def bucket_for(self, prompt_len: int) -> int:
        """Prefill bucket for a prompt: next power of two (>= min_bucket,
        <= max_len), or the exact length for sequential-state archs where
        padded prefill would corrupt the state."""
        if self._exact_buckets:
            return prompt_len
        b = self.min_bucket
        while b < prompt_len:
            b *= 2
        return min(b, self.max_len)

    def _prefill_fn(self, bucket: int) -> Callable:
        key = ("prefill", self.cfg.name, bucket, 1, self.ctx)
        return self.compile_cache.get(key, lambda: (
            self.compile_cache.counted_jit(
                key, steps_lib.make_bucket_prefill_step(self.cfg,
                                                        self.max_len))))

    def _chunk_fn(self) -> Callable:
        key = ("chunk_prefill", self.cfg.name, self.slots,
               self.prefill_chunk, self.ctx)
        return self.compile_cache.get(key, lambda: (
            self.compile_cache.counted_jit(
                key, steps_lib.make_chunk_prefill_step(self.cfg),
                donate_argnums=(2,))))

    def _decode_fn(self) -> Callable:
        key = ("decode", self.cfg.name, self.slots, self.pool.kind,
               self.sampling, self.ctx)
        return self.compile_cache.get(key, lambda: (
            self.compile_cache.counted_jit(
                key,
                steps_lib.make_pool_serve_step(
                    self.cfg, self._sample_fn,
                    paged=(self.pool.kind == "paged")),
                donate_argnums=(2,))))

    def _spec_verify_fn(self) -> Callable:
        key = ("spec_verify", self.cfg.name, self.slots, self.spec_k,
               self.ctx)
        return self.compile_cache.get(key, lambda: (
            self.compile_cache.counted_jit(
                key, steps_lib.make_spec_decode_step(self.cfg, self.spec_k),
                donate_argnums=(2,))))

    def _draft_fn(self) -> Callable:
        key = ("spec_draft", self.cfg.name, self.slots, self.spec_k,
               self.ctx)
        return self.compile_cache.get(key, lambda: (
            self.compile_cache.counted_jit(
                key, steps_lib.make_draft_step(self.cfg, self.spec_k))))

    def _insert_fn(self) -> Callable:
        key = ("insert", self.cfg.name, self.slots, self.pool.kind,
               self.ctx)
        if self.pool.kind == "paged":
            def build():
                return self.compile_cache.counted_jit(
                    key,
                    lambda caches, sub, slot, page_row:
                        self.pool.write_slot(caches, sub, slot, page_row),
                    donate_argnums=(0,))
        else:
            def build():
                return self.compile_cache.counted_jit(
                    key,
                    lambda caches, sub, slot:
                        self.pool.write_slot(caches, sub, slot),
                    donate_argnums=(0,))
        return self.compile_cache.get(key, build)

    def _reset_fn(self) -> Callable:
        key = ("reset", self.cfg.name, self.slots, self.pool.kind,
               self.ctx)
        if self.pool.kind == "paged":
            def build():
                return self.compile_cache.counted_jit(
                    key,
                    lambda caches, slot, page_row:
                        self.pool.reset_slot(caches, slot, page_row),
                    donate_argnums=(0,))
        else:
            def build():
                return self.compile_cache.counted_jit(
                    key,
                    lambda caches, slot: self.pool.reset_slot(caches, slot),
                    donate_argnums=(0,))
        return self.compile_cache.get(key, build)

    def _first_token_fn(self) -> Callable:
        key = ("sample", self.cfg.name, self.sampling, self.ctx)
        return self.compile_cache.get(key, lambda: (
            self.compile_cache.counted_jit(key, self._sample_fn)))

    # -- client surface ------------------------------------------------

    def submit(self, request: Request, *legacy_args, **legacy_kwargs
               ) -> Future:
        """Queue a :class:`Request`; returns a future resolving to a
        :class:`GenerationResult`. Thread-safe."""
        if not isinstance(request, Request) or legacy_args or legacy_kwargs:
            raise TypeError(f"ServeEngine.submit() {_SUBMIT_MIGRATION}")
        plen = len(request.prompt)
        if plen + request.max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt_len {plen} + max_new_tokens "
                f"{request.max_new_tokens} exceeds the engine's per-slot "
                f"budget max_len={self.max_len}")
        if (request.sampling is not None
                and request.sampling != self.sampling):
            raise ValueError(
                "per-request sampling must match the engine-wide policy "
                f"(engine: {self.sampling}, request: {request.sampling}) — "
                "sampling is a trace-time constant of the pooled decode "
                "step; run a second engine for a different policy")
        if isinstance(self.pool, cache_lib.PagedCachePool):
            need = self.pool.pages_for(
                self._n_front + plen + request.max_new_tokens)
            usable = self.pool.total_pages - 1
            if need > usable:
                raise ValueError(
                    f"request needs {need} pages but the pool only has "
                    f"{usable} usable pages — it could never be admitted "
                    f"(raise num_pages or lower the request budget)")
        with self._lock:
            if (self.queue_limit is not None
                    and len(self._queue) >= self.queue_limit):
                # bounded queue: shed load with a typed error the caller
                # can retry on, instead of queueing unboundedly
                self.metrics.on_queue_full()
                self.tracer.instant("shed", pid=self.replica,
                                    tid=TRACK_ENGINE, reason="queue_full",
                                    prompt_len=plen)
                raise QueueFull(self.queue_limit)
            if request.rid is None:
                rid = self._next_rid
            else:
                rid = int(request.rid)
                if self.metrics.request(rid) is not None:
                    raise ValueError(f"rid {rid} is already in flight")
            self._next_rid = max(self._next_rid, rid) + 1
            slot = _Slot(req=request, rid=rid, future=Future(),
                         prompt=np.asarray(request.prompt, np.int32))
            slot.trace_t0 = self.tracer.now()
            self.metrics.on_submit(rid, slot.prompt.size)
            self._queue.append(slot)
        return slot.future

    def has_work(self) -> bool:
        with self._lock:
            queued = bool(self._queue)
        return queued or any(s is not None for s in self._slots)

    def occupied_slots(self) -> int:
        """Decode lanes currently holding an admitted request."""
        return sum(s is not None for s in self._slots)

    def queued(self) -> int:
        """Requests waiting for admission (thread-safe)."""
        with self._lock:
            return len(self._queue)

    def outstanding(self) -> int:
        """Queued + in-flight requests — the load signal a router's
        least-outstanding-requests dispatch reads (thread-safe)."""
        return self.queued() + self.occupied_slots()

    # -- router surface (driver-thread only, except where noted) --------

    def drain_queued(self) -> List[Tuple["_Slot", object]]:
        """Pop every not-yet-admitted request off the queue, returning
        ``(slot, record)`` pairs for :meth:`adopt` on another replica.

        The internal slot travels whole so preemption-recompute state
        (``prefill_seq`` carrying already-generated tokens) survives the
        move; the metrics record is evicted here and re-registered by
        ``adopt`` so TTFT/latency still span from the original submit.
        Must run on the tick-driver thread: admission peeks the queue
        head and pops it in two lock sections, so stealing the queue
        from another thread could race a mid-admission pop.
        """
        with self._lock:
            stolen = list(self._queue)
            self._queue.clear()
        return [(s, self.metrics.evict(s.rid)) for s in stolen]

    def adopt(self, slot: "_Slot", record=None, *, front: bool = False
              ) -> None:
        """Enqueue a slot drained from another replica — same
        :class:`Request`, same ``Future``, same generated-token state.

        The request was already admitted by the tier, so the bounded
        ``queue_limit`` does not apply (shedding it here would drop work
        the client was promised). Assumes replica geometry is uniform
        (same ``max_len``; chunked prefill wherever preempted slots may
        move) — the :class:`repro.serve.router.Router` constructor
        enforces this. Thread-safe.
        """
        budget = int(slot.prompt.size) + slot.req.max_new_tokens
        if budget > self.max_len:
            raise ValueError(
                f"adopted request {slot.rid} needs {budget} tokens but "
                f"this replica's max_len is {self.max_len} — router "
                f"replicas must have uniform geometry")
        with self._lock:
            if (self.metrics.request(slot.rid) is not None
                    or any(s.rid == slot.rid for s in self._queue)):
                raise ValueError(f"rid {slot.rid} is already live on "
                                 f"this replica")
            self._next_rid = max(self._next_rid, slot.rid + 1)
            # the queue span restarts on THIS replica's tracer timeline
            # (timestamps are per-tracer epochs, not transferable)
            slot.trace_t0 = self.tracer.now()
            if record is not None:
                self.metrics.adopt(record)
            else:
                self.metrics.on_submit(slot.rid, int(slot.prompt.size))
            if front:
                self._queue.appendleft(slot)
            else:
                self._queue.append(slot)

    def set_params(self, params) -> None:
        """Hot-swap the model parameters (checkpoint swap on a drained
        replica). Compiled steps are pure functions of the param arrays,
        so no retrace happens as long as shapes/dtypes match — which the
        loader's template-validated restore guarantees. Refuses to swap
        under live requests: a mid-flight swap would splice two
        checkpoints into one output stream."""
        if self.has_work():
            raise RuntimeError(
                "set_params with requests queued or in flight — drain "
                "this engine first (Router.drain + wait_drained)")
        self._params = params

    def abort_all(self, exc: BaseException) -> None:
        """Fail every queued and in-flight request with ``exc``.

        The crash path: when a tick raises (bad extras, an arch the pool
        can't serve, a device error), whoever drives the loop calls this so
        every outstanding future resolves with the real error instead of
        hanging until its timeout. The pool is left empty (pages freed for
        recycling); the engine itself stays usable for new submissions.
        """
        with self._lock:
            dead = list(self._queue)
            self._queue.clear()
            self._cancels.clear()
        for i, s in enumerate(self._slots):
            if s is not None:
                self._slots[i] = None
                self._release_slot(i)
                dead.append(s)
        self.metrics.sync_pool(self.pool)
        if dead:
            self.tracer.instant("abort", pid=self.replica,
                                tid=TRACK_ENGINE, count=len(dead),
                                error=repr(exc))
        for s in dead:
            self.metrics.evict(s.rid)
            if not s.future.done():
                s.future.set_exception(exc)

    def cancel(self, rid: int) -> bool:
        """Request cancellation of a queued or in-flight request.

        Thread-safe. Returns ``True`` when ``rid`` is currently queued or
        occupying a slot; the cancellation is processed at the next tick
        boundary (slot and pool state belong to the single driver
        thread): the request's future resolves with
        :class:`RequestCancelled` and its slot + pages free right there —
        an abandoned request can no longer hold capacity. A rid that
        finishes between this call and the boundary is a harmless no-op.
        """
        with self._lock:
            known = any(s.rid == rid for s in self._queue)
        known = known or any(s is not None and s.rid == rid
                             for s in self._slots)
        if not known:
            return False
        with self._lock:
            self._cancels.add(rid)
        return True

    def active_requests(self) -> List[int]:
        return [s.rid for s in self._slots if s is not None]

    @property
    def compile_stats(self) -> Dict:
        return {"compiles": self.compile_cache.compiles,
                "traces": dict(self.compile_cache.traces)}

    def reset_metrics(self) -> None:
        """Fresh metrics (tick clock included) without touching compiled
        state or the pool's *allocations* — a benchmark warms every
        bucket, resets, then measures a compile-free steady state. Only
        valid while no request is in flight (in-flight RequestMetrics
        would be orphaned).

        Rebases everything burn-in could have inflated: the pool's
        high-water stats (``pages_hwm`` used to survive reset through
        ``sync_pool`` re-importing the allocator's stale ``_hwm`` — the
        regression test in ``tests/test_obs.py`` pins the fix) and the
        tracer ring (burn-in spans would pollute the exported timeline).
        Registry callbacks read through ``self``, so the object swap is
        invisible to the unified telemetry surface.
        """
        if self.has_work():
            raise RuntimeError("reset_metrics with requests in flight")
        self.pool.reset_stats()
        self.tracer.clear()
        self._name_tracks()          # clear() drops the track-name maps
        self.metrics = self._fresh_metrics(
            history=self.metrics.max_request_history)
        self.metrics.sync_pool(self.pool)

    # -- the tick loop -------------------------------------------------

    def step(self) -> int:
        """One engine tick: process cancellations and deadlines, admit
        into free slots, grow/preempt page tables (incremental
        admission), advance chunked prefills by one chunk, then one
        pooled decode. Returns the number of slots still active after
        the tick."""
        tick = self.metrics.ticks
        t_wall = time.monotonic()
        tt0 = self.tracer.now()
        self._process_cancels()
        self._expire_deadlines()
        self._admit()
        self.metrics.on_occupancy(
            sum(s is not None for s in self._slots))
        if self.faults is not None:
            # the mid-tick crash site: admissions landed, compute has not
            # run — exactly where a device error would strand futures if
            # the driver's abort path were broken
            self.faults.check("engine.tick")
        if self.admission == "incremental":
            self._grow_pages()
        if self.prefill_chunk is not None:
            self._chunk_tick()
        if any(s is not None and s.decoding for s in self._slots):
            self._decode_tick()
        self.metrics.on_tick()
        active = sum(s is not None for s in self._slots)
        self.tracer.complete("tick", tt0, self.tracer.now(),
                             pid=self.replica, tid=TRACK_ENGINE,
                             tick=tick, active=active)
        self._tick_hist.observe(time.monotonic() - t_wall)
        return active

    def run_until_idle(self, max_ticks: int = 100_000) -> int:
        """Drive ticks until queue and pool drain; returns ticks spent."""
        start = self.metrics.ticks
        while self.has_work():
            self.step()
            if self.metrics.ticks - start > max_ticks:
                raise RuntimeError(
                    f"engine did not drain within {max_ticks} ticks "
                    f"(active={self.active_requests()})")
        return self.metrics.ticks - start

    # -- internals -----------------------------------------------------

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self._slots):
            if s is None:
                return i
        return None

    def _admit(self) -> None:
        while True:
            idx = self._free_slot()
            if idx is None:
                return
            with self._lock:
                if not self._queue:
                    return
                slot = self._queue[0]
            if self.admission == "incremental":
                # prompt-only reservation; the decode budget grows page-
                # by-page in _grow_pages as the slot actually decodes
                budget = self._n_front + int(slot.prefill_seq.size)
            else:
                budget = (self._n_front + int(slot.prefill_seq.size)
                          + slot.req.max_new_tokens)
            try:
                self.pool.alloc_pages(idx, budget)
            except PoolExhausted:
                # keep FIFO order: the head request waits for pages freed
                # by finishing slots; admission retries every tick
                self.metrics.on_pool_exhausted()
                return
            with self._lock:
                self._queue.popleft()
            self.metrics.sync_pool(self.pool)
            self._admit_one(slot, idx)

    def _admit_one(self, slot: _Slot, idx: int) -> None:
        self.metrics.on_admit(slot.rid)
        tid = slot.rid + 1
        tnow = self.tracer.now()
        self.tracer.name_track(self.replica, tid, f"req {slot.rid}")
        self.tracer.complete("queue", slot.trace_t0, tnow,
                             pid=self.replica, tid=tid, rid=slot.rid,
                             resume=bool(slot.tokens))
        self.tracer.instant("admit", pid=self.replica, tid=tid, ts=tnow,
                            rid=slot.rid, slot=idx,
                            tick=self.metrics.ticks)
        slot.admit_seq = self._admit_seq
        self._admit_seq += 1
        if self.prefill_chunk is not None:
            # chunked admission: no prefill work here — the chunk tick(s)
            # stream the prompt through the pool
            slot.prefilled = 0
            self._slots[idx] = slot
            return
        self._admit_bucketed(slot, idx)

    def _admit_bucketed(self, slot: _Slot, idx: int) -> None:
        """Whole-prompt admission (dense pools and non-chunkable archs):
        right-pad to a bucket, prefill at batch 1, splice into the pool.
        Prefills ``prefill_seq`` (== ``prompt`` except after a preemption)
        so a resumed slot recomputes its full prefix."""
        req = slot.req
        plen = int(slot.prefill_seq.size)
        bucket = self.bucket_for(plen)
        tokens = np.zeros((1, bucket), np.int32)
        tokens[0, :plen] = slot.prefill_seq
        batch = {"tokens": jnp.asarray(tokens)}
        if req.extras:
            batch.update({k: jnp.asarray(v) for k, v in req.extras.items()})
        last_pos = jnp.asarray([plen - 1], jnp.int32)
        t0 = time.monotonic()
        tt0 = self.tracer.now()
        with self._scope():
            logits, sub = self._prefill_fn(bucket)(self._params, batch,
                                                   last_pos)
            insert_args = [self._caches, sub, jnp.asarray(idx, jnp.int32)]
            if self.pool.kind == "paged":
                insert_args.append(self.pool.page_row(idx))
            self._caches = self._insert_fn()(*insert_args)
            tok = int(self._first_token_fn()(
                logits, jax.random.fold_in(self._key, slot.rid))[0])
        self.metrics.on_prefill_work(plen, time.monotonic() - t0)
        self.tracer.complete("prefill", tt0, self.tracer.now(),
                             pid=self.replica, tid=slot.rid + 1,
                             rid=slot.rid, bucket=bucket, tokens=plen,
                             recompute=bool(slot.tokens))
        if slot.tokens:
            # resumed after preemption: this prefill recomputed an
            # already-counted prefix, and the sampled token is the NEXT
            # one — bumping `prefills` or re-firing on_first_token here
            # would inflate the prefill count and reset new_tokens/TTFT
            self.metrics.on_token(slot.rid)
            slot.tokens.append(tok)
        else:
            self.metrics.on_prefill_done()
            self.metrics.on_first_token(slot.rid)
            self.tracer.instant("first_token", pid=self.replica,
                                tid=slot.rid + 1, rid=slot.rid,
                                tick=self.metrics.ticks)
            slot.tokens = [tok]
        slot.last_token = tok
        slot.cur_pos = self._n_front + plen
        self._slots[idx] = slot
        if self._finished(slot):
            self._finish(idx)

    # -- lifecycle: cancel / deadline / preempt -------------------------

    def _resolve_dead(self, dead: List[Tuple[_Slot, BaseException]],
                      on_record: Callable[[int], None]) -> None:
        """Shared tail of the cancel/deadline paths: evict the metrics
        record and fail the future."""
        for s, exc in dead:
            on_record(s.rid)
            if not s.future.done():
                s.future.set_exception(exc)

    def _process_cancels(self) -> None:
        """Resolve every pending ``cancel(rid)``: queued requests leave
        the queue, in-flight ones free their slot and pages immediately.
        Unknown/already-finished rids are no-ops."""
        with self._lock:
            if not self._cancels:
                return
            rids, self._cancels = self._cancels, set()
            hit = [s for s in self._queue if s.rid in rids]
            for s in hit:
                self._queue.remove(s)
        for i, s in enumerate(self._slots):
            if s is not None and s.rid in rids:
                self._slots[i] = None
                self._release_slot(i)
                hit.append(s)
        if hit:
            self.metrics.sync_pool(self.pool)
        for s in hit:
            self.tracer.instant("cancel", pid=self.replica,
                                tid=s.rid + 1, rid=s.rid,
                                tick=self.metrics.ticks)
        self._resolve_dead([(s, RequestCancelled(s.rid)) for s in hit],
                           self.metrics.on_cancel)

    def _deadline_reason(self, slot: _Slot) -> Optional[str]:
        req = slot.req
        if req.deadline_ticks is None and req.deadline_s is None:
            return None
        rm = self.metrics.request(slot.rid)
        if rm is None:
            return None
        if req.deadline_ticks is not None:
            waited = self.metrics.ticks - rm.submit_tick
            if waited >= req.deadline_ticks:
                return (f"{waited} ticks since submit >= deadline_ticks="
                        f"{req.deadline_ticks}")
        if req.deadline_s is not None:
            waited_s = self.metrics.clock() - rm.submit_t
            if waited_s >= req.deadline_s:
                return (f"{waited_s:.3f}s since submit >= deadline_s="
                        f"{req.deadline_s}")
        return None

    def _expire_deadlines(self) -> None:
        """Fail every queued or in-flight request past its deadline with
        :class:`DeadlineExceeded`, freeing slots and pages — a stuck or
        abandoned request cannot hold capacity forever."""
        with self._lock:
            expired = [(s, self._deadline_reason(s)) for s in self._queue]
            expired = [(s, r) for s, r in expired if r is not None]
            for s, _ in expired:
                self._queue.remove(s)
        for i, s in enumerate(self._slots):
            if s is None:
                continue
            r = self._deadline_reason(s)
            if r is not None:
                self._slots[i] = None
                self._release_slot(i)
                expired.append((s, r))
        if expired:
            self.metrics.sync_pool(self.pool)
        for s, r in expired:
            self.tracer.instant("deadline", pid=self.replica,
                                tid=s.rid + 1, rid=s.rid, reason=r,
                                tick=self.metrics.ticks)
        self._resolve_dead(
            [(s, DeadlineExceeded(s.rid, r)) for s, r in expired],
            self.metrics.on_deadline)

    def _preempt(self, idx: int) -> None:
        """Kick slot ``idx`` for pages: free its pages and re-queue the
        request at the queue head with its already-generated tokens
        appended to the prompt. Re-admission recomputes the whole prefix
        through the ordinary chunked-prefill path; greedy decoding makes
        the resumed output token-identical to a never-preempted run."""
        s = self._slots[idx]
        self._slots[idx] = None
        self._release_slot(idx)
        computed = (s.prefilled if s.prefilling
                    else int(s.prompt.size) + len(s.tokens))
        if s.tokens:
            s.prefill_seq = np.concatenate(
                [s.prompt, np.asarray(s.tokens, np.int32)])
        else:
            s.prefill_seq = s.prompt
        s.prefilled = -1
        s.cur_pos = 0
        s.last_token = -1
        s.anchor = None          # recompute re-derives it (final chunk)
        self.metrics.on_preempt(s.rid, computed)
        self.tracer.instant("preempt", pid=self.replica, tid=s.rid + 1,
                            rid=s.rid, computed=computed,
                            tick=self.metrics.ticks)
        s.trace_t0 = self.tracer.now()   # back in the queue: new span
        with self._lock:
            self._queue.appendleft(s)

    def _grow_pages(self) -> None:
        """Incremental admission: grow every live slot's page table to
        cover this tick's cache writes, oldest slot first; on
        :class:`PoolExhausted` preempt the *youngest* slot and retry.
        Terminates: every preemption frees pages, the growing slot may
        end up preempting itself, and ``submit()`` already rejected any
        request whose full budget could never fit the pool."""
        C = self.prefill_chunk
        order = sorted(
            (i for i, s in enumerate(self._slots) if s is not None),
            key=lambda i: self._slots[i].admit_seq)
        tt0 = self.tracer.now()
        for i in order:
            s = self._slots[i]
            if s is None:                  # preempted as a younger victim
                continue
            # under speculation a decode tick writes spec_k extra draft
            # positions past the committed one; grow to cover them, but
            # never past the request's own budget — overshoot beyond it
            # routes to the trash page and needs no pages
            budget = (self._n_front + int(s.prompt.size)
                      + s.req.max_new_tokens)
            if s.prefilling:
                end = min(s.prefilled + C, int(s.prefill_seq.size))
                need = self._n_front + end
                if end == s.prefill_seq.size:
                    # final chunk lands this tick: the slot joins this
                    # very tick's decode, writing one position further
                    # (plus its draft positions when speculating)
                    need = min(need + 1 + self.spec_k, budget)
            else:
                # this tick's decode write (+ draft positions)
                need = min(s.cur_pos + 1 + self.spec_k, budget)
            while True:
                try:
                    self.pool.alloc_pages(i, need)
                    break
                except PoolExhausted:
                    self.metrics.on_pool_exhausted()
                    victim = max(
                        (j for j, v in enumerate(self._slots)
                         if v is not None),
                        key=lambda j: self._slots[j].admit_seq)
                    self._preempt(victim)
                    if victim == i:
                        break              # kicked ourselves; slot is gone
        self.metrics.sync_pool(self.pool)
        if order:
            self.tracer.complete("grow_pages", tt0, self.tracer.now(),
                                 pid=self.replica, tid=TRACK_ENGINE,
                                 tick=self.metrics.ticks,
                                 pages_in_use=self.pool.pages_in_use)

    def _chunk_tick(self) -> None:
        """Advance every prefilling slot by one prompt chunk (one pooled
        call). Slots whose final chunk lands sample their first token off
        the chunk logits and join this very tick's decode."""
        live = [(i, s) for i, s in enumerate(self._slots)
                if s is not None and s.prefilling]
        if not live:
            return
        C = self.prefill_chunk
        tokens = np.zeros((self.slots, C), np.int32)
        start = np.zeros((self.slots,), np.int32)
        last = np.zeros((self.slots,), np.int32)
        active = np.zeros((self.slots,), bool)
        spans = {}
        for i, s in live:
            lo = s.prefilled
            hi = min(lo + C, int(s.prefill_seq.size))
            tokens[i, :hi - lo] = s.prefill_seq[lo:hi]
            start[i] = lo
            last[i] = hi - lo - 1
            active[i] = True
            spans[i] = (lo, hi)
        t0 = time.monotonic()
        tt0 = self.tracer.now()
        with self._scope():
            logits, h_last, self._caches = self._chunk_fn()(
                self._params, jnp.asarray(tokens), self._caches,
                jnp.asarray(start), jnp.asarray(last),
                jnp.asarray(active), self.pool.gather_args()["page_table"])
        tt1 = self.tracer.now()
        real = sum(hi - lo for lo, hi in spans.values())
        self.metrics.on_prefill_work(real, time.monotonic() - t0,
                                     chunked=True)
        self.tracer.complete("prefill_chunk", tt0, tt1, pid=self.replica,
                             tid=TRACK_ENGINE, slots=len(live),
                             tokens=real, tick=self.metrics.ticks)
        for i, s in live:
            lo, hi = spans[i]
            self.tracer.complete(f"prefill_chunk[{lo // C}]", tt0, tt1,
                                 pid=self.replica, tid=s.rid + 1,
                                 rid=s.rid, lo=lo, hi=hi,
                                 recompute=bool(s.tokens))
        finishers = []
        anchors = np.asarray(h_last) if self.spec_k else None
        for i, s in live:
            lo, hi = spans[i]
            s.prefilled = hi
            if s.prefilling:
                continue
            with self._scope():
                tok = int(self._first_token_fn()(
                    logits[i:i + 1],
                    jax.random.fold_in(self._key, s.rid))[0])
            if s.tokens:
                # resumed after preemption: the recomputed prefix already
                # ends in generated tokens, so this is the NEXT token —
                # and the request's one real prefill was already counted,
                # so on_prefill_done would inflate `prefills`
                self.metrics.on_token(s.rid)
            else:
                self.metrics.on_prefill_done()
                self.metrics.on_first_token(s.rid)
                self.tracer.instant("first_token", pid=self.replica,
                                    tid=s.rid + 1, rid=s.rid,
                                    tick=self.metrics.ticks)
            s.tokens.append(tok)
            s.last_token = tok
            s.cur_pos = self._n_front + int(s.prefill_seq.size)
            s.prefilled = -1                # decode phase
            if anchors is not None:
                s.anchor = anchors[i]       # draft seed for this tick
            if self._finished(s):
                finishers.append(i)
        for i in finishers:
            self._finish(i)

    def _finished(self, slot: _Slot) -> bool:
        if len(slot.tokens) >= slot.req.max_new_tokens:
            return True
        stop = slot.req.stop_token
        return stop is not None and slot.last_token == stop

    def _release_slot(self, idx: int) -> None:
        """The ONE scrub-then-free tail for every slot-exit path — finish,
        cancel, deadline, preempt, abort. Under ``scrub_freed_slots`` the
        slot's cache state is re-initialized BEFORE ``pool.free()``: after
        free() the slot's page-table row points at trash, so a late scrub
        would zero the trash page while the request's real KV survived in
        recycled pages (the stale-KV scrub-bypass bug the lifecycle paths
        used to have)."""
        if self.scrub_freed_slots:
            with self._scope():
                reset_args = [self._caches, jnp.asarray(idx, jnp.int32)]
                if self.pool.kind == "paged":
                    reset_args.append(self.pool.page_row(idx))
                self._caches = self._reset_fn()(*reset_args)
        self.pool.free(idx)

    def _finish(self, idx: int) -> None:
        slot = self._slots[idx]
        self._slots[idx] = None
        rm = self.metrics.on_finish(slot.rid)
        self._release_slot(idx)
        self.metrics.sync_pool(self.pool)
        self.tracer.instant("finish", pid=self.replica,
                            tid=slot.rid + 1, rid=slot.rid,
                            new_tokens=len(slot.tokens),
                            tick=self.metrics.ticks)
        slot.future.set_result(GenerationResult(
            rid=slot.rid, prompt=slot.prompt,
            tokens=list(slot.tokens), metrics=rm))

    def _decode_tick(self) -> None:
        if self.spec_k:
            return self._spec_decode_tick()
        tokens = np.zeros((self.slots,), np.int32)
        cur_pos = np.zeros((self.slots,), np.int32)
        active = np.zeros((self.slots,), bool)
        for i, s in enumerate(self._slots):
            if s is None or s.prefilling:
                continue
            tokens[i] = s.last_token
            cur_pos[i] = s.cur_pos
            active[i] = True
        n_active = int(active.sum())
        rng = jax.random.fold_in(self._key, 0x5E57E9 + self.metrics.ticks)
        t0 = time.monotonic()
        tt0 = self.tracer.now()
        step_args = [self._params, jnp.asarray(tokens), self._caches,
                     jnp.asarray(cur_pos), rng, jnp.asarray(active)]
        if self.pool.kind == "paged":
            step_args.append(self.pool.gather_args()["page_table"])
        with self._scope():
            nxt, self._caches = self._decode_fn()(*step_args)
        nxt = np.asarray(nxt)
        tt1 = self.tracer.now()
        self.metrics.on_decode_tick(n_active, n_active,
                                    time.monotonic() - t0)
        self.tracer.complete("decode", tt0, tt1, pid=self.replica,
                             tid=TRACK_ENGINE, active=n_active,
                             tick=self.metrics.ticks)
        for i, s in enumerate(self._slots):
            if s is None or s.prefilling:
                continue
            tok = int(nxt[i])
            s.tokens.append(tok)
            s.last_token = tok
            s.cur_pos += 1
            self.metrics.on_token(s.rid)
            self.tracer.complete("decode", tt0, tt1, pid=self.replica,
                                 tid=s.rid + 1, rid=s.rid, token=tok,
                                 pos=s.cur_pos)
            if self._finished(s):
                self._finish(i)

    def _spec_decode_tick(self) -> None:
        """Draft-k-verify-1: propose ``spec_k`` tokens per slot off each
        slot's residual-stream anchor, verify every position in ONE
        batched full-model pass, commit each slot's accepted prefix.

        The committed stream is the verify pass's own greedy targets —
        position by position exactly what non-speculative decode would
        have sampled — so acceptance only decides how many land per tick,
        never which tokens. A commit truncated below the accepted length
        (budget or stop token) always finishes the slot, so the verify
        anchor (valid only for full commits) is never used stale.
        """
        live = [(i, s) for i, s in enumerate(self._slots)
                if s is not None and s.decoding]
        if not live:
            return
        K1 = self.spec_k + 1
        tokens = np.zeros((self.slots, K1), np.int32)
        cur_pos = np.zeros((self.slots,), np.int32)
        active = np.zeros((self.slots,), bool)
        anchors = np.zeros((self.slots, self.cfg.d_model), np.float32)
        for i, s in live:
            tokens[i, 0] = s.last_token
            cur_pos[i] = s.cur_pos
            active[i] = True
            anchors[i] = s.anchor
        t0 = time.monotonic()
        tt0 = self.tracer.now()
        with self._scope():
            drafts = self._draft_fn()(self._params, jnp.asarray(anchors),
                                      jnp.asarray(tokens[:, 0]))
            tokens[:, 1:] = np.asarray(drafts)
            ttd = self.tracer.now()
            targets, accepted, anchor_out, self._caches = \
                self._spec_verify_fn()(
                    self._params, jnp.asarray(tokens), self._caches,
                    jnp.asarray(cur_pos), jnp.asarray(active),
                    self.pool.gather_args()["page_table"])
        tt1 = self.tracer.now()
        self.tracer.complete("spec_draft", tt0, ttd, pid=self.replica,
                             tid=TRACK_ENGINE, slots=len(live),
                             tick=self.metrics.ticks)
        self.tracer.complete("spec_verify", ttd, tt1, pid=self.replica,
                             tid=TRACK_ENGINE, slots=len(live),
                             tick=self.metrics.ticks)
        targets = np.asarray(targets)
        accepted = np.asarray(accepted)
        anchor_out = np.asarray(anchor_out)
        committed_total = 0
        for i, s in live:
            m = int(accepted[i]) + 1
            m = min(m, s.req.max_new_tokens - len(s.tokens))
            toks = [int(t) for t in targets[i, :m]]
            stop = s.req.stop_token
            if stop is not None and stop in toks:
                toks = toks[:toks.index(stop) + 1]
            s.tokens.extend(toks)
            s.last_token = toks[-1]
            s.cur_pos += len(toks)
            s.anchor = anchor_out[i]
            committed_total += len(toks)
            self.metrics.on_token(s.rid, len(toks))
            self.tracer.complete("spec", tt0, tt1, pid=self.replica,
                                 tid=s.rid + 1, rid=s.rid,
                                 drafted=self.spec_k,
                                 accepted=int(accepted[i]),
                                 committed=len(toks))
        self.metrics.on_spec_tick(
            drafted=len(live) * self.spec_k,
            accepted=int(accepted[[i for i, _ in live]].sum()))
        self.metrics.on_decode_tick(len(live), committed_total,
                                    time.monotonic() - t0)
        for i, s in live:
            if self._finished(s):
                self._finish(i)
