"""Flash-attention Pallas kernel (TPU target) — beyond-paper optimization.

The jnp blockwise path in :mod:`repro.models.attention` implements the same
online-softmax algorithm but XLA materializes each (block_q, block_kv) score
tile and the f32 accumulator in HBM between loop steps (visible in the
roofline memory term). This kernel keeps q-tile, running max/denominator and
the accumulator resident in VMEM for the whole KV sweep: HBM traffic drops
to one read of Q/K/V + one write of O.

Grid: (batch*heads, num_q_blocks); the KV sweep is a fori_loop inside the
kernel body. Causal + sliding-window masking supported. Validated against
:func:`repro.kernels.ref.flash_attention_ref` in interpret mode (tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int,
                  block_kv: int, seq_len: int, causal: bool, window: int,
                  scale: float):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale          # (bq, d)
    q_ids = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    nkv = seq_len // block_kv
    if causal:
        hi = (qi * block_q + block_q + block_kv - 1) // block_kv
    else:
        hi = nkv
    if window > 0:
        lo = jnp.maximum(0, (qi * block_q - window) // block_kv)
    else:
        lo = 0

    def body(j, state):
        m, l, acc = state
        k = pl.load(k_ref, (pl.dslice(j * block_kv, block_kv),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * block_kv, block_kv),
                            slice(None))).astype(jnp.float32)
        s = q @ k.T                                     # (bq, bkv)
        k_ids = j * block_kv + jax.lax.iota(jnp.int32, block_kv)
        mask = jnp.ones((block_q, block_kv), jnp.bool_)
        if causal:
            mask &= k_ids[None, :] <= q_ids[:, None]
        if window > 0:
            mask &= k_ids[None, :] > q_ids[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, q_ref.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q/k/v: (B, H, S, D) (KV heads pre-expanded or H == KV). S must be a
    multiple of the block sizes."""
    B, H, S, D = q.shape
    assert S % block_q == 0 and S % block_kv == 0, (S, block_q, block_kv)
    scale = D ** -0.5
    grid = (B * H, S // block_q)
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, S, D)
    vf = v.reshape(B * H, S, D)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q,
                          block_kv=block_kv, seq_len=S, causal=causal,
                          window=window, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, S, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, S, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)
