"""`repro.runtime.fault_tolerance`: failure detection, elastic re-mesh
planning, straggler mitigation — the control logic the serving client's
watchdog (`tests/test_serve_faults.py`) builds on.

These tests use generous timing margins (detection timeouts of 100s of ms
against 10s-of-ms poll intervals) so they stay deterministic on loaded CI
machines: they assert *ordering* (pinged workers stay alive, silent ones
die, the callback fires exactly once) rather than precise latencies.
"""

import threading
import time

import pytest

from repro.runtime.fault_tolerance import (HeartbeatMonitor, MeshPlan,
                                           StragglerMonitor,
                                           plan_elastic_mesh)


class TestHeartbeatMonitor:
    def test_pinged_workers_stay_alive(self):
        with HeartbeatMonitor(["a", "b"], timeout=0.3, poll=0.02) as hb:
            for _ in range(10):
                hb.ping("a")
                hb.ping("b")
                time.sleep(0.02)
            assert hb.dead == []
            assert hb.alive == ["a", "b"]

    def test_silent_worker_dies_and_callback_fires_once(self):
        failures = []
        done = threading.Event()

        def on_failure(w):
            failures.append(w)
            done.set()

        with HeartbeatMonitor(["quiet", "loud"], timeout=0.15, poll=0.02,
                              on_failure=on_failure) as hb:
            t0 = time.monotonic()
            # keep "loud" alive well past the timeout; never ping "quiet"
            while time.monotonic() - t0 < 0.6:
                hb.ping("loud")
                time.sleep(0.02)
            assert done.wait(timeout=2.0)
            assert hb.dead == ["quiet"]
            assert hb.alive == ["loud"]
        # the callback fired exactly once despite many poll cycles past
        # the deadline — death is latched
        assert failures == ["quiet"]

    def test_dead_worker_ping_does_not_resurrect(self):
        with HeartbeatMonitor(["w"], timeout=0.1, poll=0.02) as hb:
            deadline = time.monotonic() + 2.0
            while hb.dead != ["w"] and time.monotonic() < deadline:
                time.sleep(0.02)
            assert hb.dead == ["w"]
            hb.ping("w")                 # late ping from a zombie
            time.sleep(0.1)
            assert hb.dead == ["w"]

    def test_close_is_idempotent_and_stops_the_watchdog(self):
        hb = HeartbeatMonitor(["w"], timeout=10.0, poll=0.02)
        hb.close()
        hb.close()
        assert not hb._thread.is_alive()


class TestElasticMeshPlan:
    def test_full_complement_uses_every_device(self):
        plan = plan_elastic_mesh(8, model_parallelism=2, global_batch=32)
        assert plan == MeshPlan(shape=(4, 2), axes=("data", "model"),
                                dropped_devices=0)
        assert plan.n_devices == 8

    def test_survivor_loss_shrinks_data_axis_keeps_model(self):
        plan = plan_elastic_mesh(6, model_parallelism=2, global_batch=32)
        assert plan.shape[-1] == 2           # model axis untouched
        assert plan.n_devices <= 6
        assert plan.shape[0] * 2 + plan.dropped_devices == 6

    def test_data_axis_must_divide_global_batch(self):
        plan = plan_elastic_mesh(8, model_parallelism=1, global_batch=6)
        assert 6 % plan.shape[0] == 0
        assert plan.dropped_devices == 8 - plan.n_devices

    def test_too_few_survivors_raises(self):
        with pytest.raises(ValueError, match="survivors"):
            plan_elastic_mesh(3, model_parallelism=4, global_batch=8)

    def test_multi_pod_keeps_pod_axis(self):
        plan = plan_elastic_mesh(8, model_parallelism=2, global_batch=32,
                                 pods=2)
        assert plan.axes == ("pod", "data", "model")
        assert plan.shape[0] == 2


class TestStragglerMonitor:
    WORKERS = ["w0", "w1", "w2", "w3"]

    def test_uniform_times_no_action(self):
        mon = StragglerMonitor(self.WORKERS)
        for _ in range(5):
            act = mon.record({w: 1.0 for w in self.WORKERS})
        assert act.kind == "none"

    def test_transient_slowdown_rebalances_then_clears(self):
        mon = StragglerMonitor(self.WORKERS, threshold=1.5, patience=3)
        act = mon.record({"w0": 1.0, "w1": 1.0, "w2": 1.0, "w3": 4.0})
        assert act.kind == "rebalance" and act.worker == "w3"
        # the plan shifts work away from the straggler
        assert act.microbatch_weights["w3"] == min(
            act.microbatch_weights.values())
        assert abs(sum(act.microbatch_weights.values()) - 1.0) < 1e-9
        # recovery: EMA decays back under threshold -> flags reset
        for _ in range(20):
            act = mon.record({w: 1.0 for w in self.WORKERS})
        assert act.kind == "none"
        assert mon.flags["w3"] == 0

    def test_persistent_straggler_evicted_after_patience(self):
        mon = StragglerMonitor(self.WORKERS, threshold=1.5, patience=3)
        kinds = []
        for _ in range(6):
            act = mon.record({"w0": 1.0, "w1": 1.0, "w2": 1.0, "w3": 10.0})
            kinds.append(act.kind)
        assert "evict" in kinds
        first_evict = kinds.index("evict")
        assert first_evict == 2              # patience=3 flagged steps
        assert all(k == "rebalance" for k in kinds[:first_evict])
        assert act.worker == "w3"

    def test_ema_actually_smooths(self):
        mon = StragglerMonitor(["a", "b"], alpha=0.3, threshold=1.5,
                               patience=100)
        for _ in range(10):
            mon.record({"a": 1.0, "b": 1.0})
        mon.record({"a": 1.0, "b": 100.0})   # one-step spike
        # EMA after one spike: 0.3*100 + 0.7*1 ~ 30.7, not 100
        assert 25.0 < mon.ema["b"] < 35.0
