"""OLMoE-1B-7B — 64-expert top-8 MoE [arXiv:2409.02060]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1024, vocab_size=50304, head_dim=128,
    n_experts=64, top_k=8,
    block_unit=("moe",),
    mlp_variant="swiglu",
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        name="olmoe-1b-7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=32, vocab_size=512,
        n_experts=8, top_k=2, blockwise_threshold=64,
        attn_block_q=16, attn_block_kv=16)
