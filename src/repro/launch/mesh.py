"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run must set XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit-sharding axis types
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto on every axis
    AxisType = None


def _mesh(shape: Tuple[int, ...], axes: Tuple[str, ...], devices) -> Mesh:
    """``jax.make_mesh`` with Auto axis types when the jax version has them
    (older ``make_mesh`` signatures take no ``axis_types`` at all)."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes, devices=devices,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single-pod 16x16 (data, model) or 2-pod 2x16x16 (pod, data, model).

    256 chips/pod (TPU v5e pod slice); the multi-pod mesh prepends a DCN
    ``pod`` axis that composes with ``data`` for cross-pod data parallelism.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"mesh {shape} needs {ndev} devices but only {len(devices)} are "
            f"visible; the dry-run entrypoint must set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={ndev} before "
            f"importing jax")
    return _mesh(shape, axes, devices[:ndev])


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None) -> Mesh:
    """General mesh helper used by tests and the elastic re-mesh planner."""
    devices = list(devices if devices is not None else jax.devices())
    ndev = int(np.prod(shape))
    return _mesh(tuple(shape), tuple(axes), devices[:ndev])


def single_device_mesh() -> Mesh:
    """1-device mesh with the production axis names (smoke tests)."""
    return make_mesh((1, 1), ("data", "model"))


def simulated_mesh(ndev: int = 8,
                   axes: Sequence[str] = ("data",),
                   shape: Optional[Sequence[int]] = None) -> Mesh:
    """Data-parallel mesh over ``ndev`` host-simulated devices.

    The CPU-verifiable twin of :func:`make_production_mesh`: run under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=<ndev>`` (set before
    jax initializes — ``tests/conftest.py`` does this for the test suite)
    and every shard_map/psum path executes for real on one host. ``shape``
    defaults to ``(ndev,)`` for a single axis; multi-axis layouts (e.g.
    ``("pod", "data")``) must pass an explicit shape whose product is
    ``ndev``.
    """
    axes = tuple(axes)
    if shape is None:
        if len(axes) != 1:
            raise ValueError(
                f"simulated_mesh needs an explicit shape for axes {axes}")
        shape = (ndev,)
    shape = tuple(int(s) for s in shape)
    if int(np.prod(shape)) != ndev:
        raise ValueError(f"shape {shape} does not use {ndev} devices")
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"simulated mesh needs {ndev} devices but only {len(devices)} "
            f"are visible; set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={ndev} before importing jax")
    return _mesh(shape, axes, devices[:ndev])


@functools.lru_cache(maxsize=None)
def butterfly_mesh(mesh_shape: Tuple[int, ...]) -> Mesh:
    """Mesh for ``ButterflyConfig.mesh_shape``: ``(d,)`` -> ``("data",)``,
    ``(p, d)`` -> ``("pod", "data")``. Cached so trace-time callers
    (``models/common.linear_apply``) reuse one Mesh object per shape.

    Works over whatever devices are visible — real accelerators or
    simulated host devices alike — so the too-few-devices error spells out
    both recoveries."""
    mesh_shape = tuple(int(s) for s in mesh_shape)
    if len(mesh_shape) == 1:
        axes: Tuple[str, ...] = ("data",)
    elif len(mesh_shape) == 2:
        axes = ("pod", "data")
    else:
        raise ValueError(
            f"butterfly mesh_shape must be (data,) or (pod, data); got "
            f"{mesh_shape}")
    ndev = int(np.prod(mesh_shape))
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"butterfly mesh_shape {mesh_shape} needs {ndev} devices but "
            f"only {len(devices)} are visible; use a smaller mesh_shape on "
            f"this host, or — for a CPU simulation — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={ndev} before "
            f"importing jax (launch/train.py: --simulated-devices {ndev})")
    return _mesh(mesh_shape, axes, devices[:ndev])
