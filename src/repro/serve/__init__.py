"""``repro.serve`` — continuous-batching inference for the butterfly LMs.

    from repro.serve import Request, ServeEngine, ServeClient, loader

    cfg = registry.get("smollm-135m-smoke")
    step, params = loader.load_for_serving(cfg, checkpoint_dir)
    engine = ServeEngine(cfg, params, slots=4, max_len=128)  # paged pool
    with ServeClient(engine) as client:
        fut = client.submit(Request(prompt=[1, 2, 3], max_new_tokens=16))
        print(fut.result().tokens)

The engine serves over a :class:`CachePool` — paged by default
(``pool="paged"``: fixed-size pages, per-slot page tables, free-list
recycling, chunked prefill), with the dense PR-5 layout available as
``pool="dense"`` for bisection. See :mod:`repro.serve.engine` for the
tick-loop / compile-cache design, :mod:`repro.serve.cache` for the pool
API, and ``python -m repro.launch.serve --help`` for the workload-replay
CLI.
"""

from repro.serve import cache, loader, metrics, sampling
from repro.serve.cache import (CachePool, DenseCachePool, PagedCachePool,
                               PoolExhausted, make_pool)
from repro.serve.client import ServeClient
from repro.serve.engine import (CompileCache, GenerationResult, Request,
                                ServeEngine)
from repro.serve.metrics import EngineMetrics, RequestMetrics
from repro.serve.sampling import GREEDY, SamplingParams, sample_logits

__all__ = [
    # engine + client
    "ServeEngine", "ServeClient", "CompileCache",
    # request/result surface
    "Request", "GenerationResult",
    # cache pools
    "CachePool", "DenseCachePool", "PagedCachePool", "PoolExhausted",
    "make_pool",
    # metrics
    "EngineMetrics", "RequestMetrics",
    # sampling
    "SamplingParams", "GREEDY", "sample_logits",
    # submodules
    "cache", "loader", "metrics", "sampling",
]
