"""Paper Figure 1 / Figure 10: parameter counts of the dense layer vs the
butterfly replacement, at the layer sizes the paper's models use, plus the
assigned-LM head sizes (our framework's integration point)."""

from __future__ import annotations

import jax

from benchmarks.common import emit
from repro.core import layers as bl

# (model, n1, n2) — final dense layers of the paper's Table 1 architectures
PAPER_LAYERS = [
    ("efficientnet-b0", 1280, 10),      # CIFAR-10 head
    ("preactresnet18", 512, 10),
    ("seresnet152", 2048, 100),          # CIFAR-100
    ("senet154", 2048, 1000),            # ImageNet
    ("flair-tagger-en", 4096, 20),       # CoNLL-03 NER
    ("flair-tagger-pos", 4096, 50),      # PTB POS
]

# LM-head sizes of the assigned architectures (d_model -> vocab)
LM_HEADS = [
    ("smollm-135m-head", 576, 49152),
    ("gemma3-27b-head", 5376, 262144),
    ("mistral-large-head", 12288, 32768),
    ("olmoe-head", 2048, 50304),
]


def run() -> None:
    key = jax.random.PRNGKey(0)
    for name, n1, n2 in PAPER_LAYERS + LM_HEADS:
        dense = bl.dense_param_count(n1, n2)
        spec = bl.make_spec(key, n1, n2)          # paper's k = log2(n)
        ours = bl.param_count(spec)
        eff = bl.effective_param_count(spec)
        emit(f"params/{name}", 0.0,
             f"dense={dense};butterfly={ours};effective={eff};"
             f"reduction={dense / max(ours, 1):.1f}x")


if __name__ == "__main__":
    run()
