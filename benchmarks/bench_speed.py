"""Paper Figures 12/13: training/inference time of dense layer vs butterfly
replacement (CPU timings here; the TPU story is the §Roofline analysis)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import layers as bl


def run() -> None:
    key = jax.random.PRNGKey(0)
    B = 64
    for n in (512, 1024, 2048, 4096):
        W = jax.random.normal(key, (n, n)) / jnp.sqrt(n)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, n))
        dense = jax.jit(lambda x: x @ W.T)
        us_d = time_fn(dense, x)

        spec = bl.make_spec(jax.random.PRNGKey(2), n, n, use_bias=False)
        params = bl.init_butterfly_linear(jax.random.PRNGKey(3), spec)
        bfly = jax.jit(lambda x: bl.butterfly_linear_apply(spec, params, x))
        us_b = time_fn(bfly, x)
        emit(f"speed/forward_n{n}", us_b,
             f"dense_us={us_d:.1f};speedup={us_d / us_b:.2f}x")

        # training step (forward+backward+sgd)
        y = jax.random.normal(jax.random.PRNGKey(4), (B, n))

        @jax.jit
        def dense_step(W):
            g = jax.grad(lambda W: jnp.mean((x @ W.T - y) ** 2))(W)
            return W - 0.1 * g

        @jax.jit
        def bfly_step(params):
            g = jax.grad(lambda p: jnp.mean(
                (bl.butterfly_linear_apply(spec, p, x) - y) ** 2))(params)
            return jax.tree_util.tree_map(lambda p, g: p - 0.1 * g,
                                          params, g)

        us_dt = time_fn(dense_step, W)
        us_bt = time_fn(bfly_step, params)
        emit(f"speed/train_n{n}", us_bt,
             f"dense_us={us_dt:.1f};speedup={us_dt / us_bt:.2f}x")


if __name__ == "__main__":
    run()
