"""Optimizers, schedules and error-feedback gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import optimizer as opt
from repro.optim.compression import compress_gradients, compression_stats


def _quadratic():
    A = jnp.asarray(np.diag(np.linspace(1.0, 10.0, 8)), jnp.float32)
    b = jnp.arange(8, dtype=jnp.float32)

    def loss(x):
        return 0.5 * x @ A @ x - b @ x

    x_star = jnp.linalg.solve(A, b)
    return loss, x_star


def test_adamw_converges_on_quadratic():
    loss, x_star = _quadratic()
    tx = opt.adamw(0.1)
    x = jnp.zeros(8)
    state = tx.init(x)
    for _ in range(400):
        g = jax.grad(loss)(x)
        u, state = tx.update(g, state, x)
        x = opt.apply_updates(x, u)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_star), atol=0.05)


def test_sgd_momentum_converges():
    loss, x_star = _quadratic()
    tx = opt.sgd(0.02, momentum=0.9)
    x = jnp.zeros(8)
    state = tx.init(x)
    for _ in range(500):
        g = jax.grad(loss)(x)
        u, state = tx.update(g, state, x)
        x = opt.apply_updates(x, u)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_star), atol=0.05)


def test_clip_by_global_norm():
    tx = opt.clip_by_global_norm(1.0)
    g = {"a": jnp.full((4,), 10.0), "b": jnp.full((4,), -10.0)}
    state = tx.init(g)
    clipped, _ = tx.update(g, state)
    total = sum(float(jnp.sum(jnp.square(x)))
                for x in jax.tree_util.tree_leaves(clipped))
    assert abs(total - 1.0) < 1e-4


def test_warmup_cosine_schedule_shape():
    sched = opt.warmup_cosine_schedule(1.0, 10, 100)
    assert float(sched(0)) == 0.0
    assert abs(float(sched(10)) - 1.0) < 1e-6
    assert float(sched(5)) == pytest.approx(0.5)
    assert float(sched(100)) < float(sched(50)) < float(sched(10))


def test_frozen_leaves_skipped():
    """Integer (non-trainable) leaves must survive the optimizer untouched."""
    tx = opt.adamw(0.1)
    params = {"w": jnp.ones(3), "idx": jnp.arange(3, dtype=jnp.int32)}
    state = tx.init(params)
    grads = {"w": jnp.ones(3), "idx": None}
    u, state = tx.update(grads, state, params)
    new = opt.apply_updates(params, u)
    assert new["idx"].dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(new["idx"]), np.arange(3))


# ---------------------------------------------------------------------------
# Compression
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,ratio", [("topk", 0.25), ("int8", 0.0)])
def test_error_feedback_compression_converges(kind, ratio):
    """Compressed-gradient descent with error feedback still converges on a
    quadratic (the Stich et al. guarantee this implements)."""
    loss, x_star = _quadratic()
    tx = opt.chain(compress_gradients(kind, ratio),
                   opt.sgd(0.02, momentum=0.9))
    x = jnp.zeros(8)
    state = tx.init(x)
    for _ in range(800):
        g = jax.grad(loss)(x)
        u, state = tx.update(g, state, x)
        x = opt.apply_updates(x, u)
    np.testing.assert_allclose(np.asarray(x), np.asarray(x_star), atol=0.15)


def test_topk_keeps_largest():
    from repro.optim.compression import _topk_compress
    g = jnp.asarray([0.1, -5.0, 0.2, 3.0])
    out = np.asarray(_topk_compress(g, 0.5))
    np.testing.assert_array_equal(out != 0, [False, True, False, True])


def test_error_feedback_accumulates_residual():
    tx = compress_gradients("topk", 0.25)
    g = {"w": jnp.asarray([1.0, 0.5, 0.25, 0.1])}
    state = tx.init(g)
    c1, state = tx.update(g, state)
    # residual = g - compressed
    resid = np.asarray(state.error["w"])
    np.testing.assert_allclose(np.asarray(c1["w"]) + resid,
                               np.asarray(g["w"]), atol=1e-6)
    # the residual is re-injected next round
    c2, state = tx.update(g, state)
    assert float(jnp.abs(c2["w"]).sum()) > 0


def test_compression_stats_bandwidth():
    g = np.zeros((1024,), np.float32)
    raw, wire_topk = compression_stats("topk", g, 0.01)
    _, wire_int8 = compression_stats("int8", g)
    assert wire_topk < raw / 10
    assert wire_int8 < raw / 3
