"""The tick driver + in-process async client.

The engine's tick loop is single-threaded by contract; :class:`TickDriver`
owns that thread. It drives any *tickable* — an object with
``has_work() -> bool``, ``step()``, and ``abort_all(exc)`` — which is a
:class:`~repro.serve.engine.ServeEngine` for the single-engine
:class:`ServeClient`, and a :class:`repro.serve.router.Router` (whose one
``step()`` round-robins a tick over every replica) for the multi-replica
tier: ONE thread multiplexes all replicas, so router scheduling stays as
deterministic and CPU-testable as the engine itself.

The driver loop: ping the heartbeat, ``step()`` while work exists, park on
a wake event when the target drains — no busy-polling between bursts. A
``step()`` that *raises* stops the driver and fails every outstanding
future with the real error via ``abort_all`` (no stranded futures on a
dead daemon thread); a ``step()`` that never *returns* is caught by the
heartbeat watchdog (``tick_timeout``) and surfaces as
:class:`EngineWedged`.

    with ServeClient(engine) as client:
        futs = [client.submit(Request(prompt=p, max_new_tokens=16))
                for p in prompts]
        results = [f.result(timeout=60) for f in futs]

Liveness: with ``tick_timeout`` set, a :class:`repro.runtime.
fault_tolerance.HeartbeatMonitor` watches the driver thread — every loop
iteration pings it, so a *wedged tick* (``step()`` stuck in a hung device
call) goes silent and the watchdog fires within ``tick_timeout`` seconds:
outstanding futures fail with :class:`EngineWedged` instead of hanging
until their ``result()`` timeouts, and further submissions are refused.
Detection, not recovery — the wedged thread itself cannot be killed from
Python; the point is that callers *find out*.
"""

from __future__ import annotations

import contextlib
import threading
from concurrent.futures import Future
from typing import Optional

from repro.runtime.fault_tolerance import HeartbeatMonitor
from repro.serve.engine import Request, ServeEngine

#: the heartbeat worker name the driver thread pings
_DRIVER = "serve-driver"


class EngineWedged(RuntimeError):
    """The driver thread stopped ticking (a hung ``step()``): the
    heartbeat watchdog failed all outstanding futures and closed the
    driver to new submissions. Distinct from a tick that *raises* (futures
    get the real exception) — this is the tick that never returns."""

    def __init__(self, timeout: float):
        super().__init__(
            f"serve driver thread missed its heartbeat for more than "
            f"{timeout:.3f}s — a tick is wedged; outstanding requests "
            f"were failed and the client is closed")
        self.timeout = timeout


class TickDriver:
    """One daemon thread driving a tickable's ``step()`` loop.

    ``target`` needs three methods: ``has_work()`` (anything queued or in
    flight?), ``step()`` (advance one tick — for a router, one round-robin
    pass over its replicas), and ``abort_all(exc)`` (fail every
    outstanding future). ``tick_timeout`` (seconds, ``None`` = no
    watchdog) bounds one *loop iteration* — a tick plus the idle park
    (50 ms) — so set it comfortably above the slowest expected tick
    (compile ticks included), not above the whole request latency.

    Whoever enqueues work onto the target must do it inside
    :meth:`submit_scope` (which raises once the driver stopped) and then
    :meth:`wake` the thread — that ordering is what guarantees a submit
    racing :meth:`close` either lands before the post-exit sweep (and is
    failed by it) or observes the stop flag and raises, never leaving a
    silently stranded future.
    """

    def __init__(self, target, tick_timeout: Optional[float] = None,
                 name: str = "serve-engine"):
        self.target = target
        self.tick_timeout = tick_timeout
        self.wedged = False
        self._wake = threading.Event()
        self._stop = threading.Event()
        # serializes submit_scope's stop-check+enqueue against the
        # driver's post-exit sweep (see class docstring)
        self._lock = threading.Lock()
        self._hb: Optional[HeartbeatMonitor] = None
        if tick_timeout is not None:
            if tick_timeout <= 0:
                raise ValueError(f"tick_timeout must be positive or None, "
                                 f"got {tick_timeout}")
            self._hb = HeartbeatMonitor(
                [_DRIVER], timeout=tick_timeout,
                on_failure=self._on_wedged,
                poll=min(0.05, tick_timeout / 4))
        self._thread = threading.Thread(target=self._drive, name=name,
                                        daemon=True)
        self._thread.start()

    # -- public --------------------------------------------------------

    @contextlib.contextmanager
    def submit_scope(self):
        """Context for enqueueing work on the target: raises when the
        driver has stopped (wedged or closed), and serializes against the
        post-exit sweep so the enqueued future can never be stranded.
        Call :meth:`wake` after the scope exits."""
        with self._lock:
            if self._stop.is_set():
                raise RuntimeError(
                    "client is wedged" if self.wedged else
                    "client is closed")
            yield

    def wake(self) -> None:
        self._wake.set()

    @property
    def stopped(self) -> bool:
        return self._stop.is_set()

    def close(self, timeout: float = 60.0) -> None:
        """Stop the driver thread after the target drains its current
        work; idempotent."""
        self._stop.set()
        self._wake.set()
        self._thread.join(timeout=timeout)
        if self._hb is not None:
            self._hb.close()

    # -- watchdog ------------------------------------------------------

    def _on_wedged(self, worker: str) -> None:
        """Heartbeat callback (watchdog thread): the driver went silent.

        Best-effort crash surfacing — the wedged thread may sit inside a
        hung tick holding partial slot state, so the target is NOT safe to
        reuse afterwards; what matters is that every outstanding future
        resolves with :class:`EngineWedged` instead of hanging, and that
        submits are refused."""
        self.wedged = True
        self._stop.set()
        self._wake.set()
        with self._lock:
            if self.target.has_work():
                self.target.abort_all(EngineWedged(self.tick_timeout))

    # -- driver --------------------------------------------------------

    def _drive(self) -> None:
        exc: BaseException = RuntimeError("client is closed")
        while True:
            if self._hb is not None:
                self._hb.ping(_DRIVER)
            if self._stop.is_set() and self.wedged:
                # watchdog declared us wedged while we were merely slow:
                # it already swept the futures; just exit
                return
            if self.target.has_work():
                try:
                    self.target.step()
                except BaseException as e:
                    # a dead driver must not strand futures: fail every
                    # queued/in-flight request with the real error and
                    # refuse further submissions (submit_scope raises once
                    # _stop is set)
                    self._stop.set()
                    exc = e
                    break
                continue
            if self._stop.is_set():
                break
            self._wake.wait(timeout=0.05)
            self._wake.clear()
        # post-exit sweep, serialized against submit_scope: anything that
        # raced its way into the queue after our last has_work() look
        # resolves with an error instead of hanging until a result()
        # timeout
        with self._lock:
            if self.target.has_work():
                self.target.abort_all(exc)


class ServeClient:
    """Async facade over a :class:`ServeEngine` (one driver thread).

    ``submit() -> Future`` over a :class:`TickDriver` that owns the
    engine's tick loop; futures resolve to
    :class:`~repro.serve.engine.GenerationResult` as requests finish, in
    completion (not submission) order — which is the whole point of
    continuous batching. ``tick_timeout`` arms the driver's heartbeat
    watchdog (see :class:`TickDriver`).
    """

    def __init__(self, engine: ServeEngine,
                 tick_timeout: Optional[float] = None):
        self.engine = engine
        self.tick_timeout = tick_timeout
        self._driver = TickDriver(engine, tick_timeout=tick_timeout)

    # -- public --------------------------------------------------------

    @property
    def wedged(self) -> bool:
        return self._driver.wedged

    def submit(self, request: Request, *legacy_args, **legacy_kwargs
               ) -> Future:
        """Queue a :class:`repro.serve.Request`; the engine raises a
        migration ``TypeError`` for the removed positional form."""
        with self._driver.submit_scope():
            fut = self.engine.submit(request, *legacy_args,
                                     **legacy_kwargs)
        self._driver.wake()
        return fut

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or in-flight request by rid (thread-safe).

        Returns whether the engine currently knows the rid; when it does,
        the request's future resolves with
        :class:`~repro.serve.engine.RequestCancelled` at the next tick
        boundary and its slot + pages free immediately there."""
        known = self.engine.cancel(rid)
        if known:
            self._driver.wake()
        return known

    def close(self, timeout: float = 60.0) -> None:
        """Stop the driver thread after the engine drains its current
        work; idempotent."""
        self._driver.close(timeout=timeout)

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
