"""Fused butterfly-sandwich Pallas kernel (TPU target).

Computes the paper's full dense-layer replacement ``J2ᵀ · W' · J1 · x`` in a
single VMEM residency per activation tile:

    butterfly(b_in) → truncate (one-hot MXU matmul) → small dense core (MXU)
    → scatter (one-hot MXU matmul) → transposed butterfly(b_out)

Truncation/scatter are lowered as multiplications with fixed one-hot matrices
(``sel_in``: (n1, k1), ``sel_out``: (k2, n2)) — TPU has no fast dynamic
gather across lanes, but one-hot matmuls ride the MXU (DESIGN.md §3).

Five HBM round trips (one per op in the unfused jnp path) collapse into one.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.butterfly import num_stages
from repro.kernels.butterfly import _swap_halves, DEFAULT_BLOCK_B


def _sandwich_kernel(x_ref, w_in_ref, sel_in_ref, core_ref, sel_out_ref,
                     w_out_ref, o_ref, *, stages_in: int, stages_out: int,
                     scale_in: float, scale_out: float):
    x = x_ref[...]                                        # (bb, n1)
    for s in range(stages_in):
        a = w_in_ref[s, 0, :]
        b = w_in_ref[s, 1, :]
        x = a * x + b * _swap_halves(x, 1 << s)
    h = jnp.dot(x, sel_in_ref[...],
                preferred_element_type=jnp.float32)       # (bb, k1)
    h = h * scale_in
    h = jnp.dot(h, core_ref[...].T.astype(h.dtype),
                preferred_element_type=jnp.float32)       # (bb, k2)
    z = jnp.dot(h, sel_out_ref[...].astype(h.dtype),
                preferred_element_type=jnp.float32)       # (bb, n2)
    z = (z * scale_out).astype(x.dtype)
    for s in reversed(range(stages_out)):
        a = w_out_ref[s, 0, :]
        b = w_out_ref[s, 1, :]
        z = a * z + _swap_halves(b * z, 1 << s)
    o_ref[...] = z


def one_hot_select(idx, n: int, dtype=jnp.float32) -> jnp.ndarray:
    """(n, k) one-hot matrix with column j selecting coordinate idx[j]."""
    sel = np.zeros((n, len(idx)), dtype=np.float32)
    sel[np.asarray(idx), np.arange(len(idx))] = 1.0
    return jnp.asarray(sel, dtype=dtype)


@functools.partial(jax.jit, static_argnames=("scale_in", "scale_out",
                                             "block_b", "interpret"))
def sandwich_matmul(x: jnp.ndarray, b_in: jnp.ndarray, sel_in: jnp.ndarray,
                    core: jnp.ndarray, sel_out: jnp.ndarray,
                    b_out: jnp.ndarray, *, scale_in: float = 1.0,
                    scale_out: float = 1.0, block_b: int = DEFAULT_BLOCK_B,
                    interpret: bool = False) -> jnp.ndarray:
    """Fused sandwich over the last axis: (..., n1) -> (..., n2).

    ``b_in``: (p1, 2, n1); ``sel_in``: (n1, k1); ``core``: (k2, k1);
    ``sel_out``: (k2, n2); ``b_out``: (p2, 2, n2). n1/n2 powers of two.
    """
    p1, _, n1 = b_in.shape
    p2, _, n2 = b_out.shape
    k1 = sel_in.shape[1]
    k2 = sel_out.shape[0]
    assert core.shape == (k2, k1), (core.shape, k1, k2)
    lead = x.shape[:-1]
    b = int(np.prod(lead)) if lead else 1
    x2 = x.reshape(b, n1)
    bb = min(block_b, b)
    padded_b = -(-b // bb) * bb
    if padded_b != b:
        x2 = jnp.pad(x2, ((0, padded_b - b), (0, 0)))
    grid = (padded_b // bb,)
    out = pl.pallas_call(
        functools.partial(_sandwich_kernel, stages_in=num_stages(n1),
                          stages_out=num_stages(n2),
                          scale_in=scale_in, scale_out=scale_out),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, n1), lambda i: (i, 0)),
            pl.BlockSpec((p1, 2, n1), lambda i: (0, 0, 0)),
            pl.BlockSpec((n1, k1), lambda i: (0, 0)),
            pl.BlockSpec((k2, k1), lambda i: (0, 0)),
            pl.BlockSpec((k2, n2), lambda i: (0, 0)),
            pl.BlockSpec((p2, 2, n2), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, n2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_b, n2), x.dtype),
        interpret=interpret,
    )(x2, b_in.astype(x.dtype), sel_in.astype(x.dtype), core,
      sel_out, b_out.astype(x.dtype))
    return out[:b].reshape(*lead, n2)
