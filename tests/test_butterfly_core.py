"""Core butterfly math: materialization, transpose, FJLT, param counts,
and hypothesis property tests on the network invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core import butterfly as bf


@pytest.mark.parametrize("n", [2, 8, 64, 256])
def test_apply_matches_materialized(n):
    w = bf.random_weights(jax.random.PRNGKey(0), n)
    B = np.asarray(bf.materialize(w))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (5, n)))
    got = np.asarray(bf.butterfly_apply(w, jnp.asarray(x)))
    np.testing.assert_allclose(got, x @ B.T, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", [4, 32, 128])
def test_transpose_matches_materialized(n):
    w = bf.random_weights(jax.random.PRNGKey(2), n)
    B = np.asarray(bf.materialize(w))
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(3), (3, n)))
    got = np.asarray(bf.butterfly_transpose_apply(w, jnp.asarray(x)))
    np.testing.assert_allclose(got, x @ B, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", [16, 64, 1024])
def test_fjlt_is_orthogonal(n):
    w = bf.fjlt_weights(jax.random.PRNGKey(4), n)
    B = np.asarray(bf.materialize(w))
    np.testing.assert_allclose(B @ B.T, np.eye(n), atol=1e-5)


def test_fjlt_norm_preservation():
    n = 512
    w = bf.fjlt_weights(jax.random.PRNGKey(5), n)
    x = jax.random.normal(jax.random.PRNGKey(6), (20, n))
    y = bf.butterfly_apply(w, x)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=1),
                               np.linalg.norm(np.asarray(x), axis=1),
                               rtol=1e-5)


def test_truncation_jl_isometry_in_expectation():
    """sqrt(n/ell)-scaled coordinate sampling of the FJLT preserves norms in
    expectation (the JL property the paper builds on)."""
    n, ell, trials = 256, 64, 50
    x = np.array(jax.random.normal(jax.random.PRNGKey(7), (n,)))
    x = x / np.linalg.norm(x)
    norms = []
    for t in range(trials):
        kw, ki = jax.random.split(jax.random.PRNGKey(100 + t))
        w = bf.fjlt_weights(kw, n)
        idx = bf.truncation_indices(ki, n, ell)
        y = bf.truncate(bf.butterfly_apply(w, jnp.asarray(x)), idx, n)
        norms.append(float(jnp.sum(y * y)))
    assert abs(np.mean(norms) - 1.0) < 0.15


def test_effective_param_count_bound():
    for n in (64, 256, 1024):
        for ell in (4, 16, n // 4):
            idx = list(range(ell))
            exact = bf.effective_param_count(n, idx)
            assert exact <= bf.effective_param_bound(n, ell)


def test_truncate_untruncate_adjoint():
    """<truncate(x), y> == <x, untruncate(y)> (adjointness incl. JL scale)."""
    n, ell = 64, 16
    idx = bf.truncation_indices(jax.random.PRNGKey(8), n, ell)
    x = jax.random.normal(jax.random.PRNGKey(9), (n,))
    y = jax.random.normal(jax.random.PRNGKey(10), (ell,))
    lhs = jnp.vdot(bf.truncate(x, idx, n), y)
    rhs = jnp.vdot(x, bf.untruncate(y, idx, n))
    np.testing.assert_allclose(float(lhs), float(rhs), rtol=1e-5)


# ---------------------------------------------------------------------------
# Property tests
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(logn=st.integers(1, 7), seed=st.integers(0, 2**30))
def test_property_linearity(logn, seed):
    n = 1 << logn
    w = bf.random_weights(jax.random.PRNGKey(seed), n)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 1))
    x = jax.random.normal(k1, (n,))
    y = jax.random.normal(k2, (n,))
    a = 2.5
    lhs = bf.butterfly_apply(w, a * x + y)
    rhs = a * bf.butterfly_apply(w, x) + bf.butterfly_apply(w, y)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=20, deadline=None)
@given(logn=st.integers(1, 6), seed=st.integers(0, 2**30))
def test_property_transpose_adjoint(logn, seed):
    """<Bx, y> == <x, Bᵀy> for random weights — validates the transposed
    stage formula used by the sandwich's output butterfly."""
    n = 1 << logn
    w = bf.random_weights(jax.random.PRNGKey(seed), n)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + 7))
    x = jax.random.normal(k1, (n,))
    y = jax.random.normal(k2, (n,))
    lhs = float(jnp.vdot(bf.butterfly_apply(w, x), y))
    rhs = float(jnp.vdot(x, bf.butterfly_transpose_apply(w, y)))
    np.testing.assert_allclose(lhs, rhs, rtol=5e-3, atol=5e-3)


@settings(max_examples=15, deadline=None)
@given(logn=st.integers(2, 6), seed=st.integers(0, 2**30))
def test_property_identity_weights(logn, seed):
    n = 1 << logn
    w = bf.identity_weights(n)
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, n))
    np.testing.assert_allclose(np.asarray(bf.butterfly_apply(w, x)),
                               np.asarray(x), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(logn=st.integers(1, 5), seed=st.integers(0, 2**30),
       stride_pow=st.integers(0, 4))
def test_property_swap_involution(logn, seed, stride_pow):
    n = 1 << logn
    stride = 1 << min(stride_pow, logn - 1)
    if 2 * stride > n:
        stride = n // 2
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    y = bf.stage_swap(bf.stage_swap(x, stride), stride)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=0)
