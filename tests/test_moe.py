"""MoE dispatch: sort-based capacity routing vs the dense all-experts
reference, capacity-drop behaviour, aux losses."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import moe
from repro.runtime import pytree as pt


def _setup(capacity_factor=8.0, seed=0):
    cfg = registry.get("olmoe-1b-7b-smoke").with_(
        compute_dtype="float32", capacity_factor=capacity_factor)
    params = pt.init_params(jax.random.PRNGKey(seed), moe.moe_specs(cfg))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, cfg.d_model))
    return cfg, params, x


def test_moe_matches_dense_reference_at_high_capacity():
    """With capacity >> tokens nothing drops, so the sorted dispatch must
    equal the dense all-experts computation exactly."""
    cfg, params, x = _setup(capacity_factor=16.0)
    got, aux = moe.moe_apply(cfg, params, x)
    want = moe.moe_dense_reference(cfg, params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_degrade_gracefully():
    """Tight capacity drops tokens (output norm shrinks) but stays finite."""
    cfg, params, x = _setup(capacity_factor=16.0)
    full, _ = moe.moe_apply(cfg, params, x)
    cfg_tight = cfg.with_(capacity_factor=0.25)
    tight, _ = moe.moe_apply(cfg_tight, params, x)
    assert bool(jnp.isfinite(tight).all())
    assert float(jnp.linalg.norm(tight)) <= float(jnp.linalg.norm(full)) * 1.1


def test_moe_combine_weights_normalized():
    cfg, params, x = _setup()
    logits = (x.reshape(-1, cfg.d_model) @ params["router"]).astype(
        jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, _ = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(top_p.sum(-1)),
                               np.ones(top_p.shape[0]), rtol=1e-5)


def test_moe_gradients_flow_to_experts_and_router():
    cfg, params, x = _setup()

    def loss(p):
        out, aux = moe.moe_apply(cfg, p, x)
        return jnp.sum(out * out) + aux

    g = jax.grad(loss)(params)
    for key in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.sum(jnp.abs(g[key]))) > 0, key
