"""Serving benchmark: steady-state decode throughput + TTFT percentiles.

Replays a seeded Poisson-ish synthetic trace (mixed prompt lengths, all
submitted up front — on CPU the engine is always the bottleneck, so arrival
gaps only add noise) through a greedy :class:`repro.serve.ServeEngine` on
the smoke arch and emits:

* ``serve/trace_e2e`` — wall µs to drain the whole fixed seeded trace on a
  warmed engine (the timed row the regression gate covers: per-token decode
  is a few hundred µs on this arch, under ``diff.py``'s noise floor, while
  the trace wall time sits comfortably above it and covers admission +
  scheduling + decode together); µs/token, tokens/s, p50/p95 TTFT and slot
  occupancy ride the derived column;
* ``serve/large_pool`` — the 16-slot variant, emitted as *skipped* on CPU
  (one tick is minutes of wall clock at that batch) and timed on TPU.

Compile time is excluded from the steady-state number by warming every
bucket and the pooled decode step with a burn-in trace first — the engine's
CompileCache makes "warm" checkable rather than hoped-for.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common


def _trace(cfg, rng, n, max_prompt):
    return [rng.integers(0, cfg.vocab_size,
                         size=int(rng.integers(4, max_prompt + 1)))
            for _ in range(n)]


def _drain(engine, prompts, max_new):
    futs = [engine.submit(p, max_new_tokens=max_new) for p in prompts]
    engine.run_until_idle()
    for f in futs:
        f.result(0)


def _run_engine(slots: int, requests: int, max_new: int, seed: int = 0):
    from repro.configs import registry
    from repro.serve import ServeEngine, loader

    cfg = registry.get("smollm-135m-smoke")
    _, params = loader.load_for_serving(cfg, seed=0)
    engine = ServeEngine(cfg, params, slots=slots, max_len=96, seed=seed)
    rng = np.random.default_rng(seed)
    # burn-in: one request per power-of-two bucket warms every compile,
    # then the metrics (incl. the tick clock) reset so neither compile
    # wall-time nor cold-TTFT requests leak into the gated snapshot
    _drain(engine, [rng.integers(0, cfg.vocab_size, size=n)
                    for n in (8, 16, 32, 48)], 2)
    warm_compiles = engine.compile_stats["compiles"]
    engine.reset_metrics()

    prompts = _trace(cfg, rng, requests, max_prompt=48)
    t0 = time.perf_counter()
    _drain(engine, prompts, max_new)
    wall = time.perf_counter() - t0
    assert engine.compile_stats["compiles"] == warm_compiles, \
        "benchmark trace hit a cold compile; widen the burn-in buckets"
    return engine.metrics.snapshot(), wall


def run(requests: int = 24, max_new: int = 8) -> None:
    snap, wall = _run_engine(slots=4, requests=requests, max_new=max_new)
    tok_s = snap["decode_tok_per_s"]
    common.emit(
        "serve/trace_e2e", wall * 1e6,
        f"us_per_tok={1e6 / tok_s:.1f};tok_s={tok_s:.1f};"
        f"p50_ttft_ms={snap['ttft_ms']['p50']};"
        f"p95_ttft_ms={snap['ttft_ms']['p95']};"
        f"occupancy={snap['slot_occupancy']};"
        f"requests={snap['requests_finished']};"
        f"tokens={snap['total_tokens']}")

    if jax.default_backend() == "tpu":
        snap, wall = _run_engine(slots=16, requests=4 * requests,
                                 max_new=max_new)
        tok_s = snap["decode_tok_per_s"]
        common.emit("serve/large_pool", 1e6 / tok_s if tok_s else None,
                    f"tok_s={tok_s:.1f};"
                    f"p95_ttft_ms={snap['ttft_ms']['p95']};"
                    f"occupancy={snap['slot_occupancy']}")
    else:
        common.emit_skipped("serve/large_pool",
                            "16-slot pool too slow on CPU; timed on TPU")
