"""Fwd+bwd step time of the fused kernels vs the jnp oracle.

The paper's pitch is cheaper *training*, so this measures a full
value-and-grad step (input and weight cotangents) through
``butterfly_apply``, ``sandwich_apply`` and ``flash_attention`` at
n ∈ {1024, 4096, 8192} under the :mod:`repro.kernels.tuning` autotuned
block sizes (recorded in each row's ``derived`` field). The fused Pallas
path compiles only on TPU (Mosaic); on CPU those rows are emitted as
skipped (``us_per_call: null`` + ``"skipped": true`` — interpret-mode
timings are Python-loop artifacts, not kernel performance) while the
jnp-oracle rows still track the unfused baseline per platform. The flash
jnp oracle materializes the O(S²) score matrix, so its S = 8192 row is
also skipped on CPU hosts.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from benchmarks.common import emit, emit_skipped, time_fn
from repro.core import butterfly as bf
from repro.core import layers as bl
from repro.kernels import ops, ref, tuning
from repro.kernels.flash import flash_attention
from repro.kernels.sandwich import one_hot_select

NS = (1024, 4096, 8192)
FLASH_HEADS = 2
FLASH_DIM = 64

NO_TPU = "no_tpu_interpret_timing_meaningless"


def _tuned(kernel: str, n: int) -> str:
    c = tuning.tune(kernel, n, "float32", "bwd")
    return f"block_b={c.block_b};segment={c.segment}"


def _butterfly_step(backend, c):
    def loss(x, w):
        return jnp.vdot(c, ops.butterfly_apply(x, w, context=backend))

    return jax.jit(jax.grad(loss, argnums=(0, 1)))


def _bench_butterfly(n: int, batch: int, iters: int, on_tpu: bool) -> None:
    w = bf.random_weights(jax.random.PRNGKey(0), n)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, n))
    c = jax.random.normal(jax.random.PRNGKey(2), (batch, n))
    t_jnp = time_fn(_butterfly_step("jnp", c), x, w, iters=iters)
    emit(f"backward/butterfly_fwdbwd_jnp_n{n}", t_jnp, f"batch={batch}")
    name = f"backward/butterfly_fwdbwd_fused_n{n}"
    if on_tpu:
        t_fused = time_fn(_butterfly_step("pallas", c), x, w, iters=iters)
        emit(name, t_fused, f"batch={batch};{_tuned('butterfly', n)};"
             f"speedup_vs_jnp={t_jnp / t_fused:.2f}x")
    else:
        emit_skipped(name, NO_TPU, _tuned("butterfly", n))


def _bench_sandwich(n: int, batch: int, iters: int, on_tpu: bool) -> None:
    k = max(2, int(math.log2(n)))
    spec = bl.make_spec(jax.random.PRNGKey(3), n, n, k_in=k, k_out=k,
                        use_bias=False)
    params = bl.init_butterfly_linear(jax.random.PRNGKey(4), spec)
    x = jax.random.normal(jax.random.PRNGKey(5), (batch, n))
    c = jax.random.normal(jax.random.PRNGKey(6), (batch, n))
    sel_in = one_hot_select(spec.idx_in, n)
    sel_out = one_hot_select(spec.idx_out, n).T
    si = so = math.sqrt(n / k)

    def step(backend):
        def loss(x, b_in, core, b_out):
            return jnp.vdot(c, ops.sandwich_apply(
                x, b_in, sel_in, core, sel_out, b_out,
                scale_in=si, scale_out=so, context=backend))

        fn = jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3)))
        return lambda: fn(x, params["b_in"], params["core"], params["b_out"])

    t_jnp = time_fn(step("jnp"), iters=iters)
    emit(f"backward/sandwich_fwdbwd_jnp_n{n}", t_jnp,
         f"batch={batch};k={k}")
    name = f"backward/sandwich_fwdbwd_fused_n{n}"
    if on_tpu:
        t_fused = time_fn(step("pallas"), iters=iters)
        emit(name, t_fused, f"batch={batch};k={k};{_tuned('sandwich', n)};"
             f"speedup_vs_jnp={t_jnp / t_fused:.2f}x")
    else:
        emit_skipped(name, NO_TPU, _tuned("sandwich", n))


def _bench_flash(seq: int, iters: int, on_tpu: bool) -> None:
    B, H, D = 1, FLASH_HEADS, FLASH_DIM
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(ks[0], (B, H, seq, D))
    k = jax.random.normal(ks[1], (B, H, seq, D))
    v = jax.random.normal(ks[2], (B, H, seq, D))
    c = jax.random.normal(ks[3], (B, H, seq, D))
    bq, bkv = tuning.flash_blocks(seq, D, "float32", "bwd")
    tuned = f"block_q={bq};block_kv={bkv}"

    jnp_name = f"backward/flash_fwdbwd_jnp_n{seq}"
    if on_tpu or seq <= 4096:
        def jnp_loss(q, k, v):
            return jnp.vdot(c, ref.flash_attention_ref(q, k, v, causal=True))

        t_jnp = time_fn(jax.jit(jax.grad(jnp_loss, argnums=(0, 1, 2))),
                        q, k, v, iters=iters)
        emit(jnp_name, t_jnp, f"heads={H};head_dim={D}")
    else:
        emit_skipped(jnp_name, "cpu_quadratic_oracle_guard",
                     f"heads={H};head_dim={D}")

    name = f"backward/flash_fwdbwd_fused_n{seq}"
    if on_tpu:
        def fused_loss(q, k, v):
            return jnp.vdot(c, flash_attention(q, k, v, causal=True))

        t_fused = time_fn(jax.jit(jax.grad(fused_loss, argnums=(0, 1, 2))),
                          q, k, v, iters=iters)
        emit(name, t_fused, f"heads={H};head_dim={D};{tuned}")
    else:
        emit_skipped(name, NO_TPU, tuned)


def run(ns=NS, batch: int = 64, iters=None) -> None:
    on_tpu = jax.default_backend() == "tpu"
    for n in ns:
        it = iters if iters is not None else (20 if n <= 2048 else 5)
        _bench_butterfly(n, batch, it, on_tpu)
        _bench_sandwich(n, batch, it, on_tpu)
        _bench_flash(n, it, on_tpu)


if __name__ == "__main__":
    run()
