"""``repro.nn`` — drop-in module facade for the paper's butterfly layers.

The ergonomic bar is HazyResearch's ``torch_butterfly.Butterfly`` /
Pixelated Butterfly: an ``nn.Linear``-compatible *object*, not a kwarg
pipeline. :class:`ButterflyLinear` is that object for this codebase —
``create`` / ``init`` / ``apply`` / ``from_dense`` over the §3.2 butterfly
sandwich, arbitrary (non-power-of-two) in/out dims, execution policy via
:class:`repro.kernels.context.ExecutionContext`.
"""

from repro.nn.linear import ButterflyLinear, SandwichLinear

__all__ = ["ButterflyLinear", "SandwichLinear"]
