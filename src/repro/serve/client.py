"""In-process async client: ``submit() -> Future`` over a driver thread.

The engine's tick loop is single-threaded by contract; the client owns that
thread. ``submit()`` enqueues on the (thread-safe) engine and wakes the
driver, which runs ticks while work exists and parks on an event when the
engine drains — no busy-polling between bursts. Futures resolve to
:class:`repro.serve.engine.GenerationResult` as requests finish, in
completion (not submission) order, which is the whole point of continuous
batching.

    with ServeClient(engine) as client:
        futs = [client.submit(Request(prompt=p, max_new_tokens=16))
                for p in prompts]
        results = [f.result(timeout=60) for f in futs]

Liveness: with ``tick_timeout`` set, a :class:`repro.runtime.
fault_tolerance.HeartbeatMonitor` watches the driver thread — every loop
iteration pings it, so a *wedged tick* (``engine.step()`` stuck in a hung
device call) goes silent and the watchdog fires within ``tick_timeout``
seconds: outstanding futures fail with :class:`EngineWedged` instead of
hanging until their ``result()`` timeouts, and further submissions are
refused. Detection, not recovery — the wedged thread itself cannot be
killed from Python; the point is that callers *find out*.
"""

from __future__ import annotations

import threading
from concurrent.futures import Future
from typing import Optional

from repro.runtime.fault_tolerance import HeartbeatMonitor
from repro.serve.engine import Request, ServeEngine

#: the heartbeat worker name the driver thread pings
_DRIVER = "serve-driver"


class EngineWedged(RuntimeError):
    """The driver thread stopped ticking (a hung ``engine.step()``): the
    heartbeat watchdog failed all outstanding futures and closed the
    client to new submissions. Distinct from a tick that *raises* (futures
    get the real exception) — this is the tick that never returns."""

    def __init__(self, timeout: float):
        super().__init__(
            f"serve driver thread missed its heartbeat for more than "
            f"{timeout:.3f}s — a tick is wedged; outstanding requests "
            f"were failed and the client is closed")
        self.timeout = timeout


class ServeClient:
    """Async facade over a :class:`ServeEngine` (one driver thread).

    ``tick_timeout`` (seconds, ``None`` = no watchdog) arms the heartbeat
    monitor described in the module docstring. It bounds one *loop
    iteration* — a tick plus the idle park (50 ms) — so set it comfortably
    above the slowest expected tick (compile ticks included), not above
    the whole request latency.
    """

    def __init__(self, engine: ServeEngine,
                 tick_timeout: Optional[float] = None):
        self.engine = engine
        self.tick_timeout = tick_timeout
        self.wedged = False
        self._wake = threading.Event()
        self._stop = threading.Event()
        # serializes submit's stop-check+enqueue against the driver's
        # post-exit sweep, so a submit racing close() either enqueues
        # before the sweep (and gets failed by it) or observes the stop
        # flag and raises — never a silently stranded future
        self._lock = threading.Lock()
        self._hb: Optional[HeartbeatMonitor] = None
        if tick_timeout is not None:
            if tick_timeout <= 0:
                raise ValueError(f"tick_timeout must be positive or None, "
                                 f"got {tick_timeout}")
            self._hb = HeartbeatMonitor(
                [_DRIVER], timeout=tick_timeout,
                on_failure=self._on_wedged,
                poll=min(0.05, tick_timeout / 4))
        self._thread = threading.Thread(target=self._drive,
                                        name="serve-engine", daemon=True)
        self._thread.start()

    # -- public --------------------------------------------------------

    def submit(self, request: Request, *legacy_args, **legacy_kwargs
               ) -> Future:
        """Queue a :class:`repro.serve.Request`; the engine raises a
        migration ``TypeError`` for the removed positional form."""
        with self._lock:
            if self._stop.is_set():
                raise RuntimeError(
                    "client is wedged" if self.wedged else
                    "client is closed")
            fut = self.engine.submit(request, *legacy_args,
                                     **legacy_kwargs)
        self._wake.set()
        return fut

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or in-flight request by rid (thread-safe).

        Returns whether the engine currently knows the rid; when it does,
        the request's future resolves with
        :class:`~repro.serve.engine.RequestCancelled` at the next tick
        boundary and its slot + pages free immediately there."""
        known = self.engine.cancel(rid)
        if known:
            self._wake.set()
        return known

    def close(self, timeout: float = 60.0) -> None:
        """Stop the driver thread after the engine drains its current
        work; idempotent."""
        self._stop.set()
        self._wake.set()
        self._thread.join(timeout=timeout)
        if self._hb is not None:
            self._hb.close()

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- watchdog ------------------------------------------------------

    def _on_wedged(self, worker: str) -> None:
        """Heartbeat callback (watchdog thread): the driver went silent.

        Best-effort crash surfacing — the wedged thread may sit inside a
        hung tick holding partial slot state, so the engine is NOT safe to
        reuse afterwards; what matters is that every outstanding future
        resolves with :class:`EngineWedged` instead of hanging, and that
        ``submit()`` refuses new work."""
        self.wedged = True
        self._stop.set()
        self._wake.set()
        with self._lock:
            if self.engine.has_work():
                self.engine.abort_all(EngineWedged(self.tick_timeout))

    # -- driver --------------------------------------------------------

    def _drive(self) -> None:
        exc: BaseException = RuntimeError("client is closed")
        while True:
            if self._hb is not None:
                self._hb.ping(_DRIVER)
            if self._stop.is_set() and self.wedged:
                # watchdog declared us wedged while we were merely slow:
                # it already swept the futures; just exit
                return
            if self.engine.has_work():
                try:
                    self.engine.step()
                except BaseException as e:
                    # a dead driver must not strand futures: fail every
                    # queued/in-flight request with the real error and
                    # refuse further submissions (submit() raises once
                    # _stop is set)
                    self._stop.set()
                    exc = e
                    break
                continue
            if self._stop.is_set():
                break
            self._wake.wait(timeout=0.05)
            self._wake.clear()
        # post-exit sweep, serialized against submit: anything that raced
        # its way into the queue after our last has_work() look resolves
        # with an error instead of hanging until a result() timeout
        with self._lock:
            if self.engine.has_work():
                self.engine.abort_all(exc)
