"""GPipe-style pipeline parallelism over a mesh axis (e.g. the DCN ``pod``
axis), built on shard_map + ppermute.

The layer stack is split into S contiguous stages; stage s's parameters
live only on the devices of mesh axis ``stage`` coordinate s. Microbatches
stream through the classic GPipe schedule: at tick t, stage s computes
microbatch ``t - s`` (when in range) and passes activations to stage s+1
with a single ``ppermute`` — the only inter-stage communication. Bubble
fraction is (S-1)/(T+S-1) as usual.

Differentiable end-to-end (JAX transposes ppermute to the reverse shift),
so the same function serves training. Correctness is validated against the
unpipelined stack in ``tests/test_pipeline.py`` on 8 simulated devices.

This composes with the rest of the framework: ``stage`` is just another
mesh axis, so a (stage, data, model) mesh runs PP over DCN with FSDP+TP
inside each stage — the standard 1000+ node layout when a model's layers
don't fit one pod's HBM.
"""

from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime.compat import shard_map_compat as _shard_map

PyTree = Any


def pipeline_apply(stage_fn: Callable[[PyTree, jnp.ndarray], jnp.ndarray],
                   stage_params: PyTree, x: jnp.ndarray, *, mesh: Mesh,
                   stage_axis: str = "stage",
                   microbatches: int = 4) -> jnp.ndarray:
    """Run ``y = stage_{S-1}(...stage_0(x))`` pipelined over ``stage_axis``.

    ``stage_params``: pytree whose leaves have a leading stage dim S
    (sharded one-stage-per-coordinate on ``stage_axis``).
    ``stage_fn(params_s, x_mb) -> y_mb`` applies ONE stage to ONE microbatch.
    ``x``: (B, ...) global batch; B must divide ``microbatches``.
    """
    S = mesh.shape[stage_axis]
    B = x.shape[0]
    T = microbatches
    assert B % T == 0, (B, T)
    mb = x.reshape((T, B // T) + x.shape[1:])

    other_axes = [a for a in mesh.shape if a != stage_axis]

    def region(params_local, mb_local):
        # params_local leaves: (1, ...) — this device's stage
        params_s = jax.tree_util.tree_map(lambda p: p[0], params_local)
        stage_id = jax.lax.axis_index(stage_axis)
        n_ticks = T + S - 1
        mb_shape = mb_local.shape[1:]

        def tick(carry, t):
            inflight, outputs = carry
            # stage s works on microbatch t - s
            mb_idx = t - stage_id
            active = (mb_idx >= 0) & (mb_idx < T)
            # stage 0 reads fresh input; others use the handed-over act
            x_in = jnp.where(
                stage_id == 0,
                mb_local[jnp.clip(mb_idx, 0, T - 1)],
                inflight)
            y = stage_fn(params_s, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # last stage writes output for microbatch mb_idx
            out_idx = jnp.clip(mb_idx, 0, T - 1)
            write = active & (stage_id == S - 1)
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs,
                jnp.where(write, y, outputs[out_idx]),
                out_idx, 0)
            # hand activations to the next stage
            perm = [(i, (i + 1) % S) for i in range(S)]
            handed = jax.lax.ppermute(y, stage_axis, perm)
            return (handed, outputs), None

        inflight0 = jnp.zeros(mb_shape, x.dtype)
        outputs0 = jnp.zeros((T,) + mb_shape, x.dtype)
        (_, outputs), _ = jax.lax.scan(tick, (inflight0, outputs0),
                                       jnp.arange(n_ticks))
        # outputs live on the last stage; broadcast over the stage axis so
        # every shard returns the same value (out_spec replicates stage)
        outputs = jax.lax.psum(
            jnp.where(stage_id == S - 1, outputs,
                      jnp.zeros_like(outputs)), stage_axis)
        return outputs

    pspec = jax.tree_util.tree_map(lambda _: P(stage_axis), stage_params)
    out = _shard_map(
        region, mesh=mesh,
        in_specs=(jax.tree_util.tree_map(lambda _: P(stage_axis),
                                         stage_params), P()),
        out_specs=P(),
    )(stage_params, mb)
    return out.reshape((B,) + x.shape[1:])


def reference_apply(stage_fn: Callable, stage_params: PyTree,
                    x: jnp.ndarray) -> jnp.ndarray:
    """Unpipelined oracle: apply all stages sequentially."""
    S = jax.tree_util.tree_leaves(stage_params)[0].shape[0]
    for s in range(S):
        params_s = jax.tree_util.tree_map(lambda p: p[s], stage_params)
        x = stage_fn(params_s, x)
    return x
