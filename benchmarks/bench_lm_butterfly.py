"""§5.1 analogue at framework scale: train the smoke LM with a dense head
vs the butterfly-sandwich head (paper's replacement site) on the synthetic
LM stream; compare convergence and parameter counts."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs import registry
from repro.configs.base import TrainConfig
from repro.models import lm
from repro.runtime import pytree as pt
from repro.train.trainer import Trainer


def run(steps: int = 60) -> None:
    tc = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=steps)
    results = {}
    for variant in ("smollm-135m-smoke", "smollm-135m-butterfly-smoke"):
        cfg = registry.get(variant)
        tr = Trainer(cfg, tc, seq_len=64, global_batch=8)
        res = tr.run(steps)
        n_params = pt.param_count(lm.model_specs(cfg))
        results[variant] = (res.losses, n_params)
    dense_losses, dense_n = results["smollm-135m-smoke"]
    bfly_losses, bfly_n = results["smollm-135m-butterfly-smoke"]
    emit("lm_butterfly/final_loss", 0.0,
         f"dense={np.mean(dense_losses[-5:]):.4f};"
         f"butterfly={np.mean(bfly_losses[-5:]):.4f};"
         f"dense_params={dense_n};butterfly_params={bfly_n}")


if __name__ == "__main__":
    run()
