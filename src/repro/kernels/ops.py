"""Public jit'd entry points for the Pallas kernels.

Backend selection (``auto`` | ``jnp`` | ``pallas`` | ``pallas_interpret``):

* On TPU ``auto`` resolves to the compiled Pallas kernels (Mosaic) — for
  inference *and* training: every fused kernel carries a
  :func:`jax.custom_vjp` with a fused Pallas backward pass, so ``jax.grad``
  through these entry points stays on the fast path instead of falling back
  to log n unfused HBM round trips per stage.
* On CPU (this container) ``auto`` resolves to the *pure-jnp oracles*
  (Pallas interpret mode executes the kernel body in Python — correct but
  slow), while tests explicitly request ``backend="pallas_interpret"`` to
  validate the kernel bodies — forward and backward — themselves.
* ``REPRO_KERNEL_BACKEND`` in the environment overrides what ``auto``
  resolves to (read at trace time), e.g. to force the oracle path on TPU
  when bisecting a kernel bug.

Block sizes: the Pallas entry points take optional ``block_b`` (batch-tile
rows) and ``segment`` (backward checkpoint interval) knobs. ``None`` — the
default everywhere — defers to the :mod:`repro.kernels.tuning` VMEM/roofline
autotuner, so callers never pass magic numbers; explicit ints override it
(as do the ``REPRO_TUNE_*`` env vars, see ``tuning.py``).
"""

from __future__ import annotations

import os
from typing import Literal, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.butterfly import butterfly_matmul as _butterfly_pallas
from repro.kernels.sandwich import sandwich_matmul as _sandwich_pallas
from repro.kernels.sandwich import one_hot_select

Backend = Literal["auto", "jnp", "pallas", "pallas_interpret"]

_CONCRETE = ("jnp", "pallas", "pallas_interpret")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: Backend = "auto") -> str:
    """Resolve ``auto`` to a concrete backend (env override, then platform)."""
    if backend == "auto":
        env = os.environ.get("REPRO_KERNEL_BACKEND", "").strip().lower()
        if env and env != "auto":
            backend = env
        else:
            backend = "pallas" if _on_tpu() else "jnp"
    if backend not in _CONCRETE:
        raise ValueError(f"unknown kernel backend {backend!r}; expected one "
                         f"of {('auto',) + _CONCRETE}")
    return backend


def butterfly_apply(x: jnp.ndarray, w: jnp.ndarray, *,
                    transpose: bool = False,
                    backend: Backend = "auto",
                    block_b: Optional[int] = None,
                    segment: Optional[int] = None) -> jnp.ndarray:
    """Fused butterfly product over the last axis of ``x``.

    Differentiable under every backend; the Pallas backends use the fused
    custom_vjp backward kernel with segmented stage checkpointing.
    ``block_b``/``segment`` default to the autotuner (``tuning.py``).
    """
    backend = resolve_backend(backend)
    if backend == "jnp":
        return _ref.butterfly_ref(w.astype(x.dtype), x, transpose=transpose)
    interpret = backend == "pallas_interpret"
    return _butterfly_pallas(x, w, transpose=transpose, block_b=block_b,
                             segment=segment, interpret=interpret)


def sandwich_apply(x: jnp.ndarray, b_in: jnp.ndarray, sel_in: jnp.ndarray,
                   core: jnp.ndarray, sel_out: jnp.ndarray,
                   b_out: jnp.ndarray, *, scale_in: float = 1.0,
                   scale_out: float = 1.0,
                   backend: Backend = "auto",
                   block_b: Optional[int] = None,
                   segment: Optional[int] = None) -> jnp.ndarray:
    """Fused butterfly sandwich (dense-layer replacement) over the last axis.

    Differentiable under every backend; the Pallas backends use the fused
    custom_vjp backward kernel with segmented stage checkpointing.
    ``block_b``/``segment`` default to the autotuner (``tuning.py``).
    """
    backend = resolve_backend(backend)
    if backend == "jnp":
        return _ref.sandwich_ref(x, b_in, core, b_out, sel_in, sel_out,
                                 scale_in, scale_out)
    interpret = backend == "pallas_interpret"
    return _sandwich_pallas(x, b_in, sel_in, core, sel_out, b_out,
                            scale_in=scale_in, scale_out=scale_out,
                            block_b=block_b, segment=segment,
                            interpret=interpret)


__all__ = ["butterfly_apply", "sandwich_apply", "one_hot_select", "Backend",
           "resolve_backend"]
