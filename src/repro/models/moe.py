"""Mixture-of-Experts layer: top-k routing, capacity dropping, EP sharding.

Dispatch is *sort-based* (megablocks-style), never materializing the
``(tokens, experts, capacity)`` one-hot tensor that blows up memory at 32k
sequence lengths:

  1. top-k expert choice per token  → flat (T·k,) expert ids
  2. rank of each choice within its expert via an argsort-based stable rank
  3. scatter tokens into an (E, C, d) buffer, dropping rank ≥ C
  4. batched expert matmuls ``(E,C,d)x(E,d,f)`` — the ``experts`` axis is
     sharded over the mesh ``model`` axis (expert parallelism); GSPMD turns
     the combine back into token order + psum
  5. gather back + combine weighted by router probabilities

Buffer memory is ``E·C·d = k·cf·T·d`` — a small constant times the
activations. Aux losses: load-balance (Switch-style) + router z-loss.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.runtime.pytree import ParamSpec
from repro.runtime.sharding import constrain


def moe_specs(cfg: ModelConfig) -> Dict:
    E, F, X = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.param_dtype
    return {
        "router": ParamSpec((E, X), dt, ("embed", None),
                            init="scaled_normal", fan_in_dim=0),
        "w_gate": ParamSpec((X, E, F), dt, ("experts", "embed", "expert_mlp"),
                            init="scaled_normal", fan_in_dim=1),
        "w_up": ParamSpec((X, E, F), dt, ("experts", "embed", "expert_mlp"),
                          init="scaled_normal", fan_in_dim=1),
        "w_down": ParamSpec((X, F, E), dt, ("experts", "expert_mlp", "embed"),
                            init="scaled_normal", fan_in_dim=1),
    }


def moe_apply(cfg: ModelConfig, params: Dict, x: jnp.ndarray
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, E) -> (out (B,S,E), aux_loss scalar).

    On a multi-device mesh with a `model` axis dividing the expert count,
    dispatch runs under shard_map (true EP: local sort-based dispatch,
    expert shards on the model axis, one psum to combine). Letting GSPMD
    partition the scatter instead triggers involuntary full rematerialization
    (measured: 15x FLOP inflation on dbrx train_4k).
    """
    from repro.runtime.sharding import active_ctx
    ctx = active_ctx()
    if (ctx is not None and ctx.mesh is not None
            and "model" in ctx.mesh.shape
            and ctx.mesh.shape["model"] > 1
            and cfg.n_experts % ctx.mesh.shape["model"] == 0):
        return _moe_apply_ep(cfg, params, x, ctx)
    return _moe_apply_local(cfg, params, x)


def _moe_apply_local(cfg: ModelConfig, params: Dict, x: jnp.ndarray
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-shard path (smoke tests / no mesh)."""
    B, S, E = x.shape
    X, k = cfg.n_experts, cfg.top_k
    T = B * S
    cd = x.dtype
    xt = x.reshape(T, E)

    logits = (xt @ params["router"].astype(cd)).astype(jnp.float32)  # (T, X)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                           # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- aux losses ----
    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], X, dtype=jnp.float32),
                       axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    lb_loss = X * jnp.sum(density * mean_prob)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = (cfg.load_balance_coef * lb_loss + cfg.router_z_coef * z_loss)

    # ---- sort-based dispatch with capacity ----
    capacity = max(1, int(cfg.capacity_factor * k * T / X))
    flat_e = top_e.reshape(-1)                                       # (T·k,)
    # stable rank of each (token, choice) within its expert
    order = jnp.argsort(flat_e, stable=True)                         # (T·k,)
    # position within the sorted segment of the same expert:
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(X))            # (X,)
    pos_in_sorted = jnp.arange(T * k)
    rank_sorted = pos_in_sorted - seg_start[sorted_e]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)    # (T·k,)

    keep = rank < capacity
    slot = jnp.where(keep, rank, capacity - 1)
    tok_idx = jnp.repeat(jnp.arange(T), k)

    buf = jnp.zeros((X, capacity, E), cd)
    buf = buf.at[flat_e, slot].add(
        jnp.where(keep[:, None], xt[tok_idx], 0.0).astype(cd),
        mode="drop")
    buf = constrain(buf, ("experts", None, None))

    # ---- expert computation (EP over the `experts` axis) ----
    g = jnp.einsum("xcd,xdf->xcf", buf, params["w_gate"].astype(cd))
    u = jnp.einsum("xcd,xdf->xcf", buf, params["w_up"].astype(cd))
    h = jax.nn.silu(g) * u
    h = constrain(h, ("experts", None, "expert_mlp"))
    out_buf = jnp.einsum("xcf,xfd->xcd", h, params["w_down"].astype(cd))
    out_buf = constrain(out_buf, ("experts", None, None))

    # ---- combine ----
    gathered = out_buf.reshape(X * capacity, E)[flat_e * capacity + slot]
    gathered = jnp.where(keep[:, None], gathered, 0.0)               # (T·k,E)
    weighted = gathered.reshape(T, k, E) * top_p[..., None].astype(cd)
    out = weighted.sum(axis=1).reshape(B, S, E)
    return out, aux


def _moe_apply_ep(cfg: ModelConfig, params: Dict, x: jnp.ndarray, ctx
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert parallelism under shard_map.

    Layout inside the region: tokens are local to the DP shard and
    replicated over `model`; each model-rank holds E/|model| experts (FSDP
    dim all-gathered on use). Every rank dispatches its local tokens to ALL
    experts (local sort-based scatter — no GSPMD reasoning involved),
    computes its expert slice, scatters back, and a single psum over
    `model` combines expert outputs. Collectives per layer: the FSDP
    all-gathers + ONE psum of the (T_local, E) output — the same wire cost
    as a TP MLP.
    """
    from jax.sharding import PartitionSpec as P
    mesh = ctx.mesh
    n_ep = mesh.shape["model"]
    B, S, E = x.shape
    X = cfg.n_experts
    # greedy DP axes honoring batch divisibility (e.g. chunked prefill can
    # shrink the batch below pod*data)
    dp_axes = []
    prod = 1
    for a in ("data", "pod"):
        if a in mesh.shape and B % (prod * mesh.shape[a]) == 0:
            dp_axes.append(a)
            prod *= mesh.shape[a]
    dp_axes = tuple(dp_axes)
    x_spec = P(dp_axes if dp_axes else None)
    # params enter with their FSDP/TP layout and are gathered inside
    rspec = P(None, None)
    wspec = P("model", "data" if "data" in mesh.shape else None, None)
    wspec_down = P("model", None, "data" if "data" in mesh.shape else None)

    def region(xl, router, wg, wu, wd):
        # gather the FSDP dim of the expert weights
        if "data" in mesh.shape and mesh.shape["data"] > 1:
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
        y, aux = _moe_ep_local(cfg, xl, router, wg, wu, wd, n_ep)
        axes = dp_axes + ("model",)
        aux = jax.lax.pmean(aux, axes)
        return y, aux

    y, aux = jax.shard_map(
        region, mesh=mesh,
        in_specs=(x_spec, rspec, wspec, wspec, wspec_down),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, params["router"], params["w_gate"], params["w_up"],
      params["w_down"])
    return y, aux


def _moe_ep_local(cfg: ModelConfig, x, router, wg, wu, wd, n_ep: int):
    """Per-shard MoE: local dispatch to all experts, compute own slice,
    psum-combine over the `model` (EP) axis. All shapes here are LOCAL.

    Routing is per-token, so long sequences (32k prefill) are processed in
    independent token chunks — the (E, C, d) dispatch buffer scales with
    the chunk, not the sequence (k·cf·T·d bytes otherwise: 4 GB/layer on
    dbrx prefill)."""
    B, S, E = x.shape
    chunk = cfg.moe_token_chunk
    if chunk and B * S > chunk and (B * S) % chunk == 0:
        xt = x.reshape(-1, chunk, E)

        def one(xc):
            y, aux = _moe_ep_tokens(cfg, xc[None], router, wg, wu, wd, n_ep)
            return y[0], aux

        ys, auxs = jax.lax.map(one, xt)
        return ys.reshape(B, S, E), jnp.mean(auxs)
    return _moe_ep_tokens(cfg, x, router, wg, wu, wd, n_ep)


def _moe_ep_tokens(cfg: ModelConfig, x, router, wg, wu, wd, n_ep: int):
    B, S, E = x.shape
    X, k = cfg.n_experts, cfg.top_k
    X_loc = X // n_ep
    T = B * S
    cd = x.dtype
    xt = x.reshape(T, E)

    logits = (xt @ router.astype(cd)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    density = jnp.mean(jax.nn.one_hot(top_e[:, 0], X, dtype=jnp.float32),
                       axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    lb_loss = X * jnp.sum(density * mean_prob)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = (cfg.load_balance_coef * lb_loss + cfg.router_z_coef * z_loss)

    capacity = max(1, int(cfg.capacity_factor * k * T / X))
    flat_e = top_e.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(X))
    rank_sorted = jnp.arange(T * k) - seg_start[sorted_e]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    keep = rank < capacity
    slot = jnp.where(keep, rank, capacity - 1)
    tok_idx = jnp.repeat(jnp.arange(T), k)

    buf = jnp.zeros((X, capacity, E), cd)
    buf = buf.at[flat_e, slot].add(
        jnp.where(keep[:, None], xt[tok_idx], 0.0).astype(cd), mode="drop")

    # my expert slice
    rank_id = jax.lax.axis_index("model")
    buf_mine = jax.lax.dynamic_slice_in_dim(buf, rank_id * X_loc, X_loc, 0)
    g = jnp.einsum("xcd,xdf->xcf", buf_mine, wg.astype(cd))
    u = jnp.einsum("xcd,xdf->xcf", buf_mine, wu.astype(cd))
    h = jax.nn.silu(g) * u
    out_mine = jnp.einsum("xcf,xfd->xcd", h, wd.astype(cd))

    # combine: place my experts' outputs back into token order; other
    # experts contribute zero here and arrive via the psum.
    local_e = flat_e - rank_id * X_loc
    mine = (local_e >= 0) & (local_e < X_loc) & keep
    safe_e = jnp.clip(local_e, 0, X_loc - 1)
    gathered = out_mine.reshape(X_loc * capacity, E)[
        safe_e * capacity + slot]
    gathered = jnp.where(mine[:, None], gathered, 0.0)
    weighted = gathered.reshape(T, k, E) * top_p[..., None].astype(cd)
    y = weighted.sum(axis=1)
    y = jax.lax.psum(y, "model")
    return y.reshape(B, S, E), aux


def moe_dense_reference(cfg: ModelConfig, params: Dict, x: jnp.ndarray
                        ) -> jnp.ndarray:
    """All-experts reference (no capacity drops) — oracle for tests."""
    B, S, E = x.shape
    cd = x.dtype
    logits = (x.reshape(-1, E) @ params["router"].astype(cd)
              ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    xt = x.reshape(-1, E)
    g = jnp.einsum("td,xdf->xtf", xt, params["w_gate"].astype(cd))
    u = jnp.einsum("td,xdf->xtf", xt, params["w_up"].astype(cd))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("xtf,xfd->xtd", h, params["w_down"].astype(cd))   # (X,T,E)
    w = jnp.zeros((xt.shape[0], cfg.n_experts), jnp.float32)
    w = w.at[jnp.arange(xt.shape[0])[:, None], top_e].set(top_p)
    out = jnp.einsum("tx,xtd->td", w.astype(cd), y)
    return out.reshape(B, S, E)
