"""Substrate: checkpointing, fault tolerance, data pipeline, sharding rules."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.checkpoint.checkpointing import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.launch.mesh import make_mesh
from repro.runtime import sharding as sh
from repro.runtime.fault_tolerance import (HeartbeatMonitor, StragglerMonitor,
                                           plan_elastic_mesh)
from repro.runtime.pytree import ParamSpec, abstract_params, init_params


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "idx": jnp.arange(3, dtype=jnp.int32)},
            "opt": ({"mu": jnp.ones(4)}, None)}


def test_checkpoint_roundtrip(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    m.save(7, tree, extra={"loss": 1.5})
    s, restored, extra = m.restore(tree)
    assert s == 7 and extra["loss"] == 1.5
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))
    assert restored["opt"][1] is None


def test_checkpoint_retention_and_latest(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        m.save(s, _tree())
    assert m.steps() == [3, 4]
    s, _, _ = m.restore(_tree())
    assert s == 4


def test_checkpoint_corruption_fallback(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=5)
    m.save(1, _tree())
    m.save(2, _tree())
    # corrupt the newest
    with open(os.path.join(m._step_dir(2), "arrays.npz"), "wb") as f:
        f.write(b"garbage")
    s, restored, _ = m.restore(_tree())
    assert s == 1 and restored is not None


def test_checkpoint_torn_write_ignored(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=5)
    m.save(1, _tree())
    # simulate a torn write: directory without the sentinel
    os.makedirs(os.path.join(str(tmp_path), "step_000000009"))
    assert m.steps() == [1]


def test_checkpoint_async(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2)
    m.save(5, _tree(), async_=True)
    m.wait()
    assert m.steps() == [5]


# ---------------------------------------------------------------------------
# Fault tolerance
# ---------------------------------------------------------------------------

def test_heartbeat_detects_failure():
    failures = []
    mon = HeartbeatMonitor(["w0", "w1"], timeout=0.15,
                           on_failure=failures.append, poll=0.02)
    try:
        for _ in range(6):
            mon.ping("w0")
            time.sleep(0.05)
        time.sleep(0.2)
        assert "w1" in mon.dead
        assert "w0" in mon.alive or "w0" in mon.dead  # w0 may expire later
        assert "w1" in failures
    finally:
        mon.close()


def test_elastic_mesh_plan_shrinks_data_axis():
    plan = plan_elastic_mesh(alive_devices=192, model_parallelism=16,
                             global_batch=256)
    assert plan.shape == (12, 16) if 256 % 12 == 0 else True
    assert plan.n_devices <= 192
    assert plan.shape[-1] == 16
    assert 256 % plan.shape[0] == 0


def test_elastic_mesh_plan_multipod():
    plan = plan_elastic_mesh(alive_devices=480, model_parallelism=16,
                             global_batch=256, pods=2)
    assert plan.axes == ("pod", "data", "model")
    assert plan.shape[0] == 2 and plan.shape[2] == 16
    assert plan.n_devices <= 480


def test_elastic_mesh_plan_rejects_impossible():
    with pytest.raises(ValueError):
        plan_elastic_mesh(alive_devices=8, model_parallelism=16,
                          global_batch=64)


def test_straggler_monitor_policy():
    mon = StragglerMonitor(["a", "b", "c"], threshold=1.5, patience=3)
    act = mon.record({"a": 1.0, "b": 1.0, "c": 1.0})
    assert act.kind == "none"
    # c becomes slow: first flags → rebalance; persistent → evict
    kinds = []
    for _ in range(4):
        act = mon.record({"a": 1.0, "b": 1.0, "c": 5.0})
        kinds.append(act.kind)
    assert "rebalance" in kinds
    assert kinds[-1] == "evict"
    assert act.worker == "c"


def test_straggler_rebalance_weights_shift_work():
    mon = StragglerMonitor(["a", "b"], threshold=1.2, patience=10)
    act = None
    for _ in range(3):
        act = mon.record({"a": 1.0, "b": 3.0})
    assert act.kind == "rebalance"
    assert act.microbatch_weights["a"] > act.microbatch_weights["b"]


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_per_step():
    cfg = DataConfig(vocab_size=1000, seq_len=32, global_batch=4)
    src = SyntheticLM(cfg)
    a = src.batch(5)["tokens"]
    b = src.batch(5)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = src.batch(6)["tokens"]
    assert not np.array_equal(a, c)


def test_data_host_shards_disjoint_streams():
    k = dict(vocab_size=1000, seq_len=32, global_batch=8, host_count=2)
    h0 = SyntheticLM(DataConfig(host_index=0, **k)).batch(0)["tokens"]
    h1 = SyntheticLM(DataConfig(host_index=1, **k)).batch(0)["tokens"]
    assert h0.shape == (4, 32)
    assert not np.array_equal(h0, h1)


def test_prefetcher_ordered_and_resumable():
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
    src = SyntheticLM(cfg)
    pf = Prefetcher(src, start_step=10)
    try:
        steps = [next(pf)[0] for _ in range(3)]
        assert steps == [10, 11, 12]
    finally:
        pf.close()


def test_data_has_learnable_structure():
    """Motif spans must repeat across batches (models can beat unigram)."""
    cfg = DataConfig(vocab_size=5000, seq_len=128, global_batch=2)
    src = SyntheticLM(cfg)
    toks = np.concatenate([src.batch(i)["tokens"].ravel()
                           for i in range(4)])
    # motif tokens recur far more often than Zipf tail would suggest
    _, counts = np.unique(toks, return_counts=True)
    assert counts.max() > 10


# ---------------------------------------------------------------------------
# Sharding rules
# ---------------------------------------------------------------------------

def test_rules_divisibility_fallback():
    mesh = make_mesh((1,), ("model",))
    # kv_heads = 8 on a 16-way axis must fall back to replication —
    # simulate via a fake mesh dict-driven resolve
    from jax.sharding import PartitionSpec as P
    used = set()
    got = sh.resolve_axis("kv_heads", 8, _FakeMesh({"model": 16}),
                          sh.DEFAULT_RULES, used)
    assert got is None
    got2 = sh.resolve_axis("heads", 96, _FakeMesh({"model": 16}),
                           sh.DEFAULT_RULES, set())
    assert got2 == "model"


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_rules_axis_uniqueness():
    mesh = _FakeMesh({"data": 4, "model": 4})
    spec = sh.logical_to_pspec(("embed", "heads", "head_dim"),
                               (64, 16, 64), mesh, sh.DEFAULT_RULES)
    # "model" must appear at most once across all dims
    axes = [a for part in spec for a in
            ((part,) if isinstance(part, str) else (part or ()))]
    assert axes.count("model") <= 1


def test_batch_axes_composite():
    mesh = _FakeMesh({"pod": 2, "data": 4, "model": 4})
    spec = sh.logical_to_pspec(("batch", None), (64, 128), mesh,
                               sh.DEFAULT_RULES)
    assert spec[0] == ("pod", "data")


def test_butterfly_axes_have_explicit_rules():
    """Every logical axis the butterfly ParamSpecs use resolves through a
    deliberate DEFAULT_RULES entry, not the unknown-name fallback."""
    for name in sh.BUTTERFLY_AXES:
        assert name in sh.DEFAULT_RULES


_MESH_AXES = ("pod", "data", "model")


def _spec_mesh_axes(spec):
    """Flatten a PartitionSpec into the list of mesh-axis names it uses."""
    out = []
    for part in spec:
        if part is None:
            continue
        out.extend((part,) if isinstance(part, str) else part)
    return out


@settings(max_examples=200, deadline=None)
@given(
    mesh_sizes=st.tuples(st.integers(1, 4), st.integers(1, 8),
                         st.integers(1, 4)),
    rules=st.fixed_dictionaries({
        name: st.one_of(
            st.none(),
            st.sampled_from(_MESH_AXES),
            st.lists(st.sampled_from(_MESH_AXES), min_size=1, max_size=3,
                     unique=True).map(tuple))
        # "batch" rides along so the mixed activation case below can
        # actually exercise batch-vs-butterfly mesh-axis competition
        for name in sh.BUTTERFLY_AXES + ("batch",)}),
    stages=st.integers(1, 13),
    n=st.integers(1, 64).map(lambda e: 1 << (e % 14)),
    k_out=st.integers(1, 24),
    k_in=st.integers(1, 24),
)
def test_logical_to_pspec_butterfly_properties(mesh_sizes, rules, stages, n,
                                               k_out, k_in):
    """For ANY rule set over the butterfly logical axes and ANY mesh shape:
    a mesh axis appears at most once per spec, and the mesh-axis product
    assigned to a dim always divides it (replicate instead of mis-shard)."""
    mesh = _FakeMesh(dict(zip(_MESH_AXES, mesh_sizes)))
    cases = [
        (("stages", "butterfly_pair", "butterfly_n"), (stages, 2, n)),
        (("butterfly_core_out", "butterfly_core_in"), (k_out, k_in)),
        (("butterfly_bias",), (n,)),
        # batch + butterfly mix, as in an activation spec
        (("batch", "butterfly_n"), (k_out * 8, n)),
    ]
    for axes, shape in cases:
        spec = sh.logical_to_pspec(axes, shape, mesh, rules)
        used = _spec_mesh_axes(spec)
        # uniqueness: no mesh axis twice in one spec
        assert len(used) == len(set(used)), (spec, rules)
        # all axes exist in the mesh
        assert set(used) <= set(_MESH_AXES)
        # divisibility: assigned product divides the dim (non-divisible
        # dims must have dropped the axes)
        for dim, part in zip(shape, tuple(spec) + (None,) * len(shape)):
            parts = (() if part is None
                     else ((part,) if isinstance(part, str) else part))
            prod = 1
            for a in parts:
                prod *= mesh.shape[a]
            assert dim % prod == 0, (axes, shape, spec, rules)


def test_param_spec_tree_roundtrip():
    specs = {"a": ParamSpec((4, 8), jnp.float32, ("embed", "mlp")),
             "b": [ParamSpec((3,), jnp.float32, (None,), init="zeros")]}
    params = init_params(jax.random.PRNGKey(0), specs)
    assert params["a"].shape == (4, 8)
    assert float(jnp.sum(jnp.abs(params["b"][0]))) == 0.0
    abstract = abstract_params(specs)
    assert abstract["a"].shape == (4, 8)
