"""In-process smoke tests for the serving CLI (`python -m
repro.launch.serve`): the full entrypoint — arg parsing, engine/router
construction, trace generation + open-loop replay, fault arming, the
unified telemetry JSON, and Chrome-trace export — driven by calling
`main()` with a patched argv, so CI catches CLI breakage without a
subprocess (and without re-importing jax)."""

import json
import sys

import pytest

ARCH = "smollm-135m-smoke"


def _run_cli(monkeypatch, *argv):
    import repro.launch.serve as serve_cli

    monkeypatch.setattr(sys, "argv", ["repro.launch.serve", *argv])
    serve_cli.main()


def test_cli_paged_trace_with_armed_faults(monkeypatch, tmp_path, capsys):
    """Small paged trace with the fault injector armed at a rate high
    enough to actually fire recovery paths; the unified telemetry JSON
    must land and parse (summary + registry snapshot)."""
    out = tmp_path / "metrics.json"
    _run_cli(monkeypatch,
             "--arch", ARCH, "--requests", "3", "--slots", "2",
             "--max-len", "48", "--max-new", "4", "--pool", "paged",
             "--fault-seed", "0", "--fault-rate", "0.05",
             "--metrics-json", str(out))
    text = capsys.readouterr().out
    assert "[serve]" in text and "ttft" in text
    doc = json.loads(out.read_text())
    assert doc["schema"] == "repro.serve/telemetry-1"
    snap = doc["summary"]
    assert snap["requests_finished"] == 3
    assert snap["pool"]["kind"] == "paged"
    assert snap["ttft_ms"]["p50"] <= snap["ttft_ms"]["p95"]
    metrics = doc["metrics"]
    assert metrics["schema"] == "repro.obs/v1"
    fam = metrics["metrics"]["serve_requests_finished_total"]
    assert fam["samples"][0]["value"] == 3
    # the armed injector registered its per-site families
    assert "serve_fault_calls_total" in metrics["metrics"]


def test_cli_trace_out_and_metrics_interval(monkeypatch, tmp_path, capsys):
    """--trace-out exports a validator-clean Chrome trace covering every
    request's lifecycle; --metrics-interval exercises the periodic
    flusher (the final write still wins)."""
    from repro.obs.validate import validate_chrome_trace

    trace = tmp_path / "trace.json"
    out = tmp_path / "metrics.json"
    _run_cli(monkeypatch,
             "--arch", ARCH, "--requests", "3", "--slots", "2",
             "--max-len", "48", "--max-new", "4", "--pool", "paged",
             "--prefill-chunk", "8", "--admission", "incremental",
             "--trace-out", str(trace),
             "--metrics-json", str(out), "--metrics-interval", "0.05")
    text = capsys.readouterr().out
    assert f"wrote {trace}" in text
    doc = json.loads(trace.read_text())
    events = validate_chrome_trace(doc)
    names = {e["name"] for e in events}
    assert {"queue", "admit", "tick", "finish", "compile"} <= names
    finishes = [e for e in events if e["name"] == "finish"]
    assert len(finishes) == 3
    # one request lane per request, plus the engine lane
    assert {e["tid"] for e in events} >= {0, 1, 2, 3}
    doc2 = json.loads(out.read_text())
    assert doc2["summary"]["requests_finished"] == 3


def test_cli_two_replicas_writes_router_snapshot(monkeypatch, tmp_path,
                                                 capsys):
    """--replicas 2 routes the same trace through the Router; the JSON
    summary is the tier snapshot (aggregate SLO percentiles +
    per-replica engine detail) and the trace carries one pid per
    replica."""
    from repro.obs.validate import validate_chrome_trace

    out = tmp_path / "router.json"
    trace = tmp_path / "router_trace.json"
    _run_cli(monkeypatch,
             "--arch", ARCH, "--requests", "4", "--slots", "2",
             "--max-len", "48", "--max-new", "4", "--replicas", "2",
             "--rate", "50", "--mix", "bimodal",
             "--trace-out", str(trace),
             "--metrics-json", str(out))
    text = capsys.readouterr().out
    assert "replicas=2" in text and "[serve] router:" in text
    doc = json.loads(out.read_text())
    snap = doc["summary"]
    assert snap["replicas"] == 2
    assert snap["requests_finished"] == 4
    assert len(snap["per_replica"]) == 2
    assert sum(p["dispatched"] for p in snap["per_replica"]) == 4
    assert {"p50", "p95"} <= set(snap["latency_ms"])
    # both replicas publish into the one registry, split by label
    fam = doc["metrics"]["metrics"]["serve_requests_finished_total"]
    assert {s["labels"]["replica"] for s in fam["samples"]} == {"0", "1"}
    assert sum(s["value"] for s in fam["samples"]) == 4
    events = validate_chrome_trace(json.loads(trace.read_text()))
    finishes = [e for e in events if e["name"] == "finish"]
    assert len(finishes) == 4
    # replicas trace under their own pid (dispatch split is timing-
    # dependent, so only the label space is pinned, not the split)
    assert {e["pid"] for e in finishes} <= {0, 1}
    assert {e["pid"] for e in events if e["name"] == "tick"} == {0, 1}


def test_cli_rejects_bad_geometry(monkeypatch, tmp_path):
    with pytest.raises(SystemExit, match="no valid prompt length"):
        _run_cli(monkeypatch, "--arch", ARCH, "--requests", "2",
                 "--max-len", "16", "--max-new", "14",
                 "--min-prompt", "8")
    with pytest.raises(SystemExit, match="--replicas"):
        _run_cli(monkeypatch, "--arch", ARCH, "--replicas", "0")
