"""``repro.obs`` — process-wide observability: span tracing + metrics.

Three pillars, all zero-dependency (stdlib only) so the serving tier can
instrument itself without touching jax:

* :mod:`repro.obs.tracing` — a bounded-ring :class:`Tracer` emitting
  per-request and per-engine spans with wall-clock *and* deterministic
  engine-tick timestamps, exported as Chrome trace-event JSON (loadable
  in Perfetto / ``chrome://tracing``; one track per replica, one per
  request). :data:`NULL_TRACER` is the always-installed no-op default, so
  the tracing-off hot path costs a handful of no-op calls per tick.
* :mod:`repro.obs.registry` — typed :class:`Counter` / :class:`Gauge` /
  :class:`Histogram` primitives plus callback collectors in one
  lock-protected :class:`MetricsRegistry`, with a stable JSON snapshot
  schema (:data:`SNAPSHOT_SCHEMA`) and a Prometheus-style text
  exposition. The serving engine, router, cache pool, fault injector and
  compile cache all register into one registry — ONE machine-readable
  telemetry surface instead of five ad-hoc dicts.
* :mod:`repro.obs.profiling` — ``jax.profiler.TraceAnnotation`` wrappers
  around the fused butterfly / sandwich / flash / paged-attention kernel
  call sites, gated on the ambient
  :class:`repro.kernels.context.ExecutionContext` (``profile=True``), so
  device profiles line up with the engine's span names. Imported lazily
  by the kernel modules — importing ``repro.obs`` itself never imports
  jax.

:mod:`repro.obs.validate` structurally validates Chrome trace-event JSON
(every event carries ``ph/ts/pid/tid/name``, complete spans properly
nested per track) — the CI artifact gate and the tests share it:
``python -m repro.obs.validate trace.json``.
"""

from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                SNAPSHOT_SCHEMA)
from repro.obs.tracing import NULL_TRACER, NullTracer, Tracer

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "SNAPSHOT_SCHEMA",
]
