"""Flash-attention Pallas kernels (TPU target), forward *and* backward —
beyond-paper optimization.

The jnp blockwise path in :mod:`repro.models.attention` implements the same
online-softmax algorithm but XLA materializes each (block_q, block_kv) score
tile and the f32 accumulator in HBM between loop steps (visible in the
roofline memory term). The forward kernel keeps q-tile, running
max/denominator and the accumulator resident in VMEM for the whole KV sweep:
HBM traffic drops to one read of Q/K/V + one write of O (+ the (B·H, S)
logsumexp row, the only residual the backward needs).

Training support: ``flash_attention`` carries a :func:`jax.custom_vjp` with
**checkpointed recompute** in the same spirit as the butterfly kernels — the
O(S²) probability matrix is never stored; backward re-derives each score
tile from (q, k, lse) inside VMEM. Two fused kernels cover the three
cotangents (the standard flash backward split):

* dKV kernel, grid (B·H, S/block_kv): for each kv tile, sweep the valid q
  range accumulating ``dv += pᵀ·do`` and ``dk += dsᵀ·q`` in float32;
* dQ kernel, grid (B·H, S/block_q): for each q tile, sweep the valid kv
  range accumulating ``dq += ds·k``;

with ``p = exp(s − lse)`` and ``ds = p ⊙ (dp − Δ)``, ``Δ = rowsum(do ⊙ o)``
computed once outside (elementwise, XLA-fused). Causal + sliding-window
masking mirrors the forward exactly. Block sizes default to the
:mod:`repro.kernels.tuning` VMEM model.

Validated against :func:`repro.kernels.ref.flash_attention_ref` — forward
and gradients — in interpret mode (tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tuning

NEG_INF = -1e30


def _kv_bounds(qi, block_q: int, block_kv: int, seq_len: int, causal: bool,
               window: int):
    """KV-block sweep range for one q block (mirrors the masking)."""
    nkv = seq_len // block_kv
    if causal:
        hi = (qi * block_q + block_q + block_kv - 1) // block_kv
    else:
        hi = nkv
    if window > 0:
        lo = jnp.maximum(0, (qi * block_q - window) // block_kv)
    else:
        lo = 0
    return lo, hi


def _tile_mask(q_ids, k_ids, causal: bool, window: int):
    mask = jnp.ones((q_ids.shape[0], k_ids.shape[0]), jnp.bool_)
    if causal:
        mask &= k_ids[None, :] <= q_ids[:, None]
    if window > 0:
        mask &= k_ids[None, :] > q_ids[:, None] - window
    return mask


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_q: int,
                  block_kv: int, seq_len: int, causal: bool, window: int,
                  scale: float):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale          # (bq, d)
    q_ids = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    lo, hi = _kv_bounds(qi, block_q, block_kv, seq_len, causal, window)

    def body(j, state):
        m, l, acc = state
        k = pl.load(k_ref, (pl.dslice(j * block_kv, block_kv),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * block_kv, block_kv),
                            slice(None))).astype(jnp.float32)
        s = q @ k.T                                     # (bq, bkv)
        k_ids = j * block_kv + jax.lax.iota(jnp.int32, block_kv)
        s = jnp.where(_tile_mask(q_ids, k_ids, causal, window), s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    a0 = jnp.zeros((block_q, q_ref.shape[-1]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
    lse_ref[...] = m + jnp.log(jnp.maximum(l, 1e-30))


def _flash_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                         dq_ref, *, block_q: int, block_kv: int,
                         seq_len: int, causal: bool, window: int,
                         scale: float):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale          # (bq, d)
    do = do_ref[...].astype(jnp.float32)                # (bq, d)
    lse = lse_ref[...]                                  # (bq,)
    delta = delta_ref[...]                              # (bq,)
    q_ids = qi * block_q + jax.lax.iota(jnp.int32, block_q)
    lo, hi = _kv_bounds(qi, block_q, block_kv, seq_len, causal, window)

    def body(j, dq):
        k = pl.load(k_ref, (pl.dslice(j * block_kv, block_kv),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * block_kv, block_kv),
                            slice(None))).astype(jnp.float32)
        s = q @ k.T
        k_ids = j * block_kv + jax.lax.iota(jnp.int32, block_kv)
        mask = _tile_mask(q_ids, k_ids, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dp = do @ v.T                                   # (bq, bkv)
        ds = p * (dp - delta[:, None])
        return dq + ds @ k
    dq = jax.lax.fori_loop(
        lo, hi, body, jnp.zeros((block_q, q_ref.shape[-1]), jnp.float32))
    dq_ref[...] = (dq * scale).astype(dq_ref.dtype)


def _flash_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                          dk_ref, dv_ref, *, block_q: int, block_kv: int,
                          seq_len: int, causal: bool, window: int,
                          scale: float):
    ki = pl.program_id(1)
    k = k_ref[...].astype(jnp.float32)                  # (bkv, d)
    v = v_ref[...].astype(jnp.float32)
    k_ids = ki * block_kv + jax.lax.iota(jnp.int32, block_kv)
    nq = seq_len // block_q
    # valid q blocks: q >= min(k) when causal; q < max(k) + window when
    # windowed (the exact per-element mask is applied inside the tile)
    lo = (ki * block_kv) // block_q if causal else 0
    if window > 0:
        hi = jnp.minimum(nq,
                         (ki * block_kv + block_kv - 1 + window) // block_q
                         + 1)
    else:
        hi = nq

    def body(j, state):
        dk, dv = state
        q = pl.load(q_ref, (pl.dslice(j * block_q, block_q),
                            slice(None))).astype(jnp.float32) * scale
        do = pl.load(do_ref, (pl.dslice(j * block_q, block_q),
                              slice(None))).astype(jnp.float32)
        lse = pl.load(lse_ref, (pl.dslice(j * block_q, block_q),))
        delta = pl.load(delta_ref, (pl.dslice(j * block_q, block_q),))
        q_ids = j * block_q + jax.lax.iota(jnp.int32, block_q)
        s = q @ k.T                                     # (bq, bkv)
        mask = _tile_mask(q_ids, k_ids, causal, window)
        p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
        dv = dv + p.T @ do                              # (bkv, d)
        dp = do @ v.T                                   # (bq, bkv)
        ds = p * (dp - delta[:, None])
        dk = dk + ds.T @ q                              # (bkv, d), q scaled
        return dk, dv

    z = jnp.zeros((block_kv, k_ref.shape[-1]), jnp.float32)
    dk, dv = jax.lax.fori_loop(lo, hi, body, (z, z))
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _flash_fwd_call(q, k, v, causal, window, block_q, block_kv, interpret,
                    *, with_lse: bool):
    B, H, S, D = q.shape
    assert S % block_q == 0 and S % block_kv == 0, (S, block_q, block_kv)
    scale = D ** -0.5
    grid = (B * H, S // block_q)
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, S, D)
    vf = v.reshape(B * H, S, D)
    out, lse = pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q,
                          block_kv=block_kv, seq_len=S, causal=causal,
                          window=window, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, S, D), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, S, D), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, block_q, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, block_q), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
            jax.ShapeDtypeStruct((B * H, S), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out.reshape(B, H, S, D)
    return (out, lse) if with_lse else out


def _flash_bwd_call(q, k, v, out, lse, g, causal, window, block_q, block_kv,
                    interpret):
    B, H, S, D = q.shape
    scale = D ** -0.5
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, S, D)
    vf = v.reshape(B * H, S, D)
    dof = g.astype(q.dtype).reshape(B * H, S, D)
    # Δ = rowsum(dO ⊙ O): elementwise over (B·H, S, D), XLA fuses it — the
    # only O(S·D) extra HBM pass the backward needs.
    delta = jnp.sum(dof.astype(jnp.float32)
                    * out.reshape(B * H, S, D).astype(jnp.float32), axis=-1)
    kw = dict(block_q=block_q, block_kv=block_kv, seq_len=S, causal=causal,
              window=window, scale=scale)
    row_specs = [
        pl.BlockSpec((None, S, D), lambda b, i: (b, 0, 0)),   # q
        pl.BlockSpec((None, S, D), lambda b, i: (b, 0, 0)),   # k
        pl.BlockSpec((None, S, D), lambda b, i: (b, 0, 0)),   # v
        pl.BlockSpec((None, S, D), lambda b, i: (b, 0, 0)),   # do
        pl.BlockSpec((None, S), lambda b, i: (b, 0)),         # lse
        pl.BlockSpec((None, S), lambda b, i: (b, 0)),         # delta
    ]
    dq_specs = list(row_specs)
    dq_specs[0] = pl.BlockSpec((None, block_q, D), lambda b, i: (b, i, 0))
    dq_specs[3] = pl.BlockSpec((None, block_q, D), lambda b, i: (b, i, 0))
    dq_specs[4] = pl.BlockSpec((None, block_q), lambda b, i: (b, i))
    dq_specs[5] = pl.BlockSpec((None, block_q), lambda b, i: (b, i))
    dq = pl.pallas_call(
        functools.partial(_flash_bwd_dq_kernel, **kw),
        grid=(B * H, S // block_q),
        in_specs=dq_specs,
        out_specs=pl.BlockSpec((None, block_q, D), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        interpret=interpret,
    )(qf, kf, vf, dof, lse, delta)
    dkv_specs = list(row_specs)
    dkv_specs[1] = pl.BlockSpec((None, block_kv, D), lambda b, i: (b, i, 0))
    dkv_specs[2] = pl.BlockSpec((None, block_kv, D), lambda b, i: (b, i, 0))
    dk, dv = pl.pallas_call(
        functools.partial(_flash_bwd_dkv_kernel, **kw),
        grid=(B * H, S // block_kv),
        in_specs=dkv_specs,
        out_specs=[
            pl.BlockSpec((None, block_kv, D), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, block_kv, D), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, S, D), k.dtype),
            jax.ShapeDtypeStruct((B * H, S, D), v.dtype),
        ],
        interpret=interpret,
    )(qf, kf, vf, dof, lse, delta)
    shape = (B, H, S, D)
    return dq.reshape(shape), dk.reshape(shape), dv.reshape(shape)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_diff(q, k, v, causal, window, block_q, block_kv, interpret):
    return _flash_fwd_call(q, k, v, causal, window, block_q, block_kv,
                           interpret, with_lse=False)


def _flash_diff_fwd(q, k, v, causal, window, block_q, block_kv, interpret):
    out, lse = _flash_fwd_call(q, k, v, causal, window, block_q, block_kv,
                               interpret, with_lse=True)
    # residuals: inputs + output + the (B·H, S) logsumexp — the score matrix
    # is recomputed tile-by-tile in VMEM, never stored
    return out, (q, k, v, out, lse)


def _flash_diff_bwd(causal, window, block_q, block_kv, interpret, res, g):
    q, k, v, out, lse = res
    return _flash_bwd_call(q, k, v, out, lse, g, causal, window, block_q,
                           block_kv, interpret)


_flash_diff.defvjp(_flash_diff_fwd, _flash_diff_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    block_q=None, block_kv=None,
                    interpret: bool = False) -> jnp.ndarray:
    """q/k/v: (B, H, S, D) (KV heads pre-expanded or H == KV). S must be a
    multiple of the block sizes.

    Differentiable in q, k, v via fused Pallas backward kernels (custom_vjp)
    that recompute score tiles from the saved logsumexp instead of storing
    the O(S²) probability matrix. ``block_q``/``block_kv`` default to the
    :mod:`repro.kernels.tuning` VMEM model; pass ints only to override.
    """
    from repro.obs.profiling import annotate
    B, H, S, D = q.shape
    if block_q is None or block_kv is None:
        bq, bkv = tuning.flash_blocks(S, D, jnp.dtype(q.dtype).name, "bwd")
        block_q = block_q or bq
        block_kv = block_kv or bkv
    with annotate("flash_attention"):
        return _flash_diff(q, k, v, causal, window, block_q, block_kv,
                           interpret)
