"""`repro.kernels.paged_attention`: the paged-gather decode attention.

Gates the two implementations against each other and against plain dense
attention: the jnp gather oracle must equal dense masked attention on a
page-permuted pool (scatter/gather roundtrip + positional mask), trash-page
and stale-page contents must be unobservable, multi-query (chunk) calls
must agree with single-query calls, and the Pallas kernel (interpret mode
on CPU, like the flash kernels) must match the oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import context as exctx
from repro.kernels import paged_attention as pa

B, P, PS, KV, G, D = 2, 3, 4, 2, 2, 8   # P*PS = 12 logical positions
N = 1 + B * P                            # physical pages incl. trash


def _setup(seed=0, dtype=jnp.float32):
    """Random per-slot dense K/V scattered into a permuted page pool."""
    rng = np.random.default_rng(seed)
    L = P * PS
    k = rng.normal(size=(B, L, KV, D)).astype(np.float32)
    v = rng.normal(size=(B, L, KV, D)).astype(np.float32)
    # physical ids 1..N-1 in a seeded shuffle: page order must not matter
    ids = rng.permutation(np.arange(1, N)).reshape(B, P).astype(np.int32)
    k_pool = np.zeros((N, PS, KV, D), np.float32)
    v_pool = np.zeros((N, PS, KV, D), np.float32)
    for b in range(B):
        for p in range(P):
            k_pool[ids[b, p]] = k[b, p * PS:(p + 1) * PS]
            v_pool[ids[b, p]] = v[b, p * PS:(p + 1) * PS]
    return (jnp.asarray(k, dtype), jnp.asarray(v, dtype),
            jnp.asarray(k_pool, dtype), jnp.asarray(v_pool, dtype),
            jnp.asarray(ids))


def _dense_ref(q, k, v, q_pos):
    """Plain masked GQA attention over the dense (B, L, KV, D) layout."""
    L = k.shape[1]
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, k
                        ).astype(jnp.float32) * (D ** -0.5)
    valid = jnp.arange(L)[None, None, :] <= q_pos[:, :, None]
    logits = jnp.where(valid[:, None, None, :, :], logits, pa.NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)


def test_gather_pages_restores_position_order():
    k, _, k_pool, _, ids = _setup()
    np.testing.assert_array_equal(np.asarray(pa.gather_pages(k_pool, ids)),
                                  np.asarray(k))


def test_oracle_matches_dense_attention_on_permuted_pool():
    k, v, k_pool, v_pool, ids = _setup()
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(B, 1, KV, G, D)), jnp.float32)
    for pos in (0, 3, 7, 11):              # page-boundary and interior
        q_pos = jnp.full((B, 1), pos, jnp.int32)
        got = pa.paged_attend_ref(q, k_pool, v_pool, ids, q_pos)
        want = _dense_ref(q, k, v, q_pos)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-6, rtol=1e-6)


def test_trash_and_stale_pages_are_unobservable():
    """Garbage in the trash page, in unmapped table entries, and in cache
    positions past ``q_pos`` must never reach the output — the positional
    validity mask is the only thing standing between them and the softmax,
    so this is THE paging-safety gate."""
    k, v, k_pool, v_pool, ids = _setup()
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(B, 1, KV, G, D)), jnp.float32)
    q_pos = jnp.asarray([[5], [2]], jnp.int32)    # mid-page prefixes
    want = pa.paged_attend_ref(q, k_pool, v_pool, ids, q_pos)

    k_dirty, v_dirty = np.asarray(k_pool).copy(), np.asarray(v_pool).copy()
    k_dirty[pa.TRASH_PAGE] = 1e4                   # trash-page garbage
    v_dirty[pa.TRASH_PAGE] = -1e4
    for b in range(B):                             # beyond-prefix garbage
        pos = int(q_pos[b, 0])
        page, off = (pos + 1) // PS, (pos + 1) % PS
        k_dirty[int(ids[b, page]), off:] = 7e3
        v_dirty[int(ids[b, page]), off:] = -7e3
    ids_dirty = np.asarray(ids).copy()
    got = pa.paged_attend_ref(q, jnp.asarray(k_dirty),
                              jnp.asarray(v_dirty),
                              jnp.asarray(ids_dirty), q_pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


def test_chunk_queries_match_single_queries():
    """Sq>1 (the chunked-prefill read path) must agree with Sq=1 per
    position — chunking a prompt is a pure batching decision."""
    _, _, k_pool, v_pool, ids = _setup(seed=3)
    rng = np.random.default_rng(4)
    Sq = 4
    q = jnp.asarray(rng.normal(size=(B, Sq, KV, G, D)), jnp.float32)
    base = 5
    q_pos = base + jnp.tile(jnp.arange(Sq)[None, :], (B, 1))
    chunk = pa.paged_attend_ref(q, k_pool, v_pool, ids, q_pos)
    for s in range(Sq):
        single = pa.paged_attend_ref(q[:, s:s + 1], k_pool, v_pool, ids,
                                     q_pos[:, s:s + 1])
        np.testing.assert_allclose(np.asarray(chunk[:, s]),
                                   np.asarray(single[:, 0]),
                                   atol=1e-6, rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_interpret_matches_oracle(dtype):
    _, _, k_pool, v_pool, ids = _setup(seed=5, dtype=dtype)
    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.normal(size=(B, KV, G, D)), dtype)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    for pos in (0, 4, 11):
        cur = jnp.full((B,), pos, jnp.int32)
        want = pa.paged_decode_attention(q, k_pool, v_pool, ids, cur,
                                         backend="jnp")
        got = pa.paged_decode_attention(q, k_pool, v_pool, ids, cur,
                                        backend="pallas_interpret")
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=tol, rtol=tol)


def test_pallas_skips_pages_past_the_prefix():
    """Pages wholly past ``cur_pos`` are skipped by ``pl.when`` — NaN
    garbage there must not poison the online softmax (a mask applied after
    the dot product would still propagate NaN through exp; the skip must
    be structural)."""
    _, _, k_pool, v_pool, ids = _setup(seed=7)
    k_dirty = np.asarray(k_pool).copy()
    v_dirty = np.asarray(v_pool).copy()
    # slot 0's LAST page is beyond cur_pos=3: fill it with NaN
    k_dirty[int(ids[0, 2])] = np.nan
    v_dirty[int(ids[0, 2])] = np.nan
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(size=(B, KV, G, D)), jnp.float32)
    cur = jnp.asarray([3, 11], jnp.int32)
    got = pa.paged_decode_attention(jnp.asarray(q), jnp.asarray(k_dirty),
                                    jnp.asarray(v_dirty), ids, cur,
                                    backend="pallas_interpret")
    assert np.isfinite(np.asarray(got)).all()
    want = pa.paged_decode_attention(q, k_pool, v_pool, ids, cur,
                                     backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


def test_backend_resolves_from_ambient_context():
    """backend=None inside ``use_execution(pallas_interpret)`` runs the
    kernel path; the result still matches the oracle."""
    _, _, k_pool, v_pool, ids = _setup(seed=9)
    rng = np.random.default_rng(10)
    q = jnp.asarray(rng.normal(size=(B, KV, G, D)), jnp.float32)
    cur = jnp.asarray([7, 9], jnp.int32)
    want = pa.paged_decode_attention(q, k_pool, v_pool, ids, cur,
                                     backend="jnp")
    with exctx.use_execution(
            exctx.ExecutionContext(backend="pallas_interpret")):
        got = pa.paged_decode_attention(q, k_pool, v_pool, ids, cur)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)
