"""Fwd+bwd step time of the fused butterfly kernels vs the jnp oracle.

The paper's pitch is cheaper *training*, so this measures a full
value-and-grad step (input and weight cotangents) through
``butterfly_apply`` and ``sandwich_apply`` across n. The fused Pallas path
compiles only on TPU (Mosaic); on CPU those rows are emitted as skipped —
interpret-mode timings are Python-loop artifacts, not kernel performance —
while the jnp-oracle rows still track the unfused baseline per platform.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import butterfly as bf
from repro.core import layers as bl
from repro.kernels import ops
from repro.kernels.sandwich import one_hot_select

NS = (1024, 2048, 4096, 8192, 16384)


def _butterfly_step(backend, w_shape_c):
    c = w_shape_c

    def loss(x, w):
        return jnp.vdot(c, ops.butterfly_apply(x, w, backend=backend))

    return jax.jit(jax.grad(loss, argnums=(0, 1)))


def run(ns=NS, batch: int = 64) -> None:
    on_tpu = jax.default_backend() == "tpu"
    for n in ns:
        w = bf.random_weights(jax.random.PRNGKey(0), n)
        x = jax.random.normal(jax.random.PRNGKey(1), (batch, n))
        c = jax.random.normal(jax.random.PRNGKey(2), (batch, n))
        t_jnp = time_fn(_butterfly_step("jnp", c), x, w)
        emit(f"backward/butterfly_fwdbwd_jnp_n{n}", t_jnp, f"batch={batch}")
        if on_tpu:
            t_fused = time_fn(_butterfly_step("pallas", c), x, w)
            emit(f"backward/butterfly_fwdbwd_fused_n{n}", t_fused,
                 f"batch={batch};speedup_vs_jnp={t_jnp / t_fused:.2f}x")
        else:
            emit(f"backward/butterfly_fwdbwd_fused_n{n}", 0.00,
                 "status=skipped;reason=no_tpu_interpret_timing_meaningless")

    # one sandwich shape: the full dense-layer replacement, fwd+bwd
    n1 = n2 = ns[0]
    k1 = k2 = max(2, int(math.log2(n1)))
    spec = bl.make_spec(jax.random.PRNGKey(3), n1, n2, k_in=k1, k_out=k2,
                        use_bias=False)
    params = bl.init_butterfly_linear(jax.random.PRNGKey(4), spec)
    x = jax.random.normal(jax.random.PRNGKey(5), (batch, n1))
    c = jax.random.normal(jax.random.PRNGKey(6), (batch, n2))
    sel_in = one_hot_select(spec.idx_in, n1)
    sel_out = one_hot_select(spec.idx_out, n2).T
    si, so = math.sqrt(n1 / k1), math.sqrt(n2 / k2)

    def sandwich_step(backend):
        def loss(x, b_in, core, b_out):
            return jnp.vdot(c, ops.sandwich_apply(
                x, b_in, sel_in, core, sel_out, b_out,
                scale_in=si, scale_out=so, backend=backend))

        fn = jax.jit(jax.grad(loss, argnums=(0, 1, 2, 3)))
        return lambda: fn(x, params["b_in"], params["core"], params["b_out"])

    t_jnp = time_fn(sandwich_step("jnp"))
    emit(f"backward/sandwich_fwdbwd_jnp_n{n1}", t_jnp,
         f"batch={batch};k={k1}")
    if on_tpu:
        t_fused = time_fn(sandwich_step("pallas"))
        emit(f"backward/sandwich_fwdbwd_fused_n{n1}", t_fused,
             f"batch={batch};k={k1};speedup_vs_jnp={t_jnp / t_fused:.2f}x")
    else:
        emit(f"backward/sandwich_fwdbwd_fused_n{n1}", 0.00,
             "status=skipped;reason=no_tpu_interpret_timing_meaningless")


if __name__ == "__main__":
    run()
