"""Quickstart: the paper's butterfly sandwich as a drop-in dense replacement.

Run: ``PYTHONPATH=src python examples/quickstart.py``

Shows, via the ``repro.nn.ButterflyLinear`` module API, (1) the parameter
reduction, (2) Proposition 3.1 approximation at init (``from_dense``),
(3) trainability — the sandwich learns a random linear map — and (4) the
``ExecutionContext`` one-liner that would move the same layer onto another
backend or an 8-device mesh.
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import nn
from repro.kernels import ExecutionContext, use_execution
from repro.optim import optimizer as opt


def main():
    n = 512
    print(f"== Butterfly sandwich replacing a dense {n}x{n} layer ==")

    # --- Proposition 3.1: approximate a given W at init ---
    W = np.random.default_rng(0).normal(size=(n, n)).astype(np.float32)
    W /= np.sqrt(n)
    layer, params = nn.ButterflyLinear.from_dense(
        jax.random.PRNGKey(0), jnp.asarray(W), k_in=64, k_out=64)
    print(f"dense params:     {layer.dense_param_count():,}")
    print(f"butterfly params: {layer.param_count():,} "
          f"(k_in={layer.spec.k_in}, k_out={layer.spec.k_out})")

    x = np.random.default_rng(1).normal(size=(n,)).astype(np.float32)
    x /= np.linalg.norm(x)
    approx = np.asarray(layer.apply(params, jnp.asarray(x)))
    err = np.linalg.norm(approx - W @ x) / np.linalg.norm(W, 2)
    print(f"init approximation error (k=64): {err:.3f} · ||W||")

    # --- train to recover the map ---
    X = jax.random.normal(jax.random.PRNGKey(2), (1024, n))
    Y = X @ jnp.asarray(W).T

    def loss(p):
        return jnp.mean(jnp.square(layer.apply(p, X) - Y))

    tx = opt.adamw(3e-3)
    state = tx.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(loss)(p)
        u, s = tx.update(g, s, p)
        return opt.apply_updates(p, u), s

    print(f"loss before training: {float(loss(params)):.5f}")
    for i in range(300):
        params, state = step(params, state)
    print(f"loss after 300 steps: {float(loss(params)):.5f}")

    # --- execution policy is one object, not a kwarg pipeline ---
    # per-call: layer.apply(params, x, context="pallas")   (on TPU)
    # ambient:  everything in the block inherits the context
    with use_execution(ExecutionContext(backend="jnp")):
        y = layer.apply(params, jnp.asarray(x))
    print(f"ambient-context apply matches: "
          f"{bool(jnp.allclose(y, layer.apply(params, jnp.asarray(x))))}")
    print("to shard the same layer over 8 devices: "
          "use_execution(ExecutionContext(mesh_shape=(8,)))")


if __name__ == "__main__":
    main()
