"""Analytic VMEM/roofline autotuner for the fused Pallas kernels.

Every kernel in this package used to hard-code ``block_b = 256`` (and flash
``block_q = block_kv = 128``) regardless of n, dtype, or direction. That is
exactly the flat, hardware-unaware choice Pixelated Butterfly warns turns
theoretical sparsity into wall-clock slowdowns: at n = 8192 the segmented
backward keeps ~2·⌈√p⌉ activation tiles live, so a 256-row tile would need
>80 MB of VMEM — an order of magnitude over budget — while at n = 256 a
256-row tile underutilizes the VPU lanes.

This module picks ``block_b`` (batch-tile rows) and ``segment`` (backward
checkpoint segment length, see :mod:`repro.kernels.butterfly`) per
``(kernel, n, dtype, direction)`` from an analytic VMEM-footprint model plus
the roofline constants of :mod:`repro.launch.roofline`:

* footprint model — weights + weight-grad accumulators + the number of
  activation tiles the kernel keeps live (2 forward; ``⌈p/seg⌉ + seg + 3``
  for the checkpointed backward) must fit the VMEM budget;
* roofline estimate — per-row FLOPs over ``PEAK_FLOPS`` vs per-row HBM bytes
  over ``HBM_BW``; reported in :class:`KernelChoice` so benchmarks and the
  trainer can record *why* a block size was picked.

Choices are cached (``functools.lru_cache``) and overridable. The override
order matches :mod:`repro.kernels.context`: an explicit value (from an
:class:`~repro.kernels.context.ExecutionContext` or config) beats the
ambient context, which beats the env vars, which beat the model:

* ``REPRO_TUNE_BLOCK_B``   — force a batch-tile row count for butterfly and
  sandwich kernels (``ExecutionContext.block_b`` beats it).
* ``REPRO_TUNE_SEGMENT``   — force the backward checkpoint segment length
  (``ExecutionContext.segment`` beats it).
* ``REPRO_TUNE_BLOCK_Q``   — force the flash-attention q/kv block size
  (ambient ``ExecutionContext.flash_block_q`` beats it).
* ``REPRO_TUNE_VMEM_BUDGET`` — VMEM budget in bytes (default: 75% of 16 MB;
  ambient ``ExecutionContext.vmem_budget`` beats it).

Callers never pass magic numbers: an unset knob anywhere in
:mod:`repro.kernels.ops`, :mod:`repro.core.layers`, :mod:`repro.core.encdec`
or :class:`repro.configs.base.ButterflyConfig` means "ask the autotuner".
"""

from __future__ import annotations

import functools
import math
import os
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core.butterfly import num_stages
from repro.kernels import context as exctx
from repro.launch.roofline import HBM_BW, PEAK_FLOPS, VMEM_BYTES

__all__ = [
    "KernelChoice",
    "tune",
    "resolve_block_b",
    "resolve_segment",
    "default_segment",
    "flash_blocks",
    "vmem_budget",
    "cache_entries",
    "describe",
]

# v5e VMEM per core is ~16 MB (roofline.VMEM_BYTES); Mosaic needs headroom
# for its own double-buffering and spills, so the model budgets a fraction
# of it. The model only has to be right to within a power of two — block_b
# candidates are powers of two anyway.
VMEM_FRACTION = 0.75

MIN_BLOCK_B = 8
MAX_BLOCK_B = 1024


@dataclass(frozen=True)
class KernelChoice:
    """One autotuned kernel configuration (and why it was picked)."""

    kernel: str
    n: int
    dtype: str
    mode: str                 # "fwd" | "bwd"
    block_b: int
    segment: int              # backward checkpoint segment (1 for fwd)
    vmem_bytes: int           # modeled peak VMEM footprint at this choice
    est_us_per_row: float     # roofline lower bound per activation row

    def summary(self) -> str:
        return (f"{self.kernel}/{self.mode} n={self.n} {self.dtype}: "
                f"block_b={self.block_b} segment={self.segment} "
                f"vmem={self.vmem_bytes / 2**20:.2f}MB "
                f"roofline={self.est_us_per_row:.4f}us/row")


def vmem_budget() -> int:
    """VMEM bytes the footprint model may spend.

    Override order: ambient :class:`~repro.kernels.context.ExecutionContext`
    (``vmem_budget`` field), then ``REPRO_TUNE_VMEM_BUDGET``, then 75% of
    the roofline VMEM constant.
    """
    ctx = exctx.current_execution()
    if ctx is not None and ctx.vmem_budget is not None:
        return int(ctx.vmem_budget)
    env = os.environ.get("REPRO_TUNE_VMEM_BUDGET", "").strip()
    if env:
        return int(env)
    return int(VMEM_BYTES * VMEM_FRACTION)


def default_segment(stages: int) -> int:
    """⌈√p⌉ — minimizes live tiles (⌈p/seg⌉ checkpoints + seg recomputed)
    of the segmented-checkpoint backward, the O(VMEM)/O(compute) knee."""
    if stages <= 1:
        return 1
    return math.isqrt(stages - 1) + 1


def _itemsize(dtype_name: str) -> int:
    return jnp.dtype(dtype_name).itemsize


def _min_block_b(dtype_name: str) -> int:
    # TPU sublane minimum per dtype: f32 (8, 128), bf16 (16, 128), int8 (32,)
    return {4: 8, 2: 16, 1: 32}.get(_itemsize(dtype_name), MIN_BLOCK_B)


def _live_tiles(stages: int, segment: int, mode: str) -> int:
    """Activation tiles of shape (block_b, n) the kernel keeps live."""
    if mode == "fwd":
        return 2                                   # x tile + out tile
    n_ckpt = -(-stages // segment)
    # checkpoints + within-segment recomputed activations + x/g/dx
    return n_ckpt + min(segment, stages) + 3


def _footprint(kernel: str, n: int, dtype_name: str, stages: int,
               block_b: int, segment: int, mode: str) -> int:
    """Modeled peak VMEM bytes for one grid step."""
    item = _itemsize(dtype_name)
    w_bytes = 2 * stages * n * item
    tile = block_b * n * item
    total = w_bytes + _live_tiles(stages, segment, mode) * tile
    if mode == "bwd":
        total += 2 * stages * n * 4                # float32 dw accumulator
    if kernel == "sandwich":
        # second butterfly's weights (+ grads) and the small core/selection
        # matrices; modeled at the same n (the tuner is called with
        # max(n1, n2), conservative for the smaller side)
        total += w_bytes + (2 * stages * n * 4 if mode == "bwd" else 0)
        if mode == "bwd":
            # the sandwich backward allocates a checkpoint scratch buffer
            # *per butterfly* and runs a second within-segment recompute,
            # so its butterfly-specific live tiles (everything beyond the
            # shared x/g/dx) are paid twice
            total += (_live_tiles(stages, segment, mode) - 3) * tile
        k = max(2, stages)                          # paper's k = log2 n
        total += 2 * n * k * item + k * k * item
    return total


def _roofline_us_per_row(kernel: str, n: int, dtype_name: str,
                         stages: int, mode: str) -> float:
    """max(compute, memory) roofline time per activation row, in µs."""
    item = _itemsize(dtype_name)
    # one stage = 2 mul + 1 add per element; backward ~3x (recompute sweep +
    # dual sweep + weight-grad reductions)
    stage_flops = 3.0 * n * stages
    flops = stage_flops * (3.0 if mode == "bwd" else 1.0)
    if kernel == "sandwich":
        flops *= 2.0
    hbm = 2.0 * n * item * (2.0 if mode == "bwd" else 1.0)
    return max(flops / PEAK_FLOPS, hbm / HBM_BW) * 1e6


@functools.lru_cache(maxsize=None)
def _tune_cached(kernel: str, n: int, dtype_name: str, mode: str,
                 budget: int) -> KernelChoice:
    """Pick (block_b, segment) for one (kernel, n, dtype, direction) cell.

    Largest power-of-two ``block_b`` whose modeled footprint fits the VMEM
    budget, floored at the dtype's sublane minimum; ``segment`` scans the
    neighborhood of ⌈√p⌉ for the smallest live-tile count (ties go to the
    larger segment: fewer checkpoint writes). ``budget`` is part of the
    cache key so a changed ``REPRO_TUNE_VMEM_BUDGET`` is never served a
    stale choice.
    """
    if kernel not in ("butterfly", "sandwich"):
        raise ValueError(f"unknown tunable kernel {kernel!r}")
    if mode not in ("fwd", "bwd"):
        raise ValueError(f"unknown mode {mode!r}")
    stages = num_stages(n)

    seg0 = default_segment(stages)
    if mode == "bwd":
        cands = sorted({max(1, seg0 - 1), seg0, min(stages, seg0 + 1)})
        segment = min(cands,
                      key=lambda s: (_live_tiles(stages, s, mode), -s))
    else:
        segment = 1

    floor = _min_block_b(dtype_name)
    b = MAX_BLOCK_B
    while b >= floor:
        if _footprint(kernel, n, dtype_name, stages, b, segment,
                      mode) <= budget:
            break
        b //= 2
    block_b = max(b, floor)

    return KernelChoice(
        kernel=kernel, n=n, dtype=dtype_name, mode=mode,
        block_b=block_b, segment=segment,
        vmem_bytes=_footprint(kernel, n, dtype_name, stages, block_b,
                              segment, mode),
        est_us_per_row=_roofline_us_per_row(kernel, n, dtype_name, stages,
                                            mode))


def resolve_block_b(kernel: str, n: int, dtype, mode: str,
                    override: Optional[int] = None) -> int:
    """Concrete batch-tile rows: explicit override > env > autotuner."""
    if override is not None:
        return int(override)
    env = os.environ.get("REPRO_TUNE_BLOCK_B", "").strip()
    if env:
        return int(env)
    return tune(kernel, n, jnp.dtype(dtype).name, mode).block_b


def resolve_segment(stages: int, override: Optional[int] = None,
                    kernel: str = "butterfly", n: Optional[int] = None,
                    dtype=jnp.float32) -> int:
    """Concrete checkpoint segment length, clamped to [1, stages]."""
    if override is not None:
        return max(1, min(int(override), max(stages, 1)))
    env = os.environ.get("REPRO_TUNE_SEGMENT", "").strip()
    if env:
        return max(1, min(int(env), max(stages, 1)))
    if n is not None:
        return tune(kernel, n, jnp.dtype(dtype).name, "bwd").segment
    return default_segment(stages)


def flash_blocks(seq_len: int, head_dim: int, dtype_name: str,
                 mode: str = "fwd") -> Tuple[int, int]:
    """(block_q, block_kv) for the flash kernels at one (S, D, dtype).

    The kernels keep the full K/V (and in backward dO/lse/delta) rows of one
    (batch·head) resident; block_q only controls the per-step tile, so pick
    the largest power of two dividing S whose q-side tiles fit what is left
    of the budget after the sequence-length-resident buffers. Overrides —
    the ambient ``ExecutionContext.flash_block_q``, then the env var — are
    read here, outside the cache, so they always win.
    """
    ctx = exctx.current_execution()
    if ctx is not None and ctx.flash_block_q is not None:
        bq = int(ctx.flash_block_q)
        return bq, bq
    env = os.environ.get("REPRO_TUNE_BLOCK_Q", "").strip()
    if env:
        bq = int(env)
        return bq, bq
    return _flash_blocks_cached(seq_len, head_dim, dtype_name, mode,
                                vmem_budget())


@functools.lru_cache(maxsize=None)
def _flash_blocks_cached(seq_len: int, head_dim: int, dtype_name: str,
                         mode: str, budget: int) -> Tuple[int, int]:
    item = _itemsize(dtype_name)
    resident = 2 * seq_len * head_dim * item        # K + V
    if mode == "bwd":
        resident += seq_len * head_dim * item       # dO sweep
        resident += 2 * seq_len * 4                 # lse + delta (f32)
    left = max(budget - resident, 0)
    for bq in (512, 256, 128, 64, 32, 16, 8):
        if seq_len % bq:
            continue
        # q tile + o/dq tile + f32 score/prob tiles against block_kv = bq
        tiles = 2 * bq * head_dim * item + 2 * bq * head_dim * 4
        tiles += 2 * bq * bq * 4
        if tiles <= left or bq == 8:
            return bq, bq
    bq = math.gcd(seq_len, 8)
    return bq, bq


# lru_cache offers no introspection of stored values, so tune() keeps its
# own registry of every decision for logging (TrainResult, benchmarks).
_CHOICES: Dict[str, str] = {}


def tune(kernel: str, n: int, dtype_name: str, mode: str = "fwd"
         ) -> KernelChoice:
    # env (budget) is read here, outside the cache, so overrides set after
    # the first query still take effect
    choice = _tune_cached(kernel, n, dtype_name, mode, vmem_budget())
    _CHOICES[f"{kernel}/{mode}/n{n}/{dtype_name}"] = choice.summary()
    return choice


def cache_entries() -> Dict[str, str]:
    """Every choice made so far (key -> one-line summary)."""
    return dict(_CHOICES)


def describe() -> str:
    """One-line-per-choice summary of every tuning decision this process."""
    return "; ".join(sorted(_CHOICES.values())) or "no kernel tuning queried"
