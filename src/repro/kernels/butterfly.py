"""Fused multi-stage butterfly Pallas kernels (TPU target), forward *and*
backward.

TPU adaptation of the paper's butterfly product (DESIGN.md §3): instead of
``log n`` separate sparse matmuls (log n HBM round trips, arithmetic
intensity ~1), a single ``pallas_call`` keeps a ``(block_b, n)`` activation
tile resident in VMEM and applies *all* stages before writing back.

Stage ``s`` is ``y = a_s ⊙ x + b_s ⊙ swap_s(x)`` where ``swap_s`` is a
reshape ``(B, n/2t, 2, t)`` + half-swap on the ``2`` axis — strided VPU FMA
traffic only, no gather/scatter. Stage count is static so the loop fully
unrolls at trace time.

Training support: ``butterfly_matmul`` carries a :func:`jax.custom_vjp` whose
backward pass is itself a fused Pallas kernel. The butterfly backward is a
(dual) butterfly product interleaved with per-stage weight-gradient
reductions::

    da_s = Σ_batch g_{s+1} ⊙ x_s        db_s = Σ_batch g_{s+1} ⊙ swap_s(x_s)
    g_s  = a_s ⊙ g_{s+1} + swap_s(b_s ⊙ g_{s+1})

**Segmented stage checkpointing.** The reverse sweep needs the stage inputs
``x_s`` in *reverse* order. Recomputing each from the saved input tile costs
O(p²) stage applications per tile (p = log2 n — 13× more VPU work than the
forward at n = 8192). Instead, one forward sweep stashes the activation at
every ``segment``-th stage boundary in a VMEM scratch buffer
(``pl.pallas_call`` ``scratch_shapes``), and the reverse sweep recomputes
only *within* a segment (one pass per segment, held as live VMEM values):

    stage applications per tile  ≤  p (checkpoint sweep)
                                  + p (within-segment recompute)
                                  + p (dual cotangent sweep)   = O(p)

against ``⌈p/segment⌉ + segment + 3`` live ``(block_b, n)`` tiles of VMEM —
``segment`` is the VMEM/compute knob, defaulting to ⌈√p⌉ (the live-tile
minimum) via :mod:`repro.kernels.tuning`, which also sizes ``block_b`` so
the whole working set fits the VMEM budget. Weight gradients are accumulated
in float32 across the batch grid: the TPU grid is sequential, so the
``(p, 2, n)`` output block is revisited by every grid step and updated in
place.
"""

from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.butterfly import num_stages
from repro.kernels import tuning

# Trace-time instrumentation: the stage loops fully unroll, so the number of
# _stage_apply invocations while building a kernel body *is* the per-tile
# stage-application count. count_stage_applies() gates the O(p·√p) bound in
# tests/CI instead of eyeballing it.
_STAGE_APPLY_CALLS = [0]


@contextlib.contextmanager
def count_stage_applies():
    """Yields a zero-arg callable returning the number of butterfly stage
    applications issued since entering the context."""
    start = _STAGE_APPLY_CALLS[0]
    yield lambda: _STAGE_APPLY_CALLS[0] - start


def _swap_halves(x: jnp.ndarray, stride: int) -> jnp.ndarray:
    """y[i] = x[i ^ stride] along the last axis, via reshape + concat."""
    n = x.shape[-1]
    lead = x.shape[:-1]
    xs = x.reshape(*lead, n // (2 * stride), 2, stride)
    lo = xs[..., 0:1, :]
    hi = xs[..., 1:2, :]
    return jnp.concatenate([hi, lo], axis=-2).reshape(*lead, n)


def _stage_apply(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                 stride: int, transpose: bool) -> jnp.ndarray:
    """One butterfly stage: ``a ⊙ x + b ⊙ swap(x)`` or its transpose
    ``a ⊙ x + swap(b ⊙ x)``."""
    _STAGE_APPLY_CALLS[0] += 1
    if transpose:
        return a * x + _swap_halves(b * x, stride)
    return a * x + b * _swap_halves(x, stride)


def _stage_order(stages: int, transpose: bool) -> list:
    """Application order of the stage strides (Bᵀ applies them reversed)."""
    return list(reversed(range(stages))) if transpose else list(range(stages))


def _butterfly_kernel(x_ref, w_ref, o_ref, *, stages: int, transpose: bool):
    x = x_ref[...]
    for s in _stage_order(stages, transpose):
        x = _stage_apply(x, w_ref[s, 0, :], w_ref[s, 1, :], 1 << s, transpose)
    o_ref[...] = x


def _butterfly_bwd_block(x: jnp.ndarray, w_ref, g: jnp.ndarray, stages: int,
                         transpose: bool, segment: int = 0, ckpt_ref=None):
    """VJP of the fused butterfly on one ``(bb, n)`` tile.

    Returns ``(dx, dw)`` where ``dw`` is ``(p, 2, n)`` float32, summed over
    the tile's batch rows. Stage inputs come from segmented checkpointing:
    a forward sweep stores the activation entering stage ``j`` for every
    segment boundary ``j ∈ {0, segment, 2·segment, …}`` (into ``ckpt_ref``
    when a VMEM scratch ref is supplied, else as live values), then each
    segment is recomputed exactly once during the reverse sweep — O(p) stage
    applications total instead of the O(p²) full-prefix recompute.

    The cotangent rule per stage is the *dual* stage applied to ``g``: the
    transpose of ``a ⊙ x + b ⊙ swap(x)`` is ``a ⊙ g + swap(b ⊙ g)`` and vice
    versa (swap is an involution).
    """
    order = _stage_order(stages, transpose)
    seg = tuning.resolve_segment(stages, segment or None)
    bounds = list(range(0, stages, seg))

    # --- forward sweep: checkpoint the input of stage order[j] at every
    # segment boundary j (x itself is the first checkpoint) ---
    ckpts = {}
    t = x
    for ci, j0 in enumerate(bounds):
        if ckpt_ref is None:
            ckpts[ci] = t
        else:
            ckpt_ref[ci] = t
        if ci + 1 < len(bounds):
            for j in range(j0, bounds[ci + 1]):
                s = order[j]
                t = _stage_apply(t, w_ref[s, 0, :], w_ref[s, 1, :], 1 << s,
                                 transpose)

    # --- reverse sweep: one within-segment recompute per segment ---
    da = [None] * stages
    db = [None] * stages
    for ci in reversed(range(len(bounds))):
        j0 = bounds[ci]
        j1 = min(j0 + seg, stages)
        t = ckpts[ci] if ckpt_ref is None else ckpt_ref[ci]
        acts = [t]
        for j in range(j0, j1 - 1):
            s = order[j]
            acts.append(_stage_apply(acts[-1], w_ref[s, 0, :],
                                     w_ref[s, 1, :], 1 << s, transpose))
        for j in reversed(range(j0, j1)):
            s = order[j]
            a = w_ref[s, 0, :]
            b = w_ref[s, 1, :]
            gf = g.astype(jnp.float32)
            tf = acts[j - j0].astype(jnp.float32)
            if transpose:
                # y[i] = a[i]·t[i] + b[i^s]·t[i^s]  =>  ∂y/∂b[i] hits g[i^s]
                da[s] = jnp.sum(gf * tf, axis=0)
                db[s] = jnp.sum(_swap_halves(gf, 1 << s) * tf, axis=0)
            else:
                da[s] = jnp.sum(gf * tf, axis=0)
                db[s] = jnp.sum(gf * _swap_halves(tf, 1 << s), axis=0)
            g = _stage_apply(g, a, b, 1 << s, not transpose)
    dw = jnp.stack([jnp.stack(da), jnp.stack(db)], axis=1)  # (p, 2, n) f32
    return g, dw


def _butterfly_bwd_kernel(x_ref, w_ref, g_ref, dx_ref, dw_ref, ckpt_ref, *,
                          stages: int, transpose: bool, segment: int):
    dx, dw = _butterfly_bwd_block(x_ref[...], w_ref, g_ref[...], stages,
                                  transpose, segment=segment,
                                  ckpt_ref=ckpt_ref)
    dx_ref[...] = dx.astype(dx_ref.dtype)

    @pl.when(pl.program_id(0) == 0)
    def _():
        dw_ref[...] = dw

    @pl.when(pl.program_id(0) > 0)
    def _():
        dw_ref[...] += dw


def _flatten_batch(x: jnp.ndarray, block_b: int):
    """Flatten leading axes into a batch dim padded to a block multiple."""
    lead = x.shape[:-1]
    n = x.shape[-1]
    b = 1
    for d in lead:
        b *= d
    x2 = x.reshape(b, n)
    bb = min(block_b, b)
    padded_b = -(-b // bb) * bb
    if padded_b != b:
        x2 = jnp.pad(x2, ((0, padded_b - b), (0, 0)))
    return x2, lead, b, bb, padded_b


def _butterfly_fwd_call(x: jnp.ndarray, w: jnp.ndarray, transpose: bool,
                        block_b, interpret: bool) -> jnp.ndarray:
    p, two, n = w.shape
    assert two == 2 and (1 << p) == n, f"bad weight shape {w.shape}"
    stages = num_stages(n)
    block_b = tuning.resolve_block_b("butterfly", n, x.dtype, "fwd", block_b)
    x2, lead, b, bb, padded_b = _flatten_batch(x, block_b)
    grid = (padded_b // bb,)
    out = pl.pallas_call(
        functools.partial(_butterfly_kernel, stages=stages,
                          transpose=transpose),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
            pl.BlockSpec((p, 2, n), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_b, n), x.dtype),
        interpret=interpret,
    )(x2, w.astype(x.dtype))
    return out[:b].reshape(*lead, n)


def _butterfly_bwd_call(x: jnp.ndarray, w: jnp.ndarray, g: jnp.ndarray,
                        transpose: bool, block_b, segment, interpret: bool):
    p, _, n = w.shape
    stages = num_stages(n)
    block_b = tuning.resolve_block_b("butterfly", n, x.dtype, "bwd", block_b)
    seg = tuning.resolve_segment(stages, segment, kernel="butterfly", n=n,
                                 dtype=x.dtype)
    x2, lead, b, bb, padded_b = _flatten_batch(x, block_b)
    g2, _, _, _, _ = _flatten_batch(g.astype(x.dtype), block_b)
    grid = (padded_b // bb,)
    n_ckpt = len(range(0, stages, seg))
    dx, dw = pl.pallas_call(
        functools.partial(_butterfly_bwd_kernel, stages=stages,
                          transpose=transpose, segment=seg),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
            pl.BlockSpec((p, 2, n), lambda i: (0, 0, 0)),
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, n), lambda i: (i, 0)),
            pl.BlockSpec((p, 2, n), lambda i: (0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded_b, n), x.dtype),
            jax.ShapeDtypeStruct((p, 2, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n_ckpt, bb, n), x2.dtype)],
        interpret=interpret,
    )(x2, w.astype(x.dtype), g2)
    return dx[:b].reshape(*lead, n), dw


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5))
def _butterfly_diff(x, w, transpose, block_b, segment, interpret):
    return _butterfly_fwd_call(x, w, transpose, block_b, interpret)


def _butterfly_diff_fwd(x, w, transpose, block_b, segment, interpret):
    # Residuals are just (x, w): the backward kernel recomputes stage
    # activations from the input tile via segmented checkpointing, so
    # nothing else is stashed in HBM.
    return _butterfly_fwd_call(x, w, transpose, block_b, interpret), (x, w)


def _butterfly_diff_bwd(transpose, block_b, segment, interpret, res, g):
    x, w = res
    dx, dw = _butterfly_bwd_call(x, w, g, transpose, block_b, segment,
                                 interpret)
    return dx, dw.astype(w.dtype)


_butterfly_diff.defvjp(_butterfly_diff_fwd, _butterfly_diff_bwd)


@functools.partial(jax.jit,
                   static_argnames=("transpose", "block_b", "segment",
                                    "interpret"))
def butterfly_matmul(x: jnp.ndarray, w: jnp.ndarray, *,
                     transpose: bool = False,
                     block_b=None, segment=None,
                     interpret: bool = False) -> jnp.ndarray:
    """Fused butterfly product ``B x`` (or ``Bᵀ x``) over the last axis.

    ``x``: (..., n) with n a power of two; ``w``: (p, 2, n).
    Leading axes are flattened into a batch grid. Differentiable in both
    ``x`` and ``w`` via a fused Pallas backward kernel (custom_vjp) with
    segmented stage checkpointing. ``block_b`` (batch-tile rows, per
    direction) and ``segment`` (backward checkpoint interval) default to the
    :mod:`repro.kernels.tuning` autotuner; pass ints only to override it.
    """
    return _butterfly_diff(x, w, transpose, block_b, segment, interpret)
