"""Shared model components: norms, RoPE, embeddings, projections, losses.

``linear_specs``/``linear_apply`` are the single projection entry point: a
site can be a plain dense matmul or — when the site is listed in the model's
:class:`ButterflyConfig` — the paper's butterfly sandwich (§3.2). The static
:class:`repro.core.layers.ButterflySpec` for a site is derived
deterministically from (seed, site name, dims) so trace-time code can rebuild
it without storing non-array state in the param tree.
"""

from __future__ import annotations

import functools
import math
import zlib
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro import nn as bnn
from repro.configs.base import ModelConfig
from repro.core import butterfly as bfly
from repro.core import layers as blayers
from repro.kernels import context as exctx
from repro.runtime.pytree import ParamSpec
from repro.runtime.sharding import constrain


# ---------------------------------------------------------------------------
# Projections (dense or butterfly sandwich)
# ---------------------------------------------------------------------------

def _butterfly_site(cfg: ModelConfig, site: Optional[str]) -> bool:
    return (cfg.butterfly is not None and site is not None
            and site in cfg.butterfly.sites)


@functools.lru_cache(maxsize=None)
def site_butterfly_spec(seed: int, site_key: str, n_in: int, n_out: int,
                        k_factor: float, use_bias: bool
                        ) -> blayers.ButterflySpec:
    h = zlib.crc32(site_key.encode()) ^ (seed * 2654435761 & 0x7FFFFFFF)
    key = jax.random.PRNGKey(h & 0x7FFFFFFF)
    return blayers.make_spec(key, n_in, n_out, k_factor=k_factor,
                             use_bias=use_bias)


def linear_specs(cfg: ModelConfig, n_in: int, n_out: int,
                 axes: Tuple[Optional[str], Optional[str]],
                 site: Optional[str] = None, site_key: str = "",
                 scale: float = 1.0) -> Dict[str, ParamSpec]:
    """ParamSpecs for one projection site (dense or butterfly sandwich)."""
    dt = cfg.param_dtype
    if _butterfly_site(cfg, site):
        bc = cfg.butterfly
        spec = site_butterfly_spec(bc.seed, site_key or site, n_in, n_out,
                                   bc.k_factor, bc.use_bias)
        p1 = bfly.num_stages(spec.pad_in)
        p2 = bfly.num_stages(spec.pad_out)
        # every dim carries a named logical axis with an explicit (replicate)
        # entry in DEFAULT_RULES, so logical_to_pspec resolves butterfly
        # params deliberately instead of through the unknown-name fallback
        out = {
            "b_in": ParamSpec((p1, 2, spec.pad_in), dt,
                              ("stages", "butterfly_pair", "butterfly_n"),
                              init="fjlt"),
            "b_out": ParamSpec((p2, 2, spec.pad_out), dt,
                               ("stages", "butterfly_pair", "butterfly_n"),
                               init="fjlt"),
            "core": ParamSpec((spec.k_out, spec.k_in), dt,
                              ("butterfly_core_out", "butterfly_core_in"),
                              init="scaled_normal", scale=scale),
        }
        if bc.use_bias:
            out["bias"] = ParamSpec((n_out,), dt, ("butterfly_bias",),
                                    init="zeros")
        return out
    return {"w": ParamSpec((n_in, n_out), dt, axes, init="scaled_normal",
                           scale=scale, fan_in_dim=0)}


@functools.lru_cache(maxsize=None)
def _site_module(spec: blayers.ButterflySpec, bc) -> "bnn.ButterflyLinear":
    """The :class:`repro.nn.ButterflyLinear` facade for one site. The
    config's execution fields ride the module as its default context — the
    config layer of the resolution order, so an ambient ``use_execution``
    (the Trainer installs one) still wins. Cached per (spec, config) so the
    module object is a stable jit-time constant."""
    ctx = exctx.ExecutionContext.from_butterfly_config(bc)
    return bnn.ButterflyLinear(spec=spec, context=ctx)


def linear_apply(cfg: ModelConfig, params: Dict, x: jnp.ndarray,
                 site: Optional[str] = None, site_key: str = "",
                 n_out: Optional[int] = None) -> jnp.ndarray:
    if "w" in params:
        return x @ params["w"].astype(x.dtype)
    n_in = x.shape[-1]
    bc = cfg.butterfly
    spec = site_butterfly_spec(bc.seed, site_key or site, n_in,
                               int(n_out), bc.k_factor, bc.use_bias)
    return _site_module(spec, bc).apply(params, x)


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rmsnorm_spec(cfg: ModelConfig, dim: int) -> ParamSpec:
    return ParamSpec((dim,), cfg.param_dtype, (None,), init="ones")


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def act_fn(name: str):
    return {"swiglu": jax.nn.silu, "geglu": jax.nn.gelu,
            "gelu_mlp": jax.nn.gelu}[name]


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
         ) -> jnp.ndarray:
    """x: (B, S, H, D) with D even; positions: (B, S) int32."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    return {"table": ParamSpec((cfg.vocab_size, cfg.d_model),
                               cfg.param_dtype, ("vocab", "embed"),
                               init="embedding",
                               scale=1.0 / math.sqrt(cfg.d_model))}


def embed(cfg: ModelConfig, params: Dict, tokens: jnp.ndarray) -> jnp.ndarray:
    # cast the table BEFORE the gather: gathering fp32 then casting
    # materializes a full-batch fp32 (B,S,E) tensor (2x HBM at 262k vocab)
    table = params["table"].astype(cfg.cdtype())
    x = jnp.take(table, tokens, axis=0)
    return x * jnp.asarray(math.sqrt(cfg.d_model), cfg.cdtype())


def head_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    if cfg.tie_embeddings:
        return {}
    return linear_specs(cfg, cfg.d_model, cfg.vocab_size,
                        ("embed", "vocab"), site="lm_head")


def head_apply(cfg: ModelConfig, head_params: Dict, embed_params: Dict,
               x: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        logits = x @ embed_params["table"].T.astype(x.dtype)
    else:
        logits = linear_apply(cfg, head_params, x, site="lm_head",
                              n_out=cfg.vocab_size)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean CE over valid positions; logits (..., V), labels (...)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
