"""Gemma3-27B — dense, 5:1 local:global attention, 128k context
[hf:google/gemma-3-*]. 62 layers = 10 x (5 local + 1 global) + 2 local tail;
local layers use a 1024-token sliding window."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16,
    d_ff=21504, vocab_size=262144, head_dim=128,
    sliding_window=1024,
    block_unit=("local", "local", "local", "local", "local", "global"),
    mlp_variant="geglu",
    logit_softcap=30.0,
    blockwise_threshold=4096,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        name="gemma3-27b-smoke", n_layers=8, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512,
        sliding_window=16, blockwise_threshold=64,
        attn_block_q=16, attn_block_kv=16)
