"""Structural validator for Chrome trace-event JSON.

Shared by the tests and the CI artifact gate:

    python -m repro.obs.validate BENCH_serve_trace.json

Checks (raising :class:`TraceValidationError` on the first violation):

* the document is ``{"traceEvents": [...]}`` (or a bare event list);
* every event carries ``ph``, ``ts``, ``pid``, ``tid``, ``name`` with
  sane types (``ph`` one of the phases we emit, ``ts``/``dur``
  non-negative numbers);
* per ``(pid, tid)`` track, complete ("X") spans are properly nested —
  a span either contains or is disjoint from every other span on its
  track (partial overlap is the classic symptom of a broken exporter
  and renders as garbage in Perfetto).

The validator is intentionally stdlib-only so the CI step needs nothing
beyond the repo itself.
"""

from __future__ import annotations

import json
import sys
from typing import Any, Dict, List

__all__ = ["TraceValidationError", "validate_chrome_trace", "main"]

_PHASES = {"X", "i", "I", "M", "B", "E", "C"}
_EPS = 1e-6  # µs; timestamps are rounded to 1 ns by the tracer


class TraceValidationError(ValueError):
    """A trace-event document failed structural validation."""


def _fail(i: int, ev: Any, why: str) -> None:
    raise TraceValidationError(f"event[{i}] {why}: {ev!r}")


def validate_chrome_trace(doc: Any) -> List[Dict[str, Any]]:
    """Validate ``doc``; return the (non-metadata) event list on success."""
    if isinstance(doc, dict):
        events = doc.get("traceEvents")
        if not isinstance(events, list):
            raise TraceValidationError(
                "document must carry a 'traceEvents' list")
    elif isinstance(doc, list):
        events = doc
    else:
        raise TraceValidationError(
            f"document must be a dict or list, got {type(doc).__name__}")

    spans: List[Dict[str, Any]] = []
    out: List[Dict[str, Any]] = []
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            _fail(i, ev, "is not an object")
        for field in ("ph", "ts", "pid", "tid", "name"):
            if field not in ev:
                _fail(i, ev, f"missing required field {field!r}")
        if not isinstance(ev["name"], str) or not ev["name"]:
            _fail(i, ev, "has a non-string/empty name")
        if ev["ph"] not in _PHASES:
            _fail(i, ev, f"has unknown phase {ev['ph']!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            _fail(i, ev, "has a negative or non-numeric ts")
        for field in ("pid", "tid"):
            if not isinstance(ev[field], int):
                _fail(i, ev, f"has a non-integer {field}")
        if "args" in ev and not isinstance(ev["args"], dict):
            _fail(i, ev, "has non-object args")
        if ev["ph"] == "X":
            if "dur" not in ev:
                _fail(i, ev, "is a complete span without dur")
            if not isinstance(ev["dur"], (int, float)) or ev["dur"] < 0:
                _fail(i, ev, "has a negative or non-numeric dur")
            spans.append(ev)
        if ev["ph"] != "M":
            out.append(ev)

    _check_nesting(spans)
    return out


def _check_nesting(spans: List[Dict[str, Any]]) -> None:
    by_track: Dict[tuple, List[Dict[str, Any]]] = {}
    for ev in spans:
        by_track.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    for track, evs in by_track.items():
        # parent-first: earlier start, and at equal start the longer span
        evs.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack: List[float] = []  # open-span end times
        for ev in evs:
            t0, t1 = ev["ts"], ev["ts"] + ev["dur"]
            while stack and t0 >= stack[-1] - _EPS:
                stack.pop()
            if stack and t1 > stack[-1] + _EPS:
                raise TraceValidationError(
                    f"span {ev['name']!r} on track {track} "
                    f"[{t0}, {t1}] partially overlaps an enclosing span "
                    f"ending at {stack[-1]}")
            stack.append(t1)


def main(argv: List[str]) -> int:
    if len(argv) != 1:
        sys.stderr.write("usage: python -m repro.obs.validate TRACE.json\n")
        return 2
    with open(argv[0]) as fh:
        doc = json.load(fh)
    events = validate_chrome_trace(doc)
    tracks = {(e["pid"], e["tid"]) for e in events}
    spans = sum(1 for e in events if e["ph"] == "X")
    sys.stdout.write(
        f"{argv[0]}: OK — {len(events)} events ({spans} spans) on "
        f"{len(tracks)} tracks\n")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
