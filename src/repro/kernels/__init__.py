"""Fused Pallas kernels for the paper's compute hot-spots.

``repro.kernels.ops`` is the public, backend-dispatched entry point and
``repro.kernels.context`` the execution-policy object it dispatches on; the
per-kernel modules (``butterfly``, ``sandwich``, ``flash``) hold the kernel
bodies and ``repro.kernels.ref`` the pure-jnp oracles.
"""

from repro.kernels.context import (Backend, ExecutionContext,
                                   clear_backend_cache, current_execution,
                                   resolve_backend, resolve_execution,
                                   use_execution)
from repro.kernels.ops import butterfly_apply, one_hot_select, sandwich_apply

__all__ = ["Backend", "ExecutionContext", "butterfly_apply",
           "clear_backend_cache", "current_execution", "one_hot_select",
           "resolve_backend", "resolve_execution", "sandwich_apply",
           "use_execution"]
