"""xLSTM-125M — sLSTM + mLSTM blocks [arXiv:2405.04517]. 12 layers at an
~5:1 mLSTM:sLSTM ratio (2 x (5 mLSTM + 1 sLSTM)); d_ff=0 per the assignment
(mLSTM blocks carry their own 2x up/down projections; sLSTM blocks a 4/3
gated FFN, per the xLSTM paper's block design)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=192,
    conv_width=4,
    block_unit=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm"),
    mlstm_chunk=256,
)


def smoke() -> ModelConfig:
    return CONFIG.with_(
        name="xlstm-125m-smoke", n_layers=6, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, vocab_size=512, mlstm_chunk=16,
        blockwise_threshold=64, attn_block_q=16, attn_block_kv=16)
