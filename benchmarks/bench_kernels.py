"""Kernel-level microbenchmarks: fused butterfly vs dense matmul FLOP/byte
model + CPU timings of the jnp path (Pallas timings require a TPU; the
VMEM-residency argument is in DESIGN.md §3 and the roofline tables)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core import butterfly as bf
from repro.kernels import ops


def run() -> None:
    B = 128
    for n in (256, 1024, 4096):
        w = bf.fjlt_weights(jax.random.PRNGKey(0), n)
        x = jax.random.normal(jax.random.PRNGKey(1), (B, n))
        W = jax.random.normal(jax.random.PRNGKey(2), (n, n)) / jnp.sqrt(n)

        bfly = jax.jit(lambda x: ops.butterfly_apply(x, w, context="jnp"))
        dense = jax.jit(lambda x: x @ W.T)
        us_b = time_fn(bfly, x)
        us_d = time_fn(dense, x)

        p = bf.num_stages(n)
        flops_bfly = 4 * n * p * B          # 2 mul + 2 add per coord/stage
        flops_dense = 2 * n * n * B
        # HBM traffic of the fused TPU kernel: x in + out + weights once
        bytes_bfly = (2 * B * n + 2 * n * p) * 4
        bytes_dense = (2 * B * n + n * n) * 4
        emit(f"kernel/butterfly_n{n}", us_b,
             f"dense_us={us_d:.1f};flop_ratio={flops_dense/flops_bfly:.1f}x;"
             f"byte_ratio={bytes_dense/bytes_bfly:.1f}x;"
             f"arith_intensity={flops_bfly/bytes_bfly:.2f}")


if __name__ == "__main__":
    run()
