"""Roofline-term extraction from AOT-compiled artifacts.

Per (arch × shape × mesh) cell the dry-run produces a compiled executable;
this module derives the three roofline terms (seconds, per device):

    compute    = per_device_HLO_FLOPs / PEAK_FLOPS
    memory     = per_device_HLO_bytes / HBM_BW
    collective = per_device_collective_bytes / ICI_BW
                 (+ DCN-crossing collectives on the `pod` axis at DCN_BW,
                  reported separately and included in the term)

``cost_analysis()`` returns **post-SPMD per-device** numbers (verified in
tests). Collective bytes are NOT in cost_analysis — they are parsed from the
compiled HLO text: we sum output-shape bytes of every all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute op, classified
by whether the replica group spans the ``pod`` axis.

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI; DCN between pods is modeled at 25 GB/s/host-link.
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link
DCN_BW = 25e9              # bytes/s per pod uplink (modeled)
VMEM_BYTES = 16 * 2 ** 20  # VMEM per core — the kernel autotuner's budget
                           # base (repro.kernels.tuning)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"(?P<outshape>[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")

_SHAPE_RE = re.compile(r"(?P<dtype>[a-z0-9]+)\[(?P<dims>[0-9,]*)\]")


def shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape literal like ``bf16[8,128,2048]{2,1,0}``.

    Tuple shapes (e.g. all-reduce of several tensors) are handled by the
    caller summing every embedded shape.
    """
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        d = m.group("dtype")
        if d not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for x in dims.split(","):
                n *= int(x)
        total += n * _DTYPE_BYTES[d]
    return total


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    ici_bytes: int = 0
    dcn_bytes: int = 0

    @property
    def total_bytes(self) -> int:
        return self.ici_bytes + self.dcn_bytes


def parse_collectives(hlo_text: str, pod_boundary: int = 0
                      ) -> CollectiveStats:
    """Sum collective payload bytes from post-SPMD HLO.

    ``pod_boundary``: number of devices per pod; a collective whose replica
    group spans device ids in different pods is classified as DCN traffic.
    Payload accounting is per-device: the op's (per-shard) output bytes.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        nbytes = shape_bytes(m.group("outshape"))
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + nbytes
        crosses_pod = False
        if pod_boundary:
            g = re.search(r"replica_groups=\[[^\]]*\]<=\[([0-9,]+)\]", line)
            if g:
                # iota-style groups: crosses pods iff a group dim spans
                # beyond one pod worth of devices
                rg = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
                if rg:
                    group_size = int(rg.group(2))
                    n_groups = int(rg.group(1))
                    # contiguous iota grouping: group spans pods when
                    # group_size > pod_boundary OR stride layout crosses
                    crosses_pod = group_size * _group_stride(
                        line, n_groups, group_size) > pod_boundary
        if crosses_pod:
            stats.dcn_bytes += nbytes
        else:
            stats.ici_bytes += nbytes
    return stats


def _group_stride(line: str, n_groups: int, group_size: int) -> int:
    """Detect transposed iota groups ([G,S]<=[S,G]T(1,0) ⇒ stride G)."""
    if re.search(r"<=\[[0-9,]+\]T\(", line):
        return n_groups
    return 1


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    collective: CollectiveStats
    # memory_analysis (per device)
    argument_bytes: int = 0
    output_bytes: int = 0
    temp_bytes: int = 0
    alias_bytes: int = 0
    # model-level accounting
    model_flops: float = 0.0       # 6·N_active·D (per device share)
    params_total: int = 0
    params_active: int = 0
    tokens: int = 0

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return (self.collective.ici_bytes / ICI_BW
                + self.collective.dcn_bytes / DCN_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step spent on *useful* compute if perfectly
        overlapped: model_flops_time / max(term)."""
        t_model = self.model_flops / PEAK_FLOPS
        b = self.bound_time
        return t_model / b if b > 0 else 0.0

    @property
    def flops_utilization(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS — how much compiled compute is useful
        (catches remat / causal-overcompute waste)."""
        return (self.model_flops / self.flops_per_device
                if self.flops_per_device else 0.0)

    @property
    def hbm_fit(self) -> bool:
        per_dev = (self.argument_bytes + self.output_bytes
                   + self.temp_bytes - self.alias_bytes)
        return per_dev <= 16e9    # v5e: 16 GB HBM

    def to_dict(self) -> Dict:
        d = {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_devices": self.n_devices,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_ici_bytes": self.collective.ici_bytes,
            "collective_dcn_bytes": self.collective.dcn_bytes,
            "collective_counts": self.collective.counts,
            "collective_bytes_by_op": self.collective.bytes_by_op,
            "argument_bytes": self.argument_bytes,
            "output_bytes": self.output_bytes,
            "temp_bytes": self.temp_bytes,
            "alias_bytes": self.alias_bytes,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "params_total": self.params_total,
            "params_active": self.params_active,
            "tokens": self.tokens,
            "flops_utilization": self.flops_utilization,
            "roofline_fraction": self.roofline_fraction,
            "hbm_fit": self.hbm_fit,
        }
        return d


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` across jax versions: newer jax returns a
    flat dict, jax <= 0.4.x a one-element list of dicts."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def build_report(arch: str, shape: str, mesh_name: str, n_devices: int,
                 compiled, *, pod_boundary: int, model_flops: float,
                 params_total: int, params_active: int, tokens: int
                 ) -> RooflineReport:
    from repro.launch import hlo_analysis as ha
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    # loop-aware analysis: cost_analysis() counts while-loop bodies once
    # (verified in tests) — our layer stacks are scans, so that is useless.
    cost = ha.analyze(text, pod_boundary=pod_boundary)
    stats = CollectiveStats(
        counts={k: int(v) for k, v in cost.collective_counts.items()},
        bytes_by_op={k: int(v) for k, v in cost.collective_bytes.items()},
        ici_bytes=int(cost.collective_ici),
        dcn_bytes=int(cost.collective_dcn))
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=float(cost.flops),
        bytes_per_device=float(cost.hbm_bytes),
        collective=stats,
        argument_bytes=int(getattr(ma, "argument_size_in_bytes", 0)),
        output_bytes=int(getattr(ma, "output_size_in_bytes", 0)),
        temp_bytes=int(getattr(ma, "temp_size_in_bytes", 0)),
        alias_bytes=int(getattr(ma, "alias_size_in_bytes", 0)),
        model_flops=model_flops, params_total=params_total,
        params_active=params_active, tokens=tokens)
